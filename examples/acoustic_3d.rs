//! 3-D acoustic kernel on the full 16-tile array: a 13-point 3-D star
//! (rx = ry = rz = 2, the second-order acoustic wave-equation
//! neighborhood) pencil-decomposed across 16 simulated CGRA tiles —
//! each pencil mapped via plane buffering (`map3d`), simulated
//! cycle-by-cycle, and the stitched grid verified against the golden
//! oracle — with the §VI roofline (halo-adjusted) and the §VII V100
//! model for context.
//!
//! ```sh
//! cargo run --release --example acoustic_3d
//! ```

use std::sync::Arc;

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::gpu_model::{GpuStencil, Precision, V100};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{map3d, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil3d_ref};

fn main() -> Result<()> {
    let spec = StencilSpec::dim3(32, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2))?;
    println!(
        "== acoustic 3-D stencil: {}x{}x{} grid, r=(2,2,2), {}-pt star ==\n",
        spec.nx,
        spec.ny,
        spec.nz,
        spec.points()
    );

    // Compile once: 16 tiles, y/z pencil cuts (x stays row-major
    // contiguous). The artifact owns the plan, the placed per-pencil
    // graphs and the halo-adjusted roofline.
    let machine = Machine::paper();
    let opts = CompileOptions::paper()
        .with_machine(machine.clone())
        .with_decomp(DecompKind::Pencil);
    let compiled = Arc::new(compile(&spec, 1, &opts)?);
    let (w, plan) = (compiled.workers, compiled.plan());
    println!(
        "decomposition: {} cuts (x{}, y{}, z{}) -> {} pencils, \
         {} halo points ({:.1}% redundant reads)",
        plan.kind,
        plan.cuts[0],
        plan.cuts[1],
        plan.cuts[2],
        plan.tiles.len(),
        plan.halo_points(),
        100.0 * plan.redundant_read_fraction(&spec)
    );
    let a = &compiled.analysis;
    println!(
        "roofline: AI = {:.2} flops/byte ({:.2} effective after halos) -> \
         {:.0} GFLOPS/tile, {:.0} array; w = {w}",
        a.base.arithmetic_intensity,
        a.effective_ai,
        a.attainable_gflops_tile,
        a.attainable_gflops_array
    );
    let worst = plan.tiles[0].sub_spec(&spec);
    println!(
        "plane buffering per pencil: {} delay stages/reader, {} mandatory tokens \
         ({} placed graph(s) shared by {} pencils)",
        map3d::delay_stages(&worst, w),
        map3d::required_buffer_tokens(&worst, w),
        compiled.graph_count(),
        plan.tiles.len()
    );

    // Synthetic pressure field.
    let mut rng = XorShift::new(0xAC03);
    let input = rng.normal_vec(spec.grid_points());

    let session = Session::new(Arc::clone(&compiled), machine.clone());
    let outcome = session.run(&input)?;
    let rep = outcome.final_report();
    let want = stencil3d_ref(&input, &spec);
    let err = max_abs_diff(&rep.output, &want);
    assert!(err < 1e-11, "numerics drifted: {err:.2e}");
    let used = rep.per_tile.iter().filter(|t| t.strips > 0).count();
    assert!(used > 1, "expected more than one tile to pull work");

    println!("\nper-tile accounting ({} tiles pulled work):", used);
    for (t, r) in rep.per_tile.iter().enumerate() {
        if r.strips > 0 {
            println!(
                "  tile {t:>2}: {} pencils, {:>8} cycles, {:>5} halo points",
                r.strips, r.cycles, r.halo_points
            );
        }
    }
    println!(
        "\n{} pencils on {} tiles: makespan {} cycles -> {:.1} GFLOPS \
         ({:.0}% of the {:.0} array roof)",
        rep.strips,
        used,
        rep.makespan_cycles,
        rep.gflops,
        100.0 * rep.gflops / a.attainable_gflops_array,
        a.attainable_gflops_array
    );

    // §VII context: the analytical V100 on the same workload (charged
    // with the same redundant halo traffic for a like-for-like AI).
    let v100 = V100::paper();
    let g = GpuStencil::from_spec(&spec, Precision::F64);
    let gpu = v100.best_gflops(&g);
    println!(
        "V100 model: {gpu:.0} GFLOPS ({:.0}% of its {:.0} roof); \
         halo-adjusted AI would be {:.2}",
        100.0 * gpu / v100.roofline_gflops(&g),
        v100.roofline_gflops(&g),
        g.arithmetic_intensity_with_redundancy(rep.redundant_read_fraction)
    );

    println!("\nmax|err| vs oracle = {err:.2e}\nacoustic_3d OK");
    Ok(())
}
