//! 3-D acoustic kernel: a 13-point 3-D star (rx = ry = rz = 2, the
//! second-order acoustic wave-equation neighborhood) mapped onto the
//! CGRA via plane buffering — the `map3d` extension of §III — simulated
//! cycle-by-cycle and verified against the golden oracle, with the §VI
//! roofline and the §VII V100 model for context.
//!
//! ```sh
//! cargo run --release --example acoustic_3d
//! ```

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::gpu_model::{GpuStencil, Precision, V100};
use stencil_cgra::roofline;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{map3d, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, run_sim, stencil3d_ref};

fn main() -> Result<()> {
    let spec = StencilSpec::dim3(32, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2))?;
    let machine = Machine::paper();
    println!(
        "== acoustic 3-D stencil: {}x{}x{} grid, r=(2,2,2), {}-pt star ==\n",
        spec.nx,
        spec.ny,
        spec.nz,
        spec.points()
    );

    // §VI worker sizing for the 3-D shape.
    let w = roofline::optimal_workers(&spec, &machine);
    let a = roofline::analyze(&spec, &machine, w);
    println!(
        "roofline: AI = {:.2} flops/byte -> attainable {:.0} GFLOPS; \
         w = {w} (demand {:.0})",
        a.arithmetic_intensity, a.attainable_gflops, a.demand_gflops
    );
    println!(
        "plane buffering: {} delay stages/reader, {} mandatory tokens",
        map3d::delay_stages(&spec, w),
        map3d::required_buffer_tokens(&spec, w)
    );

    // Synthetic pressure field.
    let mut rng = XorShift::new(0xAC03);
    let input = rng.normal_vec(spec.grid_points());

    let res = run_sim(&spec, w, &machine, &input)?;
    let want = stencil3d_ref(&input, &spec);
    let err = max_abs_diff(&res.output, &want);
    assert!(err < 1e-9, "numerics drifted: {err:.2e}");

    let gflops = res.gflops(spec.total_flops(), machine.clock_ghz);
    println!(
        "\nsimulated {} cycles -> {:.1} GFLOPS ({:.0}% of the {:.0} roof)",
        res.stats.cycles,
        gflops,
        100.0 * gflops / a.attainable_gflops,
        a.attainable_gflops
    );
    println!("stats: {}", res.stats.summary());

    // §VII context: the analytical V100 on the same workload.
    let v100 = V100::paper();
    let g = GpuStencil::from_spec(&spec, Precision::F64);
    let gpu = v100.best_gflops(&g);
    println!(
        "V100 model: {gpu:.0} GFLOPS ({:.0}% of its {:.0} roof)",
        100.0 * gpu / v100.roofline_gflops(&g),
        v100.roofline_gflops(&g)
    );

    println!("\nmax|err| vs oracle = {err:.2e}\nacoustic_3d OK");
    Ok(())
}
