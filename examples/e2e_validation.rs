//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_validation
//! ```
//!
//! 1. **Three-way agreement** — the Table-I-shaped 49-pt stencil
//!    (rx = ry = 12, 96x96) computed by (a) the PJRT-executed JAX/Pallas
//!    artifact, (b) the native Rust oracle and (c) the CGRA cycle
//!    simulator must agree to ~1e-12.
//! 2. **Workload run** — 200 steps of 5-point heat diffusion on a 96x96
//!    plate compiled once and executed through a 4-tile `Session`, with
//!    the residual curve logged and the final state checked against the
//!    *fused* 200-step JAX artifact (`heat2d_run200_96x96` — §IV
//!    temporal locality on the XLA side).
//!
//! The run is recorded in EXPERIMENTS.md.

use std::sync::Arc;

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions, FuseMode};
use stencil_cgra::runtime::Runtime;
use stencil_cgra::session::Session;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, run_sim, stencil2d_ref};

fn main() -> Result<()> {
    let machine = Machine::paper();
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("== e2e validation (PJRT platform: {}) ==\n", rt.platform());

    // ---- Part 1: three-way agreement on the 49-pt stencil ----
    let spec = StencilSpec::dim2(96, 96, symmetric_taps(12), y_taps(12))?;
    let mut rng = XorShift::new(0xE2E);
    let x = rng.normal_vec(96 * 96);

    let t0 = std::time::Instant::now();
    let pjrt = rt.execute("stencil2d_r12_96x96", &[&x, &spec.cx, &spec.cy])?;
    let t_pjrt = t0.elapsed();
    let oracle = stencil2d_ref(&x, &spec);
    let sim = run_sim(&spec, 4, &machine, &x)?;

    let d1 = max_abs_diff(&pjrt, &oracle);
    let d2 = max_abs_diff(&sim.output, &oracle);
    let d3 = max_abs_diff(&sim.output, &pjrt);
    println!("49-pt stencil, 96x96:");
    println!("  L1/L2 (pallas via PJRT) vs native oracle: {d1:.2e}");
    println!("  L3 (CGRA simulator)     vs native oracle: {d2:.2e}");
    println!("  simulator vs PJRT:                        {d3:.2e}");
    assert!(d1 < 1e-11 && d2 < 1e-11 && d3 < 1e-11, "layer disagreement");
    println!("  simulator: {} cycles; PJRT exec: {:.1} ms\n", sim.stats.cycles,
        t_pjrt.as_secs_f64() * 1e3);

    // ---- Part 2: 200-step heat diffusion through the coordinator ----
    let (nx, ny, alpha, steps) = (96usize, 96usize, 0.2, 200usize);
    let heat = StencilSpec::heat2d(nx, ny, alpha);
    let mut x0 = vec![0.0f64; nx * ny];
    x0[48 * 96 + 48] = 100.0;

    // Compile the 200-step workload once (host schedule: one report per
    // step for the residual curve), then execute through a session.
    let opts = CompileOptions::default()
        .with_machine(machine.clone())
        .with_workers(4)
        .with_tiles(4)
        .with_fuse(FuseMode::Host);
    let session = Session::new(Arc::new(compile(&heat, steps, &opts)?), machine.clone());
    let t1 = std::time::Instant::now();
    let outcome = session.run(&x0)?;
    let (final_grid, reports) = (outcome.output, outcome.reports);
    let wall = t1.elapsed().as_secs_f64();

    // Residual curve (log every 25 steps).
    let mut prev = x0.clone();
    println!("heat diffusion, {nx}x{ny}, {steps} steps on 4 tiles:");
    for (i, rep) in reports.iter().enumerate() {
        let res = max_abs_diff(&rep.output, &prev);
        prev = rep.output.clone();
        if i % 25 == 0 || i == steps - 1 {
            println!("  step {i:>3}: residual {res:.4e}, {:.0} GFLOPS", rep.gflops);
        }
    }

    // Validate against the FUSED 200-step JAX artifact (one XLA
    // while-loop — §IV temporal locality at the L2 layer).
    let fused = rt.execute("heat2d_run200_96x96", &[&x0])?;
    let d = max_abs_diff(&final_grid, &fused);
    println!("\nsession(200 x 1-step) vs fused JAX run200: max|err| = {d:.2e}");
    assert!(d < 1e-10, "temporal drift: {d:.3e}");

    let total_cycles: u64 = reports.iter().map(|r| r.makespan_cycles).sum();
    let gflops = heat.total_flops() * steps as f64 * machine.clock_ghz / total_cycles as f64;
    println!(
        "sustained {gflops:.1} GFLOPS over {steps} steps ({total_cycles} cycles; wall {wall:.1}s)"
    );
    println!("\ne2e_validation OK — all layers compose");
    Ok(())
}
