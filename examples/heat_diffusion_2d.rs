//! Heat diffusion: the paper's single-time-step use case ("other kernels
//! need to be applied over the stencil grid before calling the stencil
//! kernel again", §IV) — a 60-step host-driven Jacobi workload compiled
//! **once** into a multi-tile artifact and executed through a `Session`.
//!
//! ```sh
//! cargo run --release --example heat_diffusion_2d
//! ```
//!
//! Reports the residual curve (convergence toward steady state) and the
//! sustained throughput across steps.

use std::sync::Arc;

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions, FuseMode};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::verify::golden::{heat2d_step_ref, max_abs_diff};

fn main() -> Result<()> {
    let (nx, ny, alpha) = (128usize, 128usize, 0.2);
    let steps = 60;
    let spec = StencilSpec::heat2d(nx, ny, alpha);
    let machine = Machine::paper();

    println!("== heat diffusion, {nx}x{ny}, alpha={alpha}, {steps} host-driven steps ==\n");

    // Initial condition: hot square in a cold plate (Dirichlet walls).
    let mut grid = vec![0.0f64; nx * ny];
    for r in 54..74 {
        for c in 54..74 {
            grid[r * nx + c] = 100.0;
        }
    }
    let initial_heat: f64 = grid.iter().sum();

    // Compile once: a Host-fused schedule keeps one report per step so
    // the residual curve below sees every intermediate grid.
    let opts = CompileOptions::default()
        .with_machine(machine.clone())
        .with_workers(4)
        .with_tiles(4)
        .with_fuse(FuseMode::Host);
    let session = Session::new(Arc::new(compile(&spec, steps, &opts)?), machine.clone());
    let mut residuals = Vec::new();
    let mut total_cycles = 0u64;
    let mut prev = grid.clone();
    let t0 = std::time::Instant::now();
    let outcome = session.run(&grid)?;
    let (final_grid, reports) = (outcome.output, outcome.reports);
    for (i, rep) in reports.iter().enumerate() {
        let res = max_abs_diff(&rep.output, &prev);
        residuals.push(res);
        prev = rep.output.clone();
        total_cycles += rep.makespan_cycles;
        if i % 10 == 0 || i == steps - 1 {
            println!(
                "step {i:>3}: residual {res:.4e}, {:.0} GFLOPS, {} strips",
                rep.gflops, rep.strips
            );
        }
    }
    grid = final_grid;

    // Convergence: residual must decay monotonically-ish.
    assert!(
        residuals[steps - 1] < residuals[1],
        "no convergence: {:.3e} -> {:.3e}",
        residuals[1],
        residuals[steps - 1]
    );

    // Physics: interior heat decays only through the cold walls; the
    // maximum principle bounds every value by the initial max.
    let final_heat: f64 = grid.iter().sum();
    assert!(final_heat <= initial_heat + 1e-6);
    assert!(grid.iter().all(|&v| v <= 100.0 + 1e-9 && v >= -1e-12));

    // Cross-check the final state against the iterated native oracle.
    let mut want = vec![0.0f64; nx * ny];
    for r in 54..74 {
        for c in 54..74 {
            want[r * nx + c] = 100.0;
        }
    }
    for _ in 0..steps {
        want = heat2d_step_ref(&want, nx, ny, alpha);
    }
    let err = max_abs_diff(&grid, &want);
    assert!(err < 1e-10, "drifted from oracle: {err:.3e}");

    let flops = spec.total_flops() * steps as f64;
    println!(
        "\n{steps} steps in {total_cycles} simulated cycles -> {:.1} sustained GFLOPS",
        flops * machine.clock_ghz / total_cycles as f64
    );
    println!(
        "heat conserved to walls: {initial_heat:.1} -> {final_heat:.1}; max|err| vs oracle {err:.2e}"
    );
    println!("wall time {:.2}s\nheat_diffusion_2d OK", t0.elapsed().as_secs_f64());
    Ok(())
}
