//! §IV divide-and-conquer + hybrid CPU/CGRA execution.
//!
//! The grid is decomposed recursively into fabric-sized tiles
//! (cache-friendly nesting for the CPU side); CGRA tiles and CPU workers
//! pull from the same queue — the work-stealing structure the paper
//! sketches for "multiple CPU cores sharing the same last level cache
//! offloading independent stencil tasks to the CGRAs". The CGRA side
//! shares the compile phase's placed graphs (one placement per distinct
//! tile shape), so a pull costs only per-run simulator state.
//!
//! ```sh
//! cargo run --release --example hybrid_multitile
//! ```

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::coordinator::dnc::{decompose, Executor, HybridRunner};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil2d_ref};

fn main() -> Result<()> {
    let spec = StencilSpec::dim2(
        512,
        96,
        stencil_cgra::stencil::spec::symmetric_taps(4),
        stencil_cgra::stencil::spec::y_taps(4),
    )?;
    println!(
        "== hybrid D&C: {}x{} {}-pt stencil ==\n",
        spec.nx,
        spec.ny,
        spec.points()
    );

    // §IV: recursive decomposition into fabric-sized subtasks (N-dim
    // tiles; every output extent <= 32).
    let tiles = decompose(&spec, 32);
    println!("decomposed interior into {} tiles of <=32 output extent", tiles.len());

    let mut rng = XorShift::new(0x11AB);
    let input = rng.normal_vec(spec.grid_points());

    let cgra_tiles = 4;
    let cpus = 2;
    let runner = HybridRunner::new(cgra_tiles, cpus, Machine::paper());
    let t0 = std::time::Instant::now();
    let rep = runner.run(&spec, 3, &input, tiles)?;

    let want = stencil2d_ref(&input, &spec);
    let err = max_abs_diff(&rep.output, &want);
    assert!(err < 1e-11, "numerics drifted: {err:.2e}");

    println!(
        "\n{} tiles done: {} on CGRA tiles, {} stolen by CPU workers",
        rep.assignments.len(),
        rep.cgra_strips,
        rep.cpu_strips
    );
    for t in 0..cgra_tiles {
        let n = rep
            .assignments
            .iter()
            .filter(|(_, e)| *e == Executor::Cgra(t))
            .count();
        println!("  tile {t}: {n} tasks");
    }
    println!(
        "CGRA makespan {} cycles; wall {:.2}s; max|err| {err:.2e}",
        rep.makespan_cycles,
        t0.elapsed().as_secs_f64()
    );
    println!("hybrid_multitile OK");
    Ok(())
}
