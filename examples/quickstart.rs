//! Quickstart: the paper's running example (§III-A) — a 3-point 1-D
//! stencil mapped onto the CGRA with 3 workers.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the dataflow graph (readers, filters, MAC chains, writers,
//! sync), simulates it cycle by cycle, verifies the numerics against the
//! native oracle and prints the §VIII-style report.

use anyhow::Result;
use stencil_cgra::cgra::{Machine, Simulator};
use stencil_cgra::dfg::dot::to_dot;
use stencil_cgra::roofline;
use stencil_cgra::stencil::{map1d, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil1d_ref};

fn main() -> Result<()> {
    // The (2rx+1)-point stencil of Fig 1 with rx = 1.
    let n = 4096;
    let spec = StencilSpec::dim1(n, vec![0.25, 0.5, 0.25])?;
    let machine = Machine::paper();
    let workers = 3; // the paper's w = 3 walkthrough

    println!("== stencil-cgra quickstart: 3-pt 1-D stencil, w = {workers} ==\n");

    // 1. Map: stencil -> dataflow graph (§III-A).
    let graph = map1d::build(&spec, workers)?;
    println!("DFG: {}", graph.summary());
    let hist = graph.op_histogram();
    println!(
        "     {} MUL, {} MAC, {} filters, {} loads, {} stores",
        hist[&stencil_cgra::dfg::Op::Mul],
        hist[&stencil_cgra::dfg::Op::Mac],
        hist[&stencil_cgra::dfg::Op::Filter],
        hist[&stencil_cgra::dfg::Op::Load],
        hist[&stencil_cgra::dfg::Op::Store],
    );

    // Optional: write the Graphviz rendering (Fig 5-style).
    std::fs::write("/tmp/quickstart_dfg.dot", to_dot(&graph, "3-pt 1D, 3 workers"))?;
    println!("     dot written to /tmp/quickstart_dfg.dot\n");

    // 2. Roofline (§VI): is this workload bandwidth- or compute-bound?
    let a = roofline::analyze(&spec, &machine, workers);
    println!(
        "roofline: AI = {:.2} flops/byte -> attainable {:.0} GFLOPS (peak {:.0})",
        a.arithmetic_intensity, a.attainable_gflops, a.peak_gflops
    );

    // 3. Simulate (§VIII): functional + timing in one run.
    let mut rng = XorShift::new(2024);
    let input = rng.normal_vec(n);
    let res = Simulator::build(graph, &machine, input.clone(), input.clone())?.run()?;

    // 4. Verify against the native oracle.
    let want = stencil1d_ref(&input, &spec.cx);
    let err = max_abs_diff(&res.output, &want);
    println!("\nsimulated {} cycles, max|err| vs oracle = {err:.2e}", res.stats.cycles);
    assert!(err < 1e-12);

    let gflops = res.gflops(spec.total_flops(), machine.clock_ghz);
    println!(
        "achieved {gflops:.1} GFLOPS = {:.0}% of the {:.0} GFLOPS roofline",
        100.0 * gflops / a.attainable_gflops,
        a.attainable_gflops
    );
    println!("stats: {}", res.stats.summary());
    println!("\nquickstart OK");
    Ok(())
}
