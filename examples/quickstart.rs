//! Quickstart: compile once, execute many (§III map once, stream many
//! grids) — the paper's 3-point 1-D running example through the
//! two-phase API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! **Phase 1** (`compile`) does everything data-independent exactly
//! once: resolves the worker count against the §VI roofline, plans the
//! tile decomposition, builds *and places* the dataflow graph
//! (readers, filters, MAC chains, writers, sync) per tile shape.
//! **Phase 2** (`Session`) executes the immutable artifact against any
//! number of input grids — here three different wavefields plus a
//! repeat, verifying each against the native oracle and showing that
//! no planning or graph work happens after compile.

use std::sync::Arc;

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::{metrics, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil1d_ref};

fn main() -> Result<()> {
    // The (2rx+1)-point stencil of Fig 1 with rx = 1.
    let n = 4096;
    let spec = StencilSpec::dim1(n, vec![0.25, 0.5, 0.25])?;
    let machine = Machine::paper();

    println!("== stencil-cgra quickstart: 3-pt 1-D stencil, compile once / execute many ==\n");

    // Phase 1: compile. One plan, one placed graph, one roofline pass.
    let opts = CompileOptions::default()
        .with_machine(machine.clone())
        .with_workers(3); // the paper's w = 3 walkthrough
    let compiled = Arc::new(compile(&spec, 1, &opts)?);
    println!(
        "compiled: w = {}, {} tile task(s), {} placed graph(s)",
        compiled.workers,
        compiled.plan().tiles.len(),
        compiled.graph_count()
    );
    println!(
        "roofline: AI = {:.2} flops/byte -> attainable {:.0} GFLOPS (peak {:.0})\n",
        compiled.analysis.base.arithmetic_intensity,
        compiled.analysis.base.attainable_gflops,
        compiled.analysis.base.peak_gflops
    );

    // Phase 2: a session executes the artifact — &self, so it can serve
    // many threads; here a loop of distinct grids stands in for them.
    let session = Session::new(Arc::clone(&compiled), machine.clone());
    let (plans_before, graphs_before) = (metrics::plans(), metrics::graph_builds());
    let mut first_cycles = 0;
    for seed in [2024u64, 2025, 2026] {
        let mut rng = XorShift::new(seed);
        let input = rng.normal_vec(n);
        let outcome = session.run(&input)?;
        let rep = outcome.final_report();
        let want = stencil1d_ref(&input, &spec.cx);
        let err = max_abs_diff(&outcome.output, &want);
        assert!(err < 1e-12);
        println!(
            "grid {seed}: {} cycles, {:.1} GFLOPS, max|err| vs oracle = {err:.2e}",
            rep.makespan_cycles, rep.gflops
        );
        first_cycles = rep.makespan_cycles;
    }

    // Re-running the same grid is bitwise-deterministic...
    let mut rng = XorShift::new(2026);
    let again = session.run(&rng.normal_vec(n))?;
    assert_eq!(again.final_report().makespan_cycles, first_cycles);
    // ...and the execute phase did zero planning / graph construction.
    assert_eq!(metrics::plans(), plans_before);
    assert_eq!(metrics::graph_builds(), graphs_before);
    println!("\n4 executions after compile: 0 plans, 0 graph builds (counters pinned)");
    println!("quickstart OK");
    Ok(())
}
