//! The Table-I 2-D workload: the oil/gas seismic 49-point stencil
//! (rx = ry = 12) on a 960 x 449 grid, run on 16 CGRA tiles and compared
//! against the analytical V100 baseline — this example regenerates the
//! stencil2D half of Table I.
//!
//! ```sh
//! cargo run --release --example seismic_2d
//! ```

use std::sync::Arc;

use anyhow::Result;
use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::gpu_model::{GpuStencil, Precision, V100};
use stencil_cgra::roofline;
use stencil_cgra::session::Session;
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil2d_ref};

fn main() -> Result<()> {
    let spec = StencilSpec::paper_2d();
    let machine = Machine::paper();
    println!("== seismic 49-pt 2-D stencil (Table I row 'Stencil2D') ==\n");
    println!(
        "grid {}x{}, rx=ry={}, AI = {:.2} flops/byte",
        spec.nx, spec.ny, spec.rx, spec.arithmetic_intensity()
    );

    // §VI worker sizing: 5 workers (245 of 256 MACs).
    let w = roofline::optimal_workers(&spec, &machine);
    let a = roofline::analyze(&spec, &machine, w);
    println!(
        "workers = {w} (demand {:.0} GFLOPS vs attainable {:.0})",
        a.demand_gflops, a.attainable_gflops
    );

    // Synthetic seismic wavefield: random field standing in for the
    // paper's proprietary survey data (DESIGN.md Substitutions).
    let mut rng = XorShift::new(0x5E15);
    let input = rng.normal_vec(spec.grid_points());

    // Compile once for the 16-tile paper configuration, execute once.
    let opts = CompileOptions::paper().with_machine(machine.clone()).with_workers(w);
    let tiles = opts.tiles;
    let session = Session::new(Arc::new(compile(&spec, 1, &opts)?), machine.clone());
    let outcome = session.run(&input)?;
    let rep = outcome.final_report();

    let want = stencil2d_ref(&input, &spec);
    let err = max_abs_diff(&rep.output, &want);
    assert!(err < 1e-11, "numerics drifted: {err:.2e}");

    let tile_roof = machine.roofline_gflops(spec.arithmetic_intensity());
    let array_roof = tiles as f64 * tile_roof;
    println!(
        "\nCGRA x{}: {} strips, makespan {} cycles -> {:.0} GFLOPS ({:.0}% of {:.0} roof)",
        tiles,
        rep.strips,
        rep.makespan_cycles,
        rep.gflops,
        100.0 * rep.gflops / array_roof,
        array_roof
    );

    // V100 baseline (§VII register-caching kernel).
    let v100 = V100::paper();
    let g = GpuStencil::from_spec(&spec, Precision::F64);
    let gpu = v100.best_gflops(&g);
    let gpu_roof = v100.roofline_gflops(&g);
    println!(
        "V100:     {gpu:.0} GFLOPS ({:.0}% of {gpu_roof:.0} roof)",
        100.0 * gpu / gpu_roof
    );
    println!(
        "\nTable I 'Normalized GFLOPS': CGRA/V100 = {:.2}x   (paper: 3.03x)",
        rep.gflops / gpu
    );
    println!("max|err| vs oracle = {err:.2e}\nseismic_2d OK");
    Ok(())
}
