"""AOT pipeline: lower the L2 model (with L1 Pallas kernels inlined) to HLO
*text* artifacts the Rust runtime loads via the ``xla`` crate.

HLO text — NOT ``lowered.compile()`` or serialized ``HloModuleProto`` — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Run from ``python/``:  ``python -m compile.aot --outdir ../artifacts``

Emits one ``<name>.hlo.txt`` per variant plus ``manifest.txt`` with the
pipe-separated schema the Rust `runtime::artifact` parser reads:

    name|file|dtype|in0:shape,in1:shape,...|out_shape

Shapes are `x`-separated dims; scalars are `s`.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float64):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_str(shape) -> str:
    return "x".join(str(d) for d in shape) if shape else "s"


class Variant:
    """One AOT artifact: a jit-lowerable fn + its example input specs."""

    def __init__(self, name, fn, in_specs, out_shape, dtype="f64"):
        self.name = name
        self.fn = fn
        self.in_specs = in_specs
        self.out_shape = out_shape
        self.dtype = dtype

    def lower_text(self) -> str:
        return to_hlo_text(jax.jit(self.fn).lower(*self.in_specs))

    def manifest_line(self) -> str:
        ins = ",".join(_shape_str(s.shape) for s in self.in_specs)
        return "|".join(
            [self.name, f"{self.name}.hlo.txt", self.dtype, ins,
             _shape_str(self.out_shape)]
        )


def build_variants() -> list[Variant]:
    f64 = jnp.float64
    vs: list[Variant] = []

    def s1d(name, n, r, block_w=None):
        fn = lambda x, c: model.stencil1d(x, c, block_w=block_w)  # noqa: E731
        vs.append(Variant(name, fn, [_spec((n,), f64), _spec((2 * r + 1,), f64)], (n,)))

    def s2d(name, h, w, rx, ry):
        fn = model.stencil2d
        vs.append(
            Variant(
                name,
                fn,
                [_spec((h, w), f64), _spec((2 * rx + 1,), f64), _spec((2 * ry,), f64)],
                (h, w),
            )
        )

    # Small fast-loading validation artifacts.
    s1d("stencil1d_r1_n256", 256, 1)
    s1d("stencil1d_r8_n4096", 4096, 8)
    s2d("stencil2d_r2_64x64", 64, 64, 2, 2)
    # Table-I shaped (49-pt, rx=ry=12) on a compact grid for PJRT checks.
    s2d("stencil2d_r12_96x96", 96, 96, 12, 12)
    # Full Table-I 1D grid (17-pt, rx=8, n=194400).
    s1d("stencil1d_r8_n194400", 194400, 8, block_w=8192)

    # Heat diffusion: single step + a fused 200-step run (IV temporal
    # locality: one while-loop, I/O only at the boundary).
    vs.append(
        Variant(
            "heat2d_step_96x96",
            lambda x: model.heat2d_step(x, 0.2),
            [_spec((96, 96), f64)],
            (96, 96),
        )
    )
    vs.append(
        Variant(
            "heat2d_run200_96x96",
            lambda x: model.heat2d_run(x, 200, 0.2),
            [_spec((96, 96), f64)],
            (96, 96),
        )
    )
    # Pure-jnp reference artifact: lets the Rust side check pallas-vs-ref
    # through PJRT as well.
    vs.append(
        Variant(
            "stencil2d_ref_r12_96x96",
            model.stencil2d_reference,
            [_spec((96, 96), f64), _spec((25,), f64), _spec((24,), f64)],
            (96, 96),
        )
    )
    return vs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    manifest = []
    for v in build_variants():
        if only and v.name not in only:
            continue
        text = v.lower_text()
        path = os.path.join(args.outdir, f"{v.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(v.manifest_line())
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)
    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.outdir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
