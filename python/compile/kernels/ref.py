"""Pure-jnp correctness oracles for the star-stencil kernels.

These mirror the accumulation order of the paper's MAC chains exactly
(III-A / III-B): the x contribution is a left-to-right chain over taps
``k = -rx .. +rx`` (MUL on the first tap, MACs after), the y contribution is
a left-to-right chain over ``k = -ry .. +ry, k != 0`` (the centre tap
belongs to the x chain), and the final output is ``x_partial + y_partial``.
The Pallas kernels, the Rust native oracle and the CGRA simulator all use
the same order so f64 comparisons can use tight tolerances.

Boundary semantics: only interior points (``rx <= i < n - rx`` per
dimension) are stencil-computed; boundary points are copied from the input
(Dirichlet boundary), matching the data-drop filters of Fig 6 which keep
each MUL/MAC silent outside its valid range.
"""

from __future__ import annotations

import jax.numpy as jnp


def stencil1d_ref(x: jnp.ndarray, coeffs: jnp.ndarray) -> jnp.ndarray:
    """(2r+1)-point 1D star stencil, interior-only, boundary copied.

    ``out[i] = sum_k coeffs[k] * x[i - r + k]`` accumulated left-to-right.
    """
    n = x.shape[0]
    taps = coeffs.shape[0]
    r = (taps - 1) // 2
    assert taps == 2 * r + 1, "coeffs must have odd length"
    m = n - 2 * r  # number of interior outputs
    acc = coeffs[0] * x[0:m]
    for k in range(1, taps):
        acc = acc + coeffs[k] * x[k : k + m]
    return x.at[r : n - r].set(acc)


def stencil2d_ref(
    x: jnp.ndarray, cx: jnp.ndarray, cy: jnp.ndarray
) -> jnp.ndarray:
    """(2rx+1 + 2ry)-point 2D star stencil (Fig 8 / Fig 9 generalised).

    ``cx`` has ``2*rx + 1`` taps (includes the centre), ``cy`` has
    ``2*ry`` taps (centre excluded — it is counted once, in the x chain),
    ordered ``j-ry, .., j-1, j+1, .., j+ry``.
    """
    h, w = x.shape
    rx = (cx.shape[0] - 1) // 2
    ry = cy.shape[0] // 2
    assert cx.shape[0] == 2 * rx + 1
    assert cy.shape[0] == 2 * ry
    mh = h - 2 * ry
    mw = w - 2 * rx
    # x chain over the interior rows.
    acc = cx[0] * x[ry : ry + mh, 0:mw]
    for k in range(1, 2 * rx + 1):
        acc = acc + cx[k] * x[ry : ry + mh, k : k + mw]
    # y chain: taps j-ry .. j-1 then j+1 .. j+ry.
    for t in range(2 * ry):
        k = t if t < ry else t + 1  # skip the centre row offset ry
        acc = acc + cy[t] * x[k : k + mh, rx : rx + mw]
    return x.at[ry : h - ry, rx : w - rx].set(acc)


def heat2d_coeffs(alpha: float = 0.2):
    """5-point Jacobi heat-diffusion coefficients (rx = ry = 1).

    ``out = (1 - 4a) * c + a * (n + s + e + w)`` expressed as star-stencil
    coefficient vectors for :func:`stencil2d_ref`.
    """
    cx = jnp.array([alpha, 1.0 - 4.0 * alpha, alpha])
    cy = jnp.array([alpha, alpha])
    return cx, cy


def heat2d_step_ref(x: jnp.ndarray, alpha: float = 0.2) -> jnp.ndarray:
    cx, cy = heat2d_coeffs(alpha)
    return stencil2d_ref(x, cx.astype(x.dtype), cy.astype(x.dtype))
