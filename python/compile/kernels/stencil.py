"""L1 — Pallas star-stencil kernels (interpret=True for CPU-PJRT).

Hardware adaptation of the paper's CGRA mapping (DESIGN.md
Hardware-Adaptation): the CGRA keeps ``2*ry`` rows of the input resident in
PE queues so every grid point is loaded from memory exactly once and reused
``2*r`` times; here the same schedule is expressed as a *halo'd VMEM block*
— each Pallas grid step brings an ``(block_h + 2*ry, block_w + 2*rx)`` tile
of the input into kernel-local memory once and all taps read it from there.
The ``block_w`` knob is the strip width of III-B "Blocking" (strip mining).

All kernels accumulate in the exact MAC-chain order of the paper (see
``ref.py``), so kernel == oracle bit-for-bit in f64 up to fused-multiply
differences (we use separate mul+add, matching the simulator).

Kernels are lowered with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM budget used to size blocks (16 MiB, a TPU-core-like figure).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def vmem_bytes_2d(block_h: int, block_w: int, rx: int, ry: int, itemsize: int) -> int:
    """Bytes resident per grid step: halo'd input tile + output tile."""
    in_tile = (block_h + 2 * ry) * (block_w + 2 * rx) * itemsize
    out_tile = block_h * block_w * itemsize
    return in_tile + out_tile


def choose_block_2d(
    mh: int, mw: int, rx: int, ry: int, itemsize: int, budget: int = VMEM_BUDGET_BYTES
) -> tuple[int, int]:
    """Pick (block_h, block_w) fitting ``budget``, preferring full-width
    strips (the paper streams whole rows and strip-mines only when the row
    does not fit on-fabric)."""
    block_h = max(1, min(mh, 8 * max(1, ry)))
    block_w = mw
    while vmem_bytes_2d(block_h, block_w, rx, ry, itemsize) > budget and block_w > 16:
        block_w = max(16, block_w // 2)
    while vmem_bytes_2d(block_h, block_w, rx, ry, itemsize) > budget and block_h > 1:
        block_h = max(1, block_h // 2)
    return block_h, block_w


def _stencil1d_kernel(x_ref, c_ref, o_ref, *, r: int, block_w: int):
    """One strip of the 1D interior: out[i] = sum_k c[k] * x[i + k]."""
    i = pl.program_id(0)
    base = i * block_w
    xs = x_ref[pl.ds(base, block_w + 2 * r)]
    acc = c_ref[0] * xs[0:block_w]
    for k in range(1, 2 * r + 1):
        acc = acc + c_ref[k] * xs[k : k + block_w]
    o_ref[...] = acc


def stencil1d_interior(
    x: jnp.ndarray, coeffs: jnp.ndarray, *, block_w: int | None = None
) -> jnp.ndarray:
    """Interior of the (2r+1)-point 1D stencil via a Pallas kernel.

    Returns the ``n - 2r`` interior outputs; the caller applies boundary
    semantics (see ``model.py``).
    """
    n = x.shape[0]
    taps = coeffs.shape[0]
    r = (taps - 1) // 2
    assert taps == 2 * r + 1 and taps >= 3, "coeffs must have odd length >= 3"
    m = n - 2 * r
    assert m >= 1, "grid smaller than stencil"
    if block_w is None:
        block_w = min(m, 4096)
    block_w = min(block_w, m)
    grid = _ceil_div(m, block_w)
    m_pad = grid * block_w
    # Pad so the last strip's halo'd load stays in range.
    x_pad = jnp.pad(x, (0, m_pad - m))
    out = pl.pallas_call(
        functools.partial(_stencil1d_kernel, r=r, block_w=block_w),
        grid=(grid,),
        out_shape=jax.ShapeDtypeStruct((m_pad,), x.dtype),
        out_specs=pl.BlockSpec((block_w,), lambda i: (i,)),
        interpret=True,
    )(x_pad, coeffs)
    return out[0:m]


def _stencil2d_kernel(
    x_ref, cx_ref, cy_ref, o_ref, *, rx: int, ry: int, block_h: int, block_w: int
):
    """One (block_h, block_w) tile of the 2D star-stencil interior.

    Loads the halo'd input tile once (the VMEM analogue of the paper's
    mandatory 2*ry-row buffering), then runs the x chain followed by the
    y chain in the canonical order.
    """
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    base_h = bi * block_h
    base_w = bj * block_w
    xs = x_ref[pl.ds(base_h, block_h + 2 * ry), pl.ds(base_w, block_w + 2 * rx)]
    # x chain (2*rx + 1 taps, includes centre).
    acc = cx_ref[0] * xs[ry : ry + block_h, 0:block_w]
    for k in range(1, 2 * rx + 1):
        acc = acc + cx_ref[k] * xs[ry : ry + block_h, k : k + block_w]
    # y chain (2*ry taps, centre excluded).
    for t in range(2 * ry):
        k = t if t < ry else t + 1
        acc = acc + cy_ref[t] * xs[k : k + block_h, rx : rx + block_w]
    o_ref[...] = acc


def stencil2d_interior(
    x: jnp.ndarray,
    cx: jnp.ndarray,
    cy: jnp.ndarray,
    *,
    block_h: int | None = None,
    block_w: int | None = None,
) -> jnp.ndarray:
    """Interior of the 2D star stencil via a Pallas kernel.

    ``cx``: 2*rx+1 taps (with centre); ``cy``: 2*ry taps (without centre).
    Returns the ``(h - 2*ry, w - 2*rx)`` interior block.
    """
    h, w = x.shape
    rx = (cx.shape[0] - 1) // 2
    ry = cy.shape[0] // 2
    assert cx.shape[0] == 2 * rx + 1 and rx >= 1
    assert cy.shape[0] == 2 * ry and ry >= 1
    mh = h - 2 * ry
    mw = w - 2 * rx
    assert mh >= 1 and mw >= 1, "grid smaller than stencil"
    if block_h is None or block_w is None:
        bh, bw = choose_block_2d(mh, mw, rx, ry, x.dtype.itemsize)
        block_h = block_h or bh
        block_w = block_w or bw
    block_h = min(block_h, mh)
    block_w = min(block_w, mw)
    gh = _ceil_div(mh, block_h)
    gw = _ceil_div(mw, block_w)
    mh_pad = gh * block_h
    mw_pad = gw * block_w
    x_pad = jnp.pad(x, ((0, mh_pad - mh), (0, mw_pad - mw)))
    out = pl.pallas_call(
        functools.partial(
            _stencil2d_kernel, rx=rx, ry=ry, block_h=block_h, block_w=block_w
        ),
        grid=(gh, gw),
        out_shape=jax.ShapeDtypeStruct((mh_pad, mw_pad), x.dtype),
        out_specs=pl.BlockSpec((block_h, block_w), lambda i, j: (i, j)),
        interpret=True,
    )(x_pad, cx, cy)
    return out[0:mh, 0:mw]
