"""L2 — JAX compute graph for the star-stencil workloads.

Full-grid semantics on top of the L1 Pallas kernels: interior points are
stencil-computed, boundary points keep their input values (Dirichlet), the
same contract the Rust CGRA simulator and the native oracle implement.

Every public function here is jit-compatible and is what ``aot.py`` lowers
to HLO text for the Rust runtime. Python never runs on the request path:
these functions execute exactly once per artifact, at build time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import stencil as K
from .kernels import ref as R


def stencil1d(
    x: jnp.ndarray, coeffs: jnp.ndarray, *, block_w: int | None = None
) -> jnp.ndarray:
    """(2r+1)-point 1D star stencil over the full grid (boundary copied)."""
    taps = coeffs.shape[0]
    r = (taps - 1) // 2
    interior = K.stencil1d_interior(x, coeffs, block_w=block_w)
    return x.at[r : x.shape[0] - r].set(interior)


def stencil2d(
    x: jnp.ndarray,
    cx: jnp.ndarray,
    cy: jnp.ndarray,
    *,
    block_h: int | None = None,
    block_w: int | None = None,
) -> jnp.ndarray:
    """2D star stencil over the full grid (boundary ring copied)."""
    rx = (cx.shape[0] - 1) // 2
    ry = cy.shape[0] // 2
    interior = K.stencil2d_interior(x, cx, cy, block_h=block_h, block_w=block_w)
    h, w = x.shape
    return x.at[ry : h - ry, rx : w - rx].set(interior)


def heat2d_step(x: jnp.ndarray, alpha: float = 0.2) -> jnp.ndarray:
    """One 5-point Jacobi heat-diffusion step (rx = ry = 1)."""
    cx, cy = R.heat2d_coeffs(alpha)
    return stencil2d(x, cx.astype(x.dtype), cy.astype(x.dtype))


def heat2d_run(x: jnp.ndarray, steps: int, alpha: float = 0.2) -> jnp.ndarray:
    """``steps`` fused heat-diffusion steps in a single XLA while-loop.

    This is the temporal-locality workload of IV: all intermediate grids
    stay on-device; I/O happens only at the loop boundary.
    """
    return jax.lax.fori_loop(0, steps, lambda _, g: heat2d_step(g, alpha), x)


def heat2d_run_with_residual(
    x: jnp.ndarray, steps: int, alpha: float = 0.2
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Heat run that also returns max |Δ| of the final step (convergence)."""
    final = heat2d_run(x, steps, alpha)
    nxt = heat2d_step(final, alpha)
    return final, jnp.max(jnp.abs(nxt - final))


# ---------------------------------------------------------------------------
# Reference (pure-jnp) variants — used by the tests and lowered alongside the
# Pallas versions so the Rust side can cross-check kernel-vs-ref *through
# PJRT* too, not only in pytest.
# ---------------------------------------------------------------------------


def stencil1d_reference(x: jnp.ndarray, coeffs: jnp.ndarray) -> jnp.ndarray:
    return R.stencil1d_ref(x, coeffs)


def stencil2d_reference(
    x: jnp.ndarray, cx: jnp.ndarray, cy: jnp.ndarray
) -> jnp.ndarray:
    return R.stencil2d_ref(x, cx, cy)
