"""AOT pipeline: every variant lowers to parseable HLO text; the manifest
schema round-trips; executing the lowered module (via jax) matches the ref."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model
from compile.kernels import ref as R


def test_variant_inventory():
    names = [v.name for v in aot.build_variants()]
    assert len(names) == len(set(names)), "duplicate variant names"
    # The experiment-critical artifacts must exist.
    for required in [
        "stencil1d_r8_n194400",
        "stencil2d_r12_96x96",
        "heat2d_run200_96x96",
        "stencil2d_ref_r12_96x96",
    ]:
        assert required in names


def test_manifest_line_schema():
    v = next(v for v in aot.build_variants() if v.name == "stencil2d_r12_96x96")
    line = v.manifest_line()
    name, fname, dtype, ins, out = line.split("|")
    assert name == "stencil2d_r12_96x96"
    assert fname.endswith(".hlo.txt")
    assert dtype == "f64"
    assert ins == "96x96,25,24"
    assert out == "96x96"


def test_small_variant_lowers_to_hlo_text():
    v = next(v for v in aot.build_variants() if v.name == "stencil1d_r1_n256")
    text = v.lower_text()
    assert "HloModule" in text
    assert "f64" in text


def test_hlo_text_has_entry_computation():
    v = next(v for v in aot.build_variants() if v.name == "stencil2d_r2_64x64")
    text = v.lower_text()
    assert "ENTRY" in text


def test_aot_main_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        proc = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--outdir", d,
             "--only", "stencil1d_r1_n256,stencil2d_r2_64x64"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env, capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr
        assert os.path.exists(os.path.join(d, "stencil1d_r1_n256.hlo.txt"))
        assert os.path.exists(os.path.join(d, "stencil2d_r2_64x64.hlo.txt"))
        with open(os.path.join(d, "manifest.txt")) as f:
            lines = [l for l in f.read().splitlines() if l]
        assert len(lines) == 2


def test_lowered_variant_executes_and_matches_ref():
    """Execute the exact jitted fn that gets lowered; compare vs oracle."""
    g = np.random.default_rng(1234)
    x = jnp.asarray(g.standard_normal((96, 96)))
    cx = jnp.asarray(g.standard_normal(25))
    cy = jnp.asarray(g.standard_normal(24))
    got = jax.jit(model.stencil2d)(x, cx, cy)
    want = R.stencil2d_ref(x, cx, cy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-11)
