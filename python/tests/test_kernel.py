"""Kernel-vs-ref allclose — the core L1 correctness signal.

Hypothesis sweeps shapes, radii, block sizes and dtypes of the Pallas
kernels against the pure-jnp oracle in ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref as R
from compile.kernels import stencil as K


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# 1D
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,r", [(16, 1), (64, 2), (256, 8), (1000, 12)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_stencil1d_matches_ref(n, r, dtype):
    g = rng(n * 31 + r)
    x = jnp.asarray(g.standard_normal(n), dtype=dtype)
    c = jnp.asarray(g.standard_normal(2 * r + 1), dtype=dtype)
    got = K.stencil1d_interior(x, c)
    want = R.stencil1d_ref(x, c)[r : n - r]
    tol = 1e-12 if dtype == jnp.float64 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_stencil1d_boundary_copied():
    g = rng(7)
    x = jnp.asarray(g.standard_normal(64))
    c = jnp.asarray(g.standard_normal(5))  # r = 2
    from compile import model

    out = model.stencil1d(x, c)
    np.testing.assert_array_equal(np.asarray(out[:2]), np.asarray(x[:2]))
    np.testing.assert_array_equal(np.asarray(out[-2:]), np.asarray(x[-2:]))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=300),
    r=st.integers(min_value=1, max_value=3),
    block_w=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stencil1d_hypothesis(n, r, block_w, seed):
    if n - 2 * r < 1:
        return
    g = rng(seed)
    x = jnp.asarray(g.standard_normal(n))
    c = jnp.asarray(g.standard_normal(2 * r + 1))
    got = K.stencil1d_interior(x, c, block_w=block_w)
    want = R.stencil1d_ref(x, c)[r : n - r]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_stencil1d_identity_coeffs():
    # coeffs = delta at centre → interior equals input interior.
    x = jnp.arange(32.0)
    c = jnp.array([0.0, 1.0, 0.0])
    got = K.stencil1d_interior(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x[1:-1]))


def test_stencil1d_block_not_dividing():
    g = rng(3)
    x = jnp.asarray(g.standard_normal(101))
    c = jnp.asarray(g.standard_normal(3))
    got = K.stencil1d_interior(x, c, block_w=17)  # 99 not divisible by 17
    want = R.stencil1d_ref(x, c)[1:-1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


# ---------------------------------------------------------------------------
# 2D
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "h,w,rx,ry",
    [(8, 8, 1, 1), (16, 24, 2, 1), (32, 32, 2, 2), (64, 48, 4, 4), (96, 96, 12, 12)],
)
def test_stencil2d_matches_ref(h, w, rx, ry):
    g = rng(h * 1000 + w * 10 + rx + ry)
    x = jnp.asarray(g.standard_normal((h, w)))
    cx = jnp.asarray(g.standard_normal(2 * rx + 1))
    cy = jnp.asarray(g.standard_normal(2 * ry))
    got = K.stencil2d_interior(x, cx, cy)
    want = R.stencil2d_ref(x, cx, cy)[ry : h - ry, rx : w - rx]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(min_value=6, max_value=48),
    w=st.integers(min_value=6, max_value=48),
    rx=st.integers(min_value=1, max_value=2),
    ry=st.integers(min_value=1, max_value=2),
    bh=st.integers(min_value=1, max_value=16),
    bw=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stencil2d_hypothesis(h, w, rx, ry, bh, bw, seed):
    if h - 2 * ry < 1 or w - 2 * rx < 1:
        return
    g = rng(seed)
    x = jnp.asarray(g.standard_normal((h, w)))
    cx = jnp.asarray(g.standard_normal(2 * rx + 1))
    cy = jnp.asarray(g.standard_normal(2 * ry))
    got = K.stencil2d_interior(x, cx, cy, block_h=bh, block_w=bw)
    want = R.stencil2d_ref(x, cx, cy)[ry : h - ry, rx : w - rx]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-11)


def test_stencil2d_f32():
    g = rng(11)
    x = jnp.asarray(g.standard_normal((24, 24)), dtype=jnp.float32)
    cx = jnp.asarray(g.standard_normal(5), dtype=jnp.float32)
    cy = jnp.asarray(g.standard_normal(4), dtype=jnp.float32)
    got = K.stencil2d_interior(x, cx, cy)
    want = R.stencil2d_ref(x, cx, cy)[2:-2, 2:-2]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_stencil2d_separable_equals_two_1d_passes():
    """x-only coefficients (cy = 0) reduce to a row-wise 1D stencil."""
    g = rng(13)
    x = jnp.asarray(g.standard_normal((12, 40)))
    cx = jnp.asarray(g.standard_normal(3))
    cy = jnp.zeros(2)
    got = K.stencil2d_interior(x, cx, cy)
    rows = [R.stencil1d_ref(x[j], cx)[1:-1] for j in range(1, 11)]
    np.testing.assert_allclose(np.asarray(got), np.stack(rows), rtol=1e-12)


# ---------------------------------------------------------------------------
# VMEM sizing knobs
# ---------------------------------------------------------------------------


def test_choose_block_fits_budget():
    bh, bw = K.choose_block_2d(425, 936, 12, 12, 8)
    assert K.vmem_bytes_2d(bh, bw, 12, 12, 8) <= K.VMEM_BUDGET_BYTES
    assert 1 <= bh <= 425 and 1 <= bw <= 936


def test_choose_block_prefers_full_width_when_it_fits():
    bh, bw = K.choose_block_2d(62, 62, 1, 1, 8)
    assert bw == 62  # row streaming, no strip mining needed


def test_vmem_bytes_monotone_in_radius():
    a = K.vmem_bytes_2d(8, 128, 1, 1, 8)
    b = K.vmem_bytes_2d(8, 128, 12, 12, 8)
    assert b > a
