"""L2 model semantics: full-grid boundary handling, heat diffusion physics,
temporal fusion, and jit-lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref as R


def rng(seed):
    return np.random.default_rng(seed)


def test_stencil2d_full_grid_boundary_ring():
    g = rng(5)
    x = jnp.asarray(g.standard_normal((20, 30)))
    cx = jnp.asarray(g.standard_normal(5))  # rx=2
    cy = jnp.asarray(g.standard_normal(2))  # ry=1
    out = model.stencil2d(x, cx, cy)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(out[-1]), np.asarray(x[-1]))
    np.testing.assert_array_equal(np.asarray(out[:, :2]), np.asarray(x[:, :2]))
    np.testing.assert_array_equal(np.asarray(out[:, -2:]), np.asarray(x[:, -2:]))


def test_heat2d_step_matches_physics():
    """out = (1-4a)c + a(n+s+e+w) for an interior point."""
    g = rng(9)
    x = jnp.asarray(g.standard_normal((8, 8)))
    a = 0.2
    out = model.heat2d_step(x, a)
    j, i = 3, 4
    want = (1 - 4 * a) * x[j, i] + a * (x[j - 1, i] + x[j + 1, i] + x[j, i - 1] + x[j, i + 1])
    assert abs(float(out[j, i]) - float(want)) < 1e-12


def test_heat2d_conserves_with_uniform_field():
    x = jnp.full((16, 16), 3.5)
    out = model.heat2d_run(x, 10, 0.2)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-12)


def test_heat2d_hotspot_diffuses_and_is_stable():
    x = jnp.zeros((32, 32)).at[16, 16].set(100.0)
    out = model.heat2d_run(x, 50, 0.2)
    o = np.asarray(out)
    assert o[16, 16] < 100.0  # peak decays
    assert o.max() <= 100.0 + 1e-9  # maximum principle (stable alpha)
    assert o[12, 16] > 0.0  # heat spread


def test_heat2d_run_equals_iterated_steps():
    g = rng(21)
    x = jnp.asarray(g.standard_normal((12, 12)))
    fused = model.heat2d_run(x, 5, 0.2)
    step = x
    for _ in range(5):
        step = model.heat2d_step(step, 0.2)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(step), rtol=1e-12)


def test_heat2d_residual_decreases():
    x = jnp.zeros((24, 24)).at[12, 12].set(1.0)
    _, r10 = model.heat2d_run_with_residual(x, 10, 0.2)
    _, r100 = model.heat2d_run_with_residual(x, 100, 0.2)
    assert float(r100) < float(r10)


def test_model_matches_pure_ref_full_grid():
    g = rng(33)
    x = jnp.asarray(g.standard_normal((40, 40)))
    cx = jnp.asarray(g.standard_normal(7))
    cy = jnp.asarray(g.standard_normal(6))
    got = model.stencil2d(x, cx, cy)
    want = R.stencil2d_ref(x, cx, cy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize(
    "fn,specs",
    [
        (
            model.stencil1d,
            [jax.ShapeDtypeStruct((128,), jnp.float64), jax.ShapeDtypeStruct((17,), jnp.float64)],
        ),
        (
            model.stencil2d,
            [
                jax.ShapeDtypeStruct((48, 48), jnp.float64),
                jax.ShapeDtypeStruct((25,), jnp.float64),
                jax.ShapeDtypeStruct((24,), jnp.float64),
            ],
        ),
        (lambda x: model.heat2d_run(x, 3, 0.2), [jax.ShapeDtypeStruct((16, 16), jnp.float64)]),
    ],
)
def test_jit_lowers(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    assert lowered.compiler_ir("stablehlo") is not None
