//! Ablations of the design choices DESIGN.md calls out:
//!
//! * **A. worker count** — measured GFLOPS vs the §VI roofline
//!   prediction across `w` (the crossover from compute-starved to
//!   bandwidth-saturated that justifies "6 workers is enough").
//! * **B. mandatory buffering slack** — queue capacity multiplier vs
//!   cycles (undersizing throttles; §III-B).
//! * **C. tile count** — halo re-read overhead vs parallelism when
//!   decomposing for multi-tile execution (§III-B blocking generalized
//!   to N-dim tiles).
//! * **D. temporal depth** — §IV pipeline across 1-D/2-D/3-D
//!   (`temporal::build_nd`): steps computed per memory round-trip vs
//!   achieved FLOPs per DRAM byte; records `BENCH_temporal.json` for
//!   trend tracking (CI uploads it as an artifact).
//! * **E. decomposition kind** — slab vs pencil vs block cuts of a 3-D
//!   volume on 16 tiles: tasks, makespan, halo overhead.
//!
//! Run: `cargo bench --bench ablation_workers`
//! Short mode (CI): `BENCH_QUICK=1 cargo bench --bench ablation_workers`
//! runs only the §D depth sweep on shrunken grids (1 iteration) and
//! still writes `BENCH_temporal.json`.

use std::sync::Arc;

use stencil_cgra::cgra::{Machine, Simulator};
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::session::{RunReport, Session};
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{map1d, temporal, StencilSpec};
use stencil_cgra::util::bench;
use stencil_cgra::verify::golden::run_sim;

/// Compile once + execute once — the bench-side stand-in for the old
/// one-call coordinator.
fn run_once(spec: &StencilSpec, opts: &CompileOptions, x: &[f64]) -> RunReport {
    let compiled = Arc::new(compile(spec, 1, opts).unwrap());
    let machine = opts.machine.clone();
    let mut outcome = Session::new(compiled, machine).run(x).unwrap();
    outcome.reports.remove(0)
}

fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

/// §D: fused-depth sweep across dimensionalities, with machine-readable
/// records (`BENCH_temporal.json`).
fn temporal_depth_sweep(m: &Machine) {
    bench::section("D. temporal-depth ablation — §IV fused pipelines (1-D/2-D/3-D)");
    let mut sink = bench::JsonSink::new();
    let (warmup, iters) = if quick() { (0usize, 1usize) } else { (1, 3) };
    let depths = [1usize, 2, 4, 8];
    let cases: Vec<(&str, StencilSpec, usize)> = if quick() {
        vec![
            (
                "1d_3pt_n4000",
                StencilSpec::dim1(4_000, vec![0.25, 0.5, 0.25]).unwrap(),
                3,
            ),
            ("2d_heat_40x28", StencilSpec::heat2d(40, 28, 0.2), 3),
            ("3d_heat_16x14x12", StencilSpec::heat3d(16, 14, 12, 0.1), 2),
        ]
    } else {
        vec![
            (
                "1d_3pt_n20000",
                StencilSpec::dim1(20_000, vec![0.25, 0.5, 0.25]).unwrap(),
                3,
            ),
            ("2d_heat_64x48", StencilSpec::heat2d(64, 48, 0.2), 4),
            ("3d_heat_24x20x16", StencilSpec::heat3d(24, 20, 16, 0.1), 2),
        ]
    };
    for (name, spec, w) in &cases {
        let x = vec![1.0; spec.grid_points()];
        // Deepest depth the grid's trapezoid admits.
        let cap = spec
            .dims()
            .iter()
            .zip(spec.radii())
            .map(|(n, r)| (n - 1) / (2 * r))
            .min()
            .unwrap();
        println!(
            "\n{name}: {:>6} {:>10} {:>10} {:>12} {:>10}",
            "steps", "cycles", "loads", "flops/byte", "GFLOPS"
        );
        for &steps in &depths {
            if steps > cap {
                println!("  T{steps}: exceeds the grid trapezoid (cap {cap}); skipped");
                continue;
            }
            let flops = temporal::total_flops(spec, steps);
            let mut cycles = 0u64;
            let mut loads = 0u64;
            let mut bytes = 0f64;
            let case = format!("{name}/T{steps}");
            let stats = bench::run(&case, warmup, iters, || {
                let g = temporal::build_nd(spec, *w, steps).unwrap();
                let res = Simulator::build(g, m, x.clone(), x.clone())
                    .unwrap()
                    .run()
                    .unwrap();
                cycles = res.stats.cycles;
                loads = res.stats.mem.loads;
                bytes = res.stats.mem.total_dram_bytes() as f64;
            });
            let gflops = flops * m.clock_ghz / cycles as f64;
            println!(
                "{steps:>6} {cycles:>10} {loads:>10} {:>12.2} {gflops:>10.1}",
                flops / bytes
            );
            sink.record(
                &stats,
                &[
                    ("steps", steps as f64),
                    ("cycles", cycles as f64),
                    ("loads", loads as f64),
                    ("dram_bytes", bytes),
                    ("flops_per_byte", flops / bytes),
                    ("gflops", gflops),
                ],
            );
        }
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_temporal.json");
    sink.write(path).expect("writing BENCH_temporal.json");
}

fn main() {
    let m = Machine::paper();

    if !quick() {
        bench::section("A. worker-count sweep — 1D 17-pt, n=40000");
        let spec1 = StencilSpec::dim1(40_000, symmetric_taps(8)).unwrap();
        let x1 = vec![1.0; 40_000];
        println!(
            "{:>3} {:>10} {:>10} {:>10} {:>7}",
            "w", "cycles", "GFLOPS", "predicted", "ratio"
        );
        for w in 1..=8 {
            let res = run_sim(&spec1, w, &m, &x1).unwrap();
            let g = res.gflops(spec1.total_flops(), m.clock_ghz);
            // Prediction: min(worker demand, bandwidth roof).
            let pred = (w as f64 * spec1.flops_per_output() * m.clock_ghz)
                .min(m.roofline_gflops(spec1.arithmetic_intensity()));
            println!(
                "{w:>3} {:>10} {:>10.1} {:>10.1} {:>6.0}%",
                res.stats.cycles,
                g,
                pred,
                100.0 * g / pred
            );
        }

        bench::section("A'. worker-count sweep — 2D 49-pt, 240x113");
        let spec2 = StencilSpec::dim2(240, 113, symmetric_taps(12), y_taps(12)).unwrap();
        let x2 = vec![1.0; spec2.grid_points()];
        println!("{:>3} {:>10} {:>10} {:>10}", "w", "cycles", "GFLOPS", "predicted");
        for w in 1..=5 {
            let res = run_sim(&spec2, w, &m, &x2).unwrap();
            let g = res.gflops(spec2.total_flops(), m.clock_ghz);
            let pred = (w as f64 * spec2.flops_per_output() * m.clock_ghz)
                .min(m.roofline_gflops(spec2.arithmetic_intensity()));
            println!("{w:>3} {:>10} {:>10.1} {:>10.1}", res.stats.cycles, g, pred);
        }

        bench::section("B. buffering-slack ablation — 1D 17-pt, n=20000, w=6");
        let spec = StencilSpec::dim1(20_000, symmetric_taps(8)).unwrap();
        let x = vec![1.0; 20_000];
        println!("{:>12} {:>10} {:>9}", "cap scale", "cycles", "status");
        for (label, scale_num, scale_den) in
            [("2.0x", 2usize, 1usize), ("1.0x", 1, 1), ("0.5x", 1, 2), ("0.25x", 1, 4)]
        {
            let mut g = map1d::build(&spec, 6).unwrap();
            for ch in &mut g.channels {
                ch.capacity = (ch.capacity * scale_num / scale_den).max(1);
            }
            match Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .run()
            {
                Ok(res) => println!("{label:>12} {:>10} {:>9}", res.stats.cycles, "ok"),
                Err(_) => println!("{label:>12} {:>10} {:>9}", "-", "deadlock/slow"),
            }
        }

        bench::section("C. tile-count ablation — 2D 49-pt on 16 tiles (960x449)");
        let spec = StencilSpec::paper_2d();
        let x = vec![1.0; spec.grid_points()];
        println!(
            "{:>7} {:>7} {:>12} {:>10} {:>12}",
            "tiles", "tasks", "makespan", "GFLOPS", "extra reads"
        );
        let base_reads = (spec.grid_points() * 8) as f64;
        for tiles in [1usize, 2, 4, 8, 16, 32] {
            let opts = CompileOptions::default()
                .with_machine(m.clone())
                .with_workers(5)
                .with_tiles(tiles);
            let rep = run_once(&spec, &opts, &x);
            let reads: u64 = rep.per_tile.iter().map(|t| t.mem.dram_read_bytes).sum();
            println!(
                "{tiles:>7} {:>7} {:>12} {:>10.0} {:>11.1}%",
                rep.strips,
                rep.makespan_cycles,
                rep.gflops,
                100.0 * (reads as f64 - base_reads) / base_reads
            );
        }
    }

    temporal_depth_sweep(&m);

    if !quick() {
        bench::section("E. decomposition-kind ablation — 3D 13-pt on 16 tiles (40x24x16)");
        let spec = StencilSpec::dim3(40, 24, 16, symmetric_taps(2), y_taps(2), z_taps(2))
            .unwrap();
        let x = vec![1.0; spec.grid_points()];
        println!(
            "{:>8} {:>7} {:>10} {:>12} {:>10} {:>12}",
            "kind", "tasks", "cuts", "makespan", "GFLOPS", "halo reads"
        );
        for kind in [DecompKind::Slab, DecompKind::Pencil, DecompKind::Block] {
            let opts = CompileOptions::paper()
                .with_machine(m.clone())
                .with_workers(3)
                .with_decomp(kind);
            let rep = run_once(&spec, &opts, &x);
            let cuts = format!("{}x{}x{}", rep.cuts[0], rep.cuts[1], rep.cuts[2]);
            println!(
                "{kind:>8} {:>7} {cuts:>10} {:>12} {:>10.0} {:>11.1}%",
                rep.strips,
                rep.makespan_cycles,
                rep.gflops,
                100.0 * rep.redundant_read_fraction
            );
        }
    }
}
