//! Regenerates **Fig 12** — the roofline model for stencil1D and
//! stencil2D on the §VI CGRA (614 GFLOPS compute roof, 100 GB/s) — and
//! the §VI worker-sizing table, with measured simulator points overlaid.
//!
//! Run: `cargo bench --bench fig12_roofline`

use stencil_cgra::cgra::Machine;
use stencil_cgra::roofline;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::bench;
use stencil_cgra::verify::golden::run_sim;

fn main() {
    let m = Machine::paper();

    bench::section("Fig 12 — roofline curve (AI vs attainable GFLOPS)");
    println!("{:>10} {:>12}", "flops/byte", "GFLOPS");
    for (ai, gf) in roofline::roofline_series(&m, 0.25, 32.0, 22) {
        println!("{ai:>10.3} {gf:>12.1}");
    }

    bench::section("§VI analysis points");
    println!(
        "{:<12} {:>6} {:>9} {:>9} {:>9} {:>9} {:>3} {:>6}",
        "stencil", "AI", "bw-roof", "peak", "attain", "demand", "w", "w_max"
    );
    for (name, spec) in [
        ("stencil1D", StencilSpec::paper_1d()),
        ("stencil2D", StencilSpec::paper_2d()),
    ] {
        let w = roofline::optimal_workers(&spec, &m);
        let a = roofline::analyze(&spec, &m, w);
        println!(
            "{:<12} {:>6.2} {:>9.0} {:>9.0} {:>9.0} {:>9.0} {:>3} {:>6}",
            name,
            a.arithmetic_intensity,
            a.bw_gflops,
            a.peak_gflops,
            a.attainable_gflops,
            a.demand_gflops,
            a.workers,
            a.max_workers
        );
    }
    println!("(paper: 1D AI 2.06 -> 206 GFLOPS, 6 workers / 237 demand;");
    println!("        2D AI 5.59 -> 559 GFLOPS, 5 workers / 582 demand)");

    bench::section("measured simulator points vs roofline (scaled grids)");
    println!(
        "{:<26} {:>10} {:>10} {:>7}",
        "workload", "attainable", "measured", "ratio"
    );
    for (name, spec, w) in [
        (
            "1D 17-pt (n=40000)",
            StencilSpec::dim1(40000, symmetric_taps(8)).unwrap(),
            6usize,
        ),
        (
            "2D 49-pt (240x113)",
            StencilSpec::dim2(240, 113, symmetric_taps(12), y_taps(12)).unwrap(),
            5,
        ),
        (
            "2D 5-pt heat (128x128)",
            StencilSpec::heat2d(128, 128, 0.2),
            5,
        ),
    ] {
        let x = vec![1.0; spec.grid_points()];
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let g = res.gflops(spec.total_flops(), m.clock_ghz);
        let roof = m.roofline_gflops(spec.arithmetic_intensity());
        println!(
            "{:<26} {:>10.1} {:>10.1} {:>6.0}%",
            name,
            roof,
            g,
            100.0 * g / roof
        );
    }
}
