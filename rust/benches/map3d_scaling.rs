//! Worker-count scaling of the 3-D plane-buffered mapping (`map3d`) on
//! star and box workloads: measured GFLOPS vs the §VI roofline
//! prediction, plus the mandatory plane-buffering footprint per
//! configuration.
//!
//! Run: `cargo bench --bench map3d_scaling`

use stencil_cgra::cgra::Machine;
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::{map3d, StencilSpec};
use stencil_cgra::util::bench;
use stencil_cgra::verify::golden::run_sim;

fn sweep(name: &str, spec: &StencilSpec, m: &Machine, max_w: usize) {
    bench::section(name);
    println!(
        "{:>3} {:>10} {:>10} {:>10} {:>7} {:>12} {:>8}",
        "w", "cycles", "GFLOPS", "predicted", "ratio", "buf tokens", "stages"
    );
    let x = vec![1.0; spec.grid_points()];
    for w in 1..=max_w {
        let res = run_sim(spec, w, m, &x).unwrap();
        let g = res.gflops(spec.total_flops(), m.clock_ghz);
        let pred = (w as f64 * spec.flops_per_output() * m.clock_ghz)
            .min(m.roofline_gflops(spec.arithmetic_intensity()));
        println!(
            "{w:>3} {:>10} {:>10.1} {:>10.1} {:>6.0}% {:>12} {:>8}",
            res.stats.cycles,
            g,
            pred,
            100.0 * g / pred,
            map3d::required_buffer_tokens(spec, w),
            map3d::delay_stages(spec, w),
        );
    }
}

fn main() {
    let m = Machine::paper();

    let star = StencilSpec::dim3(40, 24, 12, symmetric_taps(2), y_taps(2), z_taps(2))
        .unwrap();
    sweep("3-D 13-pt star, 40x24x12", &star, &m, 4);

    let heat = StencilSpec::heat3d(32, 24, 16, 0.1);
    sweep("3-D 7-pt heat, 32x24x16", &heat, &m, 4);

    let boxed =
        StencilSpec::box3d(24, 16, 10, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
    sweep("3-D 27-pt box, 24x16x10", &boxed, &m, 3);
}
