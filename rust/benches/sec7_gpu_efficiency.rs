//! Regenerates the **§VII GPU numbers**: the SMEM vs register-caching
//! kernel comparison (1900 vs 2300 GFLOPS) and the efficiency-vs-radius
//! decay series ("with the increase of arithmetic intensity ... the
//! efficiency of the stencil dropped on V100").
//!
//! Run: `cargo bench --bench sec7_gpu_efficiency`

use stencil_cgra::gpu_model::{GpuStencil, Precision, V100};
use stencil_cgra::util::bench;

fn main() {
    let v = V100::paper();

    bench::section("§VII anchors — model vs paper");
    println!(
        "{:<34} {:>9} {:>10} {:>10} {:>7} {:>17}",
        "stencil", "roofline", "smem", "regcache", "eff", "paper"
    );
    let rows: Vec<(&str, GpuStencil, &str)> = vec![
        (
            "2D rx=ry=12 960x449 dp",
            GpuStencil::d2(960, 449, 12, 12, Precision::F64),
            "48% (2300/4800)",
        ),
        (
            "1D rx=8 194400 dp",
            GpuStencil::d1(194400, 8, Precision::F64),
            "90%",
        ),
        (
            "2D rx=ry=2 960x449 dp",
            GpuStencil::d2(960, 449, 2, 2, Precision::F64),
            "87%",
        ),
        (
            "3D r=4 384x384x128 sp",
            GpuStencil::d3([384, 384, 128], 4, Precision::F32),
            "77%",
        ),
        (
            "3D r=4 384x384x128 dp",
            GpuStencil::d3([384, 384, 128], 4, Precision::F64),
            "80%",
        ),
        (
            "3D r=8 384^3 sp",
            GpuStencil::d3([384, 384, 384], 8, Precision::F32),
            "56%",
        ),
        (
            "3D r=12 512^3 sp",
            GpuStencil::d3([512, 512, 512], 12, Precision::F32),
            "36%",
        ),
    ];
    for (name, s, paper) in rows {
        println!(
            "{:<34} {:>9.0} {:>10.0} {:>10.0} {:>6.0}% {:>17}",
            name,
            v.roofline_gflops(&s),
            v.smem_gflops(&s),
            v.regcache_gflops(&s),
            100.0 * v.regcache_efficiency(&s),
            paper
        );
    }

    bench::section("efficiency vs radius (2D dp, 960x449) — the §VII decay");
    println!(
        "{:>4} {:>6} {:>9} {:>6} {:>7} {:>12}",
        "r", "taps", "regs/thr", "warps", "eff", "GFLOPS"
    );
    for r in [1usize, 2, 4, 6, 8, 10, 12, 14, 16] {
        let s = GpuStencil::d2(960, 449, r, r, Precision::F64);
        let o = v.occupancy(&s);
        println!(
            "{:>4} {:>6} {:>9} {:>6} {:>6.0}% {:>12.0}",
            r,
            s.taps(),
            o.regs_per_thread,
            o.warps,
            100.0 * v.regcache_efficiency(&s),
            v.regcache_gflops(&s)
        );
    }

    bench::section("SMEM kernel occupancy walls (§VII narrative)");
    let s = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
    let o = v.occupancy(&s);
    println!(
        "2D r=12 dp: {} regs/thread -> {} warps (reg limit), {} warps (smem limit), smem/block {}B",
        o.regs_per_thread, o.warps_reg, o.warps_smem, o.smem_per_block_bytes
    );
    println!(
        "smem-latency hiding needs ~25 warps -> efficiency {:.0}%",
        100.0 * v.regcache_efficiency(&s)
    );
}
