//! §Perf — simulator hot-path throughput (the L3 optimization target).
//!
//! Runs every workload on **both scheduler cores** (`dense` reference
//! loop vs the default `event` ready list) and reports simulated
//! Mcycles/s plus the event core's skipped-cycle/wakeup accounting, so
//! each row is simultaneously a perf measurement and a bit-identity
//! check (outputs, cycles and memory stats are asserted equal).
//! `EXPERIMENTS.md` §Perf records the before/after trajectory; the same
//! numbers are written to `BENCH_sim.json` for machines (CI uploads it
//! as an artifact on every push). The halo-exchange section sweeps the
//! same compiled workload through `--halo reload`, `--halo
//! exchange-free` and the hop-priced `--halo exchange` (all three
//! bitwise-asserted equal) and writes the DRAM-traffic and hop-latency
//! differentials to `BENCH_exchange.json` for `EXPERIMENTS.md`
//! §Exchange. The trace
//! section records a session run, replays it on the other scheduler
//! core (cycle counts asserted equal record-for-record) and writes
//! `BENCH_replay.json`. The fault section runs the same workload
//! fault-free, under an unarmed plan (asserted cycle- and bit-identical
//! to fault-free — arming is the only cost) and under an armed plan
//! (values still bit-identical; the makespan inflation and retry count
//! are the measured overhead), writing `BENCH_fault.json` for
//! `EXPERIMENTS.md` §Faults.
//!
//! Timed region: `Simulator::from_placed` + the cycle loop — placement
//! runs once outside, matching the compile-once/execute-many split.
//!
//! Run: `cargo bench --bench sim_hotpath`
//! Short mode (CI): `BENCH_QUICK=1 cargo bench --bench sim_hotpath`
//! (1 iteration, no warmup — regression visibility, not statistics).

use std::sync::Arc;

use stencil_cgra::cgra::channel::Fifo;
use stencil_cgra::cgra::{Machine, PlacedGraph, SimCore, Simulator, Token};
use stencil_cgra::compile::{compile, CompileOptions, FuseMode, HaloMode};
use stencil_cgra::session::Session;
use stencil_cgra::util::trace::Trace;
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{build_graph, StencilSpec};
use stencil_cgra::util::bench;
use stencil_cgra::FaultPlan;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

struct CoreRun {
    mean_s: f64,
    cycles: u64,
    output_sum: f64,
}

/// Time one (workload, core) pair; returns the stats it also records.
#[allow(clippy::too_many_arguments)]
fn time_core(
    name: &str,
    spec: &StencilSpec,
    w: usize,
    m: &Machine,
    x: &[f64],
    core: SimCore,
    iters: usize,
    sink: &mut bench::JsonSink,
) -> CoreRun {
    let warmup = if quick() { 0 } else { 1 };
    // Validation + placement run once, outside the timed region — in
    // the two-phase API the compile phase owns them. The loop times the
    // execute-many path only: per-run state carving
    // (`Simulator::from_placed`) plus the cycle loop, which is the hot
    // path `Session::run` repeats per tile task.
    let pg = Arc::new(PlacedGraph::new(build_graph(spec, w).unwrap(), m).unwrap());
    let nodes = pg.node_count();
    let mut cycles = 0u64;
    let mut fires = 0u64;
    let mut skipped = 0u64;
    let mut wakeups = 0u64;
    let mut output_sum = 0.0f64;
    let case = format!("{name}/{core}");
    let stats = bench::run(&case, warmup, iters, || {
        let res = Simulator::from_placed(&pg, m, x.to_vec(), x.to_vec())
            .with_core(core)
            .run()
            .unwrap();
        cycles = res.stats.cycles;
        fires = res.stats.total_fires();
        skipped = res.stats.skipped_cycles;
        wakeups = res.stats.wakeups;
        output_sum = res.output.iter().sum();
    });
    let mcycles_s = cycles as f64 / stats.mean_s / 1e6;
    let pe_steps = cycles as f64 * nodes as f64;
    println!(
        "  -> {} nodes, {} cycles ({} skipped), {} fires, {} wakeups: \
         {:.1} Mcycles/s, {:.1} M PE-steps/s equivalent",
        nodes,
        cycles,
        skipped,
        fires,
        wakeups,
        mcycles_s,
        pe_steps / stats.mean_s / 1e6,
    );
    sink.record(
        &stats,
        &[
            ("cycles", cycles as f64),
            ("nodes", nodes as f64),
            ("fires", fires as f64),
            ("skipped_cycles", skipped as f64),
            ("wakeups", wakeups as f64),
            ("mcycles_per_s", mcycles_s),
        ],
    );
    CoreRun {
        mean_s: stats.mean_s,
        cycles,
        output_sum,
    }
}

fn sim_throughput(
    name: &str,
    spec: &StencilSpec,
    w: usize,
    m: &Machine,
    iters: usize,
    sink: &mut bench::JsonSink,
) {
    let x = vec![1.0; spec.grid_points()];
    let iters = if quick() { 1 } else { iters };
    let dense = time_core(name, spec, w, m, &x, SimCore::Dense, iters, sink);
    let event = time_core(name, spec, w, m, &x, SimCore::Event, iters, sink);
    assert_eq!(
        dense.cycles, event.cycles,
        "{name}: cores disagree on cycle count"
    );
    assert_eq!(
        dense.output_sum.to_bits(),
        event.output_sum.to_bits(),
        "{name}: cores disagree on output"
    );
    println!(
        "  == event/dense speedup: {:.2}x  (Mcycles/s {:.1} -> {:.1})",
        dense.mean_s / event.mean_s,
        dense.cycles as f64 / dense.mean_s / 1e6,
        event.cycles as f64 / event.mean_s / 1e6,
    );
}

struct HaloRun {
    mean_s: f64,
    dram_reads: u64,
    makespan: u64,
    hop_cycles: u64,
    output: Vec<f64>,
}

/// Execute one compiled workload under `halo`, timing `Session::run`
/// only (compilation happens once, outside the loop — the
/// execute-many path is what exchange accelerates).
fn time_halo(
    name: &str,
    spec: &StencilSpec,
    steps: usize,
    base: &CompileOptions,
    halo: HaloMode,
    sink: &mut bench::JsonSink,
) -> HaloRun {
    let x = vec![1.0; spec.grid_points()];
    let compiled = Arc::new(compile(spec, steps, &base.clone().with_halo(halo)).unwrap());
    let machine = compiled.options.machine.clone();
    let session = Session::new(compiled, machine);
    let (iters, warmup) = if quick() { (1, 0) } else { (3, 1) };
    let mut dram = 0u64;
    let mut exchanged = 0u64;
    let mut makespan = 0u64;
    let mut hop_cycles = 0u64;
    let mut frac = 0.0f64;
    let mut output = Vec::new();
    let case = format!("{name}/{halo}");
    let stats = bench::run(&case, warmup, iters, || {
        let out = session.run(&x).unwrap();
        dram = out.reports.iter().map(|r| r.dram_point_reads()).sum();
        exchanged = out.reports.iter().map(|r| r.exchanged_points).sum();
        makespan = out.reports.iter().map(|r| r.makespan_cycles).sum();
        hop_cycles = out.reports.iter().map(|r| r.exchanged_hop_cycles()).sum();
        frac = out.final_report().redundant_read_fraction;
        output = out.output;
    });
    println!(
        "  -> {} sim cycles, {} DRAM point reads, {} exchanged points \
         (+{} hop cyc), final-chunk redundancy {:.4}",
        makespan, dram, exchanged, hop_cycles, frac
    );
    sink.record(
        &stats,
        &[
            ("sim_cycles", makespan as f64),
            ("dram_point_reads", dram as f64),
            ("exchanged_points", exchanged as f64),
            ("exchanged_hop_cycles", hop_cycles as f64),
            ("redundant_read_fraction_last", frac),
        ],
    );
    HaloRun {
        mean_s: stats.mean_s,
        dram_reads: dram,
        makespan,
        hop_cycles,
        output,
    }
}

/// §Exchange — the halo-movement sweep on one compiled workload:
/// reload, free exchange and hop-priced exchange, outputs asserted
/// bitwise equal across all three. Reload vs exchange measures the
/// steady-state DRAM-traffic differential; priced vs free isolates the
/// latency the hop/bandwidth channel model adds on the same shipped
/// points.
fn halo_exchange_bench(
    name: &str,
    spec: &StencilSpec,
    steps: usize,
    base: &CompileOptions,
    sink: &mut bench::JsonSink,
) {
    let reload = time_halo(name, spec, steps, base, HaloMode::Reload, sink);
    let free = time_halo(name, spec, steps, base, HaloMode::ExchangeFree, sink);
    let exchange = time_halo(name, spec, steps, base, HaloMode::Exchange, sink);
    assert_eq!(
        reload.output, exchange.output,
        "{name}: exchange must be bitwise-identical to reload"
    );
    assert_eq!(
        free.output, exchange.output,
        "{name}: pricing must be bitwise-identical to free exchange"
    );
    assert_eq!(free.hop_cycles, 0, "{name}: free exchange paid hops");
    assert!(
        exchange.hop_cycles > 0,
        "{name}: priced exchange paid no hops"
    );
    assert!(
        exchange.makespan >= free.makespan,
        "{name}: hop pricing made the run faster"
    );
    println!(
        "  == DRAM point reads {} -> {} ({:.1}% saved); hop pricing: \
         {} -> {} sim cycles (+{:.2}%, {} hop cyc); \
         wall {:.3}s / {:.3}s / {:.3}s",
        reload.dram_reads,
        exchange.dram_reads,
        100.0 * (1.0 - exchange.dram_reads as f64 / reload.dram_reads.max(1) as f64),
        free.makespan,
        exchange.makespan,
        100.0 * (exchange.makespan as f64 / free.makespan.max(1) as f64 - 1.0),
        exchange.hop_cycles,
        reload.mean_s,
        free.mean_s,
        exchange.mean_s,
    );
}

fn main() {
    let mut sink = bench::JsonSink::new();
    let m = Machine::paper();

    bench::section("simulator end-to-end throughput (dense vs event)");
    sim_throughput(
        "2d_49pt_240x113_w5",
        &StencilSpec::dim2(240, 113, symmetric_taps(12), y_taps(12)).unwrap(),
        5,
        &m,
        5,
        &mut sink,
    );
    sim_throughput(
        "2d_49pt_table1_960x449_w5",
        &StencilSpec::paper_2d(),
        5,
        &m,
        3,
        &mut sink,
    );
    sim_throughput(
        "2d_heat_128x128_w5",
        &StencilSpec::heat2d(128, 128, 0.2),
        5,
        &m,
        5,
        &mut sink,
    );
    // Latency-/bandwidth-starved machine: the fabric idles most cycles
    // waiting on DRAM, which is where cycle skipping pays hardest (deep
    // 3-D fabrics and multi-tile pencil tails behave the same way).
    let starved = Machine {
        bw_gbps: 5.0,
        dram_latency: 400,
        ..Machine::paper()
    };
    sim_throughput(
        "2d_heat_96x96_w4_bw5_lat400",
        &StencilSpec::heat2d(96, 96, 0.2),
        4,
        &starved,
        3,
        &mut sink,
    );

    bench::section("halo exchange vs reload (steady-state DRAM traffic)");
    let mut xsink = bench::JsonSink::new();
    // ny = 16 caps the trapezoid at depth 7, so 8 steps always split
    // into at least two chunks — a warm chunk exists to exchange into.
    halo_exchange_bench(
        "2d_heat_96x16_t4_spatial_s8",
        &StencilSpec::heat2d(96, 16, 0.2),
        8,
        &CompileOptions::default()
            .with_workers(4)
            .with_tiles(4)
            .with_fuse(FuseMode::Spatial),
        &mut xsink,
    );
    halo_exchange_bench(
        "3d_acoustic_16tile_pencil_s4",
        &StencilSpec::dim3(16, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2)).unwrap(),
        4,
        &CompileOptions::default()
            .with_workers(2)
            .with_tiles(16)
            .with_decomp(DecompKind::Pencil)
            .with_fuse(FuseMode::Host),
        &mut xsink,
    );
    let xpath = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_exchange.json");
    xsink.write(xpath).expect("writing BENCH_exchange.json");

    bench::section("channel microbench");
    let (mut f, mut arena) = Fifo::standalone(64, 1);
    let tok = Token::new(1.0, 0, 0);
    let stats = bench::run("fifo_push_pop_1M", 2, 10, || {
        for i in 0..1_000_000u64 {
            if f.can_push() {
                f.push(&mut arena, tok, i);
            }
            bench::black_box(f.pop(&mut arena, i + 2));
        }
    });
    println!("  -> {:.1} M push+pop/s", 1.0 / stats.mean_s);
    sink.record(&stats, &[("ops", 2e6)]);

    bench::section("memory-arbiter microbench");
    let stats = bench::run("mem_100k_loads", 2, 10, || {
        let mut mem = stencil_cgra::cgra::memory::MemSys::new(
            &m,
            vec![1.0; 100_000],
            vec![0.0; 100_000],
        );
        for i in 0..100_000u64 {
            let (_, _t) = mem.load(i % 100_000, i);
            mem.step(i);
        }
        bench::black_box(&mem);
    });
    println!("  -> {:.2} M loads/s", 0.1 / stats.mean_s);
    sink.record(&stats, &[("loads", 1e5)]);

    bench::section("deterministic trace record/replay");
    let mut rsink = bench::JsonSink::new();
    {
        let spec = StencilSpec::heat2d(96, 32, 0.2);
        let compiled = Arc::new(
            compile(
                &spec,
                2,
                &CompileOptions::default().with_workers(4).with_tiles(2),
            )
            .unwrap(),
        );
        let machine = compiled.options.machine.clone();
        let x = vec![1.0; spec.grid_points()];
        let session = Session::new(compiled, machine);
        let (iters, warmup) = if quick() { (1, 0) } else { (3, 1) };
        let mut trace = Trace::default();
        let rec = bench::run("2d_heat_96x32_t2_s2/record", warmup, iters, || {
            let (_, t) = session.run_recorded(&x).unwrap();
            trace = t;
        });
        let rec_cycles: u64 = trace.records.iter().map(|r| r.cycles).sum();
        rsink.record(
            &rec,
            &[
                ("records", trace.records.len() as f64),
                ("total_cycles", rec_cycles as f64),
            ],
        );
        // Replay on the *other* core: `Trace::matches` pins cycles,
        // fires, tickets and both hashes per tile task, so a clean
        // replay IS the record-vs-replay cycle-count assertion — and
        // running it under the dense core pins the cross-core property.
        let dense = session.clone().with_sim_core(SimCore::Dense);
        let rep = bench::run("2d_heat_96x32_t2_s2/replay_dense", warmup, iters, || {
            dense.run_replay(&x, &trace).unwrap();
        });
        let (_, dense_trace) = dense.run_recorded(&x).unwrap();
        let dense_cycles: u64 = dense_trace.records.iter().map(|r| r.cycles).sum();
        assert_eq!(
            rec_cycles, dense_cycles,
            "record-then-replay cycle counts diverged across cores"
        );
        rsink.record(
            &rep,
            &[
                ("records", dense_trace.records.len() as f64),
                ("total_cycles", dense_cycles as f64),
            ],
        );
        println!(
            "  == {} records, {} total task cycles, replay clean across cores",
            trace.records.len(),
            rec_cycles
        );
    }
    let rpath = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_replay.json");
    rsink.write(rpath).expect("writing BENCH_replay.json");

    bench::section("fault injection overhead (unarmed must be free)");
    let mut fsink = bench::JsonSink::new();
    {
        let spec = StencilSpec::heat2d(96, 32, 0.2);
        let compiled = Arc::new(
            compile(
                &spec,
                2,
                &CompileOptions::default().with_workers(4).with_tiles(2),
            )
            .unwrap(),
        );
        let machine = compiled.options.machine.clone();
        let x = vec![1.0; spec.grid_points()];
        let (iters, warmup) = if quick() { (1, 0) } else { (5, 1) };

        struct FaultRun {
            mean_s: f64,
            makespan: u64,
            retries: u64,
            output: Vec<f64>,
        }
        let mut run_case = |label: &str,
                            plan: Option<FaultPlan>,
                            fsink: &mut bench::JsonSink|
         -> FaultRun {
            let session =
                Session::new(Arc::clone(&compiled), machine.clone()).with_fault_plan(plan);
            let mut makespan = 0u64;
            let mut retries = 0u64;
            let mut output = Vec::new();
            let stats = bench::run(
                &format!("2d_heat_96x32_t2_s2/{label}"),
                warmup,
                iters,
                || {
                    let out = session.run(&x).unwrap();
                    makespan = out.reports.iter().map(|r| r.makespan_cycles).sum();
                    retries = out
                        .reports
                        .iter()
                        .map(|r| {
                            r.ring_mem.retries
                                + r.per_tile.iter().map(|t| t.mem.retries).sum::<u64>()
                        })
                        .sum();
                    output = out.output;
                },
            );
            fsink.record(
                &stats,
                &[
                    ("sim_cycles", makespan as f64),
                    ("retries", retries as f64),
                ],
            );
            FaultRun {
                mean_s: stats.mean_s,
                makespan,
                retries,
                output,
            }
        };
        let base = run_case("baseline", None, &mut fsink);
        // Zero unarmed overhead, pinned: an all-zero-rate plan is
        // filtered out at the session boundary, so the hot loops take
        // the exact fault-free path — same cycles, same bits, no
        // retries. The recorded wall times let CI watch that the two
        // rows also stay within noise of each other.
        let unarmed = run_case("unarmed_plan", Some(FaultPlan::default()), &mut fsink);
        assert_eq!(
            base.makespan, unarmed.makespan,
            "unarmed plan changed simulated cycles"
        );
        assert_eq!(base.output, unarmed.output, "unarmed plan changed values");
        assert_eq!(unarmed.retries, 0, "unarmed plan retried fills");
        let armed = run_case(
            "armed_fill30_stall10_slow5",
            Some(FaultPlan::parse("seed=9 fill=30 stall=10 extra=4 slow=5 epoch=128").unwrap()),
            &mut fsink,
        );
        assert_eq!(
            armed.output, base.output,
            "faults must change timing, never values"
        );
        assert!(armed.retries > 0, "armed fill plan never retried");
        println!(
            "  == unarmed == baseline ({} cycles, zero overhead); armed: {} cycles \
             (+{:.1}%), {} retried fills; wall {:.3}s / {:.3}s / {:.3}s",
            base.makespan,
            armed.makespan,
            100.0 * (armed.makespan as f64 / base.makespan.max(1) as f64 - 1.0),
            armed.retries,
            base.mean_s,
            unarmed.mean_s,
            armed.mean_s,
        );
    }
    let fpath = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fault.json");
    fsink.write(fpath).expect("writing BENCH_fault.json");

    // Anchor to the workspace root (cargo runs bench binaries with CWD =
    // the package dir, i.e. rust/), so CI finds the artifact in one place.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json");
    sink.write(path).expect("writing BENCH_sim.json");
}
