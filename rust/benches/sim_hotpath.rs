//! §Perf — simulator hot-path throughput (the L3 optimization target).
//!
//! Tracks PE-instruction evaluations per second and simulated Mcycles/s
//! on the Table-I 2-D workload (scaled + full), plus microbenches of the
//! memory arbiter and channel operations. EXPERIMENTS.md §Perf records
//! the before/after of each optimization against this bench.
//!
//! Run: `cargo bench --bench sim_hotpath`

use stencil_cgra::cgra::channel::Fifo;
use stencil_cgra::cgra::{Machine, Simulator, Token};
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::{map2d, StencilSpec};
use stencil_cgra::util::bench;

fn sim_throughput(name: &str, spec: &StencilSpec, w: usize, iters: usize) {
    let m = Machine::paper();
    let x = vec![1.0; spec.grid_points()];
    let mut cycles = 0u64;
    let mut fires = 0u64;
    let mut nodes = 0usize;
    let stats = bench::run(name, 1, iters, || {
        let g = map2d::build(spec, w).unwrap();
        nodes = g.node_count();
        let res = Simulator::build(g, &m, x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        cycles = res.stats.cycles;
        fires = res.stats.total_fires();
    });
    let pe_steps = cycles as f64 * nodes as f64;
    println!(
        "  -> {} nodes, {} cycles, {} fires: {:.1} Mcycles/s, {:.1} M PE-steps/s, {:.1} M fires/s",
        nodes,
        cycles,
        fires,
        cycles as f64 / stats.mean_s / 1e6,
        pe_steps / stats.mean_s / 1e6,
        fires as f64 / stats.mean_s / 1e6,
    );
}

fn main() {
    bench::section("simulator end-to-end throughput");
    sim_throughput(
        "2d_49pt_240x113_w5",
        &StencilSpec::dim2(240, 113, symmetric_taps(12), y_taps(12)).unwrap(),
        5,
        5,
    );
    sim_throughput(
        "2d_49pt_table1_960x449_w5",
        &StencilSpec::paper_2d(),
        5,
        3,
    );
    sim_throughput("2d_heat_128x128_w5", &StencilSpec::heat2d(128, 128, 0.2), 5, 5);

    bench::section("channel microbench");
    let mut f = Fifo::new(64, 1);
    let tok = Token::new(1.0, 0, 0);
    let stats = bench::run("fifo_push_pop_1M", 2, 10, || {
        for i in 0..1_000_000u64 {
            if f.can_push() {
                f.push(tok, i);
            }
            bench::black_box(f.pop(i + 2));
        }
    });
    println!(
        "  -> {:.1} M push+pop/s",
        1.0 / stats.mean_s
    );

    bench::section("memory-arbiter microbench");
    let m = Machine::paper();
    let stats = bench::run("mem_100k_loads", 2, 10, || {
        let mut mem = stencil_cgra::cgra::memory::MemSys::new(
            &m,
            vec![1.0; 100_000],
            vec![0.0; 100_000],
        );
        for i in 0..100_000u64 {
            let (_, _t) = mem.load(i % 100_000, i);
            mem.step(i);
        }
        bench::black_box(&mem);
    });
    println!("  -> {:.2} M loads/s", 0.1 / stats.mean_s);
}
