//! Regenerates **Table I** — comparative analysis of stencils on CGRA
//! and GPU — plus the §VIII cache note (stencil2D shows more conflict
//! misses than stencil1D).
//!
//! Two CGRA numbers are reported per workload:
//! * `x16 measured` — 16 tiles actually simulated over strips (includes
//!   halo re-read overhead the paper's extrapolation ignores);
//! * `x16 extrapolated` — single-tile simulation x 16, the paper's
//!   method ("experiments have been done on one CGRA which then got
//!   extrapolated").
//!
//! Run: `cargo bench --bench table1_cgra_vs_gpu`

use std::sync::Arc;

use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::gpu_model::{GpuStencil, Precision, V100};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::bench;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::run_sim;

fn main() {
    let m = Machine::paper();
    let v100 = V100::paper();

    bench::section("Table I — comparative analysis of stencils on CGRA and GPU");
    println!(
        "{:<48} {:>10} {:>8} {:>12} {:>8} {:>10}",
        "workload", "GFLOPS", "%peak", "V100 GFLOPS", "%peak", "CGRA/V100"
    );

    let mut conflicts = Vec::new();
    for (name, spec, w, paper_ratio, paper_cgra_pk, paper_gpu_pk) in [
        (
            "Stencil 1D (194400, rx=8)",
            StencilSpec::paper_1d(),
            6usize,
            1.9f64,
            91.0,
            90.0,
        ),
        (
            "Stencil 2D (960x449, rx=ry=12)",
            StencilSpec::paper_2d(),
            5usize,
            3.03,
            78.0,
            48.0,
        ),
    ] {
        let mut rng = XorShift::new(0x7AB1);
        let input = rng.normal_vec(spec.grid_points());

        // Single tile (timed).
        let t0 = std::time::Instant::now();
        let single = run_sim(&spec, w, &m, &input).unwrap();
        let wall_single = t0.elapsed().as_secs_f64();
        let tile_gflops = single.gflops(spec.total_flops(), m.clock_ghz);
        let tile_roof = m.roofline_gflops(spec.arithmetic_intensity());
        conflicts.push((name, single.stats.mem.clone()));

        // 16 tiles measured — compiled once, executed via a session.
        let opts = CompileOptions::paper().with_machine(m.clone()).with_workers(w);
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let outcome = Session::new(compiled, m.clone()).run(&input).unwrap();
        let rep = &outcome.reports[0];
        let array_roof = 16.0 * tile_roof;

        // GPU baseline.
        let g = GpuStencil::from_spec(&spec, Precision::F64);
        let gpu = v100.best_gflops(&g);
        let gpu_roof = v100.roofline_gflops(&g);

        let extrap = 16.0 * tile_gflops;
        println!(
            "{:<48} {:>10.0} {:>7.0}% {:>12.0} {:>7.0}% {:>9.2}x",
            format!("{name} x16 measured"),
            rep.gflops,
            100.0 * rep.gflops / array_roof,
            gpu,
            100.0 * gpu / gpu_roof,
            rep.gflops / gpu
        );
        println!(
            "{:<48} {:>10.0} {:>7.0}% {:>12} {:>8} {:>9.2}x",
            format!("{name} x16 extrapolated"),
            extrap,
            100.0 * tile_gflops / tile_roof,
            "-",
            "-",
            extrap / gpu
        );
        println!(
            "{:<48} {:>10} {:>8} {:>12} {:>8} {:>9.2}x",
            "  (paper)",
            "-",
            format!("{paper_cgra_pk:.0}%"),
            "-",
            format!("{paper_gpu_pk:.0}%"),
            paper_ratio
        );
        println!(
            "  single tile: {} cycles, {:.1} GFLOPS ({:.0}% of {:.0} roof); sim wall {:.2}s\n",
            single.stats.cycles,
            tile_gflops,
            100.0 * tile_gflops / tile_roof,
            tile_roof,
            wall_single
        );
    }

    bench::section("§VIII cache note — conflict misses (stencil2D > stencil1D)");
    for (name, mem) in conflicts {
        println!(
            "{name:<34} conflict_misses={:<8} misses={:<8} reuse={:.1}%",
            mem.conflict_misses,
            mem.misses,
            100.0 * mem.reuse_ratio()
        );
    }
}
