//! Shared `[lo, hi)` box arithmetic — one implementation for build time
//! and check time.
//!
//! The exchange builder ([`crate::stencil::exchange`]) and the static
//! verifier's coverage rule ([`super::exchange`]) reason about the same
//! geometry: axis-aligned half-open boxes over the global grid. Both
//! call into this module, so the invariant the builder asserts in debug
//! builds (`resident + exchanged == in_points`, via
//! [`valid_coverage_violation`]) and the diagnostic `scgra check` emits
//! on a tampered artifact are one computation, not two that can drift.
//!
//! Everything here is total: empty and inverted boxes have volume 0,
//! intersections saturate, nothing panics on hostile inputs — the
//! verifier runs on untrusted artifacts.

/// Volume of a `[lo, hi)` box (0 when empty or inverted).
pub fn volume(lo: [usize; 3], hi: [usize; 3]) -> usize {
    (0..3).map(|a| hi[a].saturating_sub(lo[a])).product()
}

/// Volume of the intersection of two `[lo, hi)` boxes.
pub fn isect(alo: [usize; 3], ahi: [usize; 3], blo: [usize; 3], bhi: [usize; 3]) -> usize {
    (0..3)
        .map(|a| ahi[a].min(bhi[a]).saturating_sub(alo[a].max(blo[a])))
        .product()
}

/// The intersection box itself, `None` when empty.
pub fn isect_box(
    alo: [usize; 3],
    ahi: [usize; 3],
    blo: [usize; 3],
    bhi: [usize; 3],
) -> Option<([usize; 3], [usize; 3])> {
    let mut lo = [0usize; 3];
    let mut hi = [0usize; 3];
    for a in 0..3 {
        lo[a] = alo[a].max(blo[a]);
        hi[a] = ahi[a].min(bhi[a]);
        if lo[a] >= hi[a] {
            return None;
        }
    }
    Some((lo, hi))
}

/// True when `[ilo, ihi)` lies entirely inside `[olo, ohi)`. An empty
/// inner box is contained in anything.
pub fn contains_box(olo: [usize; 3], ohi: [usize; 3], ilo: [usize; 3], ihi: [usize; 3]) -> bool {
    volume(ilo, ihi) == 0 || (0..3).all(|a| olo[a] <= ilo[a] && ihi[a] <= ohi[a])
}

/// The coverage invariant the exchange schedule rests on: within a
/// receiving tile's input box `[in_lo, in_hi)`, the points owned by the
/// `owned` boxes must exactly equal the points inside the valid box
/// `[vlo, vhi)`. The caller guarantees the `owned` boxes are pairwise
/// disjoint (previous output boxes tile the valid region; the verifier
/// checks disjointness separately before relying on this), so summed
/// intersection volumes count each covered point once. Returns a prose
/// description of the discrepancy, `None` when the invariant holds.
pub fn valid_coverage_violation(
    in_lo: [usize; 3],
    in_hi: [usize; 3],
    owned: &[([usize; 3], [usize; 3])],
    vlo: [usize; 3],
    vhi: [usize; 3],
) -> Option<String> {
    let covered: usize = owned.iter().map(|&(lo, hi)| isect(in_lo, in_hi, lo, hi)).sum();
    let valid = isect(in_lo, in_hi, vlo, vhi);
    (covered != valid).then(|| {
        format!(
            "{} boxes cover {covered} point(s) of the input box but the \
             valid box holds {valid}",
            owned.len()
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volumes_and_intersections_are_total() {
        assert_eq!(volume([0, 0, 0], [4, 3, 2]), 24);
        assert_eq!(volume([5, 0, 0], [4, 3, 2]), 0, "inverted box is empty");
        assert_eq!(isect([0, 0, 0], [4, 1, 1], [2, 0, 0], [6, 1, 1]), 2);
        assert_eq!(isect([0, 0, 0], [2, 1, 1], [2, 0, 0], [4, 1, 1]), 0);
        assert_eq!(
            isect_box([0, 0, 0], [4, 4, 1], [2, 2, 0], [6, 6, 1]),
            Some(([2, 2, 0], [4, 4, 1]))
        );
        assert_eq!(isect_box([0, 0, 0], [2, 2, 1], [2, 2, 0], [4, 4, 1]), None);
    }

    #[test]
    fn containment_handles_empty_boxes() {
        assert!(contains_box([0, 0, 0], [8, 8, 1], [2, 2, 0], [4, 4, 1]));
        assert!(!contains_box([0, 0, 0], [8, 8, 1], [2, 2, 0], [9, 4, 1]));
        assert!(contains_box([0, 0, 0], [1, 1, 1], [5, 5, 5], [5, 5, 5]));
    }

    #[test]
    fn coverage_violation_reports_the_discrepancy() {
        // Input box [0,8), valid box [1,7), covered by [1,4) + [4,7).
        let hold = valid_coverage_violation(
            [0, 0, 0],
            [8, 1, 1],
            &[([1, 0, 0], [4, 1, 1]), ([4, 0, 0], [7, 1, 1])],
            [1, 0, 0],
            [7, 1, 1],
        );
        assert!(hold.is_none());
        // Drop the second box: 3 covered vs 6 valid.
        let broke = valid_coverage_violation(
            [0, 0, 0],
            [8, 1, 1],
            &[([1, 0, 0], [4, 1, 1])],
            [1, 0, 0],
            [7, 1, 1],
        )
        .unwrap();
        assert!(broke.contains("cover 3"), "{broke}");
        assert!(broke.contains("holds 6"), "{broke}");
    }
}
