//! Capacity/residency feasibility — re-derive every tile's token demand
//! and replay the residency plan against `fabric_tokens` (rule ids and
//! soundness argument in the [`super`] module docs).

use crate::compile::CompiledStencil;
use crate::stencil::temporal;

use super::{Diagnostic, Location, Severity};

/// Run the `capacity/*` rules over every stage's residency plan.
pub fn check(c: &CompiledStencil, diags: &mut Vec<Diagnostic>) {
    let budget = c.options.fabric_tokens;
    for (s, st) in c.stages.iter().enumerate() {
        let plan = &st.plan;
        if st.residency.resident.len() != plan.tiles.len() {
            diags.push(Diagnostic {
                rule: "capacity/plan-shape",
                severity: Severity::Error,
                location: Location::stage(s).with_object("residency".to_string()),
                message: format!(
                    "residency plan covers {} tile(s) but the stage has {}",
                    st.residency.resident.len(),
                    plan.tiles.len()
                ),
                evidence: format!(
                    "residency={} tiles={}",
                    st.residency.resident.len(),
                    plan.tiles.len()
                ),
            });
            continue;
        }

        let mut spilled = 0usize;
        for (t, (tile, &resident)) in
            plan.tiles.iter().zip(&st.residency.resident).enumerate()
        {
            // The same arithmetic ResidencyPlan::build runs: §IV
            // pipeline tokens for the tile's sub-spec at this depth,
            // plus the input box the warm chunk would keep on fabric.
            let pipeline =
                temporal::required_tokens(&tile.sub_spec(&c.spec), plan.workers, plan.fused_steps);
            let need = pipeline.saturating_add(tile.in_points());
            let fits = need <= budget;
            if !resident {
                spilled = spilled.saturating_add(tile.in_points());
            }
            if resident && !fits {
                diags.push(Diagnostic {
                    rule: "capacity/resident-overflow",
                    severity: Severity::Error,
                    location: Location::tile(s, t),
                    message: format!(
                        "tile marked resident needs {need} token(s) \
                         (pipeline {pipeline} + input {}) against a budget of {budget}",
                        tile.in_points()
                    ),
                    evidence: format!(
                        "pipeline={pipeline} input={} budget={budget}",
                        tile.in_points()
                    ),
                });
            } else if !resident && fits {
                diags.push(Diagnostic {
                    rule: "capacity/needless-spill",
                    severity: Severity::Warn,
                    location: Location::tile(s, t),
                    message: format!(
                        "tile spills {} point(s) to DRAM every warm chunk although \
                         {need} token(s) fit the budget of {budget}",
                        tile.in_points()
                    ),
                    evidence: format!(
                        "pipeline={pipeline} input={} budget={budget}",
                        tile.in_points()
                    ),
                });
            }
        }

        if st.residency.spilled_points != spilled {
            diags.push(Diagnostic {
                rule: "capacity/spill-accounting",
                severity: Severity::Error,
                location: Location::stage(s).with_object("residency".to_string()),
                message: format!(
                    "recorded spilled_points {} but the spilling tiles' inputs sum to {spilled}",
                    st.residency.spilled_points
                ),
                evidence: format!(
                    "recorded={} derived={spilled}",
                    st.residency.spilled_points
                ),
            });
        }
    }
}
