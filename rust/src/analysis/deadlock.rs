//! Structural deadlock-freedom of every placed channel graph — the
//! static analogue of the runtime quiet-period detector (rule ids and
//! soundness argument in the [`super`] module docs).

use std::collections::VecDeque;

use crate::cgra::PlacedGraph;
use crate::compile::CompiledStencil;

use super::{Diagnostic, Location, Severity};

/// Run the `deadlock/*` rules over every placed graph (fused and ring)
/// of every stage, in sorted-key order so reports are deterministic.
pub fn check(c: &CompiledStencil, diags: &mut Vec<Diagnostic>) {
    for (s, st) in c.stages.iter().enumerate() {
        for (label, graphs) in [("graph", &st.graphs), ("ring graph", &st.ring_graphs)] {
            let mut keys: Vec<&[usize; 3]> = graphs.keys().collect();
            keys.sort_unstable();
            for k in keys {
                let name = format!("{label} {}x{}x{}", k[0], k[1], k[2]);
                check_graph(s, &name, &graphs[k], diags);
            }
        }
    }
}

fn check_graph(stage: usize, name: &str, pg: &PlacedGraph, diags: &mut Vec<Diagnostic>) {
    let chans = pg.channels();

    // Per-channel floors: a zero-capacity channel is a certain deadlock
    // (the first push never gets a credit); capacity < latency + 2 loses
    // the streaming-rate sufficiency argument placement establishes.
    for (i, f) in chans.iter().enumerate() {
        let (cap, lat) = (f.capacity(), f.latency());
        let loc = Location::object(stage, format!("{name} chan {i}"));
        if cap == 0 {
            diags.push(Diagnostic {
                rule: "deadlock/zero-capacity",
                severity: Severity::Error,
                location: loc,
                message: format!(
                    "channel {} -> {} has zero capacity: its producer can never push",
                    node_name(pg, f.src_node()),
                    node_name(pg, f.dst_node())
                ),
                evidence: format!("capacity=0 latency={lat}"),
            });
        } else if (cap as u64) < lat.saturating_add(2) {
            diags.push(Diagnostic {
                rule: "deadlock/streaming-floor",
                severity: Severity::Warn,
                location: loc,
                message: format!(
                    "channel {} -> {} cannot stream at full rate: capacity {cap} < latency {lat} + 2",
                    node_name(pg, f.src_node()),
                    node_name(pg, f.dst_node())
                ),
                evidence: format!("capacity={cap} latency={lat}"),
            });
        }
    }

    // Directed forward cycle: no topological firing order exists at all.
    if let Some(cycle) = directed_cycle(pg) {
        let names: Vec<&str> = cycle.iter().map(|&id| pg.node_name(id)).collect();
        diags.push(Diagnostic {
            rule: "deadlock/forward-cycle",
            severity: Severity::Error,
            location: Location::object(stage, name.to_string()),
            message: format!(
                "directed dependency cycle through {} node(s): no firing order exists",
                cycle.len()
            ),
            evidence: format!("cycle: {}", names.join(" -> ")),
        });
        // The undirected analysis below would double-report the same
        // structure; the forward cycle is already fatal.
        return;
    }

    // Fundamental-cycle buffering: every undirected cycle needs
    // Σ capacity >= Σ latency + len (one in-flight token per channel on
    // top of every full latency window). Checking the spanning-tree
    // basis covers the violation the runtime detector would find.
    for cycle in fundamental_cycles(pg) {
        let sum_cap: u128 = cycle.iter().map(|&e| chans[e].capacity() as u128).sum();
        let sum_lat: u128 = cycle.iter().map(|&e| chans[e].latency() as u128).sum();
        let need = sum_lat + cycle.len() as u128;
        if sum_cap < need {
            let members: Vec<String> = cycle
                .iter()
                .map(|&e| {
                    format!(
                        "chan {e} ({} -> {})",
                        node_name(pg, chans[e].src_node()),
                        node_name(pg, chans[e].dst_node())
                    )
                })
                .collect();
            diags.push(Diagnostic {
                rule: "deadlock/cycle-buffering",
                severity: Severity::Error,
                location: Location::object(stage, name.to_string()),
                message: format!(
                    "cycle of {} channel(s) underbuffered: Σcapacity {sum_cap} < \
                     Σlatency {sum_lat} + {} in-flight token(s)",
                    cycle.len(),
                    cycle.len()
                ),
                evidence: format!("cycle: [{}]", members.join(", ")),
            });
        }
    }
}

fn node_name(pg: &PlacedGraph, id: u32) -> &str {
    if (id as usize) < pg.node_count() {
        pg.node_name(id as usize)
    } else {
        "<unbound>"
    }
}

/// Find a directed cycle in the channel graph (Kahn peel + walk), or
/// `None` when the graph is a DAG — which `dfg::validate` guarantees
/// for anything placement accepted, so a hit here means tampering.
fn directed_cycle(pg: &PlacedGraph) -> Option<Vec<usize>> {
    let n = pg.node_count();
    let mut indeg = vec![0usize; n];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut inn: Vec<Vec<usize>> = vec![Vec::new(); n];
    for f in pg.channels() {
        let (s, d) = (f.src_node() as usize, f.dst_node() as usize);
        if s < n && d < n {
            out[s].push(d);
            inn[d].push(s);
            indeg[d] += 1;
        }
    }
    let mut q: VecDeque<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut removed = 0usize;
    while let Some(v) = q.pop_front() {
        removed += 1;
        for &d in &out[v] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                q.push_back(d);
            }
        }
    }
    if removed == n {
        return None;
    }
    // A residue node (indeg > 0 after the peel) always has an in-edge
    // from another residue node — but not necessarily an out-edge into
    // the residue (a sink fed by a cycle survives the peel too). So
    // walk *backward* over predecessors, which must revisit a node
    // within n steps; the reversed path is then a forward cycle.
    let start = (0..n).find(|&v| indeg[v] > 0)?;
    let mut seen = vec![usize::MAX; n];
    let mut path = Vec::new();
    let mut v = start;
    loop {
        if seen[v] != usize::MAX {
            let mut cyc = path.split_off(seen[v]);
            cyc.reverse();
            return Some(cyc);
        }
        seen[v] = path.len();
        path.push(v);
        v = *inn[v].iter().find(|&&s| indeg[s] > 0)?;
    }
}

/// The fundamental cycles of the *undirected* channel graph: a DFS
/// spanning forest plus one cycle per non-tree channel (closed through
/// the tree via the endpoints' lowest common ancestor). Each cycle is a
/// list of channel indices; self-loop channels are 1-cycles. This basis
/// spans the cycle space, so a buffering bound that holds on every
/// per-channel floor plus every basis cycle holds on all cycles.
pub fn fundamental_cycles(pg: &PlacedGraph) -> Vec<Vec<usize>> {
    let n = pg.node_count();
    let chans = pg.channels();
    let mut cycles = Vec::new();
    let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (e, f) in chans.iter().enumerate() {
        let (s, d) = (f.src_node() as usize, f.dst_node() as usize);
        if s >= n || d >= n {
            continue;
        }
        if s == d {
            cycles.push(vec![e]);
            continue;
        }
        adj[s].push((d, e));
        adj[d].push((s, e));
    }

    let mut parent_node = vec![usize::MAX; n];
    let mut parent_edge = vec![usize::MAX; n];
    let mut depth = vec![0usize; n];
    let mut visited = vec![false; n];
    let mut tree = vec![false; chans.len()];
    let mut stack = Vec::new();
    for root in 0..n {
        if visited[root] {
            continue;
        }
        visited[root] = true;
        stack.push(root);
        while let Some(u) = stack.pop() {
            for &(v, e) in &adj[u] {
                if !visited[v] {
                    visited[v] = true;
                    parent_node[v] = u;
                    parent_edge[v] = e;
                    depth[v] = depth[u] + 1;
                    tree[e] = true;
                    stack.push(v);
                }
            }
        }
    }

    for (e, f) in chans.iter().enumerate() {
        if tree[e] {
            continue;
        }
        let (mut u, mut v) = (f.src_node() as usize, f.dst_node() as usize);
        if u >= n || v >= n || u == v {
            continue;
        }
        // Close the cycle through the LCA; the climb is bounded by the
        // tree depth, with a hard cap as a tamper backstop.
        let mut cyc = vec![e];
        let mut fuel = 2 * n + 2;
        while depth[u] > depth[v] && fuel > 0 {
            cyc.push(parent_edge[u]);
            u = parent_node[u];
            fuel -= 1;
        }
        while depth[v] > depth[u] && fuel > 0 {
            cyc.push(parent_edge[v]);
            v = parent_node[v];
            fuel -= 1;
        }
        while u != v && fuel > 0 {
            cyc.push(parent_edge[u]);
            u = parent_node[u];
            cyc.push(parent_edge[v]);
            v = parent_node[v];
            fuel -= 1;
        }
        if u == v {
            cycles.push(cyc);
        }
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Machine;
    use crate::stencil::spec::symmetric_taps;
    use crate::stencil::{build_graph, StencilSpec};

    fn placed_1d() -> PlacedGraph {
        let spec = StencilSpec::dim1(24, symmetric_taps(2)).unwrap();
        let g = build_graph(&spec, 2).unwrap();
        PlacedGraph::new(g, &Machine::paper()).unwrap()
    }

    #[test]
    fn placed_graphs_have_fundamental_cycles_and_pass_the_buffering_bound() {
        let pg = placed_1d();
        let cycles = fundamental_cycles(&pg);
        // Reader broadcast + MAC-chain reconvergence guarantee the
        // undirected graph is not a forest — the rule is non-vacuous.
        assert!(!cycles.is_empty(), "1-D mapped graph should have reconvergent paths");
        let chans = pg.channels();
        for cyc in &cycles {
            assert!(!cyc.is_empty());
            let cap: u128 = cyc.iter().map(|&e| chans[e].capacity() as u128).sum();
            let lat: u128 = cyc.iter().map(|&e| chans[e].latency() as u128).sum();
            assert!(cap >= lat + cyc.len() as u128, "placed cycle underbuffered");
        }
        // Placement's acyclicity carries over.
        assert!(directed_cycle(&pg).is_none());
    }

    #[test]
    fn underbuffering_every_channel_on_a_cycle_trips_the_rule() {
        let mut pg = placed_1d();
        let cyc = fundamental_cycles(&pg)[0].clone();
        for &e in &cyc {
            let lat = pg.channels()[e].latency() as usize;
            pg.override_channel_capacity(e, lat);
        }
        let mut diags = Vec::new();
        check_graph(0, "graph 24x1x1", &pg, &mut diags);
        assert!(
            diags.iter().any(|d| d.rule == "deadlock/cycle-buffering"
                && d.severity == Severity::Error
                && d.location.object.as_deref() == Some("graph 24x1x1")),
            "{diags:?}"
        );
        // The shrunken channels also lose the streaming floor.
        assert!(diags.iter().any(|d| d.rule == "deadlock/streaming-floor"));
    }

    #[test]
    fn zero_capacity_is_an_error_with_the_channel_named() {
        let mut pg = placed_1d();
        pg.override_channel_capacity(0, 0);
        let mut diags = Vec::new();
        check_graph(1, "graph 24x1x1", &pg, &mut diags);
        let d = diags.iter().find(|d| d.rule == "deadlock/zero-capacity").unwrap();
        assert_eq!(d.severity, Severity::Error);
        assert_eq!(d.location.stage, Some(1));
        assert_eq!(d.location.object.as_deref(), Some("graph 24x1x1 chan 0"));
    }
}
