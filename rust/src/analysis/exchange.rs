//! Exchange-schedule soundness — prove each tile's recorded
//! resident/own/transfer/ring regions partition its input box (rule ids
//! and soundness argument in the [`super`] module docs).

use crate::compile::CompiledStencil;
use crate::stencil::decomp::DecompPlan;
use crate::stencil::exchange::ExchangeSchedule;
use crate::stencil::temporal;

use super::boxes;
use super::{Diagnostic, Location, Severity};

/// Run the `exchange/*` rules over every chunk boundary of every stage:
/// the intra-stage schedule (previous chunk = this stage's own plan)
/// and, for stage `i > 0`, the entry schedule from stage `i - 1`.
pub fn check(c: &CompiledStencil, diags: &mut Vec<Diagnostic>) {
    for (s, st) in c.stages.iter().enumerate() {
        check_boundary(c, s, "intra-exchange", &st.intra_exchange, &st.plan, &st.plan, diags);
        if let Some(entry) = &st.entry_exchange {
            let Some(prev) = s.checked_sub(1).and_then(|p| c.stages.get(p)) else {
                diags.push(Diagnostic {
                    rule: "exchange/tile-count",
                    severity: Severity::Error,
                    location: Location::stage(s).with_object("entry-exchange".to_string()),
                    message: "first stage carries an entry exchange but has no predecessor".into(),
                    evidence: format!("stages={}", c.stages.len()),
                });
                continue;
            };
            check_boundary(c, s, "entry-exchange", entry, &st.plan, &prev.plan, diags);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn check_boundary(
    c: &CompiledStencil,
    stage: usize,
    kind: &str,
    sched: &ExchangeSchedule,
    plan: &DecompPlan,
    prev: &DecompPlan,
    diags: &mut Vec<Diagnostic>,
) {
    if sched.tiles.len() != plan.tiles.len() {
        diags.push(Diagnostic {
            rule: "exchange/tile-count",
            severity: Severity::Error,
            location: Location::stage(stage).with_object(kind.to_string()),
            message: format!(
                "schedule covers {} tile(s) but the plan has {}",
                sched.tiles.len(),
                plan.tiles.len()
            ),
            evidence: format!("schedule={} plan={}", sched.tiles.len(), plan.tiles.len()),
        });
        return;
    }

    // Zero link bandwidth makes any positive transfer demand
    // unsatisfiable: no finite drain rate exists. Machine::validate
    // rejects this up front, so a hit means a tampered artifact.
    if c.options.machine.link_words_per_cycle == 0 && sched.exchanged_points() > 0 {
        diags.push(Diagnostic {
            rule: "exchange/link-capacity",
            severity: Severity::Error,
            location: Location::stage(stage).with_object(kind.to_string()),
            message: format!(
                "{} exchanged point(s) but link_words_per_cycle = 0: the boundary can never drain",
                sched.exchanged_points()
            ),
            evidence: format!("demand={} rate=0", sched.exchanged_points()),
        });
    }

    let spec = &c.spec;
    let dims = [spec.nx, spec.ny, spec.nz];
    let radii = [spec.rx, spec.ry, spec.rz];
    let ilo = radii;
    let ihi = [
        dims[0].saturating_sub(radii[0]),
        dims[1].saturating_sub(radii[1]),
        dims[2].saturating_sub(radii[2]),
    ];
    let (vlo, vhi) = temporal::valid_box(spec, prev.fused_steps);

    for (t, (tile, ex)) in plan.tiles.iter().zip(&sched.tiles).enumerate() {
        let (lo, hi) = (tile.in_lo, tile.in_hi);
        let at = |obj: String| Location::tile(stage, t).with_object(obj);

        // Ownership: every transfer's declared producer exists, is a
        // different tile, and its previous output box contains the
        // shipped box; the box itself lies in the receiver's input and
        // its volume matches the priced point count.
        let mut regions: Vec<(String, [usize; 3], [usize; 3])> = Vec::new();
        for (j, tr) in ex.from_tiles.iter().enumerate() {
            let vol = boxes::volume(tr.lo, tr.hi);
            if vol != tr.points {
                diags.push(Diagnostic {
                    rule: "exchange/transfer-volume",
                    severity: Severity::Error,
                    location: at(format!("{kind} transfer {j}")),
                    message: format!(
                        "transfer box [{:?}, {:?}) holds {vol} point(s) but prices {}",
                        tr.lo, tr.hi, tr.points
                    ),
                    evidence: format!("volume={vol} points={}", tr.points),
                });
            }
            let owner_ok = match prev.tiles.get(tr.src) {
                Some(p) if tr.src != t => boxes::contains_box(p.out_lo, p.out_hi, tr.lo, tr.hi),
                _ => false,
            };
            if !owner_ok || !boxes::contains_box(lo, hi, tr.lo, tr.hi) {
                diags.push(Diagnostic {
                    rule: "exchange/ownership",
                    severity: Severity::Error,
                    location: at(format!("{kind} transfer {j}")),
                    message: format!(
                        "transfer from tile {} ships box [{:?}, {:?}) it does not own \
                         (or outside the receiver's input box)",
                        tr.src, tr.lo, tr.hi
                    ),
                    evidence: format!(
                        "src={} prev_tiles={} receiver_in=[{:?}, {:?})",
                        tr.src,
                        prev.tiles.len(),
                        lo,
                        hi
                    ),
                });
            }
            if tr.mesh_hops == 0 {
                diags.push(Diagnostic {
                    rule: "exchange/ownership",
                    severity: Severity::Error,
                    location: at(format!("{kind} transfer {j}")),
                    message: format!("transfer from tile {} prices zero mesh hops", tr.src),
                    evidence: "mesh_hops=0".to_string(),
                });
            }
            regions.push((format!("transfer {j} (from tile {})", tr.src), tr.lo, tr.hi));
        }

        // Own box: exactly the intersection of the input box with this
        // tile's previous output box (slot `t` keeps its buffer).
        let want_own = prev
            .tiles
            .get(t)
            .and_then(|p| boxes::isect_box(lo, hi, p.out_lo, p.out_hi));
        if ex.own_box != want_own {
            diags.push(Diagnostic {
                rule: "exchange/ownership",
                severity: Severity::Error,
                location: at(format!("{kind} own box")),
                message: format!(
                    "recorded own box {:?} is not the input ∩ previous-output intersection {:?}",
                    ex.own_box, want_own
                ),
                evidence: format!("recorded={:?} derived={:?}", ex.own_box, want_own),
            });
        }
        if let Some((olo, ohi)) = ex.own_box {
            regions.push(("own box".to_string(), olo, ohi));
        }

        // Pairwise disjointness of the priced regions — first-match
        // pricing is only well-defined (and the coverage sum only
        // counts each point once) when no two regions overlap.
        for a in 0..regions.len() {
            for b in a + 1..regions.len() {
                let (na, alo, ahi) = &regions[a];
                let (nb, blo, bhi) = &regions[b];
                let shared = boxes::isect(*alo, *ahi, *blo, *bhi);
                if shared > 0 {
                    diags.push(Diagnostic {
                        rule: "exchange/overlap",
                        severity: Severity::Error,
                        location: at(format!("{kind} {na} ∩ {nb}")),
                        message: format!("{na} and {nb} overlap on {shared} point(s)"),
                        evidence: format!(
                            "[{alo:?}, {ahi:?}) ∩ [{blo:?}, {bhi:?}) = {shared}"
                        ),
                    });
                }
            }
        }

        // Coverage: within the input box, the owned regions (transfers
        // + own box) must cover exactly the previous chunk's valid box —
        // the builder's debug assertion, promoted to a diagnostic
        // through the same `boxes` implementation.
        let owned: Vec<([usize; 3], [usize; 3])> =
            regions.iter().map(|&(_, rlo, rhi)| (rlo, rhi)).collect();
        if let Some(why) = boxes::valid_coverage_violation(lo, hi, &owned, vlo, vhi) {
            diags.push(Diagnostic {
                rule: "exchange/coverage",
                severity: Severity::Error,
                location: at(kind.to_string()),
                message: format!("input box not covered: {why}"),
                evidence: format!("in=[{lo:?}, {hi:?}) valid=[{vlo:?}, {vhi:?})"),
            });
        }

        // Ring accounting: the ring is the single-step interior minus
        // the previous valid box, clipped to this input box.
        let interior = boxes::isect(lo, hi, ilo, ihi);
        let want_ring = interior.saturating_sub(boxes::isect(lo, hi, vlo, vhi));
        if ex.from_ring != want_ring {
            diags.push(Diagnostic {
                rule: "exchange/ring-accounting",
                severity: Severity::Error,
                location: at(kind.to_string()),
                message: format!(
                    "recorded {} ring point(s); box arithmetic derives {want_ring}",
                    ex.from_ring
                ),
                evidence: format!(
                    "interior∩in={interior} valid∩in={} recorded={}",
                    boxes::isect(lo, hi, vlo, vhi),
                    ex.from_ring
                ),
            });
        }

        // Interior box: the catch-all pricing region must be exactly
        // input ∩ single-step interior.
        let want_interior = boxes::isect_box(lo, hi, ilo, ihi);
        if ex.interior_box != want_interior {
            diags.push(Diagnostic {
                rule: "exchange/ring-accounting",
                severity: Severity::Error,
                location: at(format!("{kind} interior box")),
                message: format!(
                    "recorded interior box {:?} differs from input ∩ interior {:?}",
                    ex.interior_box, want_interior
                ),
                evidence: format!("recorded={:?} derived={:?}", ex.interior_box, want_interior),
            });
        }

        // Resident accounting: frame (outside the interior) plus the own
        // box — and the partition total `resident + exchanged ==
        // in_points` the runtime accounting tests pin dynamically.
        let in_points = tile.in_points();
        let own_points = ex.own_box.map(|(olo, ohi)| boxes::volume(olo, ohi)).unwrap_or(0);
        let want_resident = in_points.saturating_sub(interior) + own_points;
        if ex.resident != want_resident
            || ex.resident.saturating_add(ex.exchanged()) != in_points
        {
            diags.push(Diagnostic {
                rule: "exchange/resident-accounting",
                severity: Severity::Error,
                location: at(kind.to_string()),
                message: format!(
                    "resident {} + exchanged {} must equal in_points {in_points} \
                     (derived resident {want_resident})",
                    ex.resident,
                    ex.exchanged()
                ),
                evidence: format!(
                    "frame={} own={own_points} ring={} transfers={}",
                    in_points.saturating_sub(interior),
                    ex.from_ring,
                    ex.from_tiles.iter().map(|tr| tr.points).sum::<usize>()
                ),
            });
        }
    }
}
