//! `scgra check` — a static verifier for compiled stencil artifacts.
//!
//! Every property the runtime can discover the hard way (a deadlock
//! forensic report, a halo coverage hole, a residency overflow) is
//! decidable from the [`CompiledStencil`] alone, because the paper's
//! whole premise (§III–§V) is that the mapping is fixed at configure
//! time. This module proves those properties **before a single cycle is
//! simulated**: [`check`] runs four rule families over the artifact and
//! returns a typed [`Report`] of [`Diagnostic`]s, rendered as text or
//! JSON and gated by [`CheckLevel`] at compile time, load time
//! (`CompiledStencil::load_checked`) and on the command line
//! (`scgra check [--artifact F] [--format text|json] [--deny warn]`).
//!
//! # Rule families and their soundness arguments
//!
//! **Deadlock-freedom** ([`deadlock`], rules `deadlock/*`). The placed
//! channel graph stalls only when a dependency cycle runs out of
//! buffering. The rules are layered so that a clean verdict is a proof,
//! not a heuristic:
//! * `deadlock/forward-cycle` (Error): a *directed* cycle in the channel
//!   graph is a certain deadlock — no topological firing order exists.
//!   Placement validates acyclicity, so this fires only on tampered
//!   state; the exact cycle is reported.
//! * `deadlock/zero-capacity` (Error): a zero-capacity channel can never
//!   accept its first token; the producer blocks forever.
//! * `deadlock/streaming-floor` (Warn): a channel with
//!   `capacity < latency + 2` cannot stream at full rate (one slot per
//!   in-flight cycle plus one being pushed and one being popped).
//!   Placement repairs every channel to `capacity >= latency + 2`,
//!   which is the per-channel *sufficient* condition: it implies
//!   `Σ capacity >= Σ latency + 2·len` around **every** undirected
//!   cycle, so a graph with no streaming-floor warning is deadlock-free
//!   by construction. The warning marks exactly where that sufficiency
//!   argument is lost.
//! * `deadlock/cycle-buffering` (Error): the static analogue of the
//!   runtime quiet-period detector. For every fundamental cycle of the
//!   undirected channel graph (spanning-tree basis — polynomial, one
//!   cycle per non-tree channel) the rule demands
//!   `Σ capacity >= Σ latency + len`: enough slots to hold one
//!   in-flight token per channel while every latency window is full. A
//!   violation is reported with the exact cycle (channel ids and node
//!   names). Passing the basis is a *necessary* condition on the whole
//!   cycle space; the proof of sufficiency is the per-channel floor
//!   above — the two rules together are why the clean sweep in
//!   `tests/static_check.rs` can cross-check against the runtime
//!   detector on the `tests/sim_cores.rs` fixtures.
//!
//! **Exchange-schedule soundness** ([`exchange`], rules `exchange/*`).
//! For every stage, boundary (intra-stage and stage-entry) and tile,
//! the recorded [`crate::stencil::exchange::TileExchange`] must
//! partition the tile's input box: transfer boxes and the own box
//! pairwise disjoint (`exchange/overlap`), together covering exactly
//! the intersection with the previous chunk's valid box
//! (`exchange/coverage`, via [`boxes::valid_coverage_violation`] — the
//! same implementation the builder debug-asserts), every transfer's
//! declared producer actually owning the shipped box
//! (`exchange/ownership`), ring and resident counts re-derived from box
//! arithmetic (`exchange/ring-accounting`, `exchange/resident-
//! accounting` — the promoted `resident + exchanged == in_points`
//! assertion), and the per-boundary link demand satisfiable under
//! `Machine::link_words_per_cycle` (`exchange/link-capacity`: any
//! positive drain rate bounds every finite transfer; zero is
//! unsatisfiable). Disjointness + exact coverage + ring/resident
//! accounting together prove the partition, because the five classes
//! (own, transfers, ring, frame, nothing) are exhaustive by
//! construction once their volumes add up to `in_points`.
//!
//! **Capacity/residency feasibility** ([`capacity`], rules
//! `capacity/*`). Re-derives the §IV pipeline token demand per tile
//! (`temporal::required_tokens` on the tile's sub-spec at the plan's
//! depth) and replays the [`crate::compile::ResidencyPlan`] decision
//! against `fabric_tokens`: a tile marked resident whose demand
//! overflows the budget is an Error (the simulator would overcommit
//! fabric storage); a spilled tile the budget would have admitted is a
//! Warn (correct but needlessly slow); the recorded `spilled_points`
//! must equal the sum over spilled tiles (Error otherwise). The
//! re-derivation is the same arithmetic `ResidencyPlan::build` runs, so
//! agreement is exact, not approximate.
//!
//! **Plan-consistency lints** ([`plan`], rules `plan/*`). Everything
//! the decomposition planner guarantees and later layers assume:
//! fused-depth trapezoid halos inside the grid (`plan/halo-bounds`,
//! also applied to the time-tiled ring tiles), a fused depth whose
//! valid box is non-empty (`plan/depth-exceeds-grid`), stages covering
//! the declared steps exactly (`plan/step-accounting`) with the tail
//! stage at depth `steps % depth` (`plan/tail-depth`),
//! `DecompPlan::layer_workers` monotone non-increasing
//! (`plan/layer-workers`), and placement mesh coordinates in-bounds
//! and injective (`plan/mesh-bounds`, `plan/mesh-injective`).
//!
//! The analyzer never simulates and never panics: all box math is
//! saturating ([`boxes`]), all indexing is checked, and every rule is
//! written to be provably silent on any artifact `compile` can produce
//! — which is what lets Error-level checking run inside `compile`
//! itself by default in debug builds (see [`CheckLevel`]).

pub mod boxes;
pub mod capacity;
pub mod deadlock;
pub mod exchange;
pub mod plan;

use crate::compile::CompiledStencil;
use crate::error::ScgraError;

/// How much static analysis a compile/load should run and enforce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckLevel {
    /// No analysis.
    Off,
    /// Run every rule; fail on Error diagnostics.
    Errors,
    /// Run every rule; fail on Error *and* Warn diagnostics (the
    /// `--deny warn` posture).
    Full,
}

impl Default for CheckLevel {
    /// Error-level checking is on by default in debug builds — every
    /// `compile` in the test suite doubles as a clean-sweep fixture —
    /// and off in release builds, where the artifact is trusted and
    /// compile latency counts.
    fn default() -> Self {
        if cfg!(debug_assertions) {
            CheckLevel::Errors
        } else {
            CheckLevel::Off
        }
    }
}

impl CheckLevel {
    /// Parse a CLI/config/artifact value (`off|errors|full`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "off" => CheckLevel::Off,
            "errors" => CheckLevel::Errors,
            "full" => CheckLevel::Full,
            other => anyhow::bail!("unknown check level `{other}` (off|errors|full)"),
        })
    }
}

impl std::fmt::Display for CheckLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            CheckLevel::Off => "off",
            CheckLevel::Errors => "errors",
            CheckLevel::Full => "full",
        })
    }
}

/// Diagnostic severity, ordered worst-first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// Where in the artifact a diagnostic points.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Location {
    /// Stage index in `CompiledStencil::stages`.
    pub stage: Option<usize>,
    /// Tile index in the stage's `plan.tiles`.
    pub tile: Option<usize>,
    /// Finer-grained object: a placed graph key, a channel id, a
    /// transfer source.
    pub object: Option<String>,
}

impl Location {
    pub fn stage(stage: usize) -> Self {
        Self { stage: Some(stage), ..Self::default() }
    }

    pub fn tile(stage: usize, tile: usize) -> Self {
        Self { stage: Some(stage), tile: Some(tile), object: None }
    }

    pub fn object(stage: usize, object: impl Into<String>) -> Self {
        Self { stage: Some(stage), tile: None, object: Some(object.into()) }
    }

    pub fn with_object(mut self, object: impl Into<String>) -> Self {
        self.object = Some(object.into());
        self
    }
}

impl std::fmt::Display for Location {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if let Some(s) = self.stage {
            parts.push(format!("stage {s}"));
        }
        if let Some(t) = self.tile {
            parts.push(format!("tile {t}"));
        }
        if let Some(o) = &self.object {
            parts.push(o.clone());
        }
        if parts.is_empty() {
            f.write_str("artifact")
        } else {
            f.write_str(&parts.join(" / "))
        }
    }
}

/// One verified fact about the artifact: which rule, how severe, where,
/// what is wrong, and the numbers that prove it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Stable rule id, `family/rule` (e.g. `deadlock/cycle-buffering`).
    pub rule: &'static str,
    pub severity: Severity,
    pub location: Location,
    /// One-line statement of the violation.
    pub message: String,
    /// The concrete quantities behind the verdict (cycle members,
    /// volumes, budgets) — machine-grepable evidence.
    pub evidence: String,
}

/// The outcome of [`check`]: every diagnostic, worst-first.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn error_count(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warn_count(&self) -> usize {
        self.count(Severity::Warn)
    }

    fn count(&self, s: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == s).count()
    }

    /// True when no rule found anything at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable rendering, one block per diagnostic plus a
    /// summary line (`check: clean` on an empty report).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!(
                "{}[{}] {}: {}\n  evidence: {}\n",
                d.severity.as_str(),
                d.rule,
                d.location,
                d.message,
                d.evidence
            ));
        }
        if self.is_clean() {
            s.push_str("check: clean (0 diagnostics)\n");
        } else {
            s.push_str(&format!(
                "check: {} error(s), {} warning(s), {} info\n",
                self.error_count(),
                self.warn_count(),
                self.count(Severity::Info)
            ));
        }
        s
    }

    /// Machine-readable rendering (hand-rolled JSON — no serde in the
    /// offline vendor set).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\"diagnostics\":[");
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"rule\":\"{}\",\"severity\":\"{}\",\"location\":\"{}\",\
                 \"message\":\"{}\",\"evidence\":\"{}\"}}",
                json_escape(d.rule),
                d.severity.as_str(),
                json_escape(&d.location.to_string()),
                json_escape(&d.message),
                json_escape(&d.evidence)
            ));
        }
        s.push_str(&format!(
            "],\"errors\":{},\"warnings\":{},\"clean\":{}}}",
            self.error_count(),
            self.warn_count(),
            self.is_clean()
        ));
        s
    }

    /// Enforce `level`: Ok when the report passes, otherwise
    /// [`ScgraError::AnalysisFailed`] carrying the offending
    /// diagnostics rendered as text.
    pub fn gate(&self, level: CheckLevel) -> Result<(), ScgraError> {
        let denied = |d: &Diagnostic| match level {
            CheckLevel::Off => false,
            CheckLevel::Errors => d.severity == Severity::Error,
            CheckLevel::Full => d.severity <= Severity::Warn,
        };
        let offending: Vec<&Diagnostic> = self.diagnostics.iter().filter(|d| denied(d)).collect();
        if offending.is_empty() {
            return Ok(());
        }
        let mut msg = format!("static analysis rejected the artifact ({} diagnostic(s)):", offending.len());
        for d in offending {
            msg.push_str(&format!(
                "\n  {}[{}] {}: {}",
                d.severity.as_str(),
                d.rule,
                d.location,
                d.message
            ));
        }
        Err(ScgraError::AnalysisFailed(msg))
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Run every rule family over `c` (analyzed against the machine it was
/// compiled for, `c.options.machine`) and return the full report,
/// errors first. Zero simulation: the rules read the artifact's placed
/// graphs, exchange schedules and plans, and re-derive the invariants
/// the execution layer assumes.
pub fn check(c: &CompiledStencil) -> Report {
    let mut diagnostics = Vec::new();
    deadlock::check(c, &mut diagnostics);
    exchange::check(c, &mut diagnostics);
    capacity::check(c, &mut diagnostics);
    plan::check(c, &mut diagnostics);
    // Worst-first, stable within a severity so rule order is
    // deterministic (rule families run in a fixed order and each walks
    // stages/tiles/sorted graph keys in order).
    diagnostics.sort_by_key(|d| d.severity);
    Report { diagnostics }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(rule: &'static str, severity: Severity) -> Diagnostic {
        Diagnostic {
            rule,
            severity,
            location: Location::tile(0, 3),
            message: "msg with \"quotes\"".into(),
            evidence: "a=1\tb=2".into(),
        }
    }

    #[test]
    fn check_level_parses_and_defaults_by_build_profile() {
        assert_eq!(CheckLevel::parse("off").unwrap(), CheckLevel::Off);
        assert_eq!(CheckLevel::parse("errors").unwrap(), CheckLevel::Errors);
        assert_eq!(CheckLevel::parse("full").unwrap(), CheckLevel::Full);
        assert!(CheckLevel::parse("paranoid").is_err());
        let want = if cfg!(debug_assertions) { CheckLevel::Errors } else { CheckLevel::Off };
        assert_eq!(CheckLevel::default(), want);
        assert_eq!(CheckLevel::Full.to_string(), "full");
    }

    #[test]
    fn locations_render_hierarchically() {
        assert_eq!(Location::default().to_string(), "artifact");
        assert_eq!(Location::stage(1).to_string(), "stage 1");
        assert_eq!(Location::tile(0, 3).to_string(), "stage 0 / tile 3");
        assert_eq!(
            Location::object(2, "graph 8x6x1").with_object("graph 8x6x1 chan 4").to_string(),
            "stage 2 / graph 8x6x1 chan 4"
        );
    }

    #[test]
    fn report_renders_text_and_json() {
        let empty = Report::default();
        assert!(empty.is_clean());
        assert!(empty.to_text().contains("check: clean"));
        assert!(empty.to_json().contains("\"clean\":true"));

        let r = Report {
            diagnostics: vec![diag("plan/halo-bounds", Severity::Error), diag("x/y", Severity::Warn)],
        };
        let text = r.to_text();
        assert!(text.contains("error[plan/halo-bounds] stage 0 / tile 3"), "{text}");
        assert!(text.contains("1 error(s), 1 warning(s)"), "{text}");
        let json = r.to_json();
        assert!(json.contains("\"rule\":\"plan/halo-bounds\""), "{json}");
        assert!(json.contains("msg with \\\"quotes\\\""), "{json}");
        assert!(json.contains("a=1\\tb=2"), "{json}");
        assert!(json.contains("\"errors\":1,\"warnings\":1,\"clean\":false"), "{json}");
    }

    #[test]
    fn gate_enforces_the_level() {
        let r = Report { diagnostics: vec![diag("x/warn-only", Severity::Warn)] };
        assert!(r.gate(CheckLevel::Off).is_ok());
        assert!(r.gate(CheckLevel::Errors).is_ok(), "warns pass at Errors level");
        let e = r.gate(CheckLevel::Full).unwrap_err();
        assert_eq!(e.kind(), "analysis-failed");
        assert!(e.to_string().contains("x/warn-only"), "{e}");
        assert!(!e.is_transient());

        let r = Report { diagnostics: vec![diag("x/err", Severity::Error)] };
        let e = r.gate(CheckLevel::Errors).unwrap_err();
        assert!(e.to_string().contains("x/err"), "{e}");
        assert!(e.to_string().contains("stage 0 / tile 3"), "{e}");
    }

    #[test]
    fn severity_orders_worst_first() {
        assert!(Severity::Error < Severity::Warn);
        assert!(Severity::Warn < Severity::Info);
    }
}
