//! Plan-consistency lints — the decomposition and placement invariants
//! later layers assume without re-checking (rule ids and soundness
//! argument in the [`super`] module docs).

use crate::compile::CompiledStencil;
use crate::stencil::decomp::Tile;
use crate::stencil::exchange::mesh_coords;

use super::boxes;
use super::{Diagnostic, Location, Severity};

/// Run the `plan/*` rules over every stage's plan, ring schedule and
/// mesh placement, plus the workload-level step accounting.
pub fn check(c: &CompiledStencil, diags: &mut Vec<Diagnostic>) {
    let dims = [c.spec.nx, c.spec.ny, c.spec.nz];
    let radii = [c.spec.rx, c.spec.ry, c.spec.rz];

    for (s, st) in c.stages.iter().enumerate() {
        let plan = &st.plan;

        // Trapezoid feasibility: a fused depth whose halo eats the whole
        // grid leaves no valid box for any tile to own.
        for a in 0..3 {
            if radii[a] > 0 && 2 * radii[a] * plan.fused_steps >= dims[a] {
                diags.push(Diagnostic {
                    rule: "plan/depth-exceeds-grid",
                    severity: Severity::Error,
                    location: Location::stage(s).with_object(format!("axis {a}")),
                    message: format!(
                        "fused depth {} with radius {} leaves no interior on a \
                         {}-point axis",
                        plan.fused_steps, radii[a], dims[a]
                    ),
                    evidence: format!(
                        "2 * {} * {} >= {}",
                        radii[a], plan.fused_steps, dims[a]
                    ),
                });
            }
        }

        // Halo bounds, for the fused tiles and every ring layer's tiles.
        for (t, tile) in plan.tiles.iter().enumerate() {
            check_tile_bounds(Location::tile(s, t), tile, dims, diags);
        }
        for (l, layer) in st.ring.iter().enumerate() {
            for (t, tile) in layer.iter().enumerate() {
                let loc = Location::object(s, format!("ring layer {l} tile {t}"));
                check_tile_bounds(loc, tile, dims, diags);
            }
        }

        // Worker taper: layer ℓ of the fused trapezoid writes a narrower
        // interior than layer ℓ-1, so its useful worker count can never
        // grow. `layer_workers` is a pure function of the plan, so a
        // violation means the formula itself regressed — worth flagging,
        // not fatal.
        let lw = plan.layer_workers(&c.spec);
        if lw.windows(2).any(|w| w[1] > w[0]) {
            diags.push(Diagnostic {
                rule: "plan/layer-workers",
                severity: Severity::Warn,
                location: Location::stage(s).with_object("layer workers".to_string()),
                message: "per-layer worker counts are not monotone non-increasing".to_string(),
                evidence: format!("layer_workers={lw:?}"),
            });
        }

        // Mesh placement: coordinates must stay inside the cut grid and
        // name each tile uniquely — hop pricing and exchange routing
        // both index by them.
        let coords = mesh_coords(plan);
        for (t, coord) in coords.iter().enumerate() {
            for a in 0..3 {
                if coord[a] >= plan.cuts[a].max(1) {
                    diags.push(Diagnostic {
                        rule: "plan/mesh-bounds",
                        severity: Severity::Error,
                        location: Location::tile(s, t),
                        message: format!(
                            "mesh coordinate {coord:?} exceeds the plan's cut grid {:?}",
                            plan.cuts
                        ),
                        evidence: format!("axis={a} coord={} cuts={}", coord[a], plan.cuts[a]),
                    });
                }
            }
        }
        let mut seen = coords.clone();
        seen.sort_unstable();
        seen.dedup();
        if seen.len() != coords.len() {
            diags.push(Diagnostic {
                rule: "plan/mesh-injective",
                severity: Severity::Error,
                location: Location::stage(s).with_object("mesh coords".to_string()),
                message: format!(
                    "{} tile(s) share a mesh coordinate: transfers cannot be routed uniquely",
                    coords.len() - seen.len() + 1
                ),
                evidence: format!("coords={coords:?}"),
            });
        }
    }

    // Step accounting: the stages must advance exactly the declared
    // step count, and a two-stage schedule's tail must be the remainder
    // `steps % depth` run exactly once.
    let covered: usize = c.stages.iter().map(|s| s.steps()).sum();
    if covered != c.steps {
        diags.push(Diagnostic {
            rule: "plan/step-accounting",
            severity: Severity::Error,
            location: Location::default(),
            message: format!("stages advance {covered} step(s) but the artifact declares {}", c.steps),
            evidence: format!("covered={covered} declared={}", c.steps),
        });
    }
    if c.stages.len() == 2 {
        let depth = c.stages[0].plan.fused_steps.max(1);
        let rem = c.steps % depth;
        let tail = &c.stages[1];
        if tail.plan.fused_steps != rem || tail.repeats != 1 {
            diags.push(Diagnostic {
                rule: "plan/tail-depth",
                severity: Severity::Error,
                location: Location::stage(1),
                message: format!(
                    "tail stage should run once at depth {rem} (= {} % {depth}); \
                     found depth {} x {} repeat(s)",
                    c.steps, tail.plan.fused_steps, tail.repeats
                ),
                evidence: format!(
                    "steps={} depth={depth} tail_depth={} tail_repeats={}",
                    c.steps, tail.plan.fused_steps, tail.repeats
                ),
            });
        }
    } else if c.stages.len() > 2 {
        diags.push(Diagnostic {
            rule: "plan/stage-count",
            severity: Severity::Warn,
            location: Location::default(),
            message: format!(
                "{} stages: the compiler only ever emits one full stage plus an \
                 optional tail",
                c.stages.len()
            ),
            evidence: format!("stages={}", c.stages.len()),
        });
    }
}

fn check_tile_bounds(
    location: Location,
    tile: &Tile,
    dims: [usize; 3],
    diags: &mut Vec<Diagnostic>,
) {
    let out_ok = boxes::volume(tile.out_lo, tile.out_hi) > 0
        && boxes::contains_box(tile.in_lo, tile.in_hi, tile.out_lo, tile.out_hi);
    let in_ok = boxes::contains_box([0, 0, 0], dims, tile.in_lo, tile.in_hi)
        && boxes::volume(tile.in_lo, tile.in_hi) > 0;
    if !out_ok || !in_ok {
        diags.push(Diagnostic {
            rule: "plan/halo-bounds",
            severity: Severity::Error,
            location,
            message: format!(
                "tile boxes out of bounds: need nonempty out [{:?}, {:?}) ⊆ \
                 in [{:?}, {:?}) ⊆ grid [{:?}]",
                tile.out_lo, tile.out_hi, tile.in_lo, tile.in_hi, dims
            ),
            evidence: format!("out_ok={out_ok} in_ok={in_ok}"),
        });
    }
}
