//! Bounded FIFO channels with delivery latency — the PE input/output
//! queues plus the on-chip network link between them (§II-A).
//!
//! A token pushed at cycle `t` becomes visible to the consumer at
//! `t + latency`. Capacity counts *all* in-flight tokens (queued +
//! traversing the link), which is how credit-based flow control behaves:
//! the producer needs a credit before injecting.
//!
//! Channels additionally know their **endpoint node ids** (bound by the
//! simulator from the DFG edge): a `push` is a future wake event for the
//! consumer at token-visibility time, and a `pop` frees a credit that
//! wakes the producer. The event-driven simulator core derives its
//! ready-list scheduling from exactly these two endpoints; the dense
//! core ignores them.

use std::collections::VecDeque;

use super::Token;

/// Endpoint placeholder for a Fifo constructed outside a DFG (tests,
/// microbenches). [`Fifo::with_endpoints`] replaces it.
pub const NO_NODE: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct Fifo {
    buf: VecDeque<(Token, u64)>,
    capacity: usize,
    latency: u64,
    /// Producer node id (`NO_NODE` when unbound).
    src_node: u32,
    /// Consumer node id (`NO_NODE` when unbound).
    dst_node: u32,
    /// High-water mark, for the occupancy statistics.
    pub max_occupancy: usize,
}

impl Fifo {
    pub fn new(capacity: usize, latency: u32) -> Self {
        assert!(capacity > 0, "zero-capacity channel deadlocks");
        Self {
            buf: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            latency: latency as u64,
            src_node: NO_NODE,
            dst_node: NO_NODE,
            max_occupancy: 0,
        }
    }

    /// Bind the producer/consumer node ids (the DFG edge endpoints).
    pub fn with_endpoints(mut self, src_node: u32, dst_node: u32) -> Self {
        self.src_node = src_node;
        self.dst_node = dst_node;
        self
    }

    /// Producer node id — the node a freed credit wakes.
    #[inline]
    pub fn src_node(&self) -> u32 {
        self.src_node
    }

    /// Consumer node id — the node a pushed token wakes at visibility.
    #[inline]
    pub fn dst_node(&self) -> u32 {
        self.dst_node
    }

    /// Cycles between a push and the token becoming visible.
    #[inline]
    pub fn latency(&self) -> u64 {
        self.latency
    }

    #[inline]
    pub fn can_push(&self) -> bool {
        self.buf.len() < self.capacity
    }

    #[inline]
    pub fn push(&mut self, t: Token, now: u64) {
        debug_assert!(self.can_push());
        self.buf.push_back((t, now + self.latency));
        if self.buf.len() > self.max_occupancy {
            self.max_occupancy = self.buf.len();
        }
    }

    /// The token at the head, if it has arrived.
    #[inline]
    pub fn peek(&self, now: u64) -> Option<&Token> {
        match self.buf.front() {
            Some((t, ready)) if *ready <= now => Some(t),
            _ => None,
        }
    }

    #[inline]
    pub fn pop(&mut self, now: u64) -> Option<Token> {
        match self.buf.front() {
            Some((_, ready)) if *ready <= now => self.buf.pop_front().map(|(t, _)| t),
            _ => None,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(v: f64) -> Token {
        Token::new(v, 0, 0)
    }

    #[test]
    fn respects_capacity() {
        let mut f = Fifo::new(2, 0);
        assert!(f.can_push());
        f.push(tok(1.0), 0);
        f.push(tok(2.0), 0);
        assert!(!f.can_push());
    }

    #[test]
    fn latency_hides_tokens() {
        let mut f = Fifo::new(4, 3);
        f.push(tok(1.0), 10);
        assert!(f.peek(10).is_none());
        assert!(f.peek(12).is_none());
        assert_eq!(f.peek(13).unwrap().val, 1.0);
        assert_eq!(f.pop(13).unwrap().val, 1.0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(8, 1);
        for i in 0..5 {
            f.push(tok(i as f64), i);
        }
        for i in 0..5 {
            assert_eq!(f.pop(100).unwrap().val, i as f64);
        }
        assert!(f.is_empty());
    }

    #[test]
    fn head_blocks_until_ready_even_if_later_pushed_earlier() {
        // Order is strictly FIFO: a head with later ready time blocks.
        let mut f = Fifo::new(4, 5);
        f.push(tok(1.0), 10); // ready 15
        f.push(tok(2.0), 10); // ready 15
        assert!(f.pop(14).is_none());
        assert_eq!(f.pop(15).unwrap().val, 1.0);
    }

    #[test]
    fn tracks_max_occupancy() {
        let mut f = Fifo::new(8, 0);
        for i in 0..6 {
            f.push(tok(i as f64), 0);
        }
        f.pop(0);
        f.pop(0);
        assert_eq!(f.max_occupancy, 6);
    }

    #[test]
    fn endpoints_default_unbound_and_bind() {
        let f = Fifo::new(2, 1);
        assert_eq!(f.src_node(), NO_NODE);
        assert_eq!(f.dst_node(), NO_NODE);
        let f = f.with_endpoints(3, 7);
        assert_eq!(f.src_node(), 3);
        assert_eq!(f.dst_node(), 7);
        assert_eq!(f.latency(), 1);
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn zero_capacity_rejected() {
        Fifo::new(0, 1);
    }
}
