//! Bounded FIFO channels with delivery latency — the PE input/output
//! queues plus the on-chip network link between them (§II-A).
//!
//! A token pushed at cycle `t` becomes visible to the consumer at
//! `t + latency`. Capacity counts *all* in-flight tokens (queued +
//! traversing the link), which is how credit-based flow control behaves:
//! the producer needs a credit before injecting.

use std::collections::VecDeque;

use super::Token;

#[derive(Debug, Clone)]
pub struct Fifo {
    buf: VecDeque<(Token, u64)>,
    capacity: usize,
    latency: u64,
    /// High-water mark, for the occupancy statistics.
    pub max_occupancy: usize,
}

impl Fifo {
    pub fn new(capacity: usize, latency: u32) -> Self {
        assert!(capacity > 0, "zero-capacity channel deadlocks");
        Self {
            buf: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            latency: latency as u64,
            max_occupancy: 0,
        }
    }

    #[inline]
    pub fn can_push(&self) -> bool {
        self.buf.len() < self.capacity
    }

    #[inline]
    pub fn push(&mut self, t: Token, now: u64) {
        debug_assert!(self.can_push());
        self.buf.push_back((t, now + self.latency));
        if self.buf.len() > self.max_occupancy {
            self.max_occupancy = self.buf.len();
        }
    }

    /// The token at the head, if it has arrived.
    #[inline]
    pub fn peek(&self, now: u64) -> Option<&Token> {
        match self.buf.front() {
            Some((t, ready)) if *ready <= now => Some(t),
            _ => None,
        }
    }

    #[inline]
    pub fn pop(&mut self, now: u64) -> Option<Token> {
        match self.buf.front() {
            Some((_, ready)) if *ready <= now => self.buf.pop_front().map(|(t, _)| t),
            _ => None,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(v: f64) -> Token {
        Token::new(v, 0, 0)
    }

    #[test]
    fn respects_capacity() {
        let mut f = Fifo::new(2, 0);
        assert!(f.can_push());
        f.push(tok(1.0), 0);
        f.push(tok(2.0), 0);
        assert!(!f.can_push());
    }

    #[test]
    fn latency_hides_tokens() {
        let mut f = Fifo::new(4, 3);
        f.push(tok(1.0), 10);
        assert!(f.peek(10).is_none());
        assert!(f.peek(12).is_none());
        assert_eq!(f.peek(13).unwrap().val, 1.0);
        assert_eq!(f.pop(13).unwrap().val, 1.0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(8, 1);
        for i in 0..5 {
            f.push(tok(i as f64), i);
        }
        for i in 0..5 {
            assert_eq!(f.pop(100).unwrap().val, i as f64);
        }
        assert!(f.is_empty());
    }

    #[test]
    fn head_blocks_until_ready_even_if_later_pushed_earlier() {
        // Order is strictly FIFO: a head with later ready time blocks.
        let mut f = Fifo::new(4, 5);
        f.push(tok(1.0), 10); // ready 15
        f.push(tok(2.0), 10); // ready 15
        assert!(f.pop(14).is_none());
        assert_eq!(f.pop(15).unwrap().val, 1.0);
    }

    #[test]
    fn tracks_max_occupancy() {
        let mut f = Fifo::new(8, 0);
        for i in 0..6 {
            f.push(tok(i as f64), 0);
        }
        f.pop(0);
        f.pop(0);
        assert_eq!(f.max_occupancy, 6);
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn zero_capacity_rejected() {
        Fifo::new(0, 1);
    }
}
