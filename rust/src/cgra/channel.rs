//! Bounded FIFO channels with delivery latency — the PE input/output
//! queues plus the on-chip network link between them (§II-A) — stored
//! as fixed power-of-two ring buffers over one shared SoA token arena.
//!
//! A token pushed at cycle `t` becomes visible to the consumer at
//! `t + latency`. Capacity counts *all* in-flight tokens (queued +
//! traversing the link), which is how credit-based flow control behaves:
//! the producer needs a credit before injecting.
//!
//! **Storage layout.** A [`Fifo`] is plain data: a base offset into a
//! [`ChanArena`], a power-of-two ring mask, and monotonically wrapping
//! `head`/`tail` push/pop counters. The arena holds every channel's
//! token payloads in four parallel arrays (`vals`/`rows`/`cols`/
//! `ready`), sized once at graph build by [`assign_arena`] — so a warm
//! simulation performs **zero heap allocations** on the push/pop path,
//! and the dense sweep walks contiguous memory instead of chasing
//! per-channel `VecDeque` blocks. The ring is `capacity`
//! rounded up to a power of two (asserted); `can_push` still gates on
//! the *exact* credit capacity, so flow control is unchanged — the
//! ring slack merely keeps the index math branch-free.
//!
//! Channels additionally know their **endpoint node ids** (bound by the
//! simulator from the DFG edge): a `push` is a future wake event for the
//! consumer at token-visibility time, and a `pop` frees a credit that
//! wakes the producer. The event-driven simulator core derives its
//! ready-list scheduling from exactly these two endpoints; the dense
//! core ignores them.

use super::Token;

/// Endpoint placeholder for a Fifo constructed outside a DFG (tests,
/// microbenches). [`Fifo::with_endpoints`] replaces it.
pub const NO_NODE: u32 = u32::MAX;

/// The shared token arena: one SoA block per simulator holding every
/// channel's in-flight tokens, indexed by `Fifo::base + (counter & mask)`.
/// Slots are assigned once by [`assign_arena`]; after that the arena
/// never grows.
#[derive(Debug, Clone)]
pub struct ChanArena {
    vals: Box<[f64]>,
    rows: Box<[u32]>,
    cols: Box<[u32]>,
    /// Cycle at which the slot's token becomes consumer-visible.
    ready: Box<[u64]>,
}

impl ChanArena {
    /// An arena with `slots` token slots (the sum of ring sizes that
    /// [`assign_arena`] returned).
    pub fn new(slots: usize) -> Self {
        Self {
            vals: vec![0.0; slots].into_boxed_slice(),
            rows: vec![0; slots].into_boxed_slice(),
            cols: vec![0; slots].into_boxed_slice(),
            ready: vec![0; slots].into_boxed_slice(),
        }
    }

    /// Total token slots.
    pub fn slots(&self) -> usize {
        self.vals.len()
    }
}

/// Assign each channel a disjoint base offset in the arena; returns the
/// total slot count an arena for these channels needs. Called once at
/// graph build ([`crate::cgra::PlacedGraph`]) — ring sizes are fixed
/// from then on.
pub fn assign_arena(fifos: &mut [Fifo]) -> usize {
    let mut off: u32 = 0;
    for f in fifos {
        f.base = off;
        off += f.ring_slots() as u32;
    }
    off as usize
}

#[derive(Debug, Clone)]
pub struct Fifo {
    /// First arena slot of this channel's ring.
    base: u32,
    /// `ring_slots - 1`; ring size is a power of two `>= capacity`.
    mask: u32,
    /// Monotonic push counter (wraps mod 2^32; slot = `base + (head & mask)`).
    head: u32,
    /// Monotonic pop counter.
    tail: u32,
    /// Credit capacity — the *exact* in-flight token limit.
    capacity: u32,
    latency: u64,
    /// Producer node id (`NO_NODE` when unbound).
    src_node: u32,
    /// Consumer node id (`NO_NODE` when unbound).
    dst_node: u32,
    /// High-water mark, for the occupancy statistics.
    pub max_occupancy: usize,
}

impl Fifo {
    pub fn new(capacity: usize, latency: u32) -> Self {
        assert!(capacity > 0, "zero-capacity channel deadlocks");
        assert!(capacity <= u32::MAX as usize / 2, "channel capacity overflows ring index");
        let ring = capacity.next_power_of_two();
        // Ring sizing is exact-and-asserted: a power of two at least the
        // credit capacity, so `counter & mask` indexing never aliases a
        // live token (occupancy is gated on `capacity <= ring`).
        assert!(ring.is_power_of_two() && ring >= capacity, "ring must cover capacity");
        Self {
            base: 0,
            mask: (ring - 1) as u32,
            head: 0,
            tail: 0,
            capacity: capacity as u32,
            latency: latency as u64,
            src_node: NO_NODE,
            dst_node: NO_NODE,
            max_occupancy: 0,
        }
    }

    /// An unbound Fifo plus a private arena exactly sized for it — the
    /// standalone form unit tests and microbenches use.
    pub fn standalone(capacity: usize, latency: u32) -> (Self, ChanArena) {
        let f = Self::new(capacity, latency);
        let a = ChanArena::new(f.ring_slots());
        (f, a)
    }

    /// Bind the producer/consumer node ids (the DFG edge endpoints).
    pub fn with_endpoints(mut self, src_node: u32, dst_node: u32) -> Self {
        self.src_node = src_node;
        self.dst_node = dst_node;
        self
    }

    /// Producer node id — the node a freed credit wakes.
    #[inline]
    pub fn src_node(&self) -> u32 {
        self.src_node
    }

    /// Consumer node id — the node a pushed token wakes at visibility.
    #[inline]
    pub fn dst_node(&self) -> u32 {
        self.dst_node
    }

    /// Cycles between a push and the token becoming visible.
    #[inline]
    pub fn latency(&self) -> u64 {
        self.latency
    }

    /// Credit capacity — the exact in-flight token limit the static
    /// deadlock rules reason about.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }

    /// Overwrite the credit capacity without re-ringing the arena —
    /// including to zero, which [`Fifo::new`] rejects. Exists solely so
    /// the static analyzer's mutation tests can seed the defects the
    /// `deadlock/*` rules must catch; a graph altered this way must
    /// never be simulated (the ring mask no longer covers the capacity).
    #[doc(hidden)]
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity as u32;
    }

    /// Ring slots this channel occupies in the arena (power of two).
    #[inline]
    pub fn ring_slots(&self) -> usize {
        self.mask as usize + 1
    }

    #[inline]
    pub fn can_push(&self) -> bool {
        self.len() < self.capacity as usize
    }

    #[inline]
    pub fn push(&mut self, a: &mut ChanArena, t: Token, now: u64) {
        self.push_delayed(a, t, now, 0);
    }

    /// Push with `extra` cycles of additional visibility latency on top
    /// of the channel's base latency — the injection point for
    /// fault-plan link-stall windows. `extra = 0` is exactly [`push`];
    /// the fault-free path always passes 0, so the hot path is
    /// unchanged when no plan is armed.
    ///
    /// [`push`]: Fifo::push
    #[inline]
    pub fn push_delayed(&mut self, a: &mut ChanArena, t: Token, now: u64, extra: u64) {
        debug_assert!(self.can_push());
        let slot = (self.base + (self.head & self.mask)) as usize;
        a.vals[slot] = t.val;
        a.rows[slot] = t.row;
        a.cols[slot] = t.col;
        a.ready[slot] = now + self.latency + extra;
        self.head = self.head.wrapping_add(1);
        let len = self.len();
        if len > self.max_occupancy {
            self.max_occupancy = len;
        }
    }

    /// The token at the head, if it has arrived.
    #[inline]
    pub fn peek(&self, a: &ChanArena, now: u64) -> Option<Token> {
        if self.is_empty() {
            return None;
        }
        let slot = (self.base + (self.tail & self.mask)) as usize;
        if a.ready[slot] <= now {
            Some(Token::new(a.vals[slot], a.rows[slot], a.cols[slot]))
        } else {
            None
        }
    }

    #[inline]
    pub fn pop(&mut self, a: &mut ChanArena, now: u64) -> Option<Token> {
        let t = self.peek(a, now)?;
        self.tail = self.tail.wrapping_add(1);
        Some(t)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.head.wrapping_sub(self.tail) as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.head == self.tail
    }

    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(v: f64) -> Token {
        Token::new(v, 0, 0)
    }

    #[test]
    fn respects_capacity() {
        let (mut f, mut a) = Fifo::standalone(2, 0);
        assert!(f.can_push());
        f.push(&mut a, tok(1.0), 0);
        f.push(&mut a, tok(2.0), 0);
        assert!(!f.can_push());
    }

    #[test]
    fn latency_hides_tokens() {
        let (mut f, mut a) = Fifo::standalone(4, 3);
        f.push(&mut a, tok(1.0), 10);
        assert!(f.peek(&a, 10).is_none());
        assert!(f.peek(&a, 12).is_none());
        assert_eq!(f.peek(&a, 13).unwrap().val, 1.0);
        assert_eq!(f.pop(&mut a, 13).unwrap().val, 1.0);
    }

    #[test]
    fn fifo_order_preserved() {
        let (mut f, mut a) = Fifo::standalone(8, 1);
        for i in 0..5 {
            f.push(&mut a, tok(i as f64), i);
        }
        for i in 0..5 {
            assert_eq!(f.pop(&mut a, 100).unwrap().val, i as f64);
        }
        assert!(f.is_empty());
    }

    #[test]
    fn head_blocks_until_ready_even_if_later_pushed_earlier() {
        // Order is strictly FIFO: a head with later ready time blocks.
        let (mut f, mut a) = Fifo::standalone(4, 5);
        f.push(&mut a, tok(1.0), 10); // ready 15
        f.push(&mut a, tok(2.0), 10); // ready 15
        assert!(f.pop(&mut a, 14).is_none());
        assert_eq!(f.pop(&mut a, 15).unwrap().val, 1.0);
    }

    #[test]
    fn push_delayed_adds_to_the_base_latency() {
        let (mut f, mut a) = Fifo::standalone(4, 3);
        f.push_delayed(&mut a, tok(1.0), 10, 5); // visible at 10 + 3 + 5
        assert!(f.peek(&a, 17).is_none());
        assert_eq!(f.peek(&a, 18).unwrap().val, 1.0);
        // extra = 0 is exactly push().
        f.push_delayed(&mut a, tok(2.0), 10, 0);
        f.pop(&mut a, 18);
        assert_eq!(f.peek(&a, 18).unwrap().val, 2.0);
    }

    #[test]
    fn tracks_max_occupancy() {
        let (mut f, mut a) = Fifo::standalone(8, 0);
        for i in 0..6 {
            f.push(&mut a, tok(i as f64), 0);
        }
        f.pop(&mut a, 0);
        f.pop(&mut a, 0);
        assert_eq!(f.max_occupancy, 6);
    }

    #[test]
    fn endpoints_default_unbound_and_bind() {
        let f = Fifo::new(2, 1);
        assert_eq!(f.src_node(), NO_NODE);
        assert_eq!(f.dst_node(), NO_NODE);
        let f = f.with_endpoints(3, 7);
        assert_eq!(f.src_node(), 3);
        assert_eq!(f.dst_node(), 7);
        assert_eq!(f.latency(), 1);
    }

    #[test]
    fn ring_sizes_are_exact_powers_of_two_covering_capacity() {
        // The old implementation clamped its pre-allocation hint to 1024
        // entries; ring sizing must instead be exact for any capacity.
        for cap in [1usize, 2, 3, 7, 64, 1000, 1024, 1025, 5000] {
            let f = Fifo::new(cap, 1);
            assert!(f.ring_slots().is_power_of_two());
            assert!(f.ring_slots() >= cap, "ring {} < cap {cap}", f.ring_slots());
            assert_eq!(f.capacity(), cap);
        }
    }

    #[test]
    fn large_capacity_fills_exactly() {
        // Past the old 1024-entry hint: all 3000 credits usable, FIFO order kept.
        let (mut f, mut a) = Fifo::standalone(3000, 0);
        for i in 0..3000 {
            assert!(f.can_push(), "credit {i} missing");
            f.push(&mut a, tok(i as f64), 0);
        }
        assert!(!f.can_push());
        for i in 0..3000 {
            assert_eq!(f.pop(&mut a, 0).unwrap().val, i as f64);
        }
    }

    #[test]
    fn wraparound_preserves_order_and_payload() {
        // Drive the monotonic counters through many ring revolutions.
        let (mut f, mut a) = Fifo::standalone(3, 0); // ring = 4 > capacity = 3
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        for _ in 0..1000 {
            while f.can_push() {
                f.push(&mut a, Token::new(next_in as f64, next_in as u32, 7), 0);
                next_in += 1;
            }
            for _ in 0..2 {
                let t = f.pop(&mut a, 0).unwrap();
                assert_eq!(t.val, next_out as f64);
                assert_eq!(t.row, next_out as u32);
                assert_eq!(t.col, 7);
                next_out += 1;
            }
        }
    }

    #[test]
    fn arena_assignment_is_disjoint_and_dense() {
        let mut fifos = vec![Fifo::new(3, 1), Fifo::new(1, 1), Fifo::new(5, 2)];
        let total = assign_arena(&mut fifos);
        assert_eq!(total, 4 + 1 + 8);
        let mut a = ChanArena::new(total);
        assert_eq!(a.slots(), total);
        // Fill every channel to capacity with channel-tagged payloads and
        // check no channel's traffic clobbers another's.
        for (ci, f) in fifos.iter_mut().enumerate() {
            for k in 0..f.capacity() {
                f.push(&mut a, Token::new(ci as f64 * 100.0 + k as f64, 0, 0), 0);
            }
        }
        for (ci, f) in fifos.iter_mut().enumerate() {
            for k in 0..f.capacity() {
                assert_eq!(f.pop(&mut a, 0).unwrap().val, ci as f64 * 100.0 + k as f64);
            }
        }
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn zero_capacity_rejected() {
        Fifo::new(0, 1);
    }
}
