//! Machine description of the target CGRA.
//!
//! Defaults follow §VI's assumptions: clock 1.2 GHz, 256 double-precision
//! MAC-capable PEs, 100 GB/s memory bandwidth — giving the 614 GFLOPS
//! compute roof of Fig 12. The physical grid is larger than the MAC count
//! because filters, copies, loads/stores and control units occupy non-MAC
//! PEs (§III-A counts them separately from the DP ops).

/// CGRA machine parameters (one tile).
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// Physical PE grid height.
    pub grid_rows: usize,
    /// Physical PE grid width.
    pub grid_cols: usize,
    /// Number of PEs capable of double-precision MUL/MAC (the §VI "Number
    /// of MACs = 256").
    pub mac_pes: usize,
    /// DRAM bandwidth in GB/s (one tile).
    pub bw_gbps: f64,
    /// DRAM access latency in cycles.
    pub dram_latency: u32,
    /// Shared-cache capacity in KiB.
    pub cache_kib: usize,
    /// Cache line size in bytes.
    pub cache_line: usize,
    /// Cache hit latency in cycles.
    pub cache_hit_latency: u32,
    /// Outstanding loads per load PE. Reader workers are decoupled
    /// access/execute pairs streaming from scratchpad-backed prefetch
    /// queues (§II-A), so this must cover the DRAM latency to stream at
    /// one load per cycle.
    pub mshr_per_load: usize,
    /// Maximum triggered instructions a PE can hold (TIA limit).
    pub max_instr_per_pe: usize,
    /// Network hops traversed per cycle (the paper estimates PE-to-PE
    /// communication ~6x faster than V100 register-to-SMEM).
    pub hops_per_cycle: usize,
    /// Words per cycle an inter-tile boundary link can carry (the
    /// bandwidth cap on one producer->consumer halo channel in the
    /// priced exchange model).
    pub link_words_per_cycle: usize,
}

impl Default for Machine {
    fn default() -> Self {
        Self::paper()
    }
}

impl Machine {
    /// The §VI target: 1.2 GHz, 256 MACs, 100 GB/s.
    pub fn paper() -> Self {
        Self {
            clock_ghz: 1.2,
            grid_rows: 24,
            grid_cols: 32,
            mac_pes: 256,
            bw_gbps: 100.0,
            dram_latency: 100,
            cache_kib: 512,
            cache_line: 64,
            cache_hit_latency: 6,
            mshr_per_load: 160,
            max_instr_per_pe: 16,
            hops_per_cycle: 4,
            link_words_per_cycle: 8,
        }
    }

    /// Check every field for physical sense. Division sites downstream
    /// (`hops.div_ceil(hops_per_cycle)` in placement and the exchange
    /// pricer, `bw_gbps / clock_ghz` in the roofline) assume these
    /// bounds, so a bad machine must be rejected at the config /
    /// `CompileOptions` boundary instead of panicking mid-compile.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(
            self.clock_ghz.is_finite() && self.clock_ghz > 0.0,
            "machine: clock_ghz = {} (must be finite and > 0)",
            self.clock_ghz
        );
        ensure!(
            self.bw_gbps.is_finite() && self.bw_gbps > 0.0,
            "machine: bw_gbps = {} (must be finite and > 0)",
            self.bw_gbps
        );
        ensure!(
            self.grid_rows >= 1 && self.grid_cols >= 1,
            "machine: grid {}x{} (both extents must be >= 1)",
            self.grid_rows,
            self.grid_cols
        );
        ensure!(self.mac_pes >= 1, "machine: mac_pes = 0 (must be >= 1)");
        ensure!(
            self.cache_line >= 8,
            "machine: cache_line = {} (must hold at least one 8-byte word)",
            self.cache_line
        );
        ensure!(
            self.mshr_per_load >= 1,
            "machine: mshr_per_load = 0 (must be >= 1)"
        );
        ensure!(
            self.max_instr_per_pe >= 1,
            "machine: max_instr_per_pe = 0 (must be >= 1)"
        );
        ensure!(
            self.hops_per_cycle >= 1,
            "machine: hops_per_cycle = 0 (must be >= 1; hop latency divides by it)"
        );
        ensure!(
            self.link_words_per_cycle >= 1,
            "machine: link_words_per_cycle = 0 (must be >= 1; the exchange \
             bandwidth cap divides by it)"
        );
        Ok(())
    }

    /// A small fabric for unit tests (forces instruction packing).
    pub fn tiny() -> Self {
        Self {
            grid_rows: 4,
            grid_cols: 4,
            mac_pes: 16,
            ..Self::paper()
        }
    }

    /// Peak double-precision GFLOPS: `2 * MACs * clock` (§VI: 614).
    pub fn peak_gflops(&self) -> f64 {
        2.0 * self.mac_pes as f64 * self.clock_ghz
    }

    /// DRAM bytes deliverable per cycle.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bw_gbps / self.clock_ghz
    }

    /// Total PEs on the fabric.
    pub fn total_pes(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// Roofline-attainable GFLOPS at arithmetic intensity `ai` (Fig 12).
    pub fn roofline_gflops(&self, ai: f64) -> f64 {
        (self.bw_gbps * ai).min(self.peak_gflops())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_is_614_gflops() {
        let m = Machine::paper();
        assert!((m.peak_gflops() - 614.4).abs() < 0.1);
    }

    #[test]
    fn paper_bytes_per_cycle() {
        let m = Machine::paper();
        assert!((m.bytes_per_cycle() - 83.33).abs() < 0.01);
    }

    #[test]
    fn roofline_crossover() {
        let m = Machine::paper();
        // §VI: AI 2.06 -> 206 GFLOPS (bandwidth-bound).
        assert!((m.roofline_gflops(2.06) - 206.0).abs() < 0.5);
        // AI 5.59 -> 559 GFLOPS (still bandwidth-bound).
        assert!((m.roofline_gflops(5.59) - 559.0).abs() < 0.5);
        // Very high AI -> compute-bound at 614.
        assert!((m.roofline_gflops(100.0) - m.peak_gflops()).abs() < 1e-9);
    }

    #[test]
    fn grid_holds_more_than_macs() {
        let m = Machine::paper();
        assert!(m.total_pes() > m.mac_pes);
    }

    #[test]
    fn validate_accepts_the_paper_machine() {
        assert!(Machine::paper().validate().is_ok());
        assert!(Machine::tiny().validate().is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_fields() {
        let cases: Vec<(&str, Machine)> = vec![
            ("hops_per_cycle", Machine { hops_per_cycle: 0, ..Machine::paper() }),
            ("clock_ghz", Machine { clock_ghz: 0.0, ..Machine::paper() }),
            ("clock_ghz", Machine { clock_ghz: f64::NAN, ..Machine::paper() }),
            ("bw_gbps", Machine { bw_gbps: -1.0, ..Machine::paper() }),
            ("grid", Machine { grid_rows: 0, ..Machine::paper() }),
            ("mac_pes", Machine { mac_pes: 0, ..Machine::paper() }),
            ("cache_line", Machine { cache_line: 4, ..Machine::paper() }),
            ("mshr_per_load", Machine { mshr_per_load: 0, ..Machine::paper() }),
            ("link_words_per_cycle", Machine { link_words_per_cycle: 0, ..Machine::paper() }),
        ];
        for (field, m) in cases {
            let err = m.validate().unwrap_err().to_string();
            assert!(err.contains(field), "{field}: {err}");
        }
    }
}
