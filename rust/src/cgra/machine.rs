//! Machine description of the target CGRA.
//!
//! Defaults follow §VI's assumptions: clock 1.2 GHz, 256 double-precision
//! MAC-capable PEs, 100 GB/s memory bandwidth — giving the 614 GFLOPS
//! compute roof of Fig 12. The physical grid is larger than the MAC count
//! because filters, copies, loads/stores and control units occupy non-MAC
//! PEs (§III-A counts them separately from the DP ops).

/// CGRA machine parameters (one tile).
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// Physical PE grid height.
    pub grid_rows: usize,
    /// Physical PE grid width.
    pub grid_cols: usize,
    /// Number of PEs capable of double-precision MUL/MAC (the §VI "Number
    /// of MACs = 256").
    pub mac_pes: usize,
    /// DRAM bandwidth in GB/s (one tile).
    pub bw_gbps: f64,
    /// DRAM access latency in cycles.
    pub dram_latency: u32,
    /// Shared-cache capacity in KiB.
    pub cache_kib: usize,
    /// Cache line size in bytes.
    pub cache_line: usize,
    /// Cache hit latency in cycles.
    pub cache_hit_latency: u32,
    /// Outstanding loads per load PE. Reader workers are decoupled
    /// access/execute pairs streaming from scratchpad-backed prefetch
    /// queues (§II-A), so this must cover the DRAM latency to stream at
    /// one load per cycle.
    pub mshr_per_load: usize,
    /// Maximum triggered instructions a PE can hold (TIA limit).
    pub max_instr_per_pe: usize,
    /// Network hops traversed per cycle (the paper estimates PE-to-PE
    /// communication ~6x faster than V100 register-to-SMEM).
    pub hops_per_cycle: usize,
}

impl Default for Machine {
    fn default() -> Self {
        Self::paper()
    }
}

impl Machine {
    /// The §VI target: 1.2 GHz, 256 MACs, 100 GB/s.
    pub fn paper() -> Self {
        Self {
            clock_ghz: 1.2,
            grid_rows: 24,
            grid_cols: 32,
            mac_pes: 256,
            bw_gbps: 100.0,
            dram_latency: 100,
            cache_kib: 512,
            cache_line: 64,
            cache_hit_latency: 6,
            mshr_per_load: 160,
            max_instr_per_pe: 16,
            hops_per_cycle: 4,
        }
    }

    /// A small fabric for unit tests (forces instruction packing).
    pub fn tiny() -> Self {
        Self {
            grid_rows: 4,
            grid_cols: 4,
            mac_pes: 16,
            ..Self::paper()
        }
    }

    /// Peak double-precision GFLOPS: `2 * MACs * clock` (§VI: 614).
    pub fn peak_gflops(&self) -> f64 {
        2.0 * self.mac_pes as f64 * self.clock_ghz
    }

    /// DRAM bytes deliverable per cycle.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bw_gbps / self.clock_ghz
    }

    /// Total PEs on the fabric.
    pub fn total_pes(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// Roofline-attainable GFLOPS at arithmetic intensity `ai` (Fig 12).
    pub fn roofline_gflops(&self, ai: f64) -> f64 {
        (self.bw_gbps * ai).min(self.peak_gflops())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_is_614_gflops() {
        let m = Machine::paper();
        assert!((m.peak_gflops() - 614.4).abs() < 0.1);
    }

    #[test]
    fn paper_bytes_per_cycle() {
        let m = Machine::paper();
        assert!((m.bytes_per_cycle() - 83.33).abs() < 0.01);
    }

    #[test]
    fn roofline_crossover() {
        let m = Machine::paper();
        // §VI: AI 2.06 -> 206 GFLOPS (bandwidth-bound).
        assert!((m.roofline_gflops(2.06) - 206.0).abs() < 0.5);
        // AI 5.59 -> 559 GFLOPS (still bandwidth-bound).
        assert!((m.roofline_gflops(5.59) - 559.0).abs() < 0.5);
        // Very high AI -> compute-bound at 614.
        assert!((m.roofline_gflops(100.0) - m.peak_gflops()).abs() < 1e-9);
    }

    #[test]
    fn grid_holds_more_than_macs() {
        let m = Machine::paper();
        assert!(m.total_pes() > m.mac_pes);
    }
}
