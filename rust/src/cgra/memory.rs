//! Memory subsystem: a shared direct-mapped cache in front of a
//! bandwidth-limited, fixed-latency DRAM channel (§VIII simulates
//! "scratchpads, private cache, shared cache ... and memory").
//!
//! * Loads read `input[addr]` functionally at issue; *timing* comes from
//!   the cache/DRAM model. Line fills are merged MSHR-style: concurrent
//!   loads to an in-flight line piggyback on the fill.
//! * Stores write `output[addr]` functionally at issue and consume write
//!   bandwidth (write-through, no allocate); the ack token the sync
//!   workers count is released when the write drains.
//! * Bandwidth is a token bucket replenished with
//!   [`Machine::bytes_per_cycle`] per cycle and drained FIFO by
//!   transactions, so reads and writes share the §VI 100 GB/s channel.
//!
//! Line metadata is **bounded**: per-set state (resident tag arrival
//! time + last-evicted tag) replaces the old ever-growing map of every
//! line ever filled, so long multi-step runs hold steady-state memory.
//! The event-driven simulator core additionally needs to *sleep until a
//! response arrives*: [`MemSys::completion`] exposes the completion
//! cycle of a ticket once the bandwidth arbiter has granted it, newly
//! granted tickets are queued for [`MemSys::drain_resolved`] (when
//! recording is enabled), and [`MemSys::advance_to`] replays the
//! per-cycle arbiter across a gap of skipped cycles — bit-identically to
//! calling [`MemSys::step`] once per cycle, but O(1) once the bandwidth
//! budget saturates with an empty queue.
//!
//! The hot path is **allocation-free after warm-up**: callers size the
//! ticket table and transaction queue up front via [`MemSys::reserve`],
//! and fill waiters form intrusive lists threaded through a
//! tickets-parallel array instead of per-line vectors.

use std::collections::HashMap;
use std::collections::VecDeque;

use super::machine::Machine;
use super::stats::MemStats;
use crate::util::fault::{FaultPlan, MAX_FILL_RETRIES};

/// Handle for an outstanding memory operation.
pub type Ticket = u32;

/// Cycles one halo word spends in the network for a transfer spanning
/// `mesh_hops` tile-mesh hops on machine `m`: the mesh distance is
/// scaled to PE hops by the fabric span (a neighboring tile sits a full
/// grid away), then divided by the per-cycle hop rate. With the span
/// at least `hops_per_cycle` (every realistic machine) the result is
/// strictly monotone in `mesh_hops` — a far neighbor always costs more
/// cycles than a near one.
pub fn mesh_hop_cycles(mesh_hops: usize, m: &Machine) -> u64 {
    let pe_hops = (mesh_hops * m.grid_rows.max(m.grid_cols)) as u64;
    pe_hops.div_ceil(m.hops_per_cycle.max(1) as u64)
}

/// One priced region of a fabric-resident input buffer: a
/// local-coordinate box `[lo, hi)` (relative to the tile's input box,
/// x-fastest row-major addressing) plus the latency surcharge its
/// boundary link adds. Each region models one producer -> consumer
/// boundary and owns an independent bandwidth bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostRegion {
    pub lo: [usize; 3],
    pub hi: [usize; 3],
    /// Network cycles added on top of hit latency
    /// (see [`mesh_hop_cycles`]).
    pub hop_cycles: u64,
}

/// Hop-latency pricing for a fabric-resident input buffer (the warm
/// halo-exchange chunks). Loads whose local address falls in a region
/// complete at `hit_latency + hop_cycles` after the cycle the region's
/// link can start the transfer (at most [`ExchangeCost::link_words`]
/// starts per cycle per region, FIFO); addresses matching no region are
/// truly resident and stay at flat hit latency. **First match wins**,
/// so callers order regions specific-to-general (neighbor transfers,
/// then the own-output box at zero cost, then the ring catch-all).
///
/// Every completion is a pure function of the load-issue sequence
/// (issue cycle + address), which both scheduler cores reproduce
/// bit-identically — so pricing needs no new arbiter machinery and
/// [`MemSys::advance_to`] is untouched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExchangeCost {
    /// Local input-box extents `[ex, ey, ez]` for address decode:
    /// `addr = (z * ey + y) * ex + x`.
    pub ext: [usize; 3],
    /// Ordered priced regions (first match wins).
    pub regions: Vec<CostRegion>,
    /// Transfers one boundary link can start per cycle
    /// ([`Machine::link_words_per_cycle`]).
    pub link_words: u64,
}

impl ExchangeCost {
    /// Index of the first region containing local word address `addr`,
    /// if any.
    fn region_of(&self, addr: u64) -> Option<usize> {
        let ex = self.ext[0] as u64;
        let ey = self.ext[1] as u64;
        let x = (addr % ex) as usize;
        let y = ((addr / ex) % ey) as usize;
        let z = (addr / (ex * ey)) as usize;
        self.regions.iter().position(|r| {
            x >= r.lo[0]
                && x < r.hi[0]
                && y >= r.lo[1]
                && y < r.hi[1]
                && z >= r.lo[2]
                && z < r.hi[2]
        })
    }
}

const UNGRANTED: u64 = u64::MAX;
const NO_TAG: u64 = u64::MAX;
/// End-of-chain sentinel for the intrusive fill-waiter lists.
const NO_WAITER: Ticket = Ticket::MAX;

#[derive(Debug)]
enum Txn {
    /// A cache-line fill for `line`; completes `dram_latency` after the
    /// bandwidth grant and then backfills every ticket waiting on it.
    /// `retry` counts transient injected failures so far; `not_before`
    /// is the backoff release cycle of the latest retry (0 on first
    /// issue — never gates an un-faulted fill).
    Fill { line: u64, retry: u32, not_before: u64 },
    /// An 8-byte store drain for `ticket`.
    Store { ticket: Ticket },
}

#[derive(Debug)]
pub struct MemSys {
    input: Vec<f64>,
    output: Vec<f64>,
    bytes_per_cycle: f64,
    budget: f64,
    budget_cap: f64,
    dram_latency: u64,
    hit_latency: u64,
    line_words: u64,
    line_bytes: f64,
    /// Direct-mapped tag store: `sets[set] = line` or `NO_TAG`.
    sets: Vec<u64>,
    /// Completion cycle of the fill that installed each set's resident
    /// line (a hit cannot be served before the line physically arrives).
    set_fill_done: Vec<u64>,
    /// Tag most recently evicted from each set (`NO_TAG` = none) — the
    /// bounded record behind conflict-miss classification: a miss that
    /// refetches the set's last victim is a conflict miss.
    last_evicted: Vec<u64>,
    /// Head ticket of the intrusive waiter list per in-flight line fill
    /// (bounded by the number of in-flight fills). The rest of each
    /// list is threaded through `waiter_next`, so MSHR merges never
    /// allocate on the hot path.
    line_waiters: HashMap<u64, Ticket>,
    /// Intrusive next-pointers, parallel to `tickets` (`NO_WAITER` ends
    /// a chain).
    waiter_next: Vec<Ticket>,
    /// Completion cycle per ticket (`UNGRANTED` until known).
    tickets: Vec<u64>,
    queue: VecDeque<(f64, Txn)>,
    /// Tickets whose completion became known at the latest grants; only
    /// populated when `record_resolved` is set (the event core drains
    /// these to schedule Load/Store wakeups).
    resolved: Vec<Ticket>,
    record_resolved: bool,
    /// Halo-exchange mode: the whole input buffer is already resident on
    /// the fabric (delivered by a neighboring tile's exchange or held
    /// from this tile's previous chunk), so loads complete at hit
    /// latency without touching the cache or DRAM.
    fabric_resident: bool,
    /// Hop-latency pricing for the resident buffer (`None` = the free
    /// PR 6 model: every resident load at flat hit latency).
    exchange_cost: Option<ExchangeCost>,
    /// Per-region link state `(cycle, starts_used)` — the issue-time
    /// bandwidth bucket each [`CostRegion`] drains.
    link_buckets: Vec<(u64, u64)>,
    /// Armed fault plan, if any. `None` (the default) is the
    /// zero-overhead path: the grant loop's only extra work is one
    /// `not_before` compare against the constant 0.
    fault: Option<FaultPlan>,
    /// Global fill-grant attempt counter — the deterministic coordinate
    /// `FaultPlan::fill_fails` is keyed on. Both scheduler cores grant
    /// in the same order, so the sequence (and therefore every injected
    /// failure) is identical across them.
    fill_attempts: u64,
    pub stats: MemStats,
}

impl MemSys {
    /// `input` is the read-only grid; `output` the store target (callers
    /// pre-fill it with the boundary values — see `verify::golden`).
    pub fn new(m: &Machine, input: Vec<f64>, output: Vec<f64>) -> Self {
        let line_bytes = m.cache_line as f64;
        let n_sets = (m.cache_kib * 1024 / m.cache_line).max(1);
        Self {
            input,
            output,
            bytes_per_cycle: m.bytes_per_cycle(),
            budget: 0.0,
            budget_cap: (4.0 * line_bytes).max(2.0 * m.bytes_per_cycle()),
            dram_latency: m.dram_latency as u64,
            hit_latency: m.cache_hit_latency as u64,
            line_words: (m.cache_line / 8) as u64,
            line_bytes,
            sets: vec![NO_TAG; n_sets],
            set_fill_done: vec![0; n_sets],
            last_evicted: vec![NO_TAG; n_sets],
            line_waiters: HashMap::new(),
            waiter_next: Vec::new(),
            tickets: Vec::new(),
            queue: VecDeque::new(),
            resolved: Vec::new(),
            record_resolved: false,
            fabric_resident: false,
            exchange_cost: None,
            link_buckets: Vec::new(),
            fault: None,
            fill_attempts: 0,
            stats: MemStats::default(),
        }
    }

    /// Arm a fault plan (or disarm with `None`). Only plans with a
    /// non-zero fill-failure percentage change this module's behaviour;
    /// stall/slow-down families are applied by the simulator cores.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault = plan.filter(|p| p.fill_fail_pct > 0);
    }

    /// Mark the whole input buffer as fabric-resident (halo exchange):
    /// every subsequent load is served at hit latency and counted in
    /// [`MemStats::exchanged`] instead of going through the cache/DRAM
    /// model. Purely a timing/accounting change — the functional value
    /// read is identical either way, so outputs cannot differ.
    pub fn set_fabric_resident(&mut self, on: bool) {
        self.fabric_resident = on;
    }

    /// Arm hop-latency pricing for the fabric-resident buffer (or
    /// disarm with `None`). Only meaningful while fabric-resident;
    /// resets every region's link bucket.
    pub fn set_exchange_cost(&mut self, cost: Option<ExchangeCost>) {
        self.link_buckets.clear();
        if let Some(c) = &cost {
            self.link_buckets.resize(c.regions.len(), (0, 0));
        }
        self.exchange_cost = cost;
    }

    /// Preallocate for a run that will issue at most `tickets` tickets
    /// and hold at most `inflight` simultaneously outstanding
    /// transactions. With honest bounds, the cycle loop performs zero
    /// heap allocations inside this module: tickets grow within the
    /// reserved capacity, the transaction queue and resolved list never
    /// exceed the MSHR-limited in-flight count, and the waiter map holds
    /// one entry per in-flight fill.
    pub fn reserve(&mut self, tickets: usize, inflight: usize) {
        self.tickets.reserve(tickets);
        self.waiter_next.reserve(tickets);
        self.queue.reserve(inflight);
        self.resolved.reserve(inflight);
        self.line_waiters.reserve(inflight);
    }

    fn new_ticket(&mut self) -> Ticket {
        self.tickets.push(UNGRANTED);
        self.waiter_next.push(NO_WAITER);
        (self.tickets.len() - 1) as Ticket
    }

    /// Advance the bandwidth arbiter one cycle. Call once per cycle
    /// before evaluating PEs. Returns true if any transaction was
    /// granted (progress, for deadlock detection).
    pub fn step(&mut self, now: u64) -> bool {
        self.budget = (self.budget + self.bytes_per_cycle).min(self.budget_cap);
        let mut progressed = false;
        while let Some((bytes, txn)) = self.queue.front() {
            // A retried fill holds the head of the queue until its
            // backoff expires (FIFO order is part of the determinism
            // contract). `not_before` is 0 on every un-faulted fill, so
            // the unarmed path pays one compare against a constant.
            if let Txn::Fill { not_before, .. } = txn {
                if *not_before > now {
                    break;
                }
            }
            if *bytes > self.budget {
                break;
            }
            let (bytes, txn) = self.queue.pop_front().unwrap();
            self.budget -= bytes;
            progressed = true;
            match txn {
                Txn::Fill { line, retry, .. } => {
                    // Transient fill failure: the grant consumed
                    // bandwidth (the bus transfer was wasted) but no
                    // data arrived — re-queue with exponential backoff.
                    // Bounded: after MAX_FILL_RETRIES the fill succeeds
                    // unconditionally, so forward progress holds under
                    // any plan.
                    let attempt = self.fill_attempts;
                    self.fill_attempts += 1;
                    if retry < MAX_FILL_RETRIES {
                        if let Some(p) = &self.fault {
                            if p.fill_fails(attempt) {
                                self.stats.retries += 1;
                                self.queue.push_back((
                                    bytes,
                                    Txn::Fill {
                                        line,
                                        retry: retry + 1,
                                        not_before: now + FaultPlan::backoff(retry),
                                    },
                                ));
                                continue;
                            }
                        }
                    }
                    let done = now + self.dram_latency;
                    self.stats.dram_read_bytes += bytes as u64;
                    // Install the tag (evicting) and release the waiters.
                    let set = (line % self.sets.len() as u64) as usize;
                    if self.sets[set] != NO_TAG && self.sets[set] != line {
                        self.stats.evictions += 1;
                        self.last_evicted[set] = self.sets[set];
                    }
                    self.sets[set] = line;
                    self.set_fill_done[set] = done;
                    if let Some(head) = self.line_waiters.remove(&line) {
                        let mut t = head;
                        while t != NO_WAITER {
                            self.tickets[t as usize] = done;
                            if self.record_resolved {
                                self.resolved.push(t);
                            }
                            let next = self.waiter_next[t as usize];
                            self.waiter_next[t as usize] = NO_WAITER;
                            t = next;
                        }
                    }
                }
                Txn::Store { ticket } => {
                    self.stats.dram_write_bytes += bytes as u64;
                    // Posted write: ack after a short drain.
                    self.tickets[ticket as usize] = now + 2;
                    if self.record_resolved {
                        self.resolved.push(ticket);
                    }
                }
            }
        }
        progressed
    }

    /// Replay the per-cycle arbiter over cycles `from + 1 ..= to`,
    /// exactly as if [`MemSys::step`] were called once per cycle.
    /// Returns the last cycle at which a transaction was granted, if
    /// any. Cycles with an empty queue only replenish the bandwidth
    /// budget; once the budget saturates at `budget_cap` the remaining
    /// idle cycles are no-ops and are skipped in O(1) — the property
    /// that lets the event core jump the clock without perturbing the
    /// timing model.
    pub fn advance_to(&mut self, from: u64, to: u64) -> Option<u64> {
        let mut last_grant = None;
        let mut c = from + 1;
        while c <= to {
            if self.queue.is_empty() {
                if self.budget == self.budget_cap {
                    break; // saturated: every further empty-queue step is a no-op
                }
                self.budget = (self.budget + self.bytes_per_cycle).min(self.budget_cap);
            } else if self.step(c) {
                last_grant = Some(c);
            }
            c += 1;
        }
        last_grant
    }

    /// Issue a load of word address `addr`. Returns the value (functional
    /// read happens now) and the ticket whose completion gates delivery.
    pub fn load(&mut self, addr: u64, now: u64) -> (f64, Ticket) {
        let val = self.input[addr as usize];
        self.stats.loads += 1;
        if self.fabric_resident {
            // Exchange hit: the word is already on fabric. Completion is
            // known at issue (like a cache hit with no line-arrival
            // bound), so the event core's sleep-until-completion path
            // works unchanged and no resolved record is needed. With a
            // cost model armed, words inside a priced region pay the
            // boundary link's hop latency and queue behind its per-cycle
            // start budget — still issue-time-known.
            let t = self.new_ticket();
            let flat = now + self.hit_latency;
            let mut done = flat;
            if let Some(cost) = &self.exchange_cost {
                if let Some(r) = cost.region_of(addr) {
                    let b = &mut self.link_buckets[r];
                    if now > b.0 {
                        *b = (now, 0);
                    }
                    if b.1 >= cost.link_words {
                        b.0 += 1;
                        b.1 = 0;
                    }
                    b.1 += 1;
                    done = b.0 + self.hit_latency + cost.regions[r].hop_cycles;
                    self.stats.exchanged_hop_cycles += done - flat;
                }
            }
            self.tickets[t as usize] = done;
            self.stats.exchanged += 1;
            return (val, t);
        }
        let line = addr / self.line_words;
        let set = (line % self.sets.len() as u64) as usize;
        let t = self.new_ticket();
        if self.sets[set] == line {
            // Hit — but not before the line actually arrived.
            let arrive = self.set_fill_done[set];
            self.tickets[t as usize] = (now + self.hit_latency).max(arrive);
            self.stats.hits += 1;
        } else if let Some(head) = self.line_waiters.get_mut(&line) {
            // Fill already queued: merge (MSHR). Prepend to the intrusive
            // chain — all waiters on one fill complete at the same cycle,
            // so order within the chain is unobservable.
            self.waiter_next[t as usize] = *head;
            *head = t;
            self.stats.merged += 1;
        } else {
            // Miss: queue a line fill. Refetching the set's last victim
            // is the bounded-state stand-in for "was cached before".
            if self.last_evicted[set] == line {
                self.stats.conflict_misses += 1;
            }
            self.stats.misses += 1;
            self.line_waiters.insert(line, t);
            self.queue
                .push_back((self.line_bytes, Txn::Fill { line, retry: 0, not_before: 0 }));
        }
        (val, t)
    }

    /// Issue a store of `val` to word address `addr`.
    pub fn store(&mut self, addr: u64, val: f64, _now: u64) -> Ticket {
        self.output[addr as usize] = val;
        self.stats.stores += 1;
        let t = self.new_ticket();
        self.queue.push_back((8.0, Txn::Store { ticket: t }));
        t
    }

    /// Is the operation behind `ticket` complete at `now`?
    #[inline]
    pub fn done(&self, ticket: Ticket, now: u64) -> bool {
        self.tickets[ticket as usize] <= now
    }

    /// Completion cycle of `ticket`, or `None` while the bandwidth
    /// arbiter has not granted it yet (the event core sleeps the owner
    /// until then and relies on [`MemSys::drain_resolved`]).
    #[inline]
    pub fn completion(&self, ticket: Ticket) -> Option<u64> {
        let c = self.tickets[ticket as usize];
        (c != UNGRANTED).then_some(c)
    }

    /// Number of tickets issued so far (ticket ids are sequential, so a
    /// caller can attribute the tickets created by a just-evaluated node
    /// as `before..count`).
    #[inline]
    pub fn ticket_count(&self) -> usize {
        self.tickets.len()
    }

    /// Enable/disable recording of newly granted tickets for
    /// [`MemSys::drain_resolved`] (off by default — the dense core never
    /// drains, so recording would only grow a vector).
    pub fn set_record_resolved(&mut self, on: bool) {
        self.record_resolved = on;
    }

    /// Move the tickets granted since the last drain into `out`.
    pub fn drain_resolved(&mut self, out: &mut Vec<Ticket>) {
        out.extend(self.resolved.drain(..));
    }

    /// Any queued or unresolved work? (for deadlock detection)
    pub fn busy(&self) -> bool {
        !self.queue.is_empty()
    }

    /// One-line state snapshot for the deadlock forensic report: queue
    /// depth, the head transaction, and the oldest ticket still
    /// outstanding at `now`. Cold path only — allocates freely; never
    /// called from inside a hot region. Deliberately excludes the
    /// bandwidth budget: it keeps replenishing during the dense core's
    /// idle quiet period while the event core's memory clock stops at
    /// the last event, and the forensic text must stay byte-identical
    /// across cores.
    pub fn forensic_summary(&self, now: u64) -> String {
        let head = match self.queue.front() {
            None => "queue empty".to_string(),
            Some((bytes, Txn::Fill { line, retry, not_before })) => format!(
                "head fill line {line} ({bytes:.0} B, retry {retry}, not before cycle {not_before})"
            ),
            Some((bytes, Txn::Store { ticket })) => {
                format!("head store ticket #{ticket} ({bytes:.0} B)")
            }
        };
        let oldest = self
            .tickets
            .iter()
            .enumerate()
            .find(|(_, &done)| done == UNGRANTED || done > now);
        let oldest = match oldest {
            None => "no outstanding tickets".to_string(),
            Some((t, &done)) if done == UNGRANTED => {
                format!("oldest outstanding ticket #{t} (ungranted)")
            }
            Some((t, &done)) => format!("oldest outstanding ticket #{t} (due cycle {done})"),
        };
        format!(
            "memory: {} queued txn(s), {head}, {oldest}, {} retried fill(s)",
            self.queue.len(),
            self.stats.retries
        )
    }

    /// Take the output grid at end of simulation.
    pub fn into_output(self) -> (Vec<f64>, MemStats) {
        (self.output, self.stats)
    }

    pub fn input_len(&self) -> usize {
        self.input.len()
    }

    pub fn output_len(&self) -> usize {
        self.output.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(input: Vec<f64>) -> MemSys {
        let n = input.len();
        MemSys::new(&Machine::paper(), input, vec![0.0; n])
    }

    #[test]
    fn load_returns_value_and_completes_after_latency() {
        let mut m = mk((0..100).map(|i| i as f64).collect());
        let (v, t) = m.load(7, 0);
        assert_eq!(v, 7.0);
        assert!(!m.done(t, 0));
        assert_eq!(m.completion(t), None, "ungranted ticket has no completion");
        // Grant the fill on the next step; completes dram_latency later.
        m.step(1);
        assert!(!m.done(t, 50));
        assert!(m.done(t, 1 + 100));
        assert_eq!(m.completion(t), Some(1 + 100));
    }

    #[test]
    fn second_load_same_line_hits_or_merges() {
        let mut m = mk((0..100).map(|i| i as f64).collect());
        let (_, _t1) = m.load(0, 0);
        let (_, _t2) = m.load(1, 0); // same 8-word line -> merged
        assert_eq!(m.stats.misses, 1);
        assert_eq!(m.stats.merged, 1);
        m.step(1);
        // After the fill is installed, a third access is a hit.
        let (_, t3) = m.load(2, 2);
        assert_eq!(m.stats.hits, 1);
        assert!(m.done(t3, 2 + 101)); // bounded by line arrival
    }

    #[test]
    fn store_writes_functionally_and_acks() {
        let mut m = mk(vec![0.0; 16]);
        let t = m.store(3, 9.5, 0);
        m.step(1);
        assert!(m.done(t, 3));
        let (out, stats) = m.into_output();
        assert_eq!(out[3], 9.5);
        assert_eq!(stats.dram_write_bytes, 8);
    }

    #[test]
    fn bandwidth_throttles_fills() {
        // bytes_per_cycle ~83; a 64-byte fill per cycle is fine, but many
        // queued fills drain at ~1.3 lines/cycle, not instantly.
        let mut m = mk((0..8192).map(|i| i as f64).collect());
        for i in 0..32 {
            let _ = m.load(i * 8, 0); // 32 distinct lines
        }
        assert_eq!(m.stats.misses, 32);
        let mut grants = 0;
        let mut cycle = 1;
        while m.busy() {
            m.step(cycle);
            grants += 1;
            cycle += 1;
            assert!(cycle < 1000);
        }
        // 32 lines * 64B / 83.3B-per-cycle ≈ 25 cycles minimum.
        assert!(grants >= 24, "drained too fast: {grants} cycles");
    }

    #[test]
    fn conflict_miss_counted_on_refetch_after_eviction() {
        let mut m = MemSys::new(
            &Machine {
                cache_kib: 1, // 16 sets of 64B -> easy conflicts
                ..Machine::paper()
            },
            (0..65536).map(|i| i as f64).collect(),
            vec![0.0; 1],
        );
        // Two addresses 16 lines apart map to the same set.
        let stride_words = 16 * 8;
        let _ = m.load(0, 0);
        m.step(1);
        let _ = m.load(stride_words, 2);
        m.step(3);
        assert_eq!(m.stats.evictions, 1);
        let _ = m.load(0, 4); // refetch of the set's last victim
        assert_eq!(m.stats.conflict_misses, 1);
    }

    #[test]
    fn ping_pong_conflicts_stay_classified_with_bounded_state() {
        // A ping-pong pattern between two same-set lines: every refetch
        // after the first round trips the last-evicted record, so the
        // bounded classification keeps counting (no unbounded map
        // needed).
        let mut m = MemSys::new(
            &Machine {
                cache_kib: 1,
                ..Machine::paper()
            },
            (0..65536).map(|i| i as f64).collect(),
            vec![0.0; 1],
        );
        let stride_words = 16 * 8;
        let mut cycle = 0;
        for _round in 0..4 {
            let _ = m.load(0, cycle);
            m.step(cycle + 1);
            let _ = m.load(stride_words, cycle + 2);
            m.step(cycle + 3);
            cycle += 4;
        }
        // 8 misses total; all but the first two are conflict refetches.
        assert_eq!(m.stats.misses, 8);
        assert_eq!(m.stats.conflict_misses, 6);
        assert_eq!(m.stats.evictions, 7);
    }

    #[test]
    fn advance_to_is_bitwise_equal_to_per_cycle_steps() {
        // Replay semantics: stepping one-by-one and advancing across a
        // gap must produce identical grant times, budgets and stats.
        let grid: Vec<f64> = (0..8192).map(|i| i as f64).collect();
        let mut a = mk(grid.clone());
        let mut b = mk(grid);
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        for i in 0..8 {
            ta.push(a.load(i * 64, 5).1);
            tb.push(b.load(i * 64, 5).1);
        }
        // a: dense per-cycle stepping; b: one advance over the gap.
        let mut last_a = None;
        for c in 6..=40u64 {
            if a.step(c) {
                last_a = Some(c);
            }
        }
        let last_b = b.advance_to(5, 40);
        assert_eq!(last_a, last_b);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(a.completion(*x), b.completion(*y));
        }
        assert_eq!(a.stats, b.stats);
        // Idle advance after drain: budget saturates, nothing changes.
        let before = b.stats.clone();
        assert_eq!(b.advance_to(40, 100_000), None);
        assert_eq!(b.stats, before);
    }

    #[test]
    fn fabric_resident_loads_bypass_cache_and_dram() {
        let mut m = mk((0..256).map(|i| i as f64).collect());
        m.set_fabric_resident(true);
        let (v, t) = m.load(17, 5);
        assert_eq!(v, 17.0, "functional value is unchanged");
        // Completion is known at issue, at hit latency.
        assert_eq!(m.completion(t), Some(5 + Machine::paper().cache_hit_latency as u64));
        assert_eq!(m.stats.loads, 1);
        assert_eq!(m.stats.exchanged, 1);
        assert_eq!(m.stats.hits + m.stats.misses + m.stats.merged, 0);
        assert!(!m.busy(), "no fill was queued");
        m.step(6);
        assert_eq!(m.stats.dram_read_bytes, 0);
    }

    #[test]
    fn mesh_hop_cycles_is_strictly_monotone_on_the_paper_machine() {
        let m = Machine::paper();
        assert_eq!(mesh_hop_cycles(0, &m), 0);
        for hops in 1..6 {
            assert!(
                mesh_hop_cycles(hops + 1, &m) > mesh_hop_cycles(hops, &m),
                "hops {hops}"
            );
        }
    }

    #[test]
    fn priced_exchange_adds_hop_latency_and_queues_on_the_link() {
        let mut m = mk((0..64).map(|i| i as f64).collect());
        m.set_fabric_resident(true);
        m.set_exchange_cost(Some(ExchangeCost {
            ext: [64, 1, 1],
            regions: vec![
                CostRegion { lo: [0, 0, 0], hi: [4, 1, 1], hop_cycles: 8 },
                CostRegion { lo: [4, 0, 0], hi: [8, 1, 1], hop_cycles: 16 },
            ],
            link_words: 2,
        }));
        let hit = Machine::paper().cache_hit_latency as u64;
        // Two near-region loads start this cycle; the third and fourth
        // queue behind the 2-per-cycle link cap.
        let done: Vec<u64> = (0..4)
            .map(|a| {
                let (_, t) = m.load(a, 5);
                m.completion(t).unwrap()
            })
            .collect();
        assert_eq!(done, vec![5 + hit + 8, 5 + hit + 8, 6 + hit + 8, 6 + hit + 8]);
        // A far-region load is strictly costlier than a near one issued
        // at the same cycle (its link is independent and idle).
        let (_, t_far) = m.load(4, 5);
        assert_eq!(m.completion(t_far), Some(5 + hit + 16));
        // Outside every region: truly resident, flat hit latency.
        let (_, t_res) = m.load(40, 5);
        assert_eq!(m.completion(t_res), Some(5 + hit));
        assert_eq!(m.stats.exchanged, 6, "all resident loads count as exchanged");
        assert_eq!(m.stats.exchanged_hop_cycles, 8 + 8 + 9 + 9 + 16);
        assert_eq!(m.stats.hits + m.stats.misses + m.stats.merged, 0);
        assert!(!m.busy(), "pricing never queues arbiter transactions");
    }

    #[test]
    fn unpriced_fabric_residency_is_unchanged_by_the_cost_machinery() {
        // `set_exchange_cost(None)` (the default) must reproduce the
        // PR 6 free model exactly.
        let mut a = mk((0..64).map(|i| i as f64).collect());
        let mut b = mk((0..64).map(|i| i as f64).collect());
        a.set_fabric_resident(true);
        b.set_fabric_resident(true);
        b.set_exchange_cost(None);
        for addr in [0u64, 17, 63] {
            let (va, ta) = a.load(addr, 3);
            let (vb, tb) = b.load(addr, 3);
            assert_eq!(va.to_bits(), vb.to_bits());
            assert_eq!(a.completion(ta), b.completion(tb));
        }
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.stats.exchanged_hop_cycles, 0);
    }

    #[test]
    fn priced_exchange_reads_the_same_values_as_free() {
        // The pricer changes completion cycles only — functional reads
        // are identical, the root of the bitwise differential suite.
        let grid: Vec<f64> = (0..32).map(|i| (i as f64) * 1.5 - 3.0).collect();
        let mut free = mk(grid.clone());
        let mut priced = mk(grid);
        free.set_fabric_resident(true);
        priced.set_fabric_resident(true);
        priced.set_exchange_cost(Some(ExchangeCost {
            ext: [32, 1, 1],
            regions: vec![CostRegion { lo: [0, 0, 0], hi: [32, 1, 1], hop_cycles: 11 }],
            link_words: 1,
        }));
        for addr in 0..32u64 {
            let (vf, _) = free.load(addr, 2);
            let (vp, tp) = priced.load(addr, 2);
            assert_eq!(vf.to_bits(), vp.to_bits(), "addr {addr}");
            assert!(priced.completion(tp).unwrap() > 2 + free.hit_latency);
        }
    }

    #[test]
    fn merged_waiters_all_complete_at_the_fill() {
        // Three loads to one line: one fill, two MSHR merges, and every
        // ticket in the intrusive waiter chain completes at the same
        // grant + dram_latency cycle.
        let mut m = mk((0..100).map(|i| i as f64).collect());
        let (_, t1) = m.load(0, 0);
        let (_, t2) = m.load(1, 0);
        let (_, t3) = m.load(2, 0);
        assert_eq!(m.stats.misses, 1);
        assert_eq!(m.stats.merged, 2);
        assert_eq!(m.completion(t1), None);
        m.step(1);
        for t in [t1, t2, t3] {
            assert_eq!(m.completion(t), Some(1 + 100));
        }
    }

    #[test]
    fn reserve_preallocates_without_changing_behaviour() {
        let grid: Vec<f64> = (0..512).map(|i| i as f64).collect();
        let mut a = mk(grid.clone());
        let mut b = mk(grid);
        b.reserve(64, 16);
        for i in 0..8 {
            assert_eq!(a.load(i * 8, 0), b.load(i * 8, 0));
        }
        for c in 1..=20 {
            assert_eq!(a.step(c), b.step(c));
        }
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn resolved_tickets_recorded_only_when_enabled() {
        let mut m = mk((0..512).map(|i| i as f64).collect());
        let mut out = Vec::new();
        let (_, _t) = m.load(0, 0);
        m.step(1);
        m.drain_resolved(&mut out);
        assert!(out.is_empty(), "recording defaults off");
        m.set_record_resolved(true);
        let (_, t2) = m.load(400, 2); // distinct line -> new fill
        let st = m.store(1, 4.0, 2);
        m.step(3);
        m.drain_resolved(&mut out);
        assert_eq!(out, vec![t2, st]);
        assert_eq!(m.completion(t2), Some(3 + 100));
        assert_eq!(m.completion(st), Some(3 + 2));
    }

    #[test]
    fn always_failing_fills_retry_until_the_bound_then_succeed() {
        let mut m = mk((0..100).map(|i| i as f64).collect());
        m.set_fault_plan(Some(FaultPlan {
            fill_fail_pct: 100,
            ..FaultPlan::default()
        }));
        let (_, t) = m.load(0, 0);
        let mut cycle = 1;
        while m.busy() {
            m.step(cycle);
            cycle += 1;
            assert!(cycle < 10_000, "retried fill never drained");
        }
        assert_eq!(m.stats.retries, MAX_FILL_RETRIES as u64);
        assert_eq!(m.stats.misses, 1, "a retried fill is one miss");
        let done = m.completion(t).expect("bounded retries guarantee completion");
        // Backoffs 8+16+32+64+128+256 cycles push the grant well past
        // the fault-free grant cycle of 1.
        assert!(done > 1 + 100 + 500, "backoff not applied: done={done}");
    }

    #[test]
    fn unarmed_plan_is_bitwise_identical_to_no_plan() {
        let grid: Vec<f64> = (0..8192).map(|i| i as f64).collect();
        let mut a = mk(grid.clone());
        let mut b = mk(grid);
        b.set_fault_plan(Some(FaultPlan::default())); // all pcts 0
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        for i in 0..16 {
            ta.push(a.load(i * 64, 0).1);
            tb.push(b.load(i * 64, 0).1);
        }
        for c in 1..=60 {
            assert_eq!(a.step(c), b.step(c));
        }
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(a.completion(*x), b.completion(*y));
        }
        assert_eq!(a.stats, b.stats);
        assert_eq!(b.stats.retries, 0);
    }

    #[test]
    fn advance_to_replays_injected_failures_bit_identically() {
        // The replay-parity pin under faults: per-cycle stepping and
        // advance_to must agree on grant times, retries and stats even
        // while fills are failing and backing off.
        let plan = FaultPlan { seed: 5, fill_fail_pct: 50, ..FaultPlan::default() };
        let grid: Vec<f64> = (0..8192).map(|i| i as f64).collect();
        let mut a = mk(grid.clone());
        let mut b = mk(grid);
        a.set_fault_plan(Some(plan.clone()));
        b.set_fault_plan(Some(plan));
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        for i in 0..8 {
            ta.push(a.load(i * 64, 5).1);
            tb.push(b.load(i * 64, 5).1);
        }
        let mut last_a = None;
        for c in 6..=4000u64 {
            if a.step(c) {
                last_a = Some(c);
            }
        }
        let last_b = b.advance_to(5, 4000);
        assert_eq!(last_a, last_b);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(a.completion(*x), b.completion(*y));
        }
        assert_eq!(a.stats, b.stats);
        assert!(a.stats.retries > 0, "plan at 50% should have injected retries");
    }

    #[test]
    fn forensic_summary_names_the_oldest_outstanding_ticket() {
        let mut m = mk((0..100).map(|i| i as f64).collect());
        let (_, t) = m.load(0, 0);
        let s = m.forensic_summary(0);
        assert!(s.contains(&format!("oldest outstanding ticket #{t} (ungranted)")), "{s}");
        assert!(s.contains("1 queued txn(s)"), "{s}");
        m.step(1);
        let s = m.forensic_summary(2);
        assert!(s.contains(&format!("oldest outstanding ticket #{t} (due cycle 101)")), "{s}");
    }
}
