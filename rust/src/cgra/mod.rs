//! Functional + timing cycle simulator of the target CGRA (§II-A, §VIII).
//!
//! The paper evaluates on "a modified version of a previously proposed
//! CGRA [7]" (the triggered-instruction architecture) with a
//! cycle-accurate simulator of "CGRA PEs, scratchpads, private cache,
//! shared cache, and communication network". That simulator is
//! proprietary; this module is the from-scratch substitute (DESIGN.md
//! "Substitutions" #1):
//!
//! * [`machine`] — the machine description (§VI's 1.2 GHz / 256 MACs /
//!   100 GB/s assumptions are the defaults).
//! * [`channel`] — bounded FIFOs with latency: the PE input/output queues
//!   and on-chip links.
//! * [`memory`] — shared cache + bandwidth-limited DRAM channel with
//!   MSHR-style line merging.
//! * [`placement`] — logical DFG → physical PE grid (Fig 4) and
//!   route-length-derived channel latencies.
//! * [`sim`] — the cycle loop executing triggered instructions: the run
//!   produces the actual output grid *and* the cycle count, so one
//!   simulation is both the correctness and the performance experiment.
//!   Two interchangeable scheduler cores ([`sim::SimCore`]): the dense
//!   reference loop and the default event-driven ready list with cycle
//!   skipping, bit-identical by construction. A simulation splits into
//!   a shared read-only [`sim::PlacedGraph`] (validation + placement,
//!   built once per graph shape by the compile phase) and the per-run
//!   mutable [`sim::Simulator`] instantiated from it.
//! * [`stats`] — utilization, traffic, cache and stall counters.

pub mod channel;
pub mod machine;
pub mod memory;
pub mod placement;
pub mod sim;
pub mod stats;

pub use machine::Machine;
pub use memory::{mesh_hop_cycles, CostRegion, ExchangeCost};
pub use sim::{PlacedGraph, SimCore, SimResult, Simulator};

/// A value flowing through the fabric, tagged with the grid coordinates
/// the control units generated for it (§III-A: control units produce
/// "addresses and row/column id corresponding to the load/store
/// operations"). For address tokens `val` carries the flat address.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Token {
    pub val: f64,
    pub row: u32,
    pub col: u32,
}

impl Token {
    pub fn new(val: f64, row: u32, col: u32) -> Self {
        Self { val, row, col }
    }
}
