//! Placement: logical DFG nodes → physical PE grid positions (Fig 4), and
//! the channel latency/capacity consequences of the routes.
//!
//! Layout strategy mirrors Fig 4: control + reader nodes occupy the top
//! rows in a row-major **snake** (even rows left-to-right, odd rows
//! right-to-left), so a deep delay-line chain — `map3d`'s plane buffers
//! are dozens of consecutive copy PEs — stays mesh-adjacent across row
//! boundaries instead of jumping back to column 0. Each compute worker
//! gets a vertical band of columns and its nodes snake down-then-up the
//! band in declaration order, which places a MAC chain contiguously
//! (PEs in the same row end up holding the same tap across workers —
//! the "PEs in the same row share the same coefficient" property). If
//! the graph exceeds the fabric, up to `max_instr_per_pe` instructions
//! share a PE (TIA supports multiple triggered instructions per PE;
//! sharing costs issue bandwidth, which the simulator models by firing
//! one instruction per PE per cycle).

use anyhow::{ensure, Result};

use crate::dfg::Graph;

use super::machine::Machine;

/// Physical coordinates of each node plus route statistics.
#[derive(Debug, Clone)]
pub struct Placement {
    /// `(row, col)` per node id.
    pub pe_of: Vec<(u16, u16)>,
    /// Number of instructions sharing each PE (`grid_rows * grid_cols`).
    pub occupancy: Vec<u8>,
    pub max_route_hops: u32,
    pub avg_route_hops: f64,
}

impl Placement {
    pub fn pe_index(&self, node: usize, m: &Machine) -> usize {
        let (r, c) = self.pe_of[node];
        r as usize * m.grid_cols + c as usize
    }

    /// The fixed evaluation order both simulator cores share: instruction
    /// groups in the exact order the cycle loop visits them. One group
    /// per occupied PE (instructions in placement order — the
    /// one-instruction-per-PE-per-cycle arbitration set); when every PE
    /// holds a single instruction the groups collapse to topological
    /// singletons (producers before consumers — better cache locality
    /// along the dataflow, and the per-PE arbitration is a no-op).
    ///
    /// This order is *the* determinism contract: the dense core sweeps
    /// all groups every cycle, the event core sweeps the ready subset in
    /// the same order, so both observe identical intra-cycle credit
    /// hand-offs and fire identically.
    pub fn eval_slots(&self, g: &Graph, m: &Machine) -> Vec<Vec<u32>> {
        let mut pe_instrs: Vec<Vec<u32>> = vec![Vec::new(); m.total_pes()];
        for id in 0..g.node_count() {
            pe_instrs[self.pe_index(id, m)].push(id as u32);
        }
        pe_instrs.retain(|v| !v.is_empty());
        if pe_instrs.iter().all(|v| v.len() == 1) {
            if let Some(order) = g.topo_order() {
                return order.into_iter().map(|i| vec![i as u32]).collect();
            }
        }
        pe_instrs
    }

    /// [`Self::eval_slots`] flattened to CSR form — `(nodes, starts)`
    /// where slot `s` holds `nodes[starts[s]..starts[s+1]]`. This is the
    /// layout the simulator sweeps: one contiguous node-id array instead
    /// of a `Vec<Vec<u32>>`, so the dense core's per-cycle walk touches
    /// one allocation.
    pub fn eval_order(&self, g: &Graph, m: &Machine) -> (Vec<u32>, Vec<u32>) {
        let slots = self.eval_slots(g, m);
        let mut nodes = Vec::with_capacity(g.node_count());
        let mut starts = Vec::with_capacity(slots.len() + 1);
        starts.push(0u32);
        for s in &slots {
            nodes.extend_from_slice(s);
            starts.push(nodes.len() as u32);
        }
        (nodes, starts)
    }
}

fn manhattan(a: (u16, u16), b: (u16, u16)) -> u32 {
    (a.0 as i32 - b.0 as i32).unsigned_abs() + (a.1 as i32 - b.1 as i32).unsigned_abs()
}

/// Place `g` on `m`'s grid and update each channel's `latency` (1 cycle
/// plus the route's hop time) and `capacity` (at least latency + 2, so a
/// long route can still stream at full rate under credit flow control).
pub fn place(g: &mut Graph, m: &Machine) -> Result<Placement> {
    ensure!(
        g.dp_ops() <= m.mac_pes,
        "{} DP ops exceed the fabric's {} MAC PEs (reduce workers)",
        g.dp_ops(),
        m.mac_pes
    );
    let total_slots = m.total_pes() * m.max_instr_per_pe;
    ensure!(
        g.node_count() <= total_slots,
        "{} nodes exceed {} instruction slots",
        g.node_count(),
        total_slots
    );

    let rows = m.grid_rows;
    let cols = m.grid_cols;
    let mut occupancy = vec![0u8; rows * cols];
    let mut pe_of = vec![(0u16, 0u16); g.node_count()];

    // Partition nodes: worker-less (control/readers) vs per-worker.
    let max_worker = g.nodes.iter().filter_map(|n| n.worker).max();
    let shared: Vec<usize> =
        g.nodes.iter().filter(|n| n.worker.is_none()).map(|n| n.id).collect();

    // Top band for shared nodes: as many rows as needed.
    let top_rows = shared.len().div_ceil(cols).min(rows);
    let mut place_at = |id: usize, r: usize, c: usize, occ: &mut Vec<u8>| {
        pe_of[id] = (r as u16, c as u16);
        occ[r * cols + c] += 1;
    };
    for (i, &id) in shared.iter().enumerate() {
        // Wrap into instruction slots if the top band overflows.
        let slot = i % (top_rows * cols).max(1);
        let r = slot / cols;
        // Row-major snake: consecutive shared nodes (delay-line stages)
        // stay one hop apart even across a row boundary.
        let c = if r % 2 == 0 {
            slot % cols
        } else {
            cols - 1 - slot % cols
        };
        place_at(id, r, c, &mut occupancy);
    }

    // Vertical bands for workers.
    if let Some(mw) = max_worker {
        let nworkers = mw + 1;
        let band_cols = (cols / nworkers).max(1);
        let body_rows = rows - top_rows.min(rows - 1);
        for w in 0..nworkers {
            let c0 = (w * band_cols) % cols;
            let nodes: Vec<usize> = g
                .nodes
                .iter()
                .filter(|n| n.worker == Some(w))
                .map(|n| n.id)
                .collect();
            let band_slots = body_rows * band_cols;
            for (i, &id) in nodes.iter().enumerate() {
                let slot = i % band_slots.max(1);
                // Column-major snake down-then-up the band: consecutive
                // nodes stay adjacent, including at column turns.
                let snake_col = slot / body_rows;
                let down = slot % body_rows;
                let rr = if snake_col % 2 == 0 {
                    down
                } else {
                    body_rows - 1 - down
                };
                let r = top_rows + rr;
                let c = c0 + snake_col % band_cols;
                place_at(id, r.min(rows - 1), c.min(cols - 1), &mut occupancy);
            }
        }
    }

    // Verify instruction-slot limits.
    for (i, &o) in occupancy.iter().enumerate() {
        ensure!(
            (o as usize) <= m.max_instr_per_pe,
            "PE {} holds {} instructions (limit {})",
            i,
            o,
            m.max_instr_per_pe
        );
    }

    // Route-derived channel latency + capacity floors.
    let mut max_hops = 0u32;
    let mut sum_hops = 0u64;
    for ch in &mut g.channels {
        let hops = manhattan(pe_of[ch.src], pe_of[ch.dst]);
        max_hops = max_hops.max(hops);
        sum_hops += hops as u64;
        let lat = 1 + hops.div_ceil(m.hops_per_cycle as u32);
        ch.latency = lat;
        ch.capacity = ch.capacity.max(lat as usize + 2);
    }
    let avg = if g.channels.is_empty() {
        0.0
    } else {
        sum_hops as f64 / g.channels.len() as f64
    };
    Ok(Placement {
        pe_of,
        occupancy,
        max_route_hops: max_hops,
        avg_route_hops: avg,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{map1d, map2d, map3d, StencilSpec};

    #[test]
    fn delay_line_chain_stays_adjacent_in_top_band() {
        // map3d's plane buffers are deep chains of shared (worker-less)
        // copy PEs; the row-major snake must keep consecutive stages one
        // hop apart, including across a row boundary (ROADMAP: cuts
        // route latency and queue floors for deep delay lines).
        let spec = StencilSpec::heat3d(10, 8, 6, 0.1);
        let mut g = map3d::build(&spec, 2).unwrap();
        let m = Machine::paper();
        let p = place(&mut g, &m).unwrap();
        let stages = map3d::delay_stages(&spec, 2);
        assert!(stages > m.grid_cols / 2, "chain must be deep enough to wrap");
        for rho in 0..2 {
            let mut prev = p.pe_of[g.find(&format!("r{rho}.ld")).unwrap()];
            for s in 1..=stages {
                let cur = p.pe_of[g.find(&format!("r{rho}.copy{s}")).unwrap()];
                assert_eq!(
                    manhattan(prev, cur),
                    1,
                    "reader {rho} stage {s} not adjacent"
                );
                prev = cur;
            }
        }
        // Adjacency shows up as minimal route latency on every delay
        // stage's input channel.
        for n in &g.nodes {
            if n.op == crate::dfg::Op::Copy {
                let ch = g.input(n.id, 0).unwrap();
                assert_eq!(g.channels[ch].latency, 2, "1 hop + 1 cycle");
            }
        }
    }

    #[test]
    fn paper_1d_fits_one_instr_per_pe() {
        let spec = StencilSpec::paper_1d();
        let mut g = map1d::build(&spec, 6).unwrap();
        let m = Machine::paper();
        let p = place(&mut g, &m).unwrap();
        assert!(p.occupancy.iter().all(|&o| o <= m.max_instr_per_pe as u8));
        // Every channel got a route-derived latency and enough capacity.
        for ch in &g.channels {
            assert!(ch.latency >= 1);
            assert!(ch.capacity >= ch.latency as usize + 2);
        }
    }

    #[test]
    fn paper_2d_fits_mac_budget() {
        let spec = StencilSpec::paper_2d();
        let mut g = map2d::build(&spec, 5).unwrap();
        let m = Machine::paper();
        assert!(g.dp_ops() <= m.mac_pes);
        let p = place(&mut g, &m).unwrap();
        assert!(p.max_route_hops > 0);
    }

    #[test]
    fn too_many_workers_rejected_by_mac_budget() {
        // 6 workers * 49 DP = 294 > 256 — the §VI constraint that only 5
        // workers fit the 2-D stencil.
        let spec = StencilSpec::paper_2d();
        let mut g = map2d::build(&spec, 6).unwrap();
        let m = Machine::paper();
        assert!(place(&mut g, &m).is_err());
    }

    #[test]
    fn rows_share_coefficients_fig4() {
        // For the 1-D mapping, MAC for tap t of every worker should land
        // on the same grid row (same coefficient per row, Fig 4).
        let spec = StencilSpec::dim1(64, crate::stencil::spec::symmetric_taps(2)).unwrap();
        let mut g = map1d::build(&spec, 3).unwrap();
        let m = Machine::paper();
        let p = place(&mut g, &m).unwrap();
        let row_of = |name: &str| p.pe_of[g.find(name).unwrap()].0;
        for t in 1..5 {
            let r0 = row_of(&format!("w0.mac{t}"));
            let r1 = row_of(&format!("w1.mac{t}"));
            let r2 = row_of(&format!("w2.mac{t}"));
            assert_eq!(r0, r1);
            assert_eq!(r1, r2);
        }
    }

    #[test]
    fn tiny_fabric_packs_instructions() {
        let spec = StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap();
        let mut g = map1d::build(&spec, 2).unwrap();
        let m = Machine::tiny();
        let p = place(&mut g, &m).unwrap();
        // 4x4 grid with ~20 nodes: someone must share.
        assert!(p.occupancy.iter().any(|&o| o > 1));
    }

    #[test]
    fn eval_slots_topological_singletons_on_big_fabric() {
        let spec = StencilSpec::dim1(64, crate::stencil::spec::symmetric_taps(2)).unwrap();
        let mut g = map1d::build(&spec, 3).unwrap();
        let m = Machine::paper();
        let p = place(&mut g, &m).unwrap();
        let slots = p.eval_slots(&g, &m);
        assert_eq!(slots.len(), g.node_count());
        assert!(slots.iter().all(|s| s.len() == 1));
        // Producers are evaluated before consumers.
        let mut pos = vec![0usize; g.node_count()];
        for (i, s) in slots.iter().enumerate() {
            pos[s[0] as usize] = i;
        }
        for ch in &g.channels {
            assert!(pos[ch.src] < pos[ch.dst], "channel {} not topo-ordered", ch.id);
        }
    }

    #[test]
    fn eval_order_csr_matches_eval_slots() {
        let spec = StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap();
        let mut g = map1d::build(&spec, 2).unwrap();
        let m = Machine::tiny();
        let p = place(&mut g, &m).unwrap();
        let slots = p.eval_slots(&g, &m);
        let (nodes, starts) = p.eval_order(&g, &m);
        assert_eq!(starts.len(), slots.len() + 1);
        assert_eq!(nodes.len(), g.node_count());
        for (s, group) in slots.iter().enumerate() {
            assert_eq!(&nodes[starts[s] as usize..starts[s + 1] as usize], &group[..]);
        }
    }

    #[test]
    fn eval_slots_group_shared_pes_on_tiny_fabric() {
        let spec = StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap();
        let mut g = map1d::build(&spec, 2).unwrap();
        let m = Machine::tiny();
        let p = place(&mut g, &m).unwrap();
        let slots = p.eval_slots(&g, &m);
        assert!(slots.iter().any(|s| s.len() > 1), "packing must share slots");
        let total: usize = slots.iter().map(|s| s.len()).sum();
        assert_eq!(total, g.node_count());
    }
}
