//! The cycle loop: triggered-instruction execution of a DFG (§II-A).
//!
//! Each DFG node is one triggered instruction mapped to a PE by
//! [`super::placement`]. An instruction *triggers* when its required
//! input queues hold visible tokens and its output queues have credit;
//! each PE fires at most one instruction per cycle (TIA's scheduler), so
//! instruction packing on a small fabric costs issue bandwidth exactly as
//! it should.
//!
//! The simulator is functional + timing in one pass: tokens carry real
//! f64 payloads, so the run yields the output grid (checked against the
//! PJRT-executed JAX artifact by `verify`) *and* the cycle count that
//! feeds the §VIII performance tables.
//!
//! Determinism: PEs are evaluated in a fixed order, pushes become visible
//! only `latency >= 1` cycles later (so evaluation order cannot leak
//! within a cycle), and the memory arbiter is FIFO. Every run is
//! bit-reproducible.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::dfg::node::{AddrIter, FilterSpec, Op, Stage};
use crate::dfg::Graph;

use super::channel::Fifo;
use super::machine::Machine;
use super::memory::{MemSys, Ticket};
use super::placement::{self, Placement};
use super::stats::SimStats;
use super::Token;

const NO_CHAN: u32 = u32::MAX;

/// Runtime state of one instruction.
struct NodeRt {
    op: Op,
    stage: Stage,
    coeff: f64,
    filter: Option<FilterSpec>,
    filter_idx: u64,
    agen: Option<AddrIter>,
    agen_pos: u64,
    agen_len: u64,
    expected: u64,
    count: u64,
    emitted: bool,
    /// Input channel per port (NO_CHAN when unconnected).
    ins: Vec<u32>,
    /// Output channels per port (fan-out lists).
    outs: Vec<Vec<u32>>,
    /// Hot-path copies (§Perf): first/second input channel and the port-0
    /// fan-out, accessed without the nested-Vec indirection.
    in0: u32,
    in1: u32,
    out0: Box<[u32]>,
    /// In-order outstanding memory operations (Load/Store).
    inflight: VecDeque<(Ticket, Token)>,
    fires: u64,
}

/// Result of a completed simulation.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Final contents of the output grid.
    pub output: Vec<f64>,
    pub stats: SimStats,
}

impl SimResult {
    /// Achieved GFLOPS for a workload of `flops` at `clock_ghz`.
    pub fn gflops(&self, flops: f64, clock_ghz: f64) -> f64 {
        self.stats.gflops(flops, clock_ghz)
    }
}

pub struct Simulator {
    nodes: Vec<NodeRt>,
    chans: Vec<Fifo>,
    mem: MemSys,
    /// Instructions grouped by PE, in placement order.
    pe_instrs: Vec<Vec<u32>>,
    /// Fast path when every PE holds exactly one instruction: flat
    /// topological evaluation order (None when instructions share PEs).
    flat_order: Option<Vec<u32>>,
    /// Quiet-period threshold for deadlock detection.
    deadlock_quiet: u64,
    max_cycles: u64,
    stats: SimStats,
    mshr: usize,
    done_node: usize,
    /// Node names (diagnostics only).
    names: Vec<String>,
}

impl Simulator {
    /// Build a simulator for `graph` on machine `m`.
    ///
    /// `input` is the source grid; `output` the initial contents of the
    /// destination (pre-filled with boundary values by the caller).
    /// Placement runs here and fixes channel latencies/capacities.
    pub fn build(
        mut graph: Graph,
        m: &Machine,
        input: Vec<f64>,
        output: Vec<f64>,
    ) -> Result<Self> {
        crate::dfg::validate::validate(&graph)?;
        let plc: Placement = placement::place(&mut graph, m)?;

        let chans: Vec<Fifo> = graph
            .channels
            .iter()
            .map(|c| Fifo::new(c.capacity, c.latency))
            .collect();

        let mut done_node = None;
        let mut nodes = Vec::with_capacity(graph.node_count());
        let mut names = Vec::with_capacity(graph.node_count());
        for n in &graph.nodes {
            if n.op == Op::DoneTree {
                done_node = Some(n.id);
            }
            let max_in = (0..16)
                .rev()
                .find(|&p| graph.input(n.id, p).is_some())
                .map(|p| p as usize + 1)
                .unwrap_or(0);
            let ins = (0..max_in)
                .map(|p| graph.input(n.id, p as u8).map(|c| c as u32).unwrap_or(NO_CHAN))
                .collect::<Vec<_>>();
            let mut outs: Vec<Vec<u32>> = Vec::new();
            for p in 0..4u8 {
                let v: Vec<u32> = graph.outputs(n.id, p).iter().map(|&c| c as u32).collect();
                if v.is_empty() && p > 0 {
                    break;
                }
                outs.push(v);
            }
            let agen_len = n.agen.map(|a| a.len()).unwrap_or(0);
            let in0 = ins.first().copied().unwrap_or(NO_CHAN);
            let in1 = ins.get(1).copied().unwrap_or(NO_CHAN);
            let out0: Box<[u32]> =
                outs.first().cloned().unwrap_or_default().into_boxed_slice();
            nodes.push(NodeRt {
                op: n.op,
                stage: n.stage,
                coeff: n.coeff.unwrap_or(0.0),
                filter: n.filter,
                filter_idx: 0,
                agen: n.agen,
                agen_pos: 0,
                agen_len,
                expected: n.expected.unwrap_or(u64::MAX),
                count: 0,
                emitted: false,
                ins,
                outs,
                in0,
                in1,
                out0,
                inflight: VecDeque::new(),
                fires: 0,
            });
            names.push(n.name.clone());
        }
        let Some(done_node) = done_node else {
            bail!("graph has no DoneTree — the simulator cannot detect completion");
        };

        // Group instructions by PE (placement order = priority order).
        let mut pe_instrs: Vec<Vec<u32>> = vec![Vec::new(); m.total_pes()];
        for id in 0..nodes.len() {
            pe_instrs[plc.pe_index(id, m)].push(id as u32);
        }
        pe_instrs.retain(|v| !v.is_empty());
        // Hot-loop fast path (§Perf): when no PE shares instructions the
        // per-PE arbitration is a no-op, so evaluate a flat node list in
        // topological order (producers before consumers — better cache
        // locality along the dataflow).
        let flat_order: Option<Vec<u32>> = if pe_instrs.iter().all(|v| v.len() == 1) {
            graph
                .topo_order()
                .map(|o| o.into_iter().map(|i| i as u32).collect())
        } else {
            None
        };

        let max_lat = graph.channels.iter().map(|c| c.latency).max().unwrap_or(1);
        let mut stats = SimStats::default();
        stats.dp_ops = graph.dp_ops();
        stats.node_count = graph.node_count();

        Ok(Self {
            nodes,
            chans,
            mem: MemSys::new(m, input, output),
            pe_instrs,
            flat_order,
            deadlock_quiet: m.dram_latency as u64 + max_lat as u64 + 256,
            max_cycles: 200_000_000,
            stats,
            mshr: m.mshr_per_load,
            done_node,
            names,
        })
    }

    /// Override the safety cap on simulated cycles.
    pub fn with_max_cycles(mut self, c: u64) -> Self {
        self.max_cycles = c;
        self
    }

    /// Run to completion (DoneTree fires) and return the output + stats.
    pub fn run(mut self) -> Result<SimResult> {
        let mut now: u64 = 0;
        let mut last_progress: u64 = 0;
        while !self.nodes[self.done_node].emitted {
            now += 1;
            let mem_prog = self.mem.step(now);
            let mut fired = false;
            if let Some(order) = &self.flat_order {
                for &id in order {
                    fired |= fire(
                        &mut self.nodes[id as usize],
                        &mut self.chans,
                        &mut self.mem,
                        &mut self.stats,
                        self.mshr,
                        now,
                    );
                }
            } else {
                for pe in 0..self.pe_instrs.len() {
                    for k in 0..self.pe_instrs[pe].len() {
                        let id = self.pe_instrs[pe][k] as usize;
                        if fire(
                            &mut self.nodes[id],
                            &mut self.chans,
                            &mut self.mem,
                            &mut self.stats,
                            self.mshr,
                            now,
                        ) {
                            fired = true;
                            break; // one instruction per PE per cycle
                        }
                    }
                }
            }
            if fired || mem_prog {
                last_progress = now;
            } else if now - last_progress > self.deadlock_quiet {
                bail!(self.deadlock_report(now));
            }
            if now > self.max_cycles {
                bail!("simulation exceeded {} cycles", self.max_cycles);
            }
        }
        self.stats.cycles = now;
        self.stats.max_queue_occupancy = self
            .chans
            .iter()
            .map(|c| c.max_occupancy)
            .max()
            .unwrap_or(0);
        let (output, mem_stats) = self.mem.into_output();
        self.stats.mem = mem_stats;
        Ok(SimResult {
            output,
            stats: self.stats,
        })
    }

    /// Human-readable account of why nothing can make progress.
    fn deadlock_report(&self, now: u64) -> String {
        let mut lines = vec![format!(
            "deadlock: no progress for {} cycles (at cycle {})",
            self.deadlock_quiet, now
        )];
        for (id, n) in self.nodes.iter().enumerate() {
            if n.emitted && matches!(n.op, Op::SyncCount | Op::DoneTree) {
                continue;
            }
            let waiting_in: Vec<String> = n
                .ins
                .iter()
                .enumerate()
                .filter(|(_, &c)| c != NO_CHAN && self.chans[c as usize].peek(now).is_none())
                .map(|(p, _)| format!("in{p} empty"))
                .collect();
            let blocked_out: Vec<String> = n
                .outs
                .iter()
                .flatten()
                .filter(|&&c| !self.chans[c as usize].can_push())
                .map(|&c| format!("out ch{c} full"))
                .collect();
            if !waiting_in.is_empty() || !blocked_out.is_empty() {
                if lines.len() < 24 {
                    lines.push(format!(
                        "  {}: {} {}",
                        self.names[id],
                        waiting_in.join(","),
                        blocked_out.join(",")
                    ));
                }
            }
        }
        lines.join("\n")
    }
}

#[inline]
fn can_push_all(chans: &[Fifo], outs: &[u32]) -> bool {
    outs.iter().all(|&c| chans[c as usize].can_push())
}

#[inline]
fn push_all(chans: &mut [Fifo], outs: &[u32], t: Token, now: u64) {
    for &c in outs {
        chans[c as usize].push(t, now);
    }
}

/// Attempt to fire one instruction; returns true if it made progress.
fn fire(
    n: &mut NodeRt,
    chans: &mut [Fifo],
    mem: &mut MemSys,
    stats: &mut SimStats,
    mshr: usize,
    now: u64,
) -> bool {
    let fired = match n.op {
        Op::AddrGen => {
            if n.agen_pos < n.agen_len && can_push_all(chans, &n.out0) {
                let (row, col, addr) = n.agen.as_ref().unwrap().token(n.agen_pos);
                n.agen_pos += 1;
                push_all(chans, &n.out0, Token::new(addr as f64, row, col), now);
                true
            } else {
                false
            }
        }
        Op::Load => {
            let mut acted = false;
            // Deliver the oldest completed response (in order).
            if let Some(&(t, tok)) = n.inflight.front() {
                if mem.done(t, now) && can_push_all(chans, &n.out0) {
                    n.inflight.pop_front();
                    push_all(chans, &n.out0, tok, now);
                    acted = true;
                }
            }
            // Issue a new request (address generator + load PE pair).
            if n.inflight.len() < mshr {
                let ch = n.in0 as usize;
                if let Some(addr_tok) = chans[ch].peek(now).copied() {
                    chans[ch].pop(now);
                    let (val, t) = mem.load(addr_tok.val as u64, now);
                    n.inflight
                        .push_back((t, Token::new(val, addr_tok.row, addr_tok.col)));
                    acted = true;
                }
            }
            acted
        }
        Op::Store => {
            let mut acted = false;
            if let Some(&(t, tok)) = n.inflight.front() {
                if mem.done(t, now) && can_push_all(chans, &n.out0) {
                    n.inflight.pop_front();
                    push_all(chans, &n.out0, tok, now);
                    acted = true;
                }
            }
            if n.inflight.len() < mshr {
                let (a, d) = (n.in0 as usize, n.in1 as usize);
                if chans[a].peek(now).is_some() && chans[d].peek(now).is_some() {
                    let addr_tok = chans[a].pop(now).unwrap();
                    let data_tok = chans[d].pop(now).unwrap();
                    let t = mem.store(addr_tok.val as u64, data_tok.val, now);
                    n.inflight
                        .push_back((t, Token::new(1.0, addr_tok.row, addr_tok.col)));
                    acted = true;
                }
            }
            acted
        }
        Op::Mul => {
            let ch = n.in0 as usize;
            if chans[ch].peek(now).is_some() && can_push_all(chans, &n.out0) {
                let d = chans[ch].pop(now).unwrap();
                stats.dp_fires += 1;
                push_all(
                    chans,
                    &n.out0,
                    Token::new(n.coeff * d.val, d.row, d.col),
                    now,
                );
                true
            } else {
                false
            }
        }
        Op::Mac => {
            let (p, d) = (n.in0 as usize, n.in1 as usize);
            if chans[p].peek(now).is_some()
                && chans[d].peek(now).is_some()
                && can_push_all(chans, &n.out0)
            {
                let part = chans[p].pop(now).unwrap();
                let data = chans[d].pop(now).unwrap();
                stats.dp_fires += 1;
                push_all(
                    chans,
                    &n.out0,
                    Token::new(part.val + n.coeff * data.val, data.row, data.col),
                    now,
                );
                true
            } else {
                false
            }
        }
        Op::Add => {
            let (a, b) = (n.in0 as usize, n.in1 as usize);
            if chans[a].peek(now).is_some()
                && chans[b].peek(now).is_some()
                && can_push_all(chans, &n.out0)
            {
                let x = chans[a].pop(now).unwrap();
                let y = chans[b].pop(now).unwrap();
                stats.dp_fires += 1;
                push_all(chans, &n.out0, Token::new(x.val + y.val, x.row, x.col), now);
                true
            } else {
                false
            }
        }
        Op::Copy | Op::Shift => {
            let ch = n.in0 as usize;
            if chans[ch].peek(now).is_some() && can_push_all(chans, &n.out0) {
                let t = chans[ch].pop(now).unwrap();
                push_all(chans, &n.out0, t, now);
                true
            } else {
                false
            }
        }
        Op::Filter => {
            let ch = n.in0 as usize;
            if let Some(&tok) = chans[ch].peek(now) {
                let pass = n
                    .filter
                    .as_ref()
                    .map(|f| f.passes(n.filter_idx, tok.row, tok.col))
                    .unwrap_or(true);
                if pass {
                    if can_push_all(chans, &n.out0) {
                        chans[ch].pop(now);
                        n.filter_idx += 1;
                        push_all(chans, &n.out0, tok, now);
                        true
                    } else {
                        false
                    }
                } else {
                    // Dropping needs no credit.
                    chans[ch].pop(now);
                    n.filter_idx += 1;
                    true
                }
            } else {
                false
            }
        }
        Op::Mux => {
            // in0 = select stream, in1 = data; pass data when sel != 0.
            let (s, d) = (n.in0 as usize, n.in1 as usize);
            if chans[s].peek(now).is_some() && chans[d].peek(now).is_some() {
                let pass = chans[s].peek(now).unwrap().val != 0.0;
                if pass && !can_push_all(chans, &n.out0) {
                    return false;
                }
                chans[s].pop(now);
                let data = chans[d].pop(now).unwrap();
                if pass {
                    push_all(chans, &n.out0, data, now);
                }
                true
            } else {
                false
            }
        }
        Op::Demux => {
            // Route by row parity band: port = row % nports.
            let ch = n.in0 as usize;
            if let Some(&tok) = chans[ch].peek(now) {
                let nports = n.outs.len().max(1);
                let port = (tok.row as usize) % nports;
                if can_push_all(chans, &n.outs[port]) {
                    chans[ch].pop(now);
                    push_all(chans, &n.outs[port], tok, now);
                    true
                } else {
                    false
                }
            } else {
                false
            }
        }
        Op::Cmp => {
            let (a, b) = (n.in0 as usize, n.in1 as usize);
            if chans[a].peek(now).is_some()
                && chans[b].peek(now).is_some()
                && can_push_all(chans, &n.out0)
            {
                let x = chans[a].pop(now).unwrap();
                let y = chans[b].pop(now).unwrap();
                let v = if x.val <= y.val { 1.0 } else { 0.0 };
                push_all(chans, &n.out0, Token::new(v, x.row, x.col), now);
                true
            } else {
                false
            }
        }
        Op::Or => {
            let (a, b) = (n.in0 as usize, n.in1 as usize);
            if chans[a].peek(now).is_some()
                && chans[b].peek(now).is_some()
                && can_push_all(chans, &n.out0)
            {
                let x = chans[a].pop(now).unwrap();
                let y = chans[b].pop(now).unwrap();
                let v = if x.val != 0.0 || y.val != 0.0 { 1.0 } else { 0.0 };
                push_all(chans, &n.out0, Token::new(v, x.row, x.col), now);
                true
            } else {
                false
            }
        }
        Op::SyncCount => {
            let mut acted = false;
            let ch = n.in0 as usize;
            if chans[ch].peek(now).is_some() {
                chans[ch].pop(now);
                n.count += 1;
                acted = true;
            }
            if !n.emitted && n.count >= n.expected {
                let outs_ok = n.outs.first().map(|o| can_push_all(chans, o)).unwrap_or(true);
                if outs_ok {
                    if let Some(o) = n.outs.first() {
                        push_all(chans, o, Token::new(n.count as f64, 0, 0), now);
                    }
                    n.emitted = true;
                    acted = true;
                }
            }
            acted
        }
        Op::DoneTree => {
            if n.emitted {
                false
            } else {
                let all = n
                    .ins
                    .iter()
                    .all(|&c| c != NO_CHAN && chans[c as usize].peek(now).is_some());
                if all {
                    for &c in &n.ins {
                        chans[c as usize].pop(now);
                    }
                    n.emitted = true;
                    if let Some(o) = n.outs.first() {
                        if can_push_all(chans, o) {
                            push_all(chans, o, Token::new(1.0, 0, 0), now);
                        }
                    }
                    true
                } else {
                    false
                }
            }
        }
        Op::Const => {
            let limit = if n.expected == u64::MAX { u64::MAX } else { n.expected };
            if n.count < limit && can_push_all(chans, &n.out0) {
                n.count += 1;
                push_all(chans, &n.out0, Token::new(n.coeff, 0, 0), now);
                true
            } else {
                false
            }
        }
    };
    if fired {
        n.fires += 1;
        stats.record_fire(n.stage);
    }
    fired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{map1d, map2d, StencilSpec};
    use crate::util::rng::XorShift;

    /// Native oracle: 1-D star stencil, interior-only, left-to-right.
    fn ref_1d(x: &[f64], c: &[f64]) -> Vec<f64> {
        let r = (c.len() - 1) / 2;
        let mut out = x.to_vec();
        for o in r..x.len() - r {
            let mut acc = c[0] * x[o - r];
            for (k, &ck) in c.iter().enumerate().skip(1) {
                acc += ck * x[o - r + k];
            }
            out[o] = acc;
        }
        out
    }

    fn run_1d(spec: &StencilSpec, w: usize, input: Vec<f64>) -> SimResult {
        let g = map1d::build(spec, w).unwrap();
        let m = Machine::paper();
        let out0 = input.clone();
        Simulator::build(g, &m, input, out0).unwrap().run().unwrap()
    }

    #[test]
    fn simulates_3pt_1d_correctly() {
        let spec = StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap();
        let mut rng = XorShift::new(1);
        let x = rng.normal_vec(32);
        let res = run_1d(&spec, 3, x.clone());
        let want = ref_1d(&x, &spec.cx);
        for i in 0..32 {
            assert!(
                (res.output[i] - want[i]).abs() < 1e-12,
                "i={i}: {} vs {}",
                res.output[i],
                want[i]
            );
        }
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn simulates_17pt_1d_all_worker_counts() {
        let spec = StencilSpec::dim1(200, crate::stencil::spec::symmetric_taps(8)).unwrap();
        let mut rng = XorShift::new(7);
        let x = rng.normal_vec(200);
        let want = ref_1d(&x, &spec.cx);
        for w in [1, 2, 3, 6] {
            let res = run_1d(&spec, w, x.clone());
            for i in 0..200 {
                assert!((res.output[i] - want[i]).abs() < 1e-12, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn dp_fire_count_matches_work() {
        let spec = StencilSpec::dim1(64, vec![0.25, 0.5, 0.25]).unwrap();
        let res = run_1d(&spec, 2, vec![1.0; 64]);
        // Each of the 62 interior outputs takes 3 DP fires.
        assert_eq!(res.stats.dp_fires, 62 * 3);
    }

    #[test]
    fn memory_traffic_is_read_once_write_once() {
        let spec = StencilSpec::dim1(512, crate::stencil::spec::symmetric_taps(4)).unwrap();
        let res = run_1d(&spec, 4, vec![1.0; 512]);
        // Reads: ceil(512*8 / 64) lines = 64 fills = 4096 bytes.
        assert_eq!(res.stats.mem.dram_read_bytes, 512 * 8);
        // Writes: interior only.
        assert_eq!(res.stats.mem.dram_write_bytes, (512 - 8) * 8);
        // Every grid point loaded exactly once.
        assert_eq!(res.stats.mem.loads, 512);
    }

    /// Native oracle: 2-D star stencil matching ref.py's chain order.
    fn ref_2d(x: &[f64], nx: usize, ny: usize, spec: &StencilSpec) -> Vec<f64> {
        let (rx, ry) = (spec.rx, spec.ry);
        let mut out = x.to_vec();
        for r in ry..ny - ry {
            for c in rx..nx - rx {
                let mut acc = spec.cx[0] * x[r * nx + c - rx];
                for t in 1..2 * rx + 1 {
                    acc += spec.cx[t] * x[r * nx + c - rx + t];
                }
                for u in 0..2 * ry {
                    let k = if u < ry { u } else { u + 1 };
                    let rr = r + k - ry;
                    acc += spec.cy[u] * x[rr * nx + c];
                }
                out[r * nx + c] = acc;
            }
        }
        out
    }

    #[test]
    fn simulates_5pt_2d_correctly() {
        let spec = StencilSpec::heat2d(20, 14, 0.2);
        let mut rng = XorShift::new(3);
        let x = rng.normal_vec(20 * 14);
        let g = map2d::build(&spec, 3).unwrap();
        let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        let want = ref_2d(&x, 20, 14, &spec);
        for i in 0..x.len() {
            assert!(
                (res.output[i] - want[i]).abs() < 1e-12,
                "i={i}: {} vs {}",
                res.output[i],
                want[i]
            );
        }
    }

    #[test]
    fn simulates_wide_radius_2d() {
        let spec = StencilSpec::dim2(
            30,
            22,
            crate::stencil::spec::symmetric_taps(3),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let mut rng = XorShift::new(11);
        let x = rng.normal_vec(30 * 22);
        for w in [1, 2, 4] {
            let g = map2d::build(&spec, w).unwrap();
            let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .run()
                .unwrap();
            let want = ref_2d(&x, 30, 22, &spec);
            for i in 0..x.len() {
                assert!((res.output[i] - want[i]).abs() < 1e-11, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn boundary_untouched() {
        let spec = StencilSpec::heat2d(12, 10, 0.2);
        let x: Vec<f64> = (0..120).map(|i| i as f64).collect();
        let g = map2d::build(&spec, 2).unwrap();
        let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        for c in 0..12 {
            assert_eq!(res.output[c], x[c]); // top row
            assert_eq!(res.output[9 * 12 + c], x[9 * 12 + c]); // bottom row
        }
        for r in 0..10 {
            assert_eq!(res.output[r * 12], x[r * 12]); // left col
            assert_eq!(res.output[r * 12 + 11], x[r * 12 + 11]); // right col
        }
    }

    #[test]
    fn undersized_buffering_deadlocks_with_report() {
        // §III-B: strip the mandatory buffering and the pipeline must
        // deadlock (failure injection).
        let spec = StencilSpec::dim2(
            24,
            18,
            crate::stencil::spec::symmetric_taps(1),
            crate::stencil::spec::y_taps(3), // ry = 3 needs deep buffers
        )
        .unwrap();
        let mut g = map2d::build(&spec, 2).unwrap();
        for ch in &mut g.channels {
            ch.capacity = ch.capacity.min(2); // sabotage
        }
        // Bypass placement's capacity floor by building directly on a
        // machine with instant routing.
        let m = Machine::paper();
        let x = vec![1.0; 24 * 18];
        // Placement re-raises capacity to lat+2 which is still < needed.
        let err = Simulator::build(g, &m, x.clone(), x)
            .unwrap()
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn deterministic_across_runs() {
        let spec = StencilSpec::heat2d(16, 12, 0.2);
        let mut rng = XorShift::new(5);
        let x = rng.normal_vec(16 * 12);
        let run = || {
            let g = map2d::build(&spec, 2).unwrap();
            Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .run()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.output, b.output);
        assert_eq!(a.stats.mem, b.stats.mem);
    }
}
