//! The cycle loop: triggered-instruction execution of a DFG (§II-A),
//! with two interchangeable scheduler cores over one **allocation-free,
//! structure-of-arrays hot path**.
//!
//! Each DFG node is one triggered instruction mapped to a PE by
//! [`super::placement`]. An instruction *triggers* when its required
//! input queues hold visible tokens and its output queues have credit;
//! each PE fires at most one instruction per cycle (TIA's scheduler), so
//! instruction packing on a small fabric costs issue bandwidth exactly as
//! it should.
//!
//! The simulator is functional + timing in one pass: tokens carry real
//! f64 payloads, so the run yields the output grid (checked against the
//! golden oracles by `verify`) *and* the cycle count that feeds the
//! §VIII performance tables.
//!
//! # Data layout (§Perf)
//!
//! A simulation splits into a shared read-only [`PlacedGraph`] and the
//! per-run mutable state, laid out so the cycle loop performs **zero
//! heap allocations after warm-up** (pinned by
//! `rust/tests/alloc_free.rs` through [`crate::util::allocwatch`]):
//!
//! * **`NodeDesc` / `NodeState` split.** Everything immutable about an
//!   instruction (op, stage, coefficient, filter, ports) lives in
//!   [`PlacedGraph`]'s `descs` and is shared by every concurrent run;
//!   the mutable remainder is a handful of parallel SoA arrays
//!   (`NodeState`: filter cursor, address-generator position, counters,
//!   emitted flags) that the dense sweep walks contiguously and the
//!   event core indexes by wheel slot.
//! * **Ring-buffer channels over one token arena.** Every [`Fifo`] is a
//!   power-of-two ring into a single [`ChanArena`]
//!   ([`super::channel::assign_arena`] lays the rings out at graph
//!   build), so `push`/`pop` is index math on preallocated memory.
//! * **Fixed in-flight rings.** Load/Store MSHR queues are flat
//!   per-memory-node rings of `mshr` entries, not growable deques.
//! * **Preallocated memory system.** [`MemSys::reserve`] sizes the
//!   ticket table, transaction queue and fill-waiter structures from
//!   the grid size and MSHR depth before the loop starts.
//!
//! Each fire also folds `(node, cycle)` into [`SimStats::fire_hash`] —
//! the order-sensitive fingerprint `util::trace` records and replays.
//!
//! # The two cores ([`SimCore`])
//!
//! * [`SimCore::Dense`] — the reference loop: every cycle, every
//!   instruction group is evaluated in the fixed order of
//!   [`super::placement::Placement::eval_slots`].
//! * [`SimCore::Event`] (default) — an event-driven ready list with
//!   cycle skipping. Channels know their endpoint node ids
//!   ([`Fifo::with_endpoints`]): a `push` schedules the consumer's
//!   wakeup at token-visibility time (`now + latency`), a `pop` wakes
//!   the producer whose credit freed, and [`MemSys`] reports the
//!   completion cycle of each ticket so Load/Store instructions sleep
//!   until their response lands. A calendar wheel of per-cycle ready
//!   bitmaps drives execution; when a cycle's ready set drains and
//!   nothing is scheduled at `now + 1`, the clock jumps straight to the
//!   next event instead of ticking idle cycles.
//!
//! # Why cycle skipping is exact
//!
//! The event core is **bit-identical** to the dense loop — same output
//! grid, same cycle count, same memory statistics, same fire hash —
//! because:
//!
//! 1. **Evaluation is pure unless it fires.** `fire` mutates nothing
//!    when it returns false, so waking a node that cannot fire is
//!    harmless; correctness only needs the ready set to be a *superset*
//!    of the nodes the dense loop would fire.
//! 2. **Every enabling condition is a discrete event.** A node's
//!    trigger state changes only when a token becomes visible (push +
//!    latency), a credit frees (pop), a memory ticket completes
//!    (arbiter grant + fixed latency), or the node itself fired (it
//!    re-arms at `now + 1`; self-rescheduling ops — AddrGen, Const,
//!    SyncCount — are covered by exactly this rule). Each such event
//!    schedules a wakeup, so no fireable node is ever asleep.
//! 3. **Intra-cycle order is preserved.** Ready slots are swept in the
//!    dense evaluation order. A credit freed by a pop at slot `s` is
//!    visible to a producer at slot `p` in the same cycle iff `p > s`
//!    (the dense sweep would reach `p` afterwards) — later producers
//!    are woken at `now`, earlier ones at `now + 1`, reproducing the
//!    dense loop's same-cycle credit hand-off exactly. Within a shared
//!    PE the one-instruction-per-cycle arbitration is replayed by
//!    evaluating the group in placement order and stopping at the
//!    first firing.
//! 4. **The memory arbiter is replayed, not modeled.**
//!    [`MemSys::advance_to`] executes the per-cycle bandwidth-bucket
//!    arbiter over skipped cycles bit-identically (idle cycles only
//!    replenish the budget, which saturates in O(1)); while
//!    transactions are queued the core never skips, so grant cycles —
//!    and therefore all completion times — are unchanged.
//!
//! Deadlock detection becomes trivial in the event core: an empty wheel
//! with the done-tree not fired *is* a deadlock, reported at the same
//! cycle (and with the same text) the dense loop's quiet-period counter
//! would produce. The report is forensic: blocked instructions, full
//! channels with their endpoint instructions, and the memory system's
//! outstanding work — byte-identical across cores.
//!
//! # Fault injection (`util::fault`)
//!
//! An armed [`crate::util::fault::FaultPlan`] (attached via
//! [`Simulator::with_fault_plan`]) injects transient faults into a
//! run: memory-line fill failures (retried by [`MemSys`] with bounded
//! exponential backoff), channel stall windows (extra token-visibility
//! latency on push) and PE slow-down epochs (whole placement slots
//! suppressed from issuing). Every injection decision is a pure
//! function of the plan's seed and stable coordinates (fill-attempt
//! index, `(channel, epoch)`, `(slot, epoch)`) — never of host state
//! or evaluation order — so both cores replay the same faults and the
//! bit-identity guarantee above holds **under any plan**. An unarmed
//! plan costs one predicted branch per injection site and nothing
//! else, preserving the zero-allocation contract.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dfg::node::{AddrIter, FilterSpec, Op, Stage};
use crate::dfg::Graph;
use crate::util::allocwatch;
use crate::util::fault::FaultPlan;

use super::channel::{assign_arena, ChanArena, Fifo};
use super::machine::Machine;
use super::memory::{MemSys, Ticket};
use super::placement::{self, Placement};
use super::stats::SimStats;
use super::Token;

const NO_CHAN: u32 = u32::MAX;
/// `NodeDesc::mem_idx` for instructions without an MSHR ring.
const NO_MEM: u32 = u32::MAX;

/// Which scheduler drives the cycle loop. Both cores are bit-identical
/// in every observable (output grid, cycle count, firing counters, fire
/// hash, memory statistics); `Event` skips guaranteed-idle work and is
/// the default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimCore {
    /// Reference loop: every instruction evaluated every cycle.
    Dense,
    /// Event-driven ready list with cycle skipping.
    #[default]
    Event,
}

impl SimCore {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dense" => Ok(Self::Dense),
            "event" => Ok(Self::Event),
            other => bail!("unknown sim core `{other}` (dense|event)"),
        }
    }
}

impl std::fmt::Display for SimCore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimCore::Dense => "dense",
            SimCore::Event => "event",
        })
    }
}

/// The immutable half of an instruction: everything `fire` reads but
/// never writes. Lives in [`PlacedGraph`] and is shared (behind an
/// `Arc`) by every concurrent run — the mutable remainder is the SoA
/// [`NodeState`].
struct NodeDesc {
    op: Op,
    stage: Stage,
    coeff: f64,
    filter: Option<FilterSpec>,
    agen: Option<AddrIter>,
    agen_len: u64,
    expected: u64,
    /// Input channel per port (NO_CHAN when unconnected).
    ins: Vec<u32>,
    /// Output channels per port (fan-out lists).
    outs: Vec<Vec<u32>>,
    /// Hot-path copies (§Perf): first/second input channel and the port-0
    /// fan-out, accessed without the nested-Vec indirection.
    in0: u32,
    in1: u32,
    out0: Box<[u32]>,
    /// Index of this node's MSHR ring in [`NodeState`] (Load/Store
    /// only; `NO_MEM` otherwise).
    mem_idx: u32,
}

/// The mutable half of every instruction, split into parallel arrays
/// (SoA): the dense core sweeps them contiguously, the event core
/// indexes them by slot, and none of them ever grows after
/// construction.
struct NodeState {
    filter_idx: Vec<u64>,
    agen_pos: Vec<u64>,
    count: Vec<u64>,
    emitted: Vec<bool>,
    /// MSHR depth — ring stride of the in-flight arrays below.
    mshr: usize,
    /// Flat per-memory-node rings of outstanding (ticket, token) pairs:
    /// node `mem_idx` owns entries `mem_idx * mshr .. (mem_idx+1) * mshr`.
    inf_tk: Box<[Ticket]>,
    inf_tok: Box<[Token]>,
    inf_head: Vec<u32>,
    inf_len: Vec<u32>,
}

impl NodeState {
    fn new(n_nodes: usize, n_mem: usize, mshr: usize) -> Self {
        let cap = n_mem * mshr;
        Self {
            filter_idx: vec![0; n_nodes],
            agen_pos: vec![0; n_nodes],
            count: vec![0; n_nodes],
            emitted: vec![false; n_nodes],
            mshr,
            inf_tk: vec![0; cap].into_boxed_slice(),
            inf_tok: vec![Token::new(0.0, 0, 0); cap].into_boxed_slice(),
            inf_head: vec![0; n_mem],
            inf_len: vec![0; n_mem],
        }
    }

    #[inline]
    fn inflight_len(&self, mi: u32) -> usize {
        self.inf_len[mi as usize] as usize
    }

    /// Oldest outstanding (ticket, token), if any.
    #[inline]
    fn inflight_front(&self, mi: u32) -> Option<(Ticket, Token)> {
        let m = mi as usize;
        if self.inf_len[m] == 0 {
            return None;
        }
        let slot = m * self.mshr + self.inf_head[m] as usize;
        Some((self.inf_tk[slot], self.inf_tok[slot]))
    }

    #[inline]
    fn inflight_pop(&mut self, mi: u32) {
        let m = mi as usize;
        debug_assert!(self.inf_len[m] > 0);
        self.inf_head[m] = (self.inf_head[m] + 1) % self.mshr as u32;
        self.inf_len[m] -= 1;
    }

    #[inline]
    fn inflight_push(&mut self, mi: u32, tk: Ticket, tok: Token) {
        let m = mi as usize;
        debug_assert!((self.inf_len[m] as usize) < self.mshr);
        let slot =
            m * self.mshr + (self.inf_head[m] as usize + self.inf_len[m] as usize) % self.mshr;
        self.inf_tk[slot] = tk;
        self.inf_tok[slot] = tok;
        self.inf_len[m] += 1;
    }
}

/// Result of a completed simulation.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Final contents of the output grid.
    pub output: Vec<f64>,
    pub stats: SimStats,
}

impl SimResult {
    /// Achieved GFLOPS for a workload of `flops` at `clock_ghz`.
    pub fn gflops(&self, flops: f64, clock_ghz: f64) -> f64 {
        self.stats.gflops(flops, clock_ghz)
    }
}

/// Calendar wheel of per-cycle ready bitmaps. Every schedulable delay
/// (channel visibility, DRAM completion, self re-arm) is bounded by the
/// horizon the wheel is sized for, so a bucket never holds wakeups from
/// two different cycles at once.
struct Wheel {
    /// `buckets[cycle & mask]` = bitmap over slots.
    buckets: Vec<Vec<u64>>,
    /// Live-bit count per bucket (O(1) emptiness check for jumps).
    live: Vec<u32>,
    mask: u64,
    words: usize,
}

/// In-order cursor over one cycle's bucket.
struct Sweep {
    bucket: usize,
    word: usize,
}

impl Wheel {
    fn new(nslots: usize, horizon: u64) -> Self {
        let size = (horizon + 2).next_power_of_two().max(2) as usize;
        let words = nslots.div_ceil(64).max(1);
        Self {
            buckets: vec![vec![0u64; words]; size],
            live: vec![0; size],
            mask: size as u64 - 1,
            words,
        }
    }

    /// Mark `slot` ready at cycle `when`. Idempotent — a slot is
    /// evaluated at most once per cycle no matter how many events
    /// target it.
    #[inline]
    fn insert(&mut self, when: u64, slot: u32) {
        let b = (when & self.mask) as usize;
        let w = (slot >> 6) as usize;
        let bit = 1u64 << (slot & 63);
        if self.buckets[b][w] & bit == 0 {
            self.buckets[b][w] |= bit;
            self.live[b] += 1;
        }
    }

    /// Earliest cycle strictly after `now` with pending wakeups.
    fn next_after(&self, now: u64) -> Option<u64> {
        for d in 1..self.buckets.len() as u64 {
            if self.live[((now + d) & self.mask) as usize] > 0 {
                return Some(now + d);
            }
        }
        None
    }

    /// Begin an in-order sweep of cycle `now`'s ready set.
    #[inline]
    fn begin(&self, now: u64) -> Sweep {
        Sweep {
            bucket: (now & self.mask) as usize,
            word: 0,
        }
    }

    /// Next ready slot in ascending slot order; clears its bit. Slots
    /// inserted *ahead of the cursor* during the sweep (the same-cycle
    /// credit rule only ever inserts ahead) are picked up too.
    #[inline]
    fn take_next(&mut self, s: &mut Sweep) -> Option<u32> {
        while s.word < self.words {
            let pending = self.buckets[s.bucket][s.word];
            if pending != 0 {
                let bit = pending.trailing_zeros();
                self.buckets[s.bucket][s.word] &= pending - 1; // clear lowest set bit
                self.live[s.bucket] -= 1;
                return Some(((s.word as u32) << 6) | bit);
            }
            s.word += 1;
        }
        None
    }
}

/// A validated, placed, simulator-ready DFG — the shared **read-only**
/// half of a simulation, produced once at compile time and reusable by
/// any number of concurrent runs. Placement (PE assignment, channel
/// latencies/capacities, the dense evaluation order, the token-arena
/// layout and the event core's slot/endpoint tables) happens here;
/// everything a run mutates — the SoA node state, channel rings, the
/// memory system — lives in [`Simulator`]. `PlacedGraph` is
/// `Send + Sync` plain data, so an `Arc<PlacedGraph>` is the unit the
/// compile-once/execute-many API shares across tiles and threads.
pub struct PlacedGraph {
    /// Immutable per-instruction descriptors (see [`NodeDesc`]).
    descs: Vec<NodeDesc>,
    /// Pristine (empty) channels with placed latencies/capacities and
    /// arena bases assigned.
    chans: Vec<Fifo>,
    /// Token slots a [`ChanArena`] for `chans` needs.
    arena_slots: usize,
    /// Dense evaluation order from [`Placement::eval_slots`] (one group
    /// per occupied PE, or topological singletons when no PE shares
    /// instructions), flattened CSR-style: slot `s` holds
    /// `slot_nodes[slot_start[s] .. slot_start[s + 1]]`.
    slot_nodes: Vec<u32>,
    slot_start: Vec<u32>,
    /// node id -> evaluation slot (event-core wheel index).
    slot_of: Vec<u32>,
    /// channel -> endpoint slots + visibility latency (event core).
    chan_src_slot: Vec<u32>,
    chan_dst_slot: Vec<u32>,
    chan_lat: Vec<u64>,
    /// Load/Store instructions (each owns one MSHR ring).
    n_mem: usize,
    deadlock_quiet: u64,
    horizon: u64,
    done_node: usize,
    dp_ops: usize,
    node_count: usize,
    names: Vec<String>,
}

pub struct Simulator {
    /// Shared read-only graph (descriptors, eval order, arena layout).
    pg: Arc<PlacedGraph>,
    /// This run's channel rings (head/tail cursors over `arena`).
    chans: Vec<Fifo>,
    /// This run's token storage.
    arena: ChanArena,
    /// This run's mutable instruction state.
    st: NodeState,
    mem: MemSys,
    max_cycles: u64,
    stats: SimStats,
    core: SimCore,
    /// Upper bound on tickets this run issues (sizes the event core's
    /// ticket-owner table); sound because the mappings are
    /// read-once/write-once per grid point.
    ticket_hint: usize,
    /// Armed fault plan for channel stalls / PE slow-downs (`None`
    /// unless one of those families is enabled — fill failures live in
    /// [`MemSys`]).
    fault: Option<FaultPlan>,
    /// Cooperative cancellation (run deadlines): when the flag flips,
    /// both cores abandon the run with a "cancelled" error.
    cancel: Option<Arc<AtomicBool>>,
}

impl PlacedGraph {
    /// Validate and place `graph` on machine `m`, building the shared
    /// simulator templates. This is the expensive, once-per-shape half
    /// of [`Simulator::build`].
    pub fn new(mut graph: Graph, m: &Machine) -> Result<Self> {
        crate::dfg::validate::validate(&graph)?;
        let plc: Placement = placement::place(&mut graph, m)?;

        let mut chans: Vec<Fifo> = graph
            .channels
            .iter()
            .map(|c| {
                // Placement floors every route to >= 1 cycle; both cores
                // depend on it (same-cycle visibility would let evaluation
                // order leak in the dense loop and would let the event
                // sweep insert behind its cursor).
                debug_assert!(c.latency >= 1, "channel {} has zero latency", c.id);
                Fifo::new(c.capacity, c.latency).with_endpoints(c.src as u32, c.dst as u32)
            })
            .collect();
        let arena_slots = assign_arena(&mut chans);

        let mut done_node = None;
        let mut n_mem = 0usize;
        let mut descs = Vec::with_capacity(graph.node_count());
        let mut names = Vec::with_capacity(graph.node_count());
        for n in &graph.nodes {
            if n.op == Op::DoneTree {
                done_node = Some(n.id);
            }
            let max_in = (0..16)
                .rev()
                .find(|&p| graph.input(n.id, p).is_some())
                .map(|p| p as usize + 1)
                .unwrap_or(0);
            let ins = (0..max_in)
                .map(|p| graph.input(n.id, p as u8).map(|c| c as u32).unwrap_or(NO_CHAN))
                .collect::<Vec<_>>();
            let mut outs: Vec<Vec<u32>> = Vec::new();
            for p in 0..4u8 {
                let v: Vec<u32> = graph.outputs(n.id, p).iter().map(|&c| c as u32).collect();
                if v.is_empty() && p > 0 {
                    break;
                }
                outs.push(v);
            }
            let agen_len = n.agen.map(|a| a.len()).unwrap_or(0);
            let in0 = ins.first().copied().unwrap_or(NO_CHAN);
            let in1 = ins.get(1).copied().unwrap_or(NO_CHAN);
            let out0: Box<[u32]> =
                outs.first().cloned().unwrap_or_default().into_boxed_slice();
            let mem_idx = if matches!(n.op, Op::Load | Op::Store) {
                n_mem += 1;
                (n_mem - 1) as u32
            } else {
                NO_MEM
            };
            descs.push(NodeDesc {
                op: n.op,
                stage: n.stage,
                coeff: n.coeff.unwrap_or(0.0),
                filter: n.filter,
                agen: n.agen,
                agen_len,
                expected: n.expected.unwrap_or(u64::MAX),
                ins,
                outs,
                in0,
                in1,
                out0,
                mem_idx,
            });
            names.push(n.name.clone());
        }
        let Some(done_node) = done_node else {
            bail!("graph has no DoneTree — the simulator cannot detect completion");
        };

        let (slot_nodes, slot_start) = plc.eval_order(&graph, m);
        let nslots = slot_start.len() - 1;
        let mut slot_of = vec![0u32; descs.len()];
        for s in 0..nslots {
            for k in slot_start[s] as usize..slot_start[s + 1] as usize {
                slot_of[slot_nodes[k] as usize] = s as u32;
            }
        }
        let chan_src_slot: Vec<u32> = chans
            .iter()
            .map(|f| slot_of[f.src_node() as usize])
            .collect();
        let chan_dst_slot: Vec<u32> = chans
            .iter()
            .map(|f| slot_of[f.dst_node() as usize])
            .collect();
        let chan_lat: Vec<u64> = chans.iter().map(|f| f.latency()).collect();

        let max_lat = graph.channels.iter().map(|c| c.latency).max().unwrap_or(1);

        Ok(Self {
            descs,
            chans,
            arena_slots,
            slot_nodes,
            slot_start,
            slot_of,
            chan_src_slot,
            chan_dst_slot,
            chan_lat,
            n_mem,
            deadlock_quiet: m.dram_latency as u64 + max_lat as u64 + 256,
            horizon: m.dram_latency as u64
                + max_lat as u64
                + m.cache_hit_latency as u64
                + 4,
            done_node,
            dp_ops: graph.dp_ops(),
            node_count: graph.node_count(),
            names,
        })
    }

    /// Instructions in the graph (sizing diagnostics).
    pub fn node_count(&self) -> usize {
        self.node_count
    }

    /// The pristine placed channels — capacities, latencies and node
    /// endpoints — for the static deadlock analysis (read-only; runs
    /// clone their own cursors).
    pub fn channels(&self) -> &[Fifo] {
        &self.chans
    }

    /// Name of node `id`, for diagnostics.
    pub fn node_name(&self, id: usize) -> &str {
        &self.names[id]
    }

    /// Quiet-period threshold the runtime deadlock detector uses — the
    /// dynamic counterpart the static `deadlock/*` verdict is
    /// cross-checked against.
    pub fn deadlock_quiet(&self) -> u64 {
        self.deadlock_quiet
    }

    /// Overwrite one channel's credit capacity in the *template*.
    /// Exists solely so the analyzer's mutation tests can seed
    /// underbuffered cycles; a graph altered this way must never be
    /// simulated (see [`Fifo::set_capacity`]).
    #[doc(hidden)]
    pub fn override_channel_capacity(&mut self, chan: usize, capacity: usize) {
        self.chans[chan].set_capacity(capacity);
    }
}

impl Simulator {
    /// Build a simulator for `graph` on machine `m` — the one-shot path:
    /// placement runs here and is thrown away with the run. Callers that
    /// execute the same graph many times (the compile-once API) place
    /// once via [`PlacedGraph::new`] and use [`Simulator::from_placed`].
    ///
    /// `input` is the source grid; `output` the initial contents of the
    /// destination (pre-filled with boundary values by the caller).
    pub fn build(
        graph: Graph,
        m: &Machine,
        input: Vec<f64>,
        output: Vec<f64>,
    ) -> Result<Self> {
        Ok(Self::from_placed(
            &Arc::new(PlacedGraph::new(graph, m)?),
            m,
            input,
            output,
        ))
    }

    /// Instantiate a run over a shared placed graph: clones the pristine
    /// channel cursors, allocates the token arena and SoA node state,
    /// and binds a fresh, pre-reserved memory system — no validation,
    /// no placement, no graph traversal, and no further allocation once
    /// the cycle loop starts.
    pub fn from_placed(
        pg: &Arc<PlacedGraph>,
        m: &Machine,
        input: Vec<f64>,
        output: Vec<f64>,
    ) -> Self {
        // Read-once/write-once per grid point bounds loads + stores;
        // 2x covers multi-phase graphs, the constant covers sync acks.
        let ticket_hint = 2 * (input.len() + output.len()) + 256;
        let mut mem = MemSys::new(m, input, output);
        mem.reserve(ticket_hint, pg.n_mem * m.mshr_per_load + 8);
        Self {
            pg: Arc::clone(pg),
            chans: pg.chans.clone(),
            arena: ChanArena::new(pg.arena_slots),
            st: NodeState::new(pg.node_count, pg.n_mem, m.mshr_per_load),
            mem,
            max_cycles: 200_000_000,
            stats: SimStats {
                dp_ops: pg.dp_ops,
                node_count: pg.node_count,
                ..SimStats::default()
            },
            core: SimCore::default(),
            ticket_hint,
            fault: None,
            cancel: None,
        }
    }

    /// Override the safety cap on simulated cycles.
    pub fn with_max_cycles(mut self, c: u64) -> Self {
        self.max_cycles = c;
        self
    }

    /// Select the scheduler core (default [`SimCore::Event`]).
    pub fn with_core(mut self, core: SimCore) -> Self {
        self.core = core;
        self
    }

    /// Mark the input buffer as fabric-resident (halo exchange): every
    /// load completes at hit latency and counts in
    /// [`super::stats::MemStats::exchanged`] instead of walking the
    /// cache/DRAM model. Values are read functionally at issue either
    /// way, so this changes timing and traffic accounting only — both
    /// scheduler cores stay bit-identical on outputs by construction
    /// (resident tickets have issue-time-known completions, exactly like
    /// cache hits, so the event core's sleep-until-completion path needs
    /// no new machinery).
    pub fn with_fabric_resident(mut self, on: bool) -> Self {
        self.mem.set_fabric_resident(on);
        self
    }

    /// Arm hop-latency pricing for the fabric-resident buffer (warm
    /// halo-exchange chunks): loads inside a priced region complete at
    /// `hit_latency + hop_cycles` behind a per-boundary bandwidth cap
    /// (see [`super::memory::ExchangeCost`]). Completion cycles stay
    /// issue-time-known pure functions of the load sequence, so both
    /// scheduler cores remain bit-identical and outputs cannot change —
    /// only cycle counts and the hop-surcharge counter move.
    pub fn with_exchange_cost(mut self, cost: Option<super::memory::ExchangeCost>) -> Self {
        self.mem.set_exchange_cost(cost);
        self
    }

    /// Arm a deterministic fault-injection plan for this run (see the
    /// module docs). `None` — or a plan with every percentage at 0 —
    /// leaves the run bit-identical to an unfaulted one.
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.mem.set_fault_plan(plan.clone());
        self.fault = plan.filter(|p| p.stall_pct > 0 || p.slow_pct > 0);
        self
    }

    /// Attach a cooperative cancellation flag (run deadlines): when it
    /// becomes true, the cycle loop exits with a "run cancelled" error
    /// instead of completing. Checked coarsely (every ~1k cycles on
    /// the dense core) so the hot path stays one predictable branch.
    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> Self {
        self.cancel = Some(flag);
        self
    }

    /// Run to completion (DoneTree fires) and return the output + stats.
    pub fn run(self) -> Result<SimResult> {
        match self.core {
            SimCore::Dense => self.run_dense(),
            SimCore::Event => self.run_event(),
        }
    }

    /// Reference core: every instruction group, every cycle.
    fn run_dense(mut self) -> Result<SimResult> {
        enum Exit {
            Done(u64),
            Deadlock(u64),
            Cancelled,
            Cap,
        }
        let pg = Arc::clone(&self.pg);
        let fault = self.fault.clone();
        let cancel = self.cancel.clone();
        // Everything past this point runs under the zero-allocation
        // watchdog; error *formatting* happens after the guard drops.
        let exit = {
            let _hot = allocwatch::enter_hot_region();
            let mut now: u64 = 0;
            let mut last_progress: u64 = 0;
            loop {
                if self.st.emitted[pg.done_node] {
                    break Exit::Done(now);
                }
                now += 1;
                if let Some(cf) = &cancel {
                    // Coarse check (cycle 1, then every 1024th) keeps
                    // the flag off the per-cycle critical path.
                    if now & 1023 == 1 && cf.load(Ordering::Relaxed) {
                        break Exit::Cancelled;
                    }
                }
                let mem_prog = self.mem.step(now);
                let mut fired = false;
                for s in 0..pg.slot_start.len() - 1 {
                    if let Some(p) = &fault {
                        if p.pe_suppressed(s as u32, now) {
                            continue; // slow-down epoch: this PE issues nothing
                        }
                    }
                    let (lo, hi) =
                        (pg.slot_start[s] as usize, pg.slot_start[s + 1] as usize);
                    for k in lo..hi {
                        let id = pg.slot_nodes[k] as usize;
                        if fire(
                            id,
                            &pg.descs[id],
                            &mut self.st,
                            &mut self.chans,
                            &mut self.arena,
                            &mut self.mem,
                            &mut self.stats,
                            now,
                            fault.as_ref(),
                        ) {
                            fired = true;
                            break; // one instruction per PE per cycle
                        }
                    }
                }
                if fired || mem_prog {
                    last_progress = now;
                } else if now - last_progress > pg.deadlock_quiet {
                    break Exit::Deadlock(now);
                }
                if now > self.max_cycles {
                    break Exit::Cap;
                }
            }
        };
        match exit {
            Exit::Done(now) => self.finish(now),
            Exit::Deadlock(at) => bail!(self.deadlock_report(at)),
            Exit::Cancelled => bail!("run cancelled: deadline exceeded"),
            Exit::Cap => bail!("simulation exceeded {} cycles", self.max_cycles),
        }
    }

    /// Event-driven core: ready-list sweeps + cycle skipping. See the
    /// module docs for the bit-identity argument.
    fn run_event(mut self) -> Result<SimResult> {
        enum Exit {
            Done(u64),
            Deadlock(u64),
            Cancelled,
            Cap,
        }
        let pg = Arc::clone(&self.pg);
        let fault = self.fault.clone();
        let cancel = self.cancel.clone();
        let nslots = pg.slot_start.len() - 1;
        // Pseudo-slot that keeps the arbiter granting once per cycle
        // while transactions are queued. Highest slot id, so it never
        // perturbs the node sweep order.
        let mem_slot = nslots as u32;

        // Warm-up: everything below allocates once, before the watched
        // cycle loop starts. Stall windows lengthen token visibility
        // by up to `max_extra_latency`, so the wheel is sized for it —
        // a far wake must never alias into a near bucket.
        let wheel_horizon = pg.horizon
            + fault.as_ref().map(|p| p.max_extra_latency()).unwrap_or(0);
        let mut wheel = Wheel::new(nslots + 1, wheel_horizon);
        // ticket id -> issuing slot (ticket ids are sequential).
        let mut ticket_owner: Vec<u32> = Vec::with_capacity(self.ticket_hint);
        let mut resolved: Vec<Ticket> =
            Vec::with_capacity(pg.n_mem * self.st.mshr + 8);
        self.mem.set_record_resolved(true);

        // Cycle 1 starts exactly like the dense loop: every instruction
        // is a candidate; the ones that cannot fire go dormant until an
        // event wakes them.
        for s in 0..nslots as u32 {
            wheel.insert(1, s);
        }

        let exit = {
            let _hot = allocwatch::enter_hot_region();
            let mut now: u64 = 0; // last processed cycle
            let mut last_progress: u64 = 0;
            loop {
                let Some(next) = wheel.next_after(now) else {
                    // Empty wheel + done not fired = deadlock. The dense
                    // loop would idle-tick the quiet period out and then
                    // report (or hit the cycle cap first); reproduce its
                    // bail cycle and text exactly.
                    let report_at = last_progress + pg.deadlock_quiet + 1;
                    break if report_at > self.max_cycles + 1 {
                        Exit::Cap
                    } else {
                        Exit::Deadlock(report_at)
                    };
                };
                // The dense loop checks its quiet-period counter every
                // cycle; if the next event lies beyond the cycle where
                // that counter expires, the dense core would report a
                // deadlock before ever reaching it. Fault plans make
                // this reachable with a non-empty wheel: suppression
                // re-arms hold far-future wakeups that promise no
                // progress. Reproduce the dense bail cycle exactly.
                let quiet_expiry = last_progress + pg.deadlock_quiet + 1;
                if next > quiet_expiry {
                    break if quiet_expiry > self.max_cycles + 1 {
                        Exit::Cap
                    } else {
                        Exit::Deadlock(quiet_expiry)
                    };
                }
                if next > self.max_cycles {
                    // The dense loop gives up at max_cycles + 1, before
                    // this event would ever be reached.
                    break Exit::Cap;
                }
                if let Some(cf) = &cancel {
                    if cf.load(Ordering::Relaxed) {
                        break Exit::Cancelled;
                    }
                }
                self.stats.skipped_cycles += next - now - 1;
                // Replay the per-cycle memory arbiter across the gap
                // (grants can only happen at processed cycles — the mem
                // pseudo-slot keeps the core processing every cycle while
                // the queue is non-empty — but advance_to is exact
                // regardless).
                if let Some(grant) = self.mem.advance_to(now, next) {
                    last_progress = grant;
                }
                now = next;
                // Tickets granted while advancing: wake the owner when
                // the response lands (fills: grant + DRAM latency;
                // stores: grant + drain).
                self.mem.drain_resolved(&mut resolved);
                for &tk in resolved.iter() {
                    let done_at = self.mem.completion(tk).unwrap_or(now);
                    wheel.insert(done_at.max(now), ticket_owner[tk as usize]);
                }
                resolved.clear();

                // Sweep this cycle's ready set in dense evaluation order.
                let mut fired_any = false;
                let mut cursor = wheel.begin(now);
                while let Some(s) = wheel.take_next(&mut cursor) {
                    if s == mem_slot {
                        continue; // arbiter pump: advance_to above did the work
                    }
                    if let Some(p) = &fault {
                        if p.pe_suppressed(s, now) {
                            // Slow-down epoch: nothing on this PE may
                            // issue until the epoch ends. Chunked
                            // re-arm, clamped to the wheel horizon;
                            // the wake re-checks, because the next
                            // epoch may be suppressed too. Exact
                            // vs. the dense core: a ready slot stays
                            // ready through suppression (only its own
                            // firing consumes its inputs), so both
                            // cores fire it at the first unsuppressed
                            // ready cycle.
                            wheel.insert(p.pe_release(now).min(now + wheel_horizon), s);
                            continue;
                        }
                    }
                    let s_us = s as usize;
                    self.stats.wakeups += 1;
                    let (lo, hi) = (
                        pg.slot_start[s_us] as usize,
                        pg.slot_start[s_us + 1] as usize,
                    );
                    for k in lo..hi {
                        let id = pg.slot_nodes[k] as usize;
                        let d = &pg.descs[id];
                        let tickets_before = self.mem.ticket_count();
                        let fired = fire(
                            id,
                            d,
                            &mut self.st,
                            &mut self.chans,
                            &mut self.arena,
                            &mut self.mem,
                            &mut self.stats,
                            now,
                            fault.as_ref(),
                        );
                        for _ in tickets_before..self.mem.ticket_count() {
                            ticket_owner.push(s);
                        }
                        if fired {
                            fired_any = true;
                            // Credit freed on our inputs: a producer later
                            // in the dense order sees it this very cycle
                            // (the dense sweep would reach it after us),
                            // earlier ones next cycle.
                            for &c in &d.ins {
                                if c == NO_CHAN {
                                    continue;
                                }
                                let p = pg.chan_src_slot[c as usize];
                                wheel.insert(if p > s { now } else { now + 1 }, p);
                            }
                            // Pushed tokens become visible `latency`
                            // (+ any stall-window extra — computed from
                            // the same (channel, cycle) coordinates the
                            // push used, so the wake lands exactly at
                            // visibility) cycles out; ports we did not
                            // push into get a spurious, harmless wake.
                            for port in &d.outs {
                                for &c in port {
                                    let extra = fault
                                        .as_ref()
                                        .map(|p| p.stall_extra_at(c, now))
                                        .unwrap_or(0);
                                    wheel.insert(
                                        now + pg.chan_lat[c as usize] + extra,
                                        pg.chan_dst_slot[c as usize],
                                    );
                                }
                            }
                            // We may fire again next cycle, and a
                            // suppressed PE-mate gets its arbitration slot
                            // back.
                            wheel.insert(now + 1, s);
                            break; // one instruction per PE per cycle
                        } else if matches!(d.op, Op::Load | Op::Store) {
                            // Blocked on an outstanding memory response
                            // whose completion time is already known:
                            // sleep until it lands. (Ungranted tickets
                            // wake via drain_resolved at grant time.)
                            if let Some((tk, _)) = self.st.inflight_front(d.mem_idx) {
                                if let Some(done_at) = self.mem.completion(tk) {
                                    if done_at > now {
                                        wheel.insert(done_at, s);
                                    }
                                }
                            }
                        }
                    }
                }
                if fired_any {
                    last_progress = now;
                }
                if self.mem.busy() {
                    wheel.insert(now + 1, mem_slot);
                }
                if self.st.emitted[pg.done_node] {
                    break Exit::Done(now);
                }
            }
        };
        match exit {
            Exit::Done(now) => self.finish(now),
            Exit::Deadlock(at) => bail!(self.deadlock_report(at)),
            Exit::Cancelled => bail!("run cancelled: deadline exceeded"),
            Exit::Cap => bail!("simulation exceeded {} cycles", self.max_cycles),
        }
    }

    /// Common epilogue: freeze the counters and hand the grid back.
    fn finish(mut self, now: u64) -> Result<SimResult> {
        self.stats.cycles = now;
        self.stats.max_queue_occupancy = self
            .chans
            .iter()
            .map(|c| c.max_occupancy)
            .max()
            .unwrap_or(0);
        let (output, mem_stats) = self.mem.into_output();
        self.stats.mem = mem_stats;
        Ok(SimResult {
            output,
            stats: self.stats,
        })
    }

    /// Forensic account of why nothing can make progress: blocked
    /// instructions (which input is starved, which output is backed
    /// up), every full channel with the producer/consumer pair at its
    /// endpoints, and the memory system's outstanding work. Both cores
    /// produce this byte-identically at the same cycle: all simulator
    /// state froze at the last progress cycle, and `now` is the same
    /// reported quiet-period expiry. The header line is load-bearing —
    /// `ScgraError::classify` keys on its prefix.
    fn deadlock_report(&self, now: u64) -> String {
        let pg = &self.pg;
        let mut lines = vec![format!(
            "deadlock: no progress for {} cycles (at cycle {})",
            pg.deadlock_quiet, now
        )];
        for (id, d) in pg.descs.iter().enumerate() {
            if self.st.emitted[id] && matches!(d.op, Op::SyncCount | Op::DoneTree) {
                continue;
            }
            let waiting_in: Vec<String> = d
                .ins
                .iter()
                .enumerate()
                .filter(|(_, &c)| {
                    c != NO_CHAN && self.chans[c as usize].peek(&self.arena, now).is_none()
                })
                .map(|(p, _)| format!("in{p} empty"))
                .collect();
            let blocked_out: Vec<String> = d
                .outs
                .iter()
                .flatten()
                .filter(|&&c| !self.chans[c as usize].can_push())
                .map(|&c| format!("out ch{c} full"))
                .collect();
            if !waiting_in.is_empty() || !blocked_out.is_empty() {
                if lines.len() < 24 {
                    lines.push(format!(
                        "  {}: {} {}",
                        pg.names[id],
                        waiting_in.join(","),
                        blocked_out.join(",")
                    ));
                }
            }
        }
        // Backpressure edges: a full channel names the stalled
        // producer -> consumer pair holding the cycle together.
        let mut full = 0usize;
        for (c, f) in self.chans.iter().enumerate() {
            if !f.can_push() {
                full += 1;
                if lines.len() < 40 {
                    lines.push(format!(
                        "  ch{c}: full {}/{} {} -> {}",
                        f.len(),
                        f.capacity(),
                        pg.names[f.src_node() as usize],
                        pg.names[f.dst_node() as usize],
                    ));
                }
            }
        }
        lines.push(format!("  {} full channel(s) total", full));
        lines.push(format!("  {}", self.mem.forensic_summary(now)));
        lines.join("\n")
    }
}

#[inline]
fn can_push_all(chans: &[Fifo], outs: &[u32]) -> bool {
    outs.iter().all(|&c| chans[c as usize].can_push())
}

#[inline]
fn push_all(
    chans: &mut [Fifo],
    a: &mut ChanArena,
    outs: &[u32],
    t: Token,
    now: u64,
    fault: Option<&FaultPlan>,
) {
    match fault {
        None => {
            for &c in outs {
                chans[c as usize].push(a, t, now);
            }
        }
        // Stall window: visibility is delayed by the plan's extra for
        // this (channel, epoch). The event core computes the same
        // extra from the same coordinates when scheduling the
        // consumer's wake.
        Some(p) => {
            for &c in outs {
                chans[c as usize].push_delayed(a, t, now, p.stall_extra_at(c, now));
            }
        }
    }
}

/// Attempt to fire one instruction; returns true if it made progress.
/// A false return mutates **nothing** — the event core relies on this
/// to make spurious wakeups harmless. `d` is the instruction's shared
/// descriptor; all mutation goes through the SoA `st`, the channel
/// cursors and the token arena — no allocation on any path.
#[allow(clippy::too_many_arguments)]
fn fire(
    id: usize,
    d: &NodeDesc,
    st: &mut NodeState,
    chans: &mut [Fifo],
    arena: &mut ChanArena,
    mem: &mut MemSys,
    stats: &mut SimStats,
    now: u64,
    fault: Option<&FaultPlan>,
) -> bool {
    let fired = match d.op {
        Op::AddrGen => {
            if st.agen_pos[id] < d.agen_len && can_push_all(chans, &d.out0) {
                let (row, col, addr) = d.agen.as_ref().unwrap().token(st.agen_pos[id]);
                st.agen_pos[id] += 1;
                push_all(chans, arena, &d.out0, Token::new(addr as f64, row, col), now, fault);
                true
            } else {
                false
            }
        }
        Op::Load => {
            let mut acted = false;
            // Deliver the oldest completed response (in order).
            if let Some((t, tok)) = st.inflight_front(d.mem_idx) {
                if mem.done(t, now) && can_push_all(chans, &d.out0) {
                    st.inflight_pop(d.mem_idx);
                    push_all(chans, arena, &d.out0, tok, now, fault);
                    acted = true;
                }
            }
            // Issue a new request (address generator + load PE pair).
            if st.inflight_len(d.mem_idx) < st.mshr {
                let ch = d.in0 as usize;
                if let Some(addr_tok) = chans[ch].peek(arena, now) {
                    chans[ch].pop(arena, now);
                    let (val, t) = mem.load(addr_tok.val as u64, now);
                    st.inflight_push(
                        d.mem_idx,
                        t,
                        Token::new(val, addr_tok.row, addr_tok.col),
                    );
                    acted = true;
                }
            }
            acted
        }
        Op::Store => {
            let mut acted = false;
            if let Some((t, tok)) = st.inflight_front(d.mem_idx) {
                if mem.done(t, now) && can_push_all(chans, &d.out0) {
                    st.inflight_pop(d.mem_idx);
                    push_all(chans, arena, &d.out0, tok, now, fault);
                    acted = true;
                }
            }
            if st.inflight_len(d.mem_idx) < st.mshr {
                let (a, dd) = (d.in0 as usize, d.in1 as usize);
                if chans[a].peek(arena, now).is_some() && chans[dd].peek(arena, now).is_some()
                {
                    let addr_tok = chans[a].pop(arena, now).unwrap();
                    let data_tok = chans[dd].pop(arena, now).unwrap();
                    let t = mem.store(addr_tok.val as u64, data_tok.val, now);
                    st.inflight_push(
                        d.mem_idx,
                        t,
                        Token::new(1.0, addr_tok.row, addr_tok.col),
                    );
                    acted = true;
                }
            }
            acted
        }
        Op::Mul => {
            let ch = d.in0 as usize;
            if chans[ch].peek(arena, now).is_some() && can_push_all(chans, &d.out0) {
                let t = chans[ch].pop(arena, now).unwrap();
                stats.dp_fires += 1;
                push_all(
                    chans,
                    arena,
                    &d.out0,
                    Token::new(d.coeff * t.val, t.row, t.col),
                    now,
                    fault,
                );
                true
            } else {
                false
            }
        }
        Op::Mac => {
            let (p, dd) = (d.in0 as usize, d.in1 as usize);
            if chans[p].peek(arena, now).is_some()
                && chans[dd].peek(arena, now).is_some()
                && can_push_all(chans, &d.out0)
            {
                let part = chans[p].pop(arena, now).unwrap();
                let data = chans[dd].pop(arena, now).unwrap();
                stats.dp_fires += 1;
                push_all(
                    chans,
                    arena,
                    &d.out0,
                    Token::new(part.val + d.coeff * data.val, data.row, data.col),
                    now,
                    fault,
                );
                true
            } else {
                false
            }
        }
        Op::Add => {
            let (a, b) = (d.in0 as usize, d.in1 as usize);
            if chans[a].peek(arena, now).is_some()
                && chans[b].peek(arena, now).is_some()
                && can_push_all(chans, &d.out0)
            {
                let x = chans[a].pop(arena, now).unwrap();
                let y = chans[b].pop(arena, now).unwrap();
                stats.dp_fires += 1;
                push_all(
                    chans,
                    arena,
                    &d.out0,
                    Token::new(x.val + y.val, x.row, x.col),
                    now,
                    fault,
                );
                true
            } else {
                false
            }
        }
        Op::Copy | Op::Shift => {
            let ch = d.in0 as usize;
            if chans[ch].peek(arena, now).is_some() && can_push_all(chans, &d.out0) {
                let t = chans[ch].pop(arena, now).unwrap();
                push_all(chans, arena, &d.out0, t, now, fault);
                true
            } else {
                false
            }
        }
        Op::Filter => {
            let ch = d.in0 as usize;
            if let Some(tok) = chans[ch].peek(arena, now) {
                let pass = d
                    .filter
                    .as_ref()
                    .map(|f| f.passes(st.filter_idx[id], tok.row, tok.col))
                    .unwrap_or(true);
                if pass {
                    if can_push_all(chans, &d.out0) {
                        chans[ch].pop(arena, now);
                        st.filter_idx[id] += 1;
                        push_all(chans, arena, &d.out0, tok, now, fault);
                        true
                    } else {
                        false
                    }
                } else {
                    // Dropping needs no credit.
                    chans[ch].pop(arena, now);
                    st.filter_idx[id] += 1;
                    true
                }
            } else {
                false
            }
        }
        Op::Mux => {
            // in0 = select stream, in1 = data; pass data when sel != 0.
            let (s, dd) = (d.in0 as usize, d.in1 as usize);
            if chans[s].peek(arena, now).is_some() && chans[dd].peek(arena, now).is_some() {
                let pass = chans[s].peek(arena, now).unwrap().val != 0.0;
                if pass && !can_push_all(chans, &d.out0) {
                    return false;
                }
                chans[s].pop(arena, now);
                let data = chans[dd].pop(arena, now).unwrap();
                if pass {
                    push_all(chans, arena, &d.out0, data, now, fault);
                }
                true
            } else {
                false
            }
        }
        Op::Demux => {
            // Route by row parity band: port = row % nports.
            let ch = d.in0 as usize;
            if let Some(tok) = chans[ch].peek(arena, now) {
                let nports = d.outs.len().max(1);
                let port = (tok.row as usize) % nports;
                if can_push_all(chans, &d.outs[port]) {
                    chans[ch].pop(arena, now);
                    push_all(chans, arena, &d.outs[port], tok, now, fault);
                    true
                } else {
                    false
                }
            } else {
                false
            }
        }
        Op::Cmp => {
            let (a, b) = (d.in0 as usize, d.in1 as usize);
            if chans[a].peek(arena, now).is_some()
                && chans[b].peek(arena, now).is_some()
                && can_push_all(chans, &d.out0)
            {
                let x = chans[a].pop(arena, now).unwrap();
                let y = chans[b].pop(arena, now).unwrap();
                let v = if x.val <= y.val { 1.0 } else { 0.0 };
                push_all(chans, arena, &d.out0, Token::new(v, x.row, x.col), now, fault);
                true
            } else {
                false
            }
        }
        Op::Or => {
            let (a, b) = (d.in0 as usize, d.in1 as usize);
            if chans[a].peek(arena, now).is_some()
                && chans[b].peek(arena, now).is_some()
                && can_push_all(chans, &d.out0)
            {
                let x = chans[a].pop(arena, now).unwrap();
                let y = chans[b].pop(arena, now).unwrap();
                let v = if x.val != 0.0 || y.val != 0.0 { 1.0 } else { 0.0 };
                push_all(chans, arena, &d.out0, Token::new(v, x.row, x.col), now, fault);
                true
            } else {
                false
            }
        }
        Op::SyncCount => {
            let mut acted = false;
            let ch = d.in0 as usize;
            if chans[ch].peek(arena, now).is_some() {
                chans[ch].pop(arena, now);
                st.count[id] += 1;
                acted = true;
            }
            if !st.emitted[id] && st.count[id] >= d.expected {
                let outs_ok = d
                    .outs
                    .first()
                    .map(|o| can_push_all(chans, o))
                    .unwrap_or(true);
                if outs_ok {
                    if let Some(o) = d.outs.first() {
                        push_all(
                            chans,
                            arena,
                            o,
                            Token::new(st.count[id] as f64, 0, 0),
                            now,
                            fault,
                        );
                    }
                    st.emitted[id] = true;
                    acted = true;
                }
            }
            acted
        }
        Op::DoneTree => {
            if st.emitted[id] {
                false
            } else {
                let all = d
                    .ins
                    .iter()
                    .all(|&c| c != NO_CHAN && chans[c as usize].peek(arena, now).is_some());
                // Completion blocks until the done channel has credit,
                // like every other op — the token is the host-visible
                // completion signal and must never be dropped.
                if all && can_push_all(chans, &d.out0) {
                    for &c in &d.ins {
                        chans[c as usize].pop(arena, now);
                    }
                    st.emitted[id] = true;
                    push_all(chans, arena, &d.out0, Token::new(1.0, 0, 0), now, fault);
                    true
                } else {
                    false
                }
            }
        }
        Op::Const => {
            // `expected` defaults to u64::MAX (unlimited stream).
            if st.count[id] < d.expected && can_push_all(chans, &d.out0) {
                st.count[id] += 1;
                push_all(chans, arena, &d.out0, Token::new(d.coeff, 0, 0), now, fault);
                true
            } else {
                false
            }
        }
    };
    if fired {
        stats.record_fire(d.stage);
        stats.note_fire_event(id as u32, now);
    }
    fired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{map1d, map2d, StencilSpec};
    use crate::util::rng::XorShift;

    /// Native oracle: 1-D star stencil, interior-only, left-to-right.
    fn ref_1d(x: &[f64], c: &[f64]) -> Vec<f64> {
        let r = (c.len() - 1) / 2;
        let mut out = x.to_vec();
        for o in r..x.len() - r {
            let mut acc = c[0] * x[o - r];
            for (k, &ck) in c.iter().enumerate().skip(1) {
                acc += ck * x[o - r + k];
            }
            out[o] = acc;
        }
        out
    }

    fn run_1d(spec: &StencilSpec, w: usize, input: Vec<f64>) -> SimResult {
        let g = map1d::build(spec, w).unwrap();
        let m = Machine::paper();
        let out0 = input.clone();
        Simulator::build(g, &m, input, out0).unwrap().run().unwrap()
    }

    #[test]
    fn simulates_3pt_1d_correctly() {
        let spec = StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap();
        let mut rng = XorShift::new(1);
        let x = rng.normal_vec(32);
        let res = run_1d(&spec, 3, x.clone());
        let want = ref_1d(&x, &spec.cx);
        for i in 0..32 {
            assert!(
                (res.output[i] - want[i]).abs() < 1e-12,
                "i={i}: {} vs {}",
                res.output[i],
                want[i]
            );
        }
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn simulates_17pt_1d_all_worker_counts() {
        let spec = StencilSpec::dim1(200, crate::stencil::spec::symmetric_taps(8)).unwrap();
        let mut rng = XorShift::new(7);
        let x = rng.normal_vec(200);
        let want = ref_1d(&x, &spec.cx);
        for w in [1, 2, 3, 6] {
            let res = run_1d(&spec, w, x.clone());
            for i in 0..200 {
                assert!((res.output[i] - want[i]).abs() < 1e-12, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn dp_fire_count_matches_work() {
        let spec = StencilSpec::dim1(64, vec![0.25, 0.5, 0.25]).unwrap();
        let res = run_1d(&spec, 2, vec![1.0; 64]);
        // Each of the 62 interior outputs takes 3 DP fires.
        assert_eq!(res.stats.dp_fires, 62 * 3);
    }

    #[test]
    fn memory_traffic_is_read_once_write_once() {
        let spec = StencilSpec::dim1(512, crate::stencil::spec::symmetric_taps(4)).unwrap();
        let res = run_1d(&spec, 4, vec![1.0; 512]);
        // Reads: ceil(512*8 / 64) lines = 64 fills = 4096 bytes.
        assert_eq!(res.stats.mem.dram_read_bytes, 512 * 8);
        // Writes: interior only.
        assert_eq!(res.stats.mem.dram_write_bytes, (512 - 8) * 8);
        // Every grid point loaded exactly once.
        assert_eq!(res.stats.mem.loads, 512);
    }

    /// Native oracle: 2-D star stencil matching ref.py's chain order.
    fn ref_2d(x: &[f64], nx: usize, ny: usize, spec: &StencilSpec) -> Vec<f64> {
        let (rx, ry) = (spec.rx, spec.ry);
        let mut out = x.to_vec();
        for r in ry..ny - ry {
            for c in rx..nx - rx {
                let mut acc = spec.cx[0] * x[r * nx + c - rx];
                for t in 1..2 * rx + 1 {
                    acc += spec.cx[t] * x[r * nx + c - rx + t];
                }
                for u in 0..2 * ry {
                    let k = if u < ry { u } else { u + 1 };
                    let rr = r + k - ry;
                    acc += spec.cy[u] * x[rr * nx + c];
                }
                out[r * nx + c] = acc;
            }
        }
        out
    }

    #[test]
    fn simulates_5pt_2d_correctly() {
        let spec = StencilSpec::heat2d(20, 14, 0.2);
        let mut rng = XorShift::new(3);
        let x = rng.normal_vec(20 * 14);
        let g = map2d::build(&spec, 3).unwrap();
        let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        let want = ref_2d(&x, 20, 14, &spec);
        for i in 0..x.len() {
            assert!(
                (res.output[i] - want[i]).abs() < 1e-12,
                "i={i}: {} vs {}",
                res.output[i],
                want[i]
            );
        }
    }

    #[test]
    fn simulates_wide_radius_2d() {
        let spec = StencilSpec::dim2(
            30,
            22,
            crate::stencil::spec::symmetric_taps(3),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let mut rng = XorShift::new(11);
        let x = rng.normal_vec(30 * 22);
        for w in [1, 2, 4] {
            let g = map2d::build(&spec, w).unwrap();
            let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .run()
                .unwrap();
            let want = ref_2d(&x, 30, 22, &spec);
            for i in 0..x.len() {
                assert!((res.output[i] - want[i]).abs() < 1e-11, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn boundary_untouched() {
        let spec = StencilSpec::heat2d(12, 10, 0.2);
        let x: Vec<f64> = (0..120).map(|i| i as f64).collect();
        let g = map2d::build(&spec, 2).unwrap();
        let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        for c in 0..12 {
            assert_eq!(res.output[c], x[c]); // top row
            assert_eq!(res.output[9 * 12 + c], x[9 * 12 + c]); // bottom row
        }
        for r in 0..10 {
            assert_eq!(res.output[r * 12], x[r * 12]); // left col
            assert_eq!(res.output[r * 12 + 11], x[r * 12 + 11]); // right col
        }
    }

    #[test]
    fn undersized_buffering_deadlocks_with_identical_report_on_both_cores() {
        // §III-B: strip the mandatory buffering and the pipeline must
        // deadlock (failure injection) — with the same report from the
        // dense quiet-period counter and the event core's empty wheel.
        let spec = StencilSpec::dim2(
            24,
            18,
            crate::stencil::spec::symmetric_taps(1),
            crate::stencil::spec::y_taps(3), // ry = 3 needs deep buffers
        )
        .unwrap();
        let m = Machine::paper();
        let x = vec![1.0; 24 * 18];
        let mut errs = Vec::new();
        for core in [SimCore::Dense, SimCore::Event] {
            let mut g = map2d::build(&spec, 2).unwrap();
            for ch in &mut g.channels {
                ch.capacity = ch.capacity.min(2); // sabotage
            }
            // Placement re-raises capacity to lat+2 which is still < needed.
            let err = Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .run()
                .unwrap_err()
                .to_string();
            assert!(err.contains("deadlock"), "{core}: {err}");
            errs.push(err);
        }
        assert_eq!(errs[0], errs[1], "cores must report the same deadlock");
        // The report is forensic: full channels named with their
        // endpoint instructions, plus the memory system's state.
        assert!(errs[0].contains("full channel(s) total"), "{}", errs[0]);
        assert!(errs[0].contains(" -> "), "endpoints expected: {}", errs[0]);
        assert!(errs[0].contains("memory:"), "{}", errs[0]);
    }

    #[test]
    fn deterministic_across_runs() {
        let spec = StencilSpec::heat2d(16, 12, 0.2);
        let mut rng = XorShift::new(5);
        let x = rng.normal_vec(16 * 12);
        let run = || {
            let g = map2d::build(&spec, 2).unwrap();
            Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .run()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.output, b.output);
        assert_eq!(a.stats.mem, b.stats.mem);
        assert_eq!(a.stats.fire_hash, b.stats.fire_hash);
    }

    #[test]
    fn event_core_bitwise_equals_dense_core_1d_and_2d() {
        let m = Machine::paper();
        let mut rng = XorShift::new(0xC0FE);

        let s1 = StencilSpec::dim1(96, crate::stencil::spec::symmetric_taps(3)).unwrap();
        let x1 = rng.normal_vec(96);
        let s2 = StencilSpec::heat2d(18, 12, 0.2);
        let x2 = rng.normal_vec(18 * 12);

        let cases: [(&StencilSpec, &Vec<f64>, usize); 2] = [(&s1, &x1, 3), (&s2, &x2, 2)];
        for (spec, x, w) in cases {
            let build = || crate::stencil::build_graph(spec, w).unwrap();
            let dense = Simulator::build(build(), &m, x.clone(), x.clone())
                .unwrap()
                .with_core(SimCore::Dense)
                .run()
                .unwrap();
            let event = Simulator::build(build(), &m, x.clone(), x.clone())
                .unwrap()
                .with_core(SimCore::Event)
                .run()
                .unwrap();
            assert_eq!(dense.output, event.output);
            assert_eq!(dense.stats.cycles, event.stats.cycles);
            assert_eq!(dense.stats.mem, event.stats.mem);
            assert_eq!(dense.stats.total_fires(), event.stats.total_fires());
            assert_eq!(dense.stats.dp_fires, event.stats.dp_fires);
            assert_eq!(
                dense.stats.fire_hash, event.stats.fire_hash,
                "fire sequences must be identical in order, not just count"
            );
            assert_eq!(
                dense.stats.max_queue_occupancy,
                event.stats.max_queue_occupancy
            );
            assert_eq!(dense.stats.skipped_cycles, 0, "dense core never skips");
            assert!(
                event.stats.wakeups > 0
                    && event.stats.wakeups
                        < event.stats.cycles * event.stats.node_count as u64,
                "event core must do strictly less evaluation work"
            );
        }
    }

    #[test]
    fn done_tree_blocks_until_credit_instead_of_dropping() {
        // Adversarial state crafted directly: a DoneTree whose single
        // input token is ready but whose capacity-1 output channel is
        // full. It must refuse to fire (and must not consume its input)
        // until the credit frees — dropping the completion token here
        // was the old behaviour this test pins the fix for.
        let mut chans = vec![Fifo::new(4, 1), Fifo::new(1, 1)];
        let slots = assign_arena(&mut chans);
        let mut arena = ChanArena::new(slots);
        chans[0].push(&mut arena, Token::new(1.0, 0, 0), 0); // visible at cycle 1
        chans[1].push(&mut arena, Token::new(9.0, 0, 0), 0); // occupies the only credit
        let d = NodeDesc {
            op: Op::DoneTree,
            stage: Stage::Sync,
            coeff: 0.0,
            filter: None,
            agen: None,
            agen_len: 0,
            expected: 1,
            ins: vec![0],
            outs: vec![vec![1]],
            in0: 0,
            in1: NO_CHAN,
            out0: vec![1u32].into_boxed_slice(),
            mem_idx: NO_MEM,
        };
        let mut st = NodeState::new(1, 0, 4);
        let m = Machine::paper();
        let mut mem = MemSys::new(&m, vec![0.0], vec![0.0]);
        let mut stats = SimStats::default();
        assert!(!fire(0, &d, &mut st, &mut chans, &mut arena, &mut mem, &mut stats, 1, None));
        assert!(!st.emitted[0], "must block, not emit-and-drop");
        assert!(
            chans[0].peek(&arena, 1).is_some(),
            "input token must stay queued"
        );
        // Credit frees: now it completes and the token is delivered.
        chans[1].pop(&mut arena, 1);
        assert!(fire(0, &d, &mut st, &mut chans, &mut arena, &mut mem, &mut stats, 2, None));
        assert!(st.emitted[0]);
        assert_eq!(chans[1].len(), 1, "completion token delivered, not dropped");
        assert!(chans[0].peek(&arena, 2).is_none(), "input consumed on completion");
    }

    #[test]
    fn done_token_flows_through_minimal_capacity_done_channel() {
        // End-to-end regression: a chained done tree behind a
        // capacity-1 channel (placement floors it to latency + 2, the
        // minimum streamable credit) still completes, on both cores with
        // the same cycle count — the completion token must reach the
        // downstream tree or the run would deadlock.
        use crate::dfg::builder::Dsl;
        let build = || {
            let mut d = Dsl::new();
            d.op("c", Op::Const, Stage::Control)
                .coeff(5.0)
                .expected(1)
                .out("tok");
            d.op("sy", Op::SyncCount, Stage::Sync)
                .expected(1)
                .input(0, "tok")
                .out("d0");
            d.op("done1", Op::DoneTree, Stage::Sync)
                .expected(1)
                .input(0, "d0")
                .out("hostd");
            d.op("done2", Op::DoneTree, Stage::Sync)
                .expected(1)
                .input_cap(0, "hostd", 1);
            d.build().unwrap()
        };
        let m = Machine::paper();
        let dense = Simulator::build(build(), &m, vec![0.0], vec![0.0])
            .unwrap()
            .with_core(SimCore::Dense)
            .run()
            .unwrap();
        let event = Simulator::build(build(), &m, vec![0.0], vec![0.0])
            .unwrap()
            .with_core(SimCore::Event)
            .run()
            .unwrap();
        assert_eq!(dense.stats.cycles, event.stats.cycles);
        assert_eq!(dense.stats.total_fires(), event.stats.total_fires());
        // Const, sync pop + emit, done1, done2 all fired.
        assert!(dense.stats.total_fires() >= 4);
    }

    #[test]
    fn warm_cycle_loop_is_allocation_free_under_watchdog() {
        // The in-crate half of the zero-allocation contract: both cores
        // run whole simulations inside a hot region without tripping
        // the watchdog flag logic (the allocator-level count lives in
        // rust/tests/alloc_free.rs where a counting global allocator is
        // installed). Here we pin that the guards are actually on the
        // run path: a run must enter and cleanly exit the hot region.
        let spec = StencilSpec::heat2d(14, 10, 0.2);
        let x = vec![1.0; 140];
        for core in [SimCore::Dense, SimCore::Event] {
            let g = map2d::build(&spec, 2).unwrap();
            let sim = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .with_core(core);
            let res = sim.run().unwrap();
            assert!(res.stats.cycles > 0);
        }
    }

    #[test]
    fn injected_fill_faults_retry_and_stay_bit_identical_across_cores() {
        let m = Machine::paper();
        let spec = StencilSpec::heat2d(18, 12, 0.2);
        let mut rng = XorShift::new(21);
        let x = rng.normal_vec(18 * 12);
        let run = |core, plan: Option<FaultPlan>| {
            let g = map2d::build(&spec, 2).unwrap();
            Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .with_fault_plan(plan)
                .run()
                .unwrap()
        };
        let plan = FaultPlan { seed: 9, fill_fail_pct: 40, ..FaultPlan::default() };
        let clean = run(SimCore::Event, None);
        let dense = run(SimCore::Dense, Some(plan.clone()));
        let event = run(SimCore::Event, Some(plan));
        assert!(dense.stats.mem.retries > 0, "a 40% plan must inject retries");
        assert_eq!(dense.output, event.output);
        assert_eq!(dense.stats.cycles, event.stats.cycles);
        assert_eq!(dense.stats.mem, event.stats.mem);
        assert_eq!(dense.stats.fire_hash, event.stats.fire_hash);
        // Transient faults perturb timing, never data.
        assert_eq!(dense.output, clean.output);
        assert!(dense.stats.cycles > clean.stats.cycles);
        assert_eq!(clean.stats.mem.retries, 0);
    }

    #[test]
    fn stall_and_slowdown_faults_stay_bit_identical_across_cores() {
        let m = Machine::paper();
        let spec = StencilSpec::dim1(96, crate::stencil::spec::symmetric_taps(3)).unwrap();
        let mut rng = XorShift::new(31);
        let x = rng.normal_vec(96);
        let run = |core, plan: Option<FaultPlan>| {
            let g = map1d::build(&spec, 3).unwrap();
            Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .with_fault_plan(plan)
                .run()
                .unwrap()
        };
        let plan = FaultPlan {
            seed: 4,
            stall_pct: 35,
            stall_extra: 6,
            slow_pct: 25,
            epoch_cycles: 64,
            ..FaultPlan::default()
        };
        let clean = run(SimCore::Event, None);
        let dense = run(SimCore::Dense, Some(plan.clone()));
        let event = run(SimCore::Event, Some(plan));
        assert_eq!(dense.output, event.output);
        assert_eq!(dense.stats.cycles, event.stats.cycles);
        assert_eq!(dense.stats.mem, event.stats.mem);
        assert_eq!(dense.stats.fire_hash, event.stats.fire_hash);
        assert_eq!(dense.output, clean.output, "faults must not corrupt data");
        assert!(
            dense.stats.cycles > clean.stats.cycles,
            "stalls + slow-downs must cost cycles ({} vs {})",
            dense.stats.cycles,
            clean.stats.cycles
        );
    }

    #[test]
    fn unarmed_fault_plan_is_bitwise_free() {
        let m = Machine::paper();
        let spec = StencilSpec::heat2d(14, 10, 0.2);
        let x = vec![1.0; 140];
        let run = |plan: Option<FaultPlan>| {
            let g = map2d::build(&spec, 2).unwrap();
            Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_fault_plan(plan)
                .run()
                .unwrap()
        };
        let without = run(None);
        let with = run(Some(FaultPlan::default())); // all percentages 0
        assert_eq!(without.output, with.output);
        assert_eq!(without.stats.cycles, with.stats.cycles);
        assert_eq!(without.stats.fire_hash, with.stats.fire_hash);
        assert_eq!(without.stats.mem, with.stats.mem);
        assert_eq!(with.stats.mem.retries, 0);
    }

    #[test]
    fn cancel_flag_aborts_both_cores_without_hanging() {
        let m = Machine::paper();
        let spec = StencilSpec::heat2d(16, 12, 0.2);
        let x = vec![1.0; 16 * 12];
        let flag = Arc::new(AtomicBool::new(true)); // pre-cancelled
        for core in [SimCore::Dense, SimCore::Event] {
            let g = map2d::build(&spec, 2).unwrap();
            let err = Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .with_cancel(Arc::clone(&flag))
                .run()
                .unwrap_err()
                .to_string();
            assert!(err.contains("cancelled"), "{core}: {err}");
        }
        // An un-tripped flag changes nothing.
        let free = Arc::new(AtomicBool::new(false));
        let g = map2d::build(&spec, 2).unwrap();
        let a = Simulator::build(g, &m, x.clone(), x.clone())
            .unwrap()
            .with_cancel(free)
            .run()
            .unwrap();
        let g = map2d::build(&spec, 2).unwrap();
        let b = Simulator::build(g, &m, x.clone(), x.clone()).unwrap().run().unwrap();
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.output, b.output);
    }

    #[test]
    fn sim_core_parse_and_display() {
        assert_eq!(SimCore::parse("dense").unwrap(), SimCore::Dense);
        assert_eq!(SimCore::parse("event").unwrap(), SimCore::Event);
        assert!(SimCore::parse("quantum").is_err());
        assert_eq!(SimCore::Dense.to_string(), "dense");
        assert_eq!(SimCore::Event.to_string(), "event");
        assert_eq!(SimCore::default(), SimCore::Event);
    }
}
