//! Simulation statistics: PE utilization, memory traffic, cache behaviour
//! and queue occupancy — the counters §VIII reports (e.g. the conflict-
//! miss comparison between stencil1D and stencil2D).

use crate::dfg::node::Stage;

/// Memory-subsystem counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemStats {
    pub loads: u64,
    pub stores: u64,
    pub hits: u64,
    pub misses: u64,
    /// Loads merged into an in-flight line fill (MSHR hits).
    pub merged: u64,
    /// Misses to lines that were previously resident (conflict misses).
    pub conflict_misses: u64,
    pub evictions: u64,
    /// Loads served from a fabric-resident buffer (halo exchange): the
    /// value never touches the cache or DRAM — a neighboring tile (or
    /// this tile's previous chunk) already holds it on fabric.
    pub exchanged: u64,
    /// Surcharge cycles the hop-latency exchange pricer added on top of
    /// flat hit latency across all exchanged loads (network hops plus
    /// boundary-link queueing). Always 0 in the free exchange model and
    /// in reload mode.
    pub exchanged_hop_cycles: u64,
    /// Line fills that failed transiently (injected via
    /// `util::fault::FaultPlan`) and were re-queued with exponential
    /// backoff. Always 0 when no fault plan is armed.
    pub retries: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
}

impl MemStats {
    /// Fold another counter set into this one, field by field. The
    /// single merge point for multi-run accounting (the coordinator's
    /// per-tile reports) — add new counters here, not at call sites,
    /// so they can never be silently dropped in a merge.
    pub fn accumulate(&mut self, other: &MemStats) {
        let MemStats {
            loads,
            stores,
            hits,
            misses,
            merged,
            conflict_misses,
            evictions,
            exchanged,
            exchanged_hop_cycles,
            retries,
            dram_read_bytes,
            dram_write_bytes,
        } = other;
        self.loads += loads;
        self.stores += stores;
        self.hits += hits;
        self.misses += misses;
        self.merged += merged;
        self.conflict_misses += conflict_misses;
        self.evictions += evictions;
        self.exchanged += exchanged;
        self.exchanged_hop_cycles += exchanged_hop_cycles;
        self.retries += retries;
        self.dram_read_bytes += dram_read_bytes;
        self.dram_write_bytes += dram_write_bytes;
    }

    pub fn total_dram_bytes(&self) -> u64 {
        self.dram_read_bytes + self.dram_write_bytes
    }

    /// Fraction of loads served without a DRAM fill (cache hits, MSHR
    /// merges and fabric-resident exchange hits alike).
    pub fn reuse_ratio(&self) -> f64 {
        if self.loads == 0 {
            return 0.0;
        }
        (self.hits + self.merged + self.exchanged) as f64 / self.loads as f64
    }
}

/// Whole-run statistics.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub cycles: u64,
    /// Instruction firings per pipeline stage.
    pub fires_control: u64,
    pub fires_reader: u64,
    pub fires_compute: u64,
    pub fires_writer: u64,
    pub fires_sync: u64,
    /// Firings of DP ops only (MUL/MAC/ADD) — the FLOP engine.
    pub dp_fires: u64,
    /// Number of DP-capable instructions in the graph.
    pub dp_ops: usize,
    pub node_count: usize,
    pub max_queue_occupancy: usize,
    /// Cycles the event core jumped over because nothing could fire
    /// (always 0 under the dense core — it ticks every cycle). Cycle
    /// counts, outputs and `mem` are identical across cores; this and
    /// `wakeups` are the only core-dependent counters.
    pub skipped_cycles: u64,
    /// Ready-list wakeups the event core processed: one per (slot,
    /// cycle) evaluation. The dense-core equivalent would be
    /// `node_count * cycles`; the ratio is the work the scheduler
    /// avoided. Always 0 under the dense core.
    pub wakeups: u64,
    /// Order-sensitive FNV-style hash of the `(node, cycle)` fire
    /// sequence — the behavioural fingerprint `util::trace` records.
    /// Identical across the dense and event cores because the fire
    /// *sequences* are identical, not just the counts.
    pub fire_hash: u64,
    pub mem: MemStats,
}

impl SimStats {
    pub fn record_fire(&mut self, stage: Stage) {
        match stage {
            Stage::Control => self.fires_control += 1,
            Stage::Reader => self.fires_reader += 1,
            Stage::Compute => self.fires_compute += 1,
            Stage::Writer => self.fires_writer += 1,
            Stage::Sync => self.fires_sync += 1,
        }
    }

    /// Fold one firing of node `id` at cycle `now` into [`fire_hash`].
    /// Must be called in execution order; non-firing evaluations must
    /// not call it.
    ///
    /// [`fire_hash`]: SimStats::fire_hash
    #[inline]
    pub fn note_fire_event(&mut self, id: u32, now: u64) {
        const P: u64 = 0x100000001b3;
        self.fire_hash ^= id as u64 + 1;
        self.fire_hash = self.fire_hash.wrapping_mul(P);
        self.fire_hash ^= now;
        self.fire_hash = self.fire_hash.wrapping_mul(P);
    }

    pub fn total_fires(&self) -> u64 {
        self.fires_control
            + self.fires_reader
            + self.fires_compute
            + self.fires_writer
            + self.fires_sync
    }

    /// Average DP-PE utilization: DP firings per DP instruction per cycle.
    pub fn dp_utilization(&self) -> f64 {
        if self.cycles == 0 || self.dp_ops == 0 {
            return 0.0;
        }
        self.dp_fires as f64 / (self.cycles as f64 * self.dp_ops as f64)
    }

    /// Achieved GFLOPS given the work done and the machine clock:
    /// MULs count 1 flop, MACs 2 — the simulator credits 2 per DP fire
    /// minus the MUL corrections, so callers pass the exact `flops`.
    pub fn gflops(&self, flops: f64, clock_ghz: f64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        flops * clock_ghz / self.cycles as f64
    }

    /// Fraction of the dense-core evaluation grid (`node_count * cycles`)
    /// the event scheduler actually visited; 0 when the dense core ran
    /// (it has no wakeup accounting).
    pub fn wakeup_fraction(&self) -> f64 {
        if self.cycles == 0 || self.node_count == 0 {
            return 0.0;
        }
        self.wakeups as f64 / (self.cycles as f64 * self.node_count as f64)
    }

    /// One-line summary for the CLI / benches.
    pub fn summary(&self) -> String {
        format!(
            "cycles={} (skipped={}) fires={} dp_util={:.1}% reuse={:.1}% dram={}B (r={} w={}) conflicts={}",
            self.cycles,
            self.skipped_cycles,
            self.total_fires(),
            100.0 * self.dp_utilization(),
            100.0 * self.mem.reuse_ratio(),
            self.mem.total_dram_bytes(),
            self.mem.dram_read_bytes,
            self.mem.dram_write_bytes,
            self.mem.conflict_misses,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_math() {
        let s = SimStats {
            cycles: 1000,
            ..Default::default()
        };
        // 33_000 flops in 1000 cycles at 1.2 GHz = 39.6 GFLOPS.
        assert!((s.gflops(33_000.0, 1.2) - 39.6).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounds() {
        let s = SimStats {
            cycles: 100,
            dp_ops: 10,
            dp_fires: 900,
            ..Default::default()
        };
        let u = s.dp_utilization();
        assert!(u > 0.0 && u <= 1.0);
        assert!((u - 0.9).abs() < 1e-12);
    }

    #[test]
    fn accumulate_sums_every_field() {
        let a = MemStats {
            loads: 1,
            stores: 2,
            hits: 3,
            misses: 4,
            merged: 5,
            conflict_misses: 6,
            evictions: 7,
            exchanged: 10,
            exchanged_hop_cycles: 12,
            retries: 11,
            dram_read_bytes: 8,
            dram_write_bytes: 9,
        };
        let mut b = a.clone();
        b.accumulate(&a);
        assert_eq!(
            b,
            MemStats {
                loads: 2,
                stores: 4,
                hits: 6,
                misses: 8,
                merged: 10,
                conflict_misses: 12,
                evictions: 14,
                exchanged: 20,
                exchanged_hop_cycles: 24,
                retries: 22,
                dram_read_bytes: 16,
                dram_write_bytes: 18,
            }
        );
    }

    #[test]
    fn fire_hash_is_order_sensitive() {
        let mut a = SimStats::default();
        a.note_fire_event(3, 10);
        a.note_fire_event(7, 10);
        let mut b = SimStats::default();
        b.note_fire_event(7, 10);
        b.note_fire_event(3, 10);
        assert_ne!(a.fire_hash, b.fire_hash, "order must matter");
        let mut c = SimStats::default();
        c.note_fire_event(3, 10);
        c.note_fire_event(7, 10);
        assert_eq!(a.fire_hash, c.fire_hash, "same sequence, same hash");
        assert_ne!(a.fire_hash, 0);
    }

    #[test]
    fn reuse_ratio() {
        let m = MemStats {
            loads: 100,
            hits: 70,
            merged: 17,
            misses: 13,
            ..Default::default()
        };
        assert!((m.reuse_ratio() - 0.87).abs() < 1e-12);
    }
}
