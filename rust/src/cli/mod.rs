//! Hand-rolled CLI for the `scgra` launcher (no clap in the offline
//! vendor set). Subcommands map 1:1 onto the paper's artifacts:
//!
//! ```text
//! scgra info                         machine + artifact inventory
//! scgra dfg      --stencil S [-w N] [--dot F] [--asm F]   §V emitters
//! scgra roofline [--stencil S] [--tiles N]                §VI analysis
//! scgra compile  --stencil S [--steps N] [--out F]        phase 1: plan + place
//! scgra check    [--artifact F | spec flags] [--format text|json] [--deny warn]
//! scgra run      --stencil S [-w N] [--tiles N] [--decomp K] [--steps N] [--fuse M] [--halo H]
//! scgra run      --artifact F                             phase 2: execute a saved artifact
//! scgra run      ... --trace record F | --trace replay F  deterministic replay check
//! scgra run      ... --fault "seed=9 fill=20" --deadline 5000   resilience knobs
//! scgra compare                                           Table I
//! scgra validate                                          3-layer check
//! ```
//!
//! Parsing is strict: flags outside the invoked subcommand's whitelist
//! and malformed values are [`ScgraError::Usage`] errors naming the
//! offending token *and the subcommand* (`unknown flag \`--out\` for
//! \`scgra check\``), so a typo — or a flag that only another
//! subcommand accepts — can never be silently ignored.
//!
//! Every planning path funnels through one flag-assembly point,
//! `CompileOptions::from_args` (workers/tiles/decomp/fuse/fabric
//! budget, with `[run]` config defaults), so `dfg`, `roofline`,
//! `compile` and `run` cannot drift apart. `compile` + `run --artifact`
//! are the compile-once/execute-many split on the command line.
//!
//! Beyond the named presets, any workload can be described with the
//! shape flags — `--shape star|box --dims X[,Y[,Z]] --radii RX[,RY[,RZ]]`
//! — which generate normalized coefficients for the requested geometry.
//! Multi-tile runs pick their cut strategy with
//! `--decomp slab|pencil|block|auto` (auto resolves per dimensionality
//! and fabric budget). A worked 3-D multi-tile example:
//!
//! ```text
//! scgra run --shape star --dims 48,32,24 --radii 2,2,2 --tiles 16 --decomp pencil
//! ```
//!
//! decomposes the 13-pt star's interior into 16 y/z pencils (x stays
//! row-major contiguous), simulates one pencil per CGRA tile, reports
//! the halo re-read overhead and checks the stitched grid against the
//! golden oracle.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::analysis::CheckLevel;
use crate::cgra::{Machine, SimCore};
use crate::compile::{compile, CompileOptions, CompiledStencil, FuseMode, HaloMode};
use crate::config::{Config, RunParams};
use crate::error::ScgraError;
use crate::gpu_model::{GpuStencil, Precision, V100};
use crate::roofline;
use crate::session::{Outcome, Session};
use crate::util::fault::FaultPlan;
use crate::stencil::decomp::{self, DecompKind};
use crate::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use crate::stencil::{build_graph, StencilSpec};
use crate::util::rng::XorShift;
use crate::util::trace::{Trace, TraceMode};
use crate::verify::golden::{max_abs_diff, run_sim, stencil2d_ref, stencil_ref_steps};

/// Parsed command line: subcommand + `--flag value` pairs.
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
}

/// Every flag any subcommand accepts — the union of the per-subcommand
/// lists below, used as the fallback whitelist when the subcommand
/// itself is unknown (so `scgra frobnicate` reports the bad *command*,
/// not a misleading flag error).
const KNOWN_FLAGS: &[&str] = &[
    "artifact",
    "asm",
    "config",
    "deadline",
    "decomp",
    "deny",
    "dims",
    "dot",
    "fabric-tokens",
    "fault",
    "format",
    "fuse",
    "halo",
    "help",
    "out",
    "radii",
    "seed",
    "shape",
    "sim-core",
    "stencil",
    "steps",
    "tiles",
    "trace",
    "workers",
];

/// Flags the planning subcommands share: the workload selectors plus
/// everything `CompileOptions::from_args` consumes.
const PLAN_FLAGS: &[&str] = &[
    "config", "decomp", "dims", "fabric-tokens", "fuse", "halo", "help",
    "radii", "shape", "stencil", "tiles", "workers",
];

/// Per-subcommand flag whitelist. `Args::parse` rejects a flag outside
/// the invoked subcommand's list with a usage error naming both the
/// token and the subcommand, so a flag that only *another* subcommand
/// accepts (`scgra check --out x`) fails loudly instead of being
/// parsed and silently ignored.
fn allowed_flags(cmd: &str) -> Vec<&'static str> {
    let extra: &[&str] = match cmd {
        "info" | "compare" | "validate" => return vec!["config", "help"],
        "dfg" => &["asm", "dot"],
        "roofline" => &[],
        "compile" => &["out", "steps"],
        "check" => &["artifact", "deny", "format", "steps"],
        "run" => &[
            "artifact", "deadline", "fault", "seed", "sim-core", "steps", "trace",
        ],
        // Unknown command: accept the union so `run` reports the bad
        // command itself rather than a misleading flag error.
        _ => return KNOWN_FLAGS.to_vec(),
    };
    let mut all = PLAN_FLAGS.to_vec();
    all.extend_from_slice(extra);
    all
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let allowed = allowed_flags(&cmd);
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = match a.strip_prefix("--").or_else(|| a.strip_prefix('-')) {
                Some(k) if !k.is_empty() => k,
                _ => {
                    return Err(ScgraError::Usage(format!(
                        "expected a flag, got `{a}` (see `scgra help`)"
                    ))
                    .into())
                }
            };
            // `-w` is the documented short form of `--workers`.
            let key = if key == "w" { "workers" } else { key };
            if !allowed.contains(&key) {
                return Err(ScgraError::Usage(format!(
                    "unknown flag `--{key}` for `scgra {cmd}` (see `scgra help`)"
                ))
                .into());
            }
            // Consecutive non-flag tokens are space-joined into one
            // value, so multi-word flags read naturally:
            // `--trace record /tmp/t.trace` -> trace = "record /tmp/t.trace".
            let mut parts: Vec<&str> = Vec::new();
            while i + 1 < argv.len() && !argv[i + 1].starts_with('-') {
                i += 1;
                parts.push(argv[i].as_str());
            }
            let val = if parts.is_empty() {
                "true".to_string()
            } else {
                parts.join(" ")
            };
            flags.insert(key.to_string(), val);
            i += 1;
        }
        Ok(Self { cmd, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| ScgraError::Usage(format!("--{key} {v}: {e}")).into()),
        }
    }
}

impl CompileOptions {
    /// One shared flag/config assembly for every planning path (`dfg`,
    /// `roofline`, `compile`, `run`): `--workers/--tiles/--decomp/
    /// --fuse/--fabric-tokens` over the `[run]` config defaults.
    pub fn from_args(args: &Args, machine: &Machine, defaults: &RunParams) -> Result<Self> {
        Ok(Self {
            machine: machine.clone(),
            workers: args.num("workers", defaults.workers)?,
            tiles: args.num("tiles", defaults.tiles)?,
            fabric_tokens: args.num("fabric-tokens", decomp::DEFAULT_FABRIC_TOKENS)?,
            decomp: match args.get("decomp") {
                Some(s) => DecompKind::parse(s)?,
                None => defaults.decomp,
            },
            fuse: match args.get("fuse") {
                Some(s) => FuseMode::parse(s)?,
                None => defaults.fuse,
            },
            halo: match args.get("halo") {
                Some(s) => HaloMode::parse(s)?,
                None => defaults.halo,
            },
            check: defaults.check,
        })
    }
}

fn stencil_by_name(name: &str) -> Result<StencilSpec> {
    Ok(match name {
        "paper1d" | "1d17" => StencilSpec::paper_1d(),
        "paper2d" | "2d49" => StencilSpec::paper_2d(),
        "heat2d" => StencilSpec::heat2d(96, 96, 0.2),
        "heat3d" => StencilSpec::heat3d(48, 48, 48, 0.1),
        "acoustic3d" => {
            StencilSpec::dim3(48, 32, 24, symmetric_taps(2), y_taps(2), z_taps(2))?
        }
        "box9" => StencilSpec::box2d(96, 96, 1, 1, uniform_box_taps(1, 1, 0))?,
        "box27" => StencilSpec::box3d(32, 24, 16, 1, 1, 1, uniform_box_taps(1, 1, 1))?,
        "3pt" => StencilSpec::dim1(4096, vec![0.25, 0.5, 0.25])?,
        other => bail!(
            "unknown stencil `{other}` \
             (paper1d|paper2d|heat2d|heat3d|acoustic3d|box9|box27|3pt)"
        ),
    })
}

fn parse_list(s: &str, flag: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|d| {
            d.trim()
                .parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--{flag} `{d}`: {e}"))
        })
        .collect()
}

/// Build a spec from the shape flags (`--shape star|box --dims X,Y,Z
/// --radii RX,RY,RZ`), generating normalized coefficients. Returns
/// `None` when `--dims` is absent so callers fall back to `--stencil`.
fn spec_from_shape_flags(args: &Args) -> Result<Option<StencilSpec>> {
    let Some(dims_s) = args.get("dims") else {
        // Catch shape flags that would otherwise be silently ignored.
        if args.get("shape").is_some() || args.get("radii").is_some() {
            bail!("--shape/--radii require --dims (e.g. --shape box --dims 64,48)");
        }
        return Ok(None);
    };
    let dims = parse_list(dims_s, "dims")?;
    ensure_dims(&dims)?;
    let radii = match args.get("radii") {
        Some(r) => parse_list(r, "radii")?,
        None => vec![1; dims.len()],
    };
    if radii.len() != dims.len() {
        bail!("--radii has {} entries but --dims has {}", radii.len(), dims.len());
    }
    let shape = args.get("shape").unwrap_or("star");
    let spec = match (shape, dims.len()) {
        ("star", 1) => StencilSpec::dim1(dims[0], symmetric_taps(radii[0]))?,
        ("star", 2) => {
            StencilSpec::dim2(dims[0], dims[1], symmetric_taps(radii[0]), y_taps(radii[1]))?
        }
        ("star", 3) => StencilSpec::dim3(
            dims[0],
            dims[1],
            dims[2],
            symmetric_taps(radii[0]),
            y_taps(radii[1]),
            z_taps(radii[2]),
        )?,
        ("box", 2) => StencilSpec::box2d(
            dims[0],
            dims[1],
            radii[0],
            radii[1],
            uniform_box_taps(radii[0], radii[1], 0),
        )?,
        ("box", 3) => StencilSpec::box3d(
            dims[0],
            dims[1],
            dims[2],
            radii[0],
            radii[1],
            radii[2],
            uniform_box_taps(radii[0], radii[1], radii[2]),
        )?,
        ("box", 1) => bail!("a 1-D box is a 1-D star; use --shape star"),
        (other, _) => bail!("unknown shape `{other}` (star|box)"),
    };
    Ok(Some(spec))
}

fn ensure_dims(dims: &[usize]) -> Result<()> {
    if dims.is_empty() || dims.len() > 3 {
        bail!("--dims takes 1 to 3 comma-separated extents");
    }
    Ok(())
}

/// Resolve the workload — the one precedence rule every subcommand
/// shares: shape flags win, then `--stencil`, then the config file's
/// `[stencil]` section, then the given default preset.
fn resolve_spec(args: &Args, cfg: Option<&Config>, default: &str) -> Result<StencilSpec> {
    if let Some(spec) = spec_from_shape_flags(args)? {
        return Ok(spec);
    }
    match (args.get("stencil"), cfg) {
        (Some(name), _) => stencil_by_name(name),
        (None, Some(c)) => c.stencil(),
        (None, None) => stencil_by_name(default),
    }
}

/// Entry point shared by `main.rs` (returns instead of exiting for
/// testability).
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let (machine, run_defaults) = match args.get("config") {
        Some(path) => {
            let c = Config::load(path)?;
            (c.machine()?, Some(c))
        }
        None => (Machine::paper(), None),
    };
    match args.cmd.as_str() {
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(&machine),
        "dfg" => cmd_dfg(&args, &machine, run_defaults.as_ref()),
        "roofline" => cmd_roofline(&args, &machine, run_defaults.as_ref()),
        "compile" => cmd_compile(&args, &machine, run_defaults.as_ref()),
        "check" => cmd_check(&args, &machine, run_defaults.as_ref()),
        "run" => cmd_run(&args, &machine, run_defaults.as_ref()),
        "compare" => cmd_compare(&machine),
        "validate" => cmd_validate(&machine),
        other => bail!("unknown command `{other}`\n{HELP}"),
    }
}

const HELP: &str = "scgra — stencils on a coarse-grained reconfigurable spatial architecture
USAGE: scgra <info|dfg|roofline|compile|check|run|compare|validate> [--flags]
  --stencil NAME        workload preset (default paper2d):
                        paper1d|paper2d|heat2d|heat3d|acoustic3d|box9|box27|3pt
  --shape star|box      custom workload shape (with --dims; default star)
  --dims X[,Y[,Z]]      custom grid extents, x first (overrides --stencil)
  --radii RX[,RY[,RZ]]  custom radii per dimension (default all 1)
  --workers N           compute workers (0 = roofline pick)
  --tiles N             CGRA tiles (default 1)
  --decomp K            multi-tile cut strategy: slab|pencil|block|auto
                        (default auto: slab = x strips in 1-D/2-D /
                        z planes in 3-D; pencil = y+z cuts, x contiguous;
                        block = every axis)
  --steps N             time steps (default 1)
  --fuse M              §IV temporal traversal: host|spatial|auto
                        (default auto: spatial fusion when the fabric
                        budget admits depth >= 2 — tiles compute T steps
                        per DRAM round-trip, only the first layer loads
                        and only the last stores; host = one round-trip
                        per step)
  --halo H              chunk-boundary halo movement:
                        exchange|exchange-free|reload (default exchange:
                        after the cold first chunk, halos ship over
                        in-fabric channels — zero redundant DRAM reads —
                        priced per Manhattan hop and boundary-link
                        bandwidth; exchange-free ships them at flat hit
                        latency; reload re-reads them from DRAM every
                        chunk, the differential baseline. All three are
                        bitwise-identical on values)
  --sim-core C          scheduler core: dense|event (default event; both
                        are bit-identical — event skips idle cycles)
  --trace record FILE   fingerprint every tile task (cycles, fires,
                        tickets, fire/output hashes) and save the trace
  --trace replay FILE   re-run and fail on the first divergence from a
                        recorded trace (replays across sim cores)
  --deadline MS         wall-clock run budget in milliseconds: on expiry
                        queued tile tasks are dropped, in-flight ones are
                        cancelled cooperatively, and `run` exits with a
                        deadline-exceeded error carrying partial progress
  --fault SPEC          deterministic fault injection plan, e.g.
                        \"seed=9 fill=20 stall=10 extra=4 slow=5 epoch=128\"
                        (fill/stall/slow are percentages; a plan with all
                        rates 0 is unarmed and costs nothing)
  --seed N              input grid RNG seed (default 42)
  --fabric-tokens N     per-tile on-fabric token budget (default 65536)
  --out FILE            where `compile` writes the artifact
                        (default compiled_stencil.txt)
  --artifact FILE       `run` or `check` a saved compiled artifact
                        instead of planning: spec, steps and plan come
                        from the file (compile once, execute many; `run`
                        re-checks a loaded artifact at the errors level
                        before executing it)
  --format text|json    `check` report rendering (default text)
  --deny warn           `check` exits nonzero on warnings too, not just
                        errors (the CI posture)
  --dot FILE / --asm FILE   emit Graphviz / assembly (dfg)
  --config FILE         TOML machine/run config ([run] decomp = \"pencil\")

Worked 3-D multi-tile example:
  scgra run --shape star --dims 48,32,24 --radii 2,2,2 --tiles 16 --decomp pencil
decomposes the 13-pt star's 44x28x20 interior into 16 y/z pencil tiles
(4 cuts along y, 4 along z; each tile a full-width 48x11x9 sub-volume
with 2-deep halos), maps each pencil onto a CGRA tile via plane
buffering, simulates all 16 cycle-by-cycle, reports the halo re-read
overhead, and checks the stitched grid against the golden oracle.";

fn cmd_info(m: &Machine) -> Result<()> {
    println!("machine: {:.1} GHz, {} MAC PEs, {} GB/s -> peak {:.0} GFLOPS",
        m.clock_ghz, m.mac_pes, m.bw_gbps, m.peak_gflops());
    println!("fabric:  {}x{} PEs, cache {} KiB, DRAM latency {} cyc",
        m.grid_rows, m.grid_cols, m.cache_kib, m.dram_latency);
    match crate::runtime::Runtime::open(crate::runtime::Runtime::default_dir()) {
        Ok(rt) => println!("artifacts ({}): {}", rt.platform(), rt.names().join(", ")),
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

/// `[run]` defaults from the config file, or the built-in defaults.
fn run_defaults(cfg: Option<&Config>) -> Result<RunParams> {
    cfg.map(|c| c.run_params()).transpose().map(Option::unwrap_or_default)
}

fn cmd_dfg(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let spec = resolve_spec(args, cfg, "paper2d")?;
    let opts = CompileOptions::from_args(args, m, &run_defaults(cfg)?)?;
    let w = opts.resolve_workers(&spec);
    let g = build_graph(&spec, w)?;
    let title = format!("{} stencil, {} workers", describe(&spec), w);
    println!("{title}: {}", g.summary());
    if let Some(path) = args.get("dot") {
        std::fs::write(path, crate::dfg::dot::to_dot(&g, &title))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("asm") {
        std::fs::write(path, crate::dfg::asm::to_asm(&g, &title))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// One-line geometry description, e.g. `48x32x24 r=(2,2,2) star 13-pt`.
fn describe(spec: &StencilSpec) -> String {
    let dims: Vec<String> = spec.dims().iter().map(|d| d.to_string()).collect();
    let radii: Vec<String> = spec.radii().iter().map(|r| r.to_string()).collect();
    let shape = if spec.is_box() { "box" } else { "star" };
    format!(
        "{} r=({}) {} {}-pt",
        dims.join("x"),
        radii.join(","),
        shape,
        spec.points()
    )
}

fn cmd_roofline(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let specs: Vec<(String, StencilSpec)> = if let Some(spec) = spec_from_shape_flags(args)? {
        vec![(describe(&spec), spec)]
    } else {
        match args.get("stencil") {
            Some(s) => vec![(s.to_string(), stencil_by_name(s)?)],
            None => vec![
                ("stencil1D".to_string(), StencilSpec::paper_1d()),
                ("stencil2D".to_string(), StencilSpec::paper_2d()),
            ],
        }
    };
    let opts = CompileOptions::from_args(args, m, &run_defaults(cfg)?)?;
    println!("{:<28} {:>6} {:>10} {:>10} {:>10} {:>8} {:>6}",
        "stencil", "AI", "bw-roof", "peak", "attain", "demand", "w");
    for (name, spec) in &specs {
        let a = roofline::analyze(spec, m, opts.resolve_workers(spec));
        println!(
            "{:<28} {:>6.2} {:>10.0} {:>10.0} {:>10.0} {:>8.0} {:>6}",
            name, a.arithmetic_intensity, a.bw_gflops, a.peak_gflops,
            a.attainable_gflops, a.demand_gflops, a.workers
        );
    }

    // Multi-tile view: halo re-reads deflate the effective intensity.
    if opts.tiles > 1 {
        println!("\ndecomposed across {} tiles ({}):", opts.tiles, opts.decomp);
        println!(
            "{:<28} {:>7} {:>12} {:>8} {:>10} {:>12}",
            "stencil", "tasks", "cuts", "eff AI", "halo", "array roof"
        );
        for (name, spec) in &specs {
            let w = opts.resolve_workers(spec);
            let plan = decomp::plan(spec, w, opts.fabric_tokens, opts.decomp, opts.tiles)?;
            let t = roofline::analyze_tiled(spec, m, w, &plan, opts.tiles);
            println!(
                "{:<28} {:>7} {:>12} {:>8.2} {:>9.1}% {:>12.0}",
                name,
                t.tasks,
                format!("{}x{}x{}", plan.cuts[0], plan.cuts[1], plan.cuts[2]),
                t.effective_ai,
                100.0 * t.redundant_read_fraction,
                t.attainable_gflops_array
            );
        }
    }
    Ok(())
}

/// Phase 1 on the command line: plan + place a workload and save the
/// artifact for later `run --artifact` executions.
fn cmd_compile(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let defaults = run_defaults(cfg)?;
    let spec = resolve_spec(args, cfg, "paper2d")?;
    let opts = CompileOptions::from_args(args, m, &defaults)?;
    let steps = args.num("steps", defaults.steps)?;
    anyhow::ensure!(steps >= 1, "--steps must be >= 1 (got {steps})");
    let compiled = compile(&spec, steps, &opts)?;
    println!(
        "compiled {} x {steps} step(s): w={}, {} stage(s), {} placed graph(s)",
        describe(&spec),
        compiled.workers,
        compiled.stages.len(),
        compiled.graph_count(),
    );
    for (i, st) in compiled.stages.iter().enumerate() {
        println!(
            "  stage {i}: {} cuts (x{}, y{}, z{}) -> {} tiles, depth {} x {} chunk(s)",
            st.plan.kind,
            st.plan.cuts[0],
            st.plan.cuts[1],
            st.plan.cuts[2],
            st.plan.tiles.len(),
            st.plan.fused_steps,
            st.repeats,
        );
    }
    println!(
        "roofline: effective AI {:.2} -> {:.0} GFLOPS array roof",
        compiled.analysis.effective_ai, compiled.analysis.attainable_gflops_array
    );
    let out = args.get("out").unwrap_or("compiled_stencil.txt");
    compiled.save(out)?;
    println!("wrote {out} (manifest header: {})", compiled.manifest_meta().name);
    Ok(())
}

/// `scgra check` — run the static verifier (the `analysis` module's
/// four rule families) over a saved artifact or a fresh compile, print
/// the report as text or JSON, and exit nonzero when the gate denies:
/// errors always, warnings too under `--deny warn`.
fn cmd_check(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let deny_level = match args.get("deny") {
        None => CheckLevel::Errors,
        Some("warn") => CheckLevel::Full,
        Some(other) => {
            return Err(ScgraError::Usage(format!(
                "--deny {other}: only `warn` can be denied (errors always are)"
            ))
            .into())
        }
    };
    let compiled = match args.get("artifact") {
        // An untrusted artifact is exactly what the analyzer is for:
        // plain `load` (structural parse only), then every rule below.
        Some(path) => CompiledStencil::load(path)?,
        None => {
            let defaults = run_defaults(cfg)?;
            let spec = resolve_spec(args, cfg, "paper2d")?;
            // The full report below is the product; don't let the
            // compile-time gate pre-empt it with an errors-only subset.
            let opts = CompileOptions::from_args(args, m, &defaults)?
                .with_check(CheckLevel::Off);
            let steps = args.num("steps", defaults.steps)?;
            anyhow::ensure!(steps >= 1, "--steps must be >= 1 (got {steps})");
            compile(&spec, steps, &opts)?
        }
    };
    let report = crate::analysis::check(&compiled);
    match args.get("format").unwrap_or("text") {
        "text" => print!("{}", report.to_text()),
        "json" => println!("{}", report.to_json()),
        other => {
            return Err(
                ScgraError::Usage(format!("--format {other}: expected text|json")).into(),
            )
        }
    }
    report.gate(deny_level)?;
    Ok(())
}

fn cmd_run(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let defaults = run_defaults(cfg)?;
    let sim_core = match args.get("sim-core") {
        Some(s) => SimCore::parse(s)?,
        None => defaults.sim_core,
    };
    // Resilience knobs: `--deadline MS` / `--fault SPEC` over the
    // config file's `[run] deadline` / `[fault]` defaults.
    let deadline_ms = match args.get("deadline") {
        Some(v) => {
            let ms: u64 = v
                .parse()
                .map_err(|e| ScgraError::Usage(format!("--deadline {v}: {e}")))?;
            if ms == 0 {
                return Err(ScgraError::Usage(
                    "--deadline 0: a zero deadline cancels every run at submit".into(),
                )
                .into());
            }
            Some(ms)
        }
        None => defaults.deadline_ms,
    };
    let fault = match args.get("fault") {
        Some(s) => Some(FaultPlan::parse(s).map_err(|e| ScgraError::Usage(e.to_string()))?),
        None => defaults.fault.clone(),
    };

    // Phase 1: a saved artifact (spec, steps and plan come from the
    // file), or compile here from the flags.
    let compiled = match args.get("artifact") {
        Some(path) => {
            // A saved artifact is untrusted input to the executor:
            // re-verify the error-level invariants (deadlock-freedom,
            // exchange partition, residency budget) before simulating.
            let c = CompiledStencil::load_checked(path, CheckLevel::Errors)?;
            println!("loaded artifact {path}: {}", c.manifest_meta().name);
            c
        }
        None => {
            let spec = resolve_spec(args, cfg, "paper2d")?;
            let opts = CompileOptions::from_args(args, m, &defaults)?;
            let steps = args.num("steps", defaults.steps)?;
            anyhow::ensure!(steps >= 1, "--steps must be >= 1 (got {steps})");
            compile(&spec, steps, &opts)?
        }
    };
    let (spec, steps) = (compiled.spec.clone(), compiled.steps);
    let tiles = compiled.options.tiles;
    // Execute on the machine the artifact was compiled (and placed)
    // for — for a loaded artifact that is the machine recorded in the
    // file, not whatever this invocation's config says.
    let machine = compiled.options.machine.clone();
    let mut rng = XorShift::new(args.num("seed", defaults.seed)?);
    let input = rng.normal_vec(spec.grid_points());

    // Phase 2: execute the artifact through a session. Every
    // dimensionality runs the same path — the compiled plan cuts
    // 1-D/2-D/3-D grids alike into halo-padded tiles.
    println!(
        "running {} stencil, w={}, tiles={tiles}, decomp={}, steps={steps}, \
         core={sim_core}, fuse={}, halo={}",
        describe(&spec),
        compiled.workers,
        compiled.options.decomp,
        compiled.options.fuse,
        compiled.options.halo,
    );
    if let Some(p) = fault.as_ref().filter(|p| p.armed()) {
        println!("fault plan armed: {}", p.to_spec());
    }
    let session = Session::new(Arc::new(compiled), machine.clone())
        .with_sim_core(sim_core)
        .with_fault_plan(fault)
        .with_deadline(deadline_ms.map(Duration::from_millis));
    // Deterministic trace capture/replay (`--trace record F` /
    // `--trace replay F`, or `[run] trace` in the config): record
    // fingerprints every tile task; replay re-runs and fails loudly on
    // the first divergence. Traces replay across sim cores — `matches`
    // ignores the core-dependent wakeup counter.
    let trace_mode = match args.get("trace").or(defaults.trace.as_deref()) {
        Some(s) => Some(TraceMode::parse(s)?),
        None => None,
    };
    let outcome = match &trace_mode {
        None => session.run(&input)?,
        Some(TraceMode::Record(path)) => {
            let (outcome, trace) = session.run_recorded(&input)?;
            trace.save(path)?;
            println!("recorded {} tile-task fingerprints -> {path}", trace.records.len());
            outcome
        }
        Some(TraceMode::Replay(path)) => {
            let reference = Trace::load(path)?;
            let outcome = session.run_replay(&input, &reference)?;
            println!(
                "replayed {path}: all {} tile-task fingerprints match",
                reference.records.len()
            );
            outcome
        }
    };
    // A deadline-cancelled run has no complete chunk to report and no
    // grid worth checking: surface the typed error (exit nonzero)
    // instead of pretending the partial output is an answer.
    if let Outcome::DeadlineExceeded {
        completed_tasks,
        total_tasks,
    } = outcome.outcome
    {
        println!(
            "deadline expired with {} chunk(s) complete; the cancelled chunk \
             finished {completed_tasks}/{total_tasks} tile tasks",
            outcome.reports.len(),
        );
        return Err(ScgraError::DeadlineExceeded {
            completed_tasks,
            total_tasks,
            deadline_ms: deadline_ms.unwrap_or(0),
        }
        .into());
    }
    let (out, reports) = (outcome.output, outcome.reports);
    let first = &reports[0];
    println!(
        "plan: {} cuts (x{}, y{}, z{}) -> {} tile tasks, fused depth {}, \
         {} halo points ({:.1}% redundant reads)",
        first.kind,
        first.cuts[0],
        first.cuts[1],
        first.cuts[2],
        first.strips,
        first.fused_steps,
        first.halo_points,
        100.0 * first.redundant_read_fraction,
    );
    for (i, r) in reports.iter().enumerate() {
        let spill = if r.exchange_spilled {
            format!(", {} points spilled", r.spilled_points)
        } else {
            String::new()
        };
        println!(
            "chunk {i}: {} step(s), {} tiles, makespan {} cyc \
             (ring critical {}), {} loads ({} from DRAM, {} exchanged, \
             +{} hop cyc{spill}), {:.1} GFLOPS \
             ({:.0}% of single-step roofline)",
            r.fused_steps,
            r.strips,
            r.makespan_cycles,
            r.ring_critical_cycles,
            r.total_loads(),
            r.dram_point_reads(),
            r.exchanged_points,
            r.exchanged_hop_cycles(),
            r.gflops,
            100.0 * r.gflops
                / (tiles as f64 * machine.roofline_gflops(spec.arithmetic_intensity())),
        );
    }
    // Correctness: the final grid against the steps-times iterated
    // golden oracle, on the whole grid — the time-tiled ring stages
    // make fused chunks full-grid correct, same as host-driven runs.
    let want = stencil_ref_steps(&spec, &input, steps);
    println!(
        "max|err| vs {steps}-step oracle: {:.2e}; final grid checksum {:.6}",
        max_abs_diff(&out, &want),
        out.iter().sum::<f64>()
    );
    Ok(())
}

fn cmd_compare(m: &Machine) -> Result<()> {
    // Table I: 16 CGRA tiles vs one V100, via the two-phase API.
    let v100 = V100::paper();
    println!("Table I — comparative analysis of stencils on CGRA and GPU");
    for (name, spec, w) in [
        ("Stencil 1D (grid=194400, rx=8)", StencilSpec::paper_1d(), 6usize),
        ("Stencil 2D (960x449, rx=ry=12)", StencilSpec::paper_2d(), 5usize),
    ] {
        let mut rng = XorShift::new(7);
        let input = rng.normal_vec(spec.grid_points());
        let opts = CompileOptions::paper().with_machine(m.clone()).with_workers(w);
        let compiled = Arc::new(compile(&spec, 1, &opts)?);
        let outcome = Session::new(compiled, m.clone()).run(&input)?;
        let rep = &outcome.reports[0];
        let cgra_roof = 16.0 * m.roofline_gflops(spec.arithmetic_intensity());
        let g = GpuStencil::from_spec(&spec, Precision::F64);
        let gpu = v100.best_gflops(&g);
        let gpu_roof = v100.roofline_gflops(&g);
        println!("\n{name}");
        println!("  CGRA x16: {:>8.0} GFLOPS  ({:>4.1}% of {:.0} roof)",
            rep.gflops, 100.0 * rep.gflops / cgra_roof, cgra_roof);
        println!(
            "  decomp:   {} x{} tasks, {:.1}% halo re-reads \
             (AI {:.2} -> {:.2} effective)",
            rep.kind,
            rep.strips,
            100.0 * rep.redundant_read_fraction,
            g.arithmetic_intensity(),
            g.arithmetic_intensity_with_redundancy(rep.redundant_read_fraction)
        );
        println!("  V100:     {:>8.0} GFLOPS  ({:>4.1}% of {:.0} roof)",
            gpu, 100.0 * gpu / gpu_roof, gpu_roof);
        println!("  normalized GFLOPS (CGRA/V100): {:.2}x", rep.gflops / gpu);
    }
    Ok(())
}

fn cmd_validate(m: &Machine) -> Result<()> {
    // Cross-layer agreement on the 49-pt stencil: the cycle simulator vs
    // the native oracle (the two independent implementations), plus the
    // artifact runtime's answer for the same workload. With the default
    // native-interpreter backend the runtime is oracle-backed, so its
    // row is a contract check, not a third independent implementation —
    // it becomes one again when a PJRT backend executes the real
    // JAX/Pallas artifacts (see `runtime`'s module docs).
    let spec = StencilSpec::dim2(
        96,
        96,
        crate::stencil::spec::symmetric_taps(12),
        crate::stencil::spec::y_taps(12),
    )?;
    let mut rng = XorShift::new(123);
    let x = rng.normal_vec(96 * 96);

    let sim = run_sim(&spec, 4, m, &x)?;
    let oracle = stencil2d_ref(&x, &spec);
    let d_sim = max_abs_diff(&sim.output, &oracle);
    println!("simulator vs oracle:  max|err| = {d_sim:.2e}  (independent impls)");

    let rt = crate::runtime::Runtime::open(crate::runtime::Runtime::default_dir())?;
    let backend = rt.platform();
    let art = rt.execute("stencil2d_r12_96x96", &[&x, &spec.cx, &spec.cy])?;
    let d_art = max_abs_diff(&art, &oracle);
    println!("runtime [{backend}] vs oracle:    max|err| = {d_art:.2e}");
    let d_cross = max_abs_diff(&art, &sim.output);
    println!("runtime [{backend}] vs simulator: max|err| = {d_cross:.2e}");
    anyhow::ensure!(d_sim < 1e-9 && d_art < 1e-9 && d_cross < 1e-9, "validation failed");
    println!("layers agree ✓");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(&sv(&["run", "--workers", "5", "--tiles", "16"])).unwrap();
        assert_eq!(a.cmd, "run");
        assert_eq!(a.num("workers", 0usize).unwrap(), 5);
        assert_eq!(a.num("tiles", 1usize).unwrap(), 16);
        assert_eq!(a.num("steps", 1usize).unwrap(), 1);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&sv(&["dfg", "--help"])).unwrap();
        assert_eq!(a.get("help"), Some("true"));
    }

    #[test]
    fn unknown_flag_is_a_usage_error_naming_the_token() {
        let e = Args::parse(&sv(&["run", "--frobnicate", "5"])).unwrap_err();
        assert!(e.to_string().contains("unknown flag `--frobnicate`"), "{e}");
        // The whole pipeline surfaces it, and classification holds.
        let e = run(&sv(&["run", "--stencil", "3pt", "--workerz", "2"])).unwrap_err();
        assert!(e.to_string().contains("--workerz"), "{e}");
        // A bare `-` or non-flag token is also named.
        let e = Args::parse(&sv(&["run", "oops"])).unwrap_err();
        assert!(e.to_string().contains("`oops`"), "{e}");
    }

    #[test]
    fn flags_are_scoped_to_their_subcommand() {
        // `--out` belongs to `compile`; `check` must name itself.
        let e = Args::parse(&sv(&["check", "--out", "x.txt"])).unwrap_err();
        assert!(
            e.to_string().contains("unknown flag `--out` for `scgra check`"),
            "{e}"
        );
        // `--trace` belongs to `run`, not `compile`.
        let e = Args::parse(&sv(&["compile", "--trace", "record", "/tmp/t"])).unwrap_err();
        assert!(e.to_string().contains("for `scgra compile`"), "{e}");
        // The shared planning flags still parse everywhere they apply.
        for cmd in ["dfg", "roofline", "compile", "check", "run"] {
            Args::parse(&sv(&[cmd, "--stencil", "3pt", "--tiles", "2"])).unwrap();
        }
    }

    #[test]
    fn check_command_is_clean_on_a_fresh_compile() {
        run(&sv(&[
            "check", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "4",
        ]))
        .unwrap();
        // JSON + deny-warn is the CI invocation; a fresh compile has
        // zero diagnostics, so even the strict gate passes.
        run(&sv(&[
            "check", "--stencil", "3pt", "--deny", "warn", "--format", "json",
        ]))
        .unwrap();
        let e = run(&sv(&["check", "--stencil", "3pt", "--format", "yaml"])).unwrap_err();
        assert!(e.to_string().contains("--format yaml"), "{e}");
        let e = run(&sv(&["check", "--stencil", "3pt", "--deny", "info"])).unwrap_err();
        assert!(e.to_string().contains("--deny info"), "{e}");
    }

    #[test]
    fn check_command_verifies_a_saved_artifact() {
        let path = std::env::temp_dir()
            .join(format!("scgra_cli_check_{}.txt", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        run(&sv(&[
            "compile", "--shape", "star", "--dims", "20,12", "--workers", "2",
            "--tiles", "2", "--steps", "2", "--out", path.as_str(),
        ]))
        .unwrap();
        run(&sv(&["check", "--artifact", path.as_str(), "--deny", "warn"])).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(run(&sv(&["check", "--artifact", "/nonexistent/a.txt"])).is_err());
    }

    #[test]
    fn malformed_flag_value_is_a_usage_error_naming_the_token() {
        let a = Args::parse(&sv(&["run", "--tiles", "many"])).unwrap();
        let e = a.num("tiles", 1usize).unwrap_err();
        assert!(e.to_string().contains("--tiles many"), "{e}");
    }

    #[test]
    fn short_w_aliases_workers() {
        let a = Args::parse(&sv(&["dfg", "-w", "3"])).unwrap();
        assert_eq!(a.num("workers", 0usize).unwrap(), 3);
    }

    #[test]
    fn multi_token_flag_values_are_space_joined() {
        let a = Args::parse(&sv(&[
            "run", "--trace", "record", "/tmp/t.trace", "--tiles", "2",
        ]))
        .unwrap();
        assert_eq!(a.get("trace"), Some("record /tmp/t.trace"));
        assert_eq!(a.num("tiles", 1usize).unwrap(), 2);
        // A flag right after the key still reads as a boolean flag.
        let b = Args::parse(&sv(&["run", "--help", "--tiles", "4"])).unwrap();
        assert_eq!(b.get("help"), Some("true"));
        assert_eq!(b.num("tiles", 1usize).unwrap(), 4);
    }

    #[test]
    fn stencil_names_resolve() {
        assert_eq!(stencil_by_name("paper1d").unwrap().points(), 17);
        assert_eq!(stencil_by_name("2d49").unwrap().points(), 49);
        assert_eq!(stencil_by_name("heat3d").unwrap().points(), 7);
        assert_eq!(stencil_by_name("acoustic3d").unwrap().points(), 13);
        assert_eq!(stencil_by_name("box9").unwrap().points(), 9);
        assert_eq!(stencil_by_name("box27").unwrap().points(), 27);
        assert!(stencil_by_name("bogus").is_err());
    }

    #[test]
    fn shape_flags_build_custom_specs() {
        let a = Args::parse(&sv(&[
            "dfg", "--shape", "star", "--dims", "20,16,12", "--radii", "1,1,1",
        ]))
        .unwrap();
        let s = spec_from_shape_flags(&a).unwrap().unwrap();
        assert!(s.is_3d() && !s.is_box());
        assert_eq!(s.dims(), vec![20, 16, 12]);
        assert_eq!(s.points(), 7);

        let b = Args::parse(&sv(&["dfg", "--shape", "box", "--dims", "24,18"])).unwrap();
        let s = spec_from_shape_flags(&b).unwrap().unwrap();
        assert!(s.is_box() && s.is_2d());
        assert_eq!(s.points(), 9);

        // No --dims: fall through to presets.
        let c = Args::parse(&sv(&["dfg"])).unwrap();
        assert!(spec_from_shape_flags(&c).unwrap().is_none());
    }

    #[test]
    fn shape_flags_reject_bad_input() {
        let a = Args::parse(&sv(&["dfg", "--dims", "10,10", "--radii", "1"])).unwrap();
        assert!(spec_from_shape_flags(&a).is_err());
        let b = Args::parse(&sv(&["dfg", "--shape", "hex", "--dims", "10,10"])).unwrap();
        assert!(spec_from_shape_flags(&b).is_err());
        let c = Args::parse(&sv(&["dfg", "--dims", "1,2,3,4"])).unwrap();
        assert!(spec_from_shape_flags(&c).is_err());
    }

    #[test]
    fn dfg_command_runs_3d() {
        run(&sv(&[
            "dfg", "--shape", "star", "--dims", "10,8,6", "--workers", "2",
        ]))
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn run_command_3d_multi_tile_via_decomp_flag() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "14,10,8", "--workers", "2",
            "--tiles", "4", "--decomp", "pencil",
        ]))
        .unwrap();
    }

    #[test]
    fn bad_decomp_value_is_an_error() {
        assert!(run(&sv(&[
            "run", "--stencil", "3pt", "--decomp", "diagonal"
        ]))
        .is_err());
    }

    #[test]
    fn run_command_fused_multistep_2d() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--steps", "4", "--fuse", "spatial",
        ]))
        .unwrap();
    }

    #[test]
    fn run_command_host_multistep_still_works() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "20,12", "--workers", "2",
            "--steps", "2", "--fuse", "host",
        ]))
        .unwrap();
    }

    #[test]
    fn run_command_halo_modes_and_rejection() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "4", "--fuse", "spatial", "--halo", "exchange",
        ]))
        .unwrap();
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "4", "--fuse", "spatial", "--halo", "reload",
        ]))
        .unwrap();
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "4", "--fuse", "spatial", "--halo", "exchange-free",
        ]))
        .unwrap();
        assert!(run(&sv(&["run", "--stencil", "3pt", "--halo", "teleport"])).is_err());
    }

    #[test]
    fn bad_fuse_value_is_an_error() {
        assert!(run(&sv(&[
            "run", "--stencil", "3pt", "--fuse", "temporal"
        ]))
        .is_err());
    }

    #[test]
    fn run_command_accepts_dense_sim_core() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "40", "--workers", "2",
            "--sim-core", "dense",
        ]))
        .unwrap();
    }

    #[test]
    fn bad_sim_core_value_is_an_error() {
        assert!(run(&sv(&[
            "run", "--stencil", "3pt", "--sim-core", "quantum"
        ]))
        .is_err());
    }

    #[test]
    fn roofline_command_reports_tiled_view() {
        run(&sv(&["roofline", "--stencil", "heat3d", "--tiles", "8"])).unwrap();
    }

    #[test]
    fn from_args_assembles_options_once_for_all_paths() {
        let a = Args::parse(&sv(&[
            "run", "--workers", "3", "--tiles", "8", "--decomp", "pencil", "--fuse",
            "host", "--halo", "reload", "--fabric-tokens", "9999",
        ]))
        .unwrap();
        let o = CompileOptions::from_args(&a, &Machine::paper(), &RunParams::default())
            .unwrap();
        assert_eq!(o.workers, 3);
        assert_eq!(o.tiles, 8);
        assert_eq!(o.decomp, DecompKind::Pencil);
        assert_eq!(o.fuse, FuseMode::Host);
        assert_eq!(o.halo, HaloMode::Reload);
        assert_eq!(o.fabric_tokens, 9999);
        // Defaults flow from RunParams when flags are absent.
        let b = Args::parse(&sv(&["run"])).unwrap();
        let d = CompileOptions::from_args(&b, &Machine::paper(), &RunParams::default())
            .unwrap();
        assert_eq!(d.workers, 0);
        assert_eq!(d.tiles, 1);
        assert_eq!(d.fuse, FuseMode::Auto);
        assert_eq!(d.halo, HaloMode::Exchange);
    }

    #[test]
    fn compile_then_run_artifact() {
        let path = std::env::temp_dir().join(format!(
            "scgra_cli_artifact_{}.txt",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        run(&sv(&[
            "compile", "--shape", "star", "--dims", "20,12", "--workers", "2",
            "--tiles", "2", "--steps", "2", "--out", path.as_str(),
        ]))
        .unwrap();
        run(&sv(&["run", "--artifact", path.as_str()])).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn run_missing_artifact_is_an_error() {
        assert!(run(&sv(&["run", "--artifact", "/nonexistent/artifact.txt"])).is_err());
    }

    #[test]
    fn trace_record_then_replay_roundtrip_across_cores() {
        let path = std::env::temp_dir()
            .join(format!("scgra_cli_trace_{}.trace", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        // Record under the event core...
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "2", "--seed", "11",
            "--trace", "record", path.as_str(),
        ]))
        .unwrap();
        // ...replay under the dense core: `matches` ignores wakeups.
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "2", "--seed", "11",
            "--sim-core", "dense", "--trace", "replay", path.as_str(),
        ]))
        .unwrap();
        // A different workload must fail the replay.
        assert!(run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--steps", "2", "--seed", "12",
            "--trace", "replay", path.as_str(),
        ]))
        .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_trace_value_is_an_error() {
        assert!(run(&sv(&["run", "--stencil", "3pt", "--trace", "record"])).is_err());
        assert!(run(&sv(&[
            "run", "--stencil", "3pt", "--trace", "verify", "/tmp/x"
        ]))
        .is_err());
    }

    #[test]
    fn run_command_with_armed_fault_plan_still_converges() {
        // Retried fills and stall windows change timing, not values —
        // the printed oracle check inside cmd_run exercises the path.
        run(&sv(&[
            "run", "--shape", "star", "--dims", "24,16", "--workers", "2",
            "--tiles", "2", "--fault", "seed=7 fill=25 stall=10",
        ]))
        .unwrap();
    }

    #[test]
    fn bad_fault_spec_is_a_usage_error() {
        let e = run(&sv(&["run", "--stencil", "3pt", "--fault", "fill=150"])).unwrap_err();
        assert!(e.to_string().contains("fill"), "{e}");
        let e = run(&sv(&["run", "--stencil", "3pt", "--fault", "chaos=1"])).unwrap_err();
        assert!(e.to_string().contains("chaos"), "{e}");
    }

    #[test]
    fn generous_deadline_completes_and_zero_deadline_is_rejected() {
        run(&sv(&[
            "run", "--shape", "star", "--dims", "20,12", "--workers", "2",
            "--deadline", "600000",
        ]))
        .unwrap();
        let e = run(&sv(&["run", "--stencil", "3pt", "--deadline", "0"])).unwrap_err();
        assert!(e.to_string().contains("deadline"), "{e}");
        let e = run(&sv(&["run", "--stencil", "3pt", "--deadline", "soon"])).unwrap_err();
        assert!(e.to_string().contains("--deadline soon"), "{e}");
    }

    #[test]
    fn roofline_command_runs() {
        run(&sv(&["roofline"])).unwrap();
    }

    #[test]
    fn dfg_command_runs_small() {
        run(&sv(&["dfg", "--stencil", "3pt", "--workers", "2"])).unwrap();
    }
}
