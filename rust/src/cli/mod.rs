//! Hand-rolled CLI for the `scgra` launcher (no clap in the offline
//! vendor set). Subcommands map 1:1 onto the paper's artifacts:
//!
//! ```text
//! scgra info                         machine + artifact inventory
//! scgra dfg      --stencil S [-w N] [--dot F] [--asm F]   §V emitters
//! scgra roofline [--stencil S]                            §VI analysis
//! scgra run      --stencil S [-w N] [--tiles N] [--steps N]  simulate
//! scgra compare                                           Table I
//! scgra validate                                          3-layer check
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::cgra::Machine;
use crate::config::Config;
use crate::coordinator::Coordinator;
use crate::gpu_model::{GpuStencil, Precision, V100};
use crate::roofline;
use crate::stencil::{map1d, map2d, StencilSpec};
use crate::util::rng::XorShift;
use crate::verify::golden::{max_abs_diff, run_sim, stencil1d_ref, stencil2d_ref};

/// Parsed command line: subcommand + `--flag value` pairs.
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .or_else(|| a.strip_prefix('-'))
                .with_context(|| format!("expected flag, got `{a}`"))?;
            let val = if i + 1 < argv.len() && !argv[i + 1].starts_with('-') {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
            i += 1;
        }
        Ok(Self { cmd, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }
}

fn stencil_by_name(name: &str) -> Result<StencilSpec> {
    Ok(match name {
        "paper1d" | "1d17" => StencilSpec::paper_1d(),
        "paper2d" | "2d49" => StencilSpec::paper_2d(),
        "heat2d" => StencilSpec::heat2d(96, 96, 0.2),
        "3pt" => StencilSpec::dim1(4096, vec![0.25, 0.5, 0.25])?,
        other => bail!("unknown stencil `{other}` (paper1d|paper2d|heat2d|3pt)"),
    })
}

/// Entry point shared by `main.rs` (returns instead of exiting for
/// testability).
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let (machine, run_defaults) = match args.get("config") {
        Some(path) => {
            let c = Config::load(path)?;
            (c.machine()?, Some(c))
        }
        None => (Machine::paper(), None),
    };
    match args.cmd.as_str() {
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(&machine),
        "dfg" => cmd_dfg(&args, &machine),
        "roofline" => cmd_roofline(&args, &machine),
        "run" => cmd_run(&args, &machine, run_defaults.as_ref()),
        "compare" => cmd_compare(&machine),
        "validate" => cmd_validate(&machine),
        other => bail!("unknown command `{other}`\n{HELP}"),
    }
}

const HELP: &str = "scgra — stencils on a coarse-grained reconfigurable spatial architecture
USAGE: scgra <info|dfg|roofline|run|compare|validate> [--flags]
  --stencil paper1d|paper2d|heat2d|3pt   workload (default paper2d)
  --workers N                            compute workers (0 = roofline pick)
  --tiles N                              CGRA tiles (default 1)
  --steps N                              host-driven time steps (default 1)
  --dot FILE / --asm FILE                emit Graphviz / assembly (dfg)
  --config FILE                          TOML machine/run config";

fn cmd_info(m: &Machine) -> Result<()> {
    println!("machine: {:.1} GHz, {} MAC PEs, {} GB/s -> peak {:.0} GFLOPS",
        m.clock_ghz, m.mac_pes, m.bw_gbps, m.peak_gflops());
    println!("fabric:  {}x{} PEs, cache {} KiB, DRAM latency {} cyc",
        m.grid_rows, m.grid_cols, m.cache_kib, m.dram_latency);
    match crate::runtime::Runtime::open(crate::runtime::Runtime::default_dir()) {
        Ok(rt) => println!("artifacts ({}): {}", rt.platform(), rt.names().join(", ")),
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn cmd_dfg(args: &Args, m: &Machine) -> Result<()> {
    let spec = stencil_by_name(args.get("stencil").unwrap_or("paper2d"))?;
    let w = match args.num("workers", 0usize)? {
        0 => roofline::optimal_workers(&spec, m),
        w => w,
    };
    let g = if spec.is_1d() {
        map1d::build(&spec, w)?
    } else {
        map2d::build(&spec, w)?
    };
    let title = format!(
        "{}x{} r=({},{}) {}-pt stencil, {} workers",
        spec.nx, spec.ny, spec.rx, spec.ry, spec.points(), w
    );
    println!("{title}: {}", g.summary());
    if let Some(path) = args.get("dot") {
        std::fs::write(path, crate::dfg::dot::to_dot(&g, &title))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("asm") {
        std::fs::write(path, crate::dfg::asm::to_asm(&g, &title))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_roofline(args: &Args, m: &Machine) -> Result<()> {
    let specs: Vec<(&str, StencilSpec)> = match args.get("stencil") {
        Some(s) => vec![(s, stencil_by_name(s)?)],
        None => vec![
            ("stencil1D", StencilSpec::paper_1d()),
            ("stencil2D", StencilSpec::paper_2d()),
        ],
    };
    println!("{:<12} {:>6} {:>10} {:>10} {:>10} {:>8} {:>6}",
        "stencil", "AI", "bw-roof", "peak", "attain", "demand", "w");
    for (name, spec) in specs {
        let w = roofline::optimal_workers(&spec, m);
        let a = roofline::analyze(&spec, m, w);
        println!(
            "{:<12} {:>6.2} {:>10.0} {:>10.0} {:>10.0} {:>8.0} {:>6}",
            name, a.arithmetic_intensity, a.bw_gflops, a.peak_gflops,
            a.attainable_gflops, a.demand_gflops, a.workers
        );
    }
    Ok(())
}

fn cmd_run(args: &Args, m: &Machine, cfg: Option<&Config>) -> Result<()> {
    let spec = match (args.get("stencil"), cfg) {
        (Some(s), _) => stencil_by_name(s)?,
        (None, Some(c)) => c.stencil()?,
        (None, None) => StencilSpec::paper_2d(),
    };
    let defaults = cfg.map(|c| c.run_params()).transpose()?.unwrap_or(
        crate::config::RunParams { workers: 0, tiles: 1, steps: 1, seed: 42 },
    );
    let w = match args.num("workers", defaults.workers)? {
        0 => roofline::optimal_workers(&spec, m),
        w => w,
    };
    let tiles = args.num("tiles", defaults.tiles)?;
    let steps = args.num("steps", defaults.steps)?;
    let mut rng = XorShift::new(defaults.seed);
    let input = rng.normal_vec(spec.grid_points());

    let coord = Coordinator::new(tiles, m.clone());
    println!(
        "running {}x{} {}-pt stencil, w={w}, tiles={tiles}, steps={steps}",
        spec.nx, spec.ny, spec.points()
    );
    let (out, reports) = coord.run_steps(&spec, w, &input, steps)?;
    for (i, r) in reports.iter().enumerate() {
        println!(
            "step {i}: {} strips, makespan {} cyc, {:.1} GFLOPS ({:.0}% of roofline)",
            r.strips,
            r.makespan_cycles,
            r.gflops,
            100.0 * r.gflops
                / (tiles as f64 * m.roofline_gflops(spec.arithmetic_intensity())),
        );
    }
    // Quick correctness spot check on the first step.
    let first = &reports[0];
    let want = if spec.is_1d() {
        stencil1d_ref(&input, &spec.cx)
    } else {
        stencil2d_ref(&input, &spec)
    };
    println!(
        "step-0 max|err| vs oracle: {:.2e}; final grid checksum {:.6}",
        max_abs_diff(&first.output, &want),
        out.iter().sum::<f64>()
    );
    Ok(())
}

fn cmd_compare(m: &Machine) -> Result<()> {
    // Table I: 16 CGRA tiles vs one V100.
    let coord = Coordinator::new(16, m.clone());
    let v100 = V100::paper();
    println!("Table I — comparative analysis of stencils on CGRA and GPU");
    for (name, spec, w) in [
        ("Stencil 1D (grid=194400, rx=8)", StencilSpec::paper_1d(), 6usize),
        ("Stencil 2D (960x449, rx=ry=12)", StencilSpec::paper_2d(), 5usize),
    ] {
        let mut rng = XorShift::new(7);
        let input = rng.normal_vec(spec.grid_points());
        let rep = coord.run(&spec, w, &input)?;
        let cgra_roof =
            coord.tiles as f64 * m.roofline_gflops(spec.arithmetic_intensity());
        let g = GpuStencil::from_spec(&spec, Precision::F64);
        let gpu = v100.best_gflops(&g);
        let gpu_roof = v100.roofline_gflops(&g);
        println!("\n{name}");
        println!("  CGRA x16: {:>8.0} GFLOPS  ({:>4.1}% of {:.0} roof)",
            rep.gflops, 100.0 * rep.gflops / cgra_roof, cgra_roof);
        println!("  V100:     {:>8.0} GFLOPS  ({:>4.1}% of {:.0} roof)",
            gpu, 100.0 * gpu / gpu_roof, gpu_roof);
        println!("  normalized GFLOPS (CGRA/V100): {:.2}x", rep.gflops / gpu);
    }
    Ok(())
}

fn cmd_validate(m: &Machine) -> Result<()> {
    // Three-layer agreement on the 49-pt stencil: simulator vs native
    // oracle vs the PJRT-executed JAX/Pallas artifact.
    let spec = StencilSpec::dim2(
        96,
        96,
        crate::stencil::spec::symmetric_taps(12),
        crate::stencil::spec::y_taps(12),
    )?;
    let mut rng = XorShift::new(123);
    let x = rng.normal_vec(96 * 96);

    let sim = run_sim(&spec, 4, m, &x)?;
    let oracle = stencil2d_ref(&x, &spec);
    let d_sim = max_abs_diff(&sim.output, &oracle);
    println!("simulator vs oracle:  max|err| = {d_sim:.2e}");

    let mut rt = crate::runtime::Runtime::open(crate::runtime::Runtime::default_dir())?;
    let pjrt = rt.execute("stencil2d_r12_96x96", &[&x, &spec.cx, &spec.cy])?;
    let d_pjrt = max_abs_diff(&pjrt, &oracle);
    println!("PJRT (pallas) vs oracle: max|err| = {d_pjrt:.2e}");
    let d_cross = max_abs_diff(&pjrt, &sim.output);
    println!("PJRT vs simulator:    max|err| = {d_cross:.2e}");
    anyhow::ensure!(d_sim < 1e-9 && d_pjrt < 1e-9 && d_cross < 1e-9, "validation failed");
    println!("all three layers agree ✓");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(&sv(&["run", "--workers", "5", "--tiles", "16"])).unwrap();
        assert_eq!(a.cmd, "run");
        assert_eq!(a.num("workers", 0usize).unwrap(), 5);
        assert_eq!(a.num("tiles", 1usize).unwrap(), 16);
        assert_eq!(a.num("steps", 1usize).unwrap(), 1);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&sv(&["dfg", "--verbose"])).unwrap();
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn stencil_names_resolve() {
        assert_eq!(stencil_by_name("paper1d").unwrap().points(), 17);
        assert_eq!(stencil_by_name("2d49").unwrap().points(), 49);
        assert!(stencil_by_name("bogus").is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn roofline_command_runs() {
        run(&sv(&["roofline"])).unwrap();
    }

    #[test]
    fn dfg_command_runs_small() {
        run(&sv(&["dfg", "--stencil", "3pt", "--workers", "2"])).unwrap();
    }
}
