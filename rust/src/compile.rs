//! Phase 1 of the compile-once / execute-many API: planning.
//!
//! The paper's flow (§III) maps a stencil onto the fabric **once** and
//! then streams grids through the resulting configuration; StencilFlow
//! draws the same line between a compiled mapping artifact and the
//! execution runtime. This module is that split for the whole system:
//! [`compile`] resolves everything data-independent — worker count,
//! the N-dim [`DecompPlan`] (including the §IV fused depth and a
//! shallower tail chunk when `steps % depth != 0`), one **placed** DFG
//! per distinct tile shape ([`PlacedGraph`]: validation, placement,
//! channel latencies, evaluation order), the time-tiled boundary-ring
//! schedule with its own depth-1 graphs ([`ring_stages`]), the
//! per-chunk halo [`ExchangeSchedule`]s, and the halo-adjusted roofline
//! — into an immutable, `Arc`-shareable [`CompiledStencil`].
//!
//! Execution never plans: [`crate::session::Session`] walks the
//! artifact's stages and only touches per-run state. The
//! [`crate::stencil::metrics`] counters pin that contract in tests.
//! Each [`PlacedGraph`] also pre-computes the per-run *allocation
//! budget* — the flat token-arena layout its channels index, the SoA
//! node-state sizes, the event wheel horizon — so
//! `Simulator::from_placed` carves a run's entire mutable state up
//! front and the cycle loop itself never allocates (the
//! zero-allocation contract `tests/alloc_free.rs` enforces).
//!
//! For the serve path, [`CompileCache`] is an LRU over compiled
//! artifacts keyed by `(spec, steps, options)`, and
//! [`CompiledStencil::save`]/[`CompiledStencil::load`] serialize the
//! planning outcome: the header line is the `runtime::artifact`
//! manifest schema (so the native artifact runtime reads the same
//! format), the body the `config` TOML subset. Graphs are rebuilt
//! deterministically from the recorded plan on load, so a loaded
//! artifact executes bitwise-identically to the in-memory one.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::analysis::CheckLevel;
use crate::cgra::{Machine, PlacedGraph};
use crate::config::Config;
use crate::error::ScgraError;
use crate::roofline::{self, TiledAnalysis};
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::stencil::decomp::{self, DecompKind, DecompPlan, Tile};
use crate::stencil::exchange::ExchangeSchedule;
use crate::stencil::spec::StencilShape;
use crate::stencil::{build_graph, temporal, StencilSpec};

/// How a multi-step run traverses time (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FuseMode {
    /// One decomposition pass per step: every step reads the grid from
    /// DRAM and writes it back (the paper's single-step use-case
    /// iterated by the host).
    #[default]
    Host,
    /// Fuse as many steps as the per-tile token budget admits into one
    /// spatial pipeline per tile ([`temporal::build_nd`]); the host
    /// loops over the fused chunks. Only the first layer loads and only
    /// the last layer stores, so DRAM traffic drops by ~the fused depth.
    Spatial,
    /// [`FuseMode::Spatial`] when the budget admits depth >= 2, else
    /// [`FuseMode::Host`].
    Auto,
}

impl FuseMode {
    /// Parse a CLI/config value (`host|spatial|auto`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "host" => FuseMode::Host,
            "spatial" => FuseMode::Spatial,
            "auto" => FuseMode::Auto,
            other => bail!("unknown fuse mode `{other}` (host|spatial|auto)"),
        })
    }
}

impl std::fmt::Display for FuseMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            FuseMode::Host => "host",
            FuseMode::Spatial => "spatial",
            FuseMode::Auto => "auto",
        })
    }
}

/// Where a chunk's halo (and, more broadly, its whole input) comes
/// from at a chunk boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HaloMode {
    /// Tiles retain their buffers across chunks and ship halo faces to
    /// neighbors through in-fabric channels
    /// ([`crate::stencil::exchange`]); only the cold first chunk reads
    /// the grid from DRAM, so the steady-state redundant-read fraction
    /// is zero.
    #[default]
    Exchange,
    /// [`HaloMode::Exchange`] with every transfer priced at zero:
    /// exchanged loads complete at flat hit latency regardless of how
    /// many mesh hops the halo face crossed. This is the pre-pricing
    /// exchange model, kept as a differential baseline — priced and
    /// free runs must produce bitwise-identical grids.
    ExchangeFree,
    /// Every chunk re-reads its full input box (grid + halo overlap)
    /// from DRAM — the pre-exchange behaviour, kept as the differential
    /// baseline.
    Reload,
}

impl HaloMode {
    /// Parse a CLI/config value (`exchange|exchange-free|reload`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exchange" => HaloMode::Exchange,
            "exchange-free" => HaloMode::ExchangeFree,
            "reload" => HaloMode::Reload,
            other => bail!("unknown halo mode `{other}` (exchange|exchange-free|reload)"),
        })
    }

    /// True for both exchange flavours: warm chunks keep tile inputs
    /// fabric-resident (where the residency plan allows).
    pub fn is_exchange(self) -> bool {
        matches!(self, HaloMode::Exchange | HaloMode::ExchangeFree)
    }
}

impl std::fmt::Display for HaloMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            HaloMode::Exchange => "exchange",
            HaloMode::ExchangeFree => "exchange-free",
            HaloMode::Reload => "reload",
        })
    }
}

/// Everything the compile phase needs besides the workload itself.
#[derive(Debug, Clone, PartialEq)]
pub struct CompileOptions {
    /// Machine the artifact is placed and budgeted for.
    pub machine: Machine,
    /// Compute workers per tile; 0 = pick via the §VI roofline.
    pub workers: usize,
    /// Hardware tiles the decomposition should feed.
    pub tiles: usize,
    /// Per-tile on-fabric token budget.
    pub fabric_tokens: usize,
    /// Cut strategy ([`DecompKind::Auto`] resolves per dimensionality).
    pub decomp: DecompKind,
    /// §IV temporal traversal for multi-step workloads.
    pub fuse: FuseMode,
    /// Halo sourcing at chunk boundaries (exchange vs DRAM reload).
    pub halo: HaloMode,
    /// How much of the static analyzer ([`crate::analysis`]) runs over
    /// the freshly compiled artifact before it is returned (default:
    /// Error-level rules in debug builds, off in release).
    pub check: CheckLevel,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self {
            machine: Machine::paper(),
            workers: 0,
            tiles: 1,
            fabric_tokens: decomp::DEFAULT_FABRIC_TOKENS,
            decomp: DecompKind::Auto,
            fuse: FuseMode::Auto,
            halo: HaloMode::Exchange,
            check: CheckLevel::default(),
        }
    }
}

impl CompileOptions {
    /// The Table-I configuration: 16 tiles of the §VI machine.
    pub fn paper() -> Self {
        Self {
            tiles: 16,
            ..Self::default()
        }
    }

    pub fn with_machine(mut self, machine: Machine) -> Self {
        self.machine = machine;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_tiles(mut self, tiles: usize) -> Self {
        self.tiles = tiles;
        self
    }

    pub fn with_fabric_tokens(mut self, tokens: usize) -> Self {
        self.fabric_tokens = tokens;
        self
    }

    pub fn with_decomp(mut self, kind: DecompKind) -> Self {
        self.decomp = kind;
        self
    }

    pub fn with_fuse(mut self, fuse: FuseMode) -> Self {
        self.fuse = fuse;
        self
    }

    pub fn with_halo(mut self, halo: HaloMode) -> Self {
        self.halo = halo;
        self
    }

    pub fn with_check(mut self, check: CheckLevel) -> Self {
        self.check = check;
        self
    }

    /// Resolve the worker count: the explicit setting, or the §VI
    /// roofline-optimal pick when 0.
    pub fn resolve_workers(&self, spec: &StencilSpec) -> usize {
        if self.workers == 0 {
            roofline::optimal_workers(spec, &self.machine)
        } else {
            self.workers
        }
    }
}

/// One homogeneous run of chunks: a plan executed `repeats` times with
/// the placed graphs for its tile shapes. A compiled workload is one
/// stage, or two when spatial fusion leaves a shallower tail
/// (`steps % fused_depth != 0`).
#[derive(Clone)]
pub struct CompiledStage {
    pub plan: DecompPlan,
    /// Consecutive executions of this plan (host: one per step; fused:
    /// one per chunk of `plan.fused_steps` steps).
    pub repeats: usize,
    /// One placed graph per distinct tile input shape, keyed by the
    /// tile's `[x, y, z]` input extents and shared by every same-extent
    /// tile.
    pub graphs: HashMap<[usize; 3], Arc<PlacedGraph>>,
    /// Time-tiled boundary-ring schedule, one band-tile list per fused
    /// layer `s = 1..=fused_steps` ([`temporal::ring_band_boxes`]):
    /// depth-1 tiles that advance the ring outside
    /// [`temporal::valid_box`] in lock-step with the fused trapezoid.
    /// Empty at depth 1 (host chunks have no ring).
    pub ring: Vec<Vec<Tile>>,
    /// Placed depth-1 graphs for the ring tiles, keyed like [`Self::graphs`]
    /// but kept separate: a ring tile and a fused tile with equal input
    /// extents map to different pipelines.
    pub ring_graphs: HashMap<[usize; 3], Arc<PlacedGraph>>,
    /// Halo movement between consecutive chunks of this stage.
    pub intra_exchange: ExchangeSchedule,
    /// Halo movement entering this stage from the previous stage's last
    /// chunk (`None` for the first stage — its first chunk is the cold
    /// DRAM read).
    pub entry_exchange: Option<ExchangeSchedule>,
    /// Which tiles can honour exchange-mode fabric residency on warm
    /// chunks, and the DRAM consequence for the ones that cannot.
    pub residency: ResidencyPlan,
}

impl CompiledStage {
    /// Time-steps this stage advances in total.
    pub fn steps(&self) -> usize {
        self.plan.fused_steps * self.repeats
    }

    /// Points of the boundary ring this stage computes per chunk.
    pub fn ring_points(&self) -> usize {
        self.ring
            .last()
            .map(|tiles| tiles.iter().map(|t| t.out_points()).sum())
            .unwrap_or(0)
    }
}

/// Workers for a depth-1 ring tile: the planned width clamped to the
/// tile's output columns (band boxes can be narrower than the fused
/// tiles the width was budgeted for).
pub fn ring_workers(w: usize, tile: &Tile) -> usize {
    w.min(tile.out_extent(0)).max(1)
}

/// The time-tiled ring schedule of a plan: band boxes per fused layer,
/// as depth-1 tiles with single-step halos. A pure function of
/// `(spec, plan)`, so [`CompiledStencil::parse`] rebuilds it exactly.
pub fn ring_stages(spec: &StencilSpec, plan: &DecompPlan) -> Vec<Vec<Tile>> {
    if plan.fused_steps <= 1 {
        return Vec::new();
    }
    let r = [spec.rx, spec.ry, spec.rz];
    (1..=plan.fused_steps)
        .map(|s| {
            temporal::ring_band_boxes(spec, plan.fused_steps, s)
                .into_iter()
                .map(|(lo, hi)| Tile::with_halo(lo, hi, r))
                .collect()
        })
        .collect()
}

/// Which tiles of a stage can actually honour [`HaloMode::Exchange`]'s
/// fabric residency. A warm chunk's tile keeps its whole input box in
/// on-fabric buffers, but those buffers share the per-tile token budget
/// with the §IV pipeline state. A tile whose pipeline tokens plus input
/// box exceed the budget cannot hold the box and must **spill**:
/// re-load its input through the cache every warm chunk (exactly the
/// [`HaloMode::Reload`] path), while covered tiles stay resident. The
/// plan is compiled here, once, so the session and the roofline agree
/// on the DRAM-traffic consequence before anything executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResidencyPlan {
    /// Per-tile verdict, indexed like `plan.tiles`: `true` = the tile's
    /// input box fits on fabric alongside its pipeline state.
    pub resident: Vec<bool>,
    /// Input points of the spilling tiles — the extra DRAM point-reads
    /// every warm chunk pays under exchange.
    pub spilled_points: usize,
}

impl ResidencyPlan {
    /// Budget check per tile: §IV pipeline tokens for the tile's
    /// sub-spec at the plan's depth, plus the input box itself.
    pub fn build(spec: &StencilSpec, plan: &DecompPlan, fabric_tokens: usize) -> Self {
        let mut resident = Vec::with_capacity(plan.tiles.len());
        let mut spilled_points = 0;
        for t in &plan.tiles {
            let pipeline =
                temporal::required_tokens(&t.sub_spec(spec), plan.workers, plan.fused_steps);
            let fits = pipeline + t.in_points() <= fabric_tokens;
            if !fits {
                spilled_points += t.in_points();
            }
            resident.push(fits);
        }
        Self { resident, spilled_points }
    }

    /// True when every tile keeps its input on fabric (no warm-chunk
    /// DRAM reads at all under exchange).
    pub fn fully_resident(&self) -> bool {
        self.spilled_points == 0
    }
}

/// The immutable product of [`compile`]: plan + placed graphs +
/// analysis for `steps` applications of `spec`. `Arc`-share it across
/// threads and execute it any number of times through a
/// [`crate::session::Session`]; no execution path re-plans or rebuilds
/// graphs.
#[derive(Clone)]
pub struct CompiledStencil {
    pub spec: StencilSpec,
    /// Total time-steps one execution advances.
    pub steps: usize,
    /// Resolved compute workers per tile.
    pub workers: usize,
    /// The options the artifact was compiled with (workers as
    /// requested; see [`Self::workers`] for the resolved count).
    pub options: CompileOptions,
    /// Execution schedule, in order.
    pub stages: Vec<CompiledStage>,
    /// Halo- and fusion-adjusted §VI roofline of the primary stage.
    pub analysis: TiledAnalysis,
}

impl CompiledStencil {
    /// The primary (deepest) plan — stage 0.
    pub fn plan(&self) -> &DecompPlan {
        &self.stages[0].plan
    }

    /// §IV fused depth of the primary stage.
    pub fn fused_steps(&self) -> usize {
        self.stages[0].plan.fused_steps
    }

    /// Chunks one execution runs (= reports a session returns).
    pub fn total_chunks(&self) -> usize {
        self.stages.iter().map(|s| s.repeats).sum()
    }

    /// Workload-level redundant-read fraction under [`HaloMode::Reload`]:
    /// per-stage plan fractions weighted by chunk count. The tail stage
    /// re-reads `radii * T_tail` halos, not the primary depth's, so this
    /// differs from stage 0's fraction whenever `steps % fused != 0` —
    /// it equals the measured `Σ chunk inputs / (chunks * grid) - 1`.
    /// Under [`HaloMode::Exchange`] only the cold first chunk pays it.
    pub fn redundant_read_fraction(&self) -> f64 {
        let grid = self.spec.grid_points() as f64;
        let mut loaded = 0.0;
        let mut chunks = 0.0;
        for st in &self.stages {
            loaded += st.plan.total_input_points() as f64 * st.repeats as f64;
            chunks += st.repeats as f64;
        }
        if chunks == 0.0 {
            return 0.0;
        }
        (loaded - grid * chunks) / (grid * chunks)
    }

    /// Distinct placed graphs across all stages.
    pub fn graph_count(&self) -> usize {
        self.stages.iter().map(|s| s.graphs.len()).sum()
    }

    /// Manifest entry describing this artifact in the
    /// `runtime::artifact` schema (z-major grid shape, x last — the
    /// same convention the artifact runtime's `grid_dims` reads).
    pub fn manifest_meta(&self) -> ArtifactMeta {
        let s = &self.spec;
        let shape: Vec<usize> = match s.ndim() {
            1 => vec![s.nx],
            2 => vec![s.ny, s.nx],
            _ => vec![s.nz, s.ny, s.nx],
        };
        let kind = if s.is_box() { "box" } else { "star" };
        ArtifactMeta {
            name: format!(
                "compiled_{}{}d_{}_t{}",
                kind,
                s.ndim(),
                s.dims().iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x"),
                self.steps
            ),
            file: "inline".to_string(),
            dtype: "f64".to_string(),
            in_shapes: vec![shape.clone()],
            out_shape: shape,
        }
    }

    /// Serialize the planning outcome. The first payload line is the
    /// `runtime::artifact` manifest schema; the rest is the `config`
    /// TOML subset. Graphs are not stored — they are deterministic
    /// functions of `(spec, workers, depth)` and are rebuilt on
    /// [`Self::load`].
    pub fn to_text(&self) -> String {
        let mut s = String::from("# stencil-cgra compiled artifact v1\n");
        s.push_str(&self.manifest_meta().to_line());
        s.push('\n');
        s.push_str(&spec_text(&self.spec));
        s.push_str(&options_text(&self.options, self.steps));
        s.push_str(&format!("resolved_workers = {}\n", self.workers));
        for (i, st) in self.stages.iter().enumerate() {
            s.push_str(&format!(
                "[stage{i}]\nkind = \"{}\"\ncuts = \"{},{},{}\"\n\
                 fused_steps = {}\nrepeats = {}\n",
                st.plan.kind,
                st.plan.cuts[0],
                st.plan.cuts[1],
                st.plan.cuts[2],
                st.plan.fused_steps,
                st.repeats,
            ));
        }
        s
    }

    /// Write [`Self::to_text`] to `path`. Filesystem failures come
    /// back as [`ScgraError::Io`].
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), ScgraError> {
        std::fs::write(path.as_ref(), self.to_text())
            .map_err(|e| ScgraError::Io(format!("writing {}: {e}", path.as_ref().display())))
    }

    /// Parse an artifact serialized by [`Self::to_text`] and rebuild
    /// its placed graphs. The result executes bitwise-identically to
    /// the artifact that was saved. Any structural problem — truncated
    /// text, wrong version line, unparseable body, inconsistent or
    /// over-budget declared geometry — is
    /// [`ScgraError::MalformedArtifact`]; corrupt input never panics
    /// (planning runs under a `catch_unwind` backstop on top of the
    /// structural validation).
    pub fn parse(text: &str) -> Result<Self, ScgraError> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Self::parse_inner(text))) {
            Ok(Ok(c)) => Ok(c),
            Ok(Err(e)) => Err(ScgraError::MalformedArtifact(e.to_string())),
            Err(_) => Err(ScgraError::MalformedArtifact(
                "compiled artifact drove planning into a panic".to_string(),
            )),
        }
    }

    fn parse_inner(text: &str) -> Result<Self> {
        // Split the manifest header line from the config body.
        let mut manifest_line = None;
        let mut body = String::new();
        for line in text.lines() {
            let t = line.trim();
            if manifest_line.is_none() && !t.is_empty() && !t.starts_with('#') {
                manifest_line = Some(t.to_string());
            } else {
                // The version header is a comment, but not an optional
                // one: an artifact declaring any other version must be
                // rejected, not silently misparsed.
                if t.starts_with('#') && t.contains("compiled artifact") {
                    ensure!(
                        t == "# stencil-cgra compiled artifact v1",
                        "unsupported artifact header `{t}`"
                    );
                }
                body.push_str(line);
                body.push('\n');
            }
        }
        let line = manifest_line.context("compiled artifact has no manifest line")?;
        let manifest = Manifest::parse(&line).context("compiled artifact manifest line")?;
        ensure!(manifest.entries.len() == 1, "expected one manifest entry");
        let meta = &manifest.entries[0];

        let c = Config::parse(&body).context("compiled artifact body")?;
        let spec = spec_from_config(&c)?;
        validate_parsed_spec(&spec)?;
        let shape_points: u128 = meta.out_shape.iter().map(|&d| d as u128).product();
        ensure!(
            shape_points == spec.grid_points() as u128,
            "manifest shape {:?} disagrees with the [spec] grid",
            meta.out_shape
        );
        let machine = c.machine()?;
        let options = CompileOptions {
            machine,
            workers: cfg_num(&c, "options", "workers")?,
            tiles: cfg_num(&c, "options", "tiles")?,
            fabric_tokens: cfg_num(&c, "options", "fabric_tokens")?,
            decomp: DecompKind::parse(cfg_str(&c, "options", "decomp")?)?,
            fuse: FuseMode::parse(cfg_str(&c, "options", "fuse")?)?,
            // Tolerate pre-exchange artifacts that carry no halo line.
            halo: match c.get("options", "halo") {
                None => HaloMode::default(),
                Some(v) => HaloMode::parse(v)?,
            },
            // Same tolerance for pre-analyzer artifacts.
            check: match c.get("options", "check") {
                None => CheckLevel::default(),
                Some(v) => CheckLevel::parse(v)?,
            },
        };
        let steps: usize = cfg_num(&c, "options", "steps")?;
        let workers: usize = cfg_num(&c, "options", "resolved_workers")?;

        let mut stages = Vec::new();
        for i in 0.. {
            let sect = format!("stage{i}");
            let Some(kind) = c.get(&sect, "kind") else { break };
            let kind = DecompKind::parse(kind)?;
            let cuts_v: Vec<usize> = cfg_str(&c, &sect, "cuts")?
                .split(',')
                .map(|d| d.trim().parse::<usize>().context("bad cut count"))
                .collect::<Result<_>>()?;
            ensure!(cuts_v.len() == 3, "[{sect}] cuts needs 3 entries");
            let cuts = [cuts_v[0], cuts_v[1], cuts_v[2]];
            // A cut count outside [1, extent] cannot come from `save`;
            // reject before the decomposition arithmetic sees it.
            for (axis, (&cut, dim)) in cuts.iter().zip([spec.nx, spec.ny, spec.nz]).enumerate() {
                ensure!(
                    cut >= 1 && cut <= dim,
                    "[{sect}] cuts[{axis}] = {cut} outside the grid's 1..={dim}"
                );
            }
            let fused_steps: usize = cfg_num(&c, &sect, "fused_steps")?;
            ensure!(
                fused_steps >= 1 && fused_steps <= spec.nx,
                "[{sect}] fused_steps = {fused_steps} infeasible for nx = {}",
                spec.nx
            );
            let repeats: usize = cfg_num(&c, &sect, "repeats")?;
            ensure!(repeats >= 1, "[{sect}] repeats must be >= 1");
            let plan = DecompPlan {
                kind,
                cuts,
                fused_steps,
                workers,
                tiles: decomp::tiles_for_cuts_depth(&spec, cuts, fused_steps),
            };
            let prev = stages.last().map(|s: &CompiledStage| s.plan.clone());
            stages.push(stage(
                &spec,
                workers,
                &options.machine,
                options.fabric_tokens,
                plan,
                repeats,
                prev.as_ref(),
            )?);
        }
        ensure!(!stages.is_empty(), "compiled artifact has no stages");
        let covered: usize = stages.iter().map(|s| s.steps()).sum();
        ensure!(
            covered == steps,
            "compiled artifact stages advance {covered} step(s) but declare {steps}"
        );
        let analysis = roofline::analyze_tiled_halo(
            &spec,
            &options.machine,
            workers,
            &stages[0].plan,
            options.tiles,
            options.halo,
            stages[0].residency.spilled_points,
        );
        Ok(Self { spec, steps, workers, options, stages, analysis })
    }

    /// Read and [`Self::parse`] an artifact file: missing/unreadable
    /// files are [`ScgraError::Io`], everything structural is
    /// [`ScgraError::MalformedArtifact`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self, ScgraError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| ScgraError::Io(format!("reading {}: {e}", path.as_ref().display())))?;
        Self::parse(&text)
    }

    /// [`Self::load`] followed by the static verifier at `check` —
    /// the untrusted-artifact entry point: structural parsing already
    /// rejects malformed text, and the analyzer then proves the
    /// *well-formed* plan is actually sound (deadlock-free buffering,
    /// exchange partition, residency arithmetic) before anything
    /// executes it. Denied diagnostics come back as
    /// [`ScgraError::AnalysisFailed`].
    pub fn load_checked(path: impl AsRef<Path>, check: CheckLevel) -> Result<Self, ScgraError> {
        let c = Self::load(path)?;
        if check != CheckLevel::Off {
            crate::analysis::check(&c).gate(check)?;
        }
        Ok(c)
    }
}

/// Compile `steps` applications of `spec` under `opts` into an
/// immutable, shareable execution artifact. All planning and DFG
/// construction for the workload happens here, exactly once:
///
/// * [`FuseMode::Host`] — one depth-1 plan, repeated `steps` times.
/// * [`FuseMode::Spatial`] — the deepest §IV depth `T` the budget
///   admits; `steps / T` chunks plus a tail stage of depth `steps % T`.
/// * [`FuseMode::Auto`] — `Spatial` when the probe finds depth >= 2,
///   else the host schedule.
///
/// Failures are classified: an unusable spec (degenerate dims, radii
/// leaving no interior, mismatched taps, zero steps) is
/// [`ScgraError::InfeasibleSpec`]; a structurally fine workload no
/// decomposition fits into the budget for is [`ScgraError::OverBudget`].
pub fn compile(
    spec: &StencilSpec,
    steps: usize,
    opts: &CompileOptions,
) -> Result<CompiledStencil, ScgraError> {
    if steps < 1 {
        return Err(ScgraError::InfeasibleSpec(
            "need at least one time-step".to_string(),
        ));
    }
    validate_parsed_spec(spec).map_err(|e| ScgraError::InfeasibleSpec(e.to_string()))?;
    opts.machine
        .validate()
        .map_err(|e| ScgraError::InvalidMachine(e.to_string()))?;
    let compiled = compile_inner(spec, steps, opts).map_err(classify_planning)?;
    // The static verifier runs over the finished artifact before anyone
    // can execute it; every rule is provably silent on a sound compile,
    // so in debug builds (where the default is Errors) this doubles as
    // a free clean-sweep over the whole test suite's compile matrix.
    if opts.check != CheckLevel::Off {
        crate::analysis::check(&compiled).gate(opts.check)?;
    }
    Ok(compiled)
}

/// Map a planning failure onto the public classification: budget
/// exhaustion is [`ScgraError::OverBudget`], everything else defers to
/// the generic prose classifier.
fn classify_planning(e: anyhow::Error) -> ScgraError {
    let msg = e.to_string();
    if msg.contains("no feasible decomposition") || msg.contains("budget") {
        ScgraError::OverBudget(msg)
    } else {
        ScgraError::classify(e)
    }
}

fn compile_inner(spec: &StencilSpec, steps: usize, opts: &CompileOptions) -> Result<CompiledStencil> {
    let w = opts.resolve_workers(spec);
    let stages = match opts.fuse {
        FuseMode::Host => {
            let plan = decomp::plan(spec, w, opts.fabric_tokens, opts.decomp, opts.tiles)?;
            vec![stage(spec, w, &opts.machine, opts.fabric_tokens, plan, steps, None)?]
        }
        FuseMode::Spatial | FuseMode::Auto => {
            let probe =
                decomp::plan_fused(spec, w, opts.fabric_tokens, opts.decomp, opts.tiles, steps)?;
            let depth = probe.fused_steps;
            if depth == 1 {
                vec![stage(spec, w, &opts.machine, opts.fabric_tokens, probe, steps, None)?]
            } else {
                let (full, rem) = (steps / depth, steps % depth);
                let mut v =
                    vec![stage(spec, w, &opts.machine, opts.fabric_tokens, probe, full, None)?];
                if rem > 0 {
                    // rem < depth, so a depth-rem plan is always
                    // feasible (buffering is monotone in depth) and the
                    // tail covers the leftover steps exactly.
                    let tail = decomp::plan_fused(
                        spec,
                        w,
                        opts.fabric_tokens,
                        opts.decomp,
                        opts.tiles,
                        rem,
                    )?;
                    let prev = v[0].plan.clone();
                    v.push(stage(
                        spec,
                        w,
                        &opts.machine,
                        opts.fabric_tokens,
                        tail,
                        1,
                        Some(&prev),
                    )?);
                }
                v
            }
        }
    };
    let analysis = roofline::analyze_tiled_halo(
        spec,
        &opts.machine,
        w,
        &stages[0].plan,
        opts.tiles,
        opts.halo,
        stages[0].residency.spilled_points,
    );
    Ok(CompiledStencil {
        spec: spec.clone(),
        steps,
        workers: w,
        options: opts.clone(),
        stages,
        analysis,
    })
}

/// Finish one stage: place the fused graphs, attach the time-tiled ring
/// schedule (with its own depth-1 placed graphs), and precompute the
/// exchange schedules. Shared by [`compile`] and
/// [`CompiledStencil::parse`] so a loaded artifact carries the same
/// ring/exchange state as a fresh one.
fn stage(
    spec: &StencilSpec,
    w: usize,
    machine: &Machine,
    fabric_tokens: usize,
    plan: DecompPlan,
    repeats: usize,
    prev: Option<&DecompPlan>,
) -> Result<CompiledStage> {
    let graphs = placed_graphs(spec, w, plan.fused_steps, &plan.tiles, machine)?;
    let ring = ring_stages(spec, &plan);
    let mut ring_graphs: HashMap<[usize; 3], Arc<PlacedGraph>> = HashMap::new();
    for t in ring.iter().flatten() {
        let dims = [t.in_extent(0), t.in_extent(1), t.in_extent(2)];
        if !ring_graphs.contains_key(&dims) {
            let g = build_graph(&t.sub_spec(spec), ring_workers(w, t))?;
            ring_graphs.insert(dims, Arc::new(PlacedGraph::new(g, machine)?));
        }
    }
    let intra_exchange = ExchangeSchedule::build(spec, &plan, &plan);
    let entry_exchange = prev.map(|p| ExchangeSchedule::build(spec, &plan, p));
    let residency = ResidencyPlan::build(spec, &plan, fabric_tokens);
    Ok(CompiledStage {
        plan,
        repeats,
        graphs,
        ring,
        ring_graphs,
        intra_exchange,
        entry_exchange,
        residency,
    })
}

/// Build one placed graph per distinct tile input shape — the dedup the
/// whole execution layer relies on: a 16-pencil plan places at most a
/// few graphs, and same-extent tiles share an `Arc`. Plans with a fused
/// depth > 1 map tiles through the §IV temporal pipeline.
pub fn placed_graphs(
    spec: &StencilSpec,
    w: usize,
    fused_steps: usize,
    tiles: &[Tile],
    machine: &Machine,
) -> Result<HashMap<[usize; 3], Arc<PlacedGraph>>> {
    let mut graphs: HashMap<[usize; 3], Arc<PlacedGraph>> = HashMap::new();
    for t in tiles {
        let dims = [t.in_extent(0), t.in_extent(1), t.in_extent(2)];
        if !graphs.contains_key(&dims) {
            let sub = t.sub_spec(spec);
            let g = if fused_steps > 1 {
                temporal::build_nd(&sub, w, fused_steps)?
            } else {
                build_graph(&sub, w)?
            };
            graphs.insert(dims, Arc::new(PlacedGraph::new(g, machine)?));
        }
    }
    Ok(graphs)
}

/// LRU cache of compiled artifacts keyed by `(spec, steps, options)` —
/// the serve path's front door: repeated requests for the same workload
/// hit the cache and do zero planning or graph construction.
pub struct CompileCache {
    cap: usize,
    /// Most-recently-used first.
    entries: Mutex<Vec<(String, Arc<CompiledStencil>)>>,
}

impl CompileCache {
    /// A cache holding at most `cap` artifacts (min 1).
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            entries: Mutex::new(Vec::new()),
        }
    }

    /// Return the cached artifact for `(spec, steps, opts)`, compiling
    /// and inserting it (evicting the least-recently-used entry past
    /// capacity) on a miss.
    pub fn get_or_compile(
        &self,
        spec: &StencilSpec,
        steps: usize,
        opts: &CompileOptions,
    ) -> Result<Arc<CompiledStencil>> {
        let key = cache_key(spec, steps, opts);
        if let Some(hit) = self.touch(&key) {
            return Ok(hit);
        }
        // Compile outside the lock; a concurrent miss on the same key
        // may duplicate work, but the first insert wins.
        let built = Arc::new(compile(spec, steps, opts)?);
        let mut e = self.entries.lock().unwrap();
        if let Some(pos) = e.iter().position(|(k, _)| *k == key) {
            let ent = e.remove(pos);
            e.insert(0, ent);
            return Ok(Arc::clone(&e[0].1));
        }
        e.insert(0, (key, Arc::clone(&built)));
        e.truncate(self.cap);
        Ok(built)
    }

    fn touch(&self, key: &str) -> Option<Arc<CompiledStencil>> {
        let mut e = self.entries.lock().unwrap();
        let pos = e.iter().position(|(k, _)| k == key)?;
        let ent = e.remove(pos);
        e.insert(0, ent);
        Some(Arc::clone(&e[0].1))
    }

    /// Artifacts currently cached.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached artifact.
    pub fn clear(&self) {
        self.entries.lock().unwrap().clear();
    }
}

/// Canonical text key for the LRU — the `save` serialization of the
/// spec and options plus a bit-pattern rendering of the machine floats.
/// The save format prints machine floats with `Display` (so
/// `Config::machine` can reparse them), but `Display` is not injective
/// on f64 — every NaN payload prints `NaN` — so the key alone must
/// carry the exact bits: two requests share an entry iff their compiled
/// artifacts would be bitwise-identical.
fn cache_key(spec: &StencilSpec, steps: usize, opts: &CompileOptions) -> String {
    let m = &opts.machine;
    format!(
        "{}{}machine_bits = \"{}\"\n",
        spec_text(spec),
        options_text(opts, steps),
        bits_csv(&[m.clock_ghz, m.bw_gbps]),
    )
}

fn bits_csv(v: &[f64]) -> String {
    v.iter()
        .map(|x| format!("{:016x}", x.to_bits()))
        .collect::<Vec<_>>()
        .join(",")
}

fn csv_bits(s: &str) -> Result<Vec<f64>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|t| {
            u64::from_str_radix(t.trim(), 16)
                .map(f64::from_bits)
                .with_context(|| format!("bad coefficient bits `{t}`"))
        })
        .collect()
}

/// `[spec]` section: geometry plus bit-exact coefficients.
fn spec_text(s: &StencilSpec) -> String {
    format!(
        "[spec]\nshape = \"{}\"\nnx = {}\nny = {}\nnz = {}\n\
         rx = {}\nry = {}\nrz = {}\n\
         cx = \"{}\"\ncy = \"{}\"\ncz = \"{}\"\nbox_taps = \"{}\"\n",
        if s.is_box() { "box" } else { "star" },
        s.nx,
        s.ny,
        s.nz,
        s.rx,
        s.ry,
        s.rz,
        bits_csv(&s.cx),
        bits_csv(&s.cy),
        bits_csv(&s.cz),
        bits_csv(&s.box_taps),
    )
}

/// `[machine]` + `[options]` sections. Machine floats print in Rust's
/// shortest-roundtrip form, so `Config::machine` reparses them exactly.
fn options_text(o: &CompileOptions, steps: usize) -> String {
    let m = &o.machine;
    format!(
        "[machine]\nclock_ghz = {}\ngrid_rows = {}\ngrid_cols = {}\nmac_pes = {}\n\
         bw_gbps = {}\ndram_latency = {}\ncache_kib = {}\ncache_line = {}\n\
         cache_hit_latency = {}\nmshr_per_load = {}\nmax_instr_per_pe = {}\n\
         hops_per_cycle = {}\nlink_words_per_cycle = {}\n\
         [options]\nworkers = {}\ntiles = {}\nfabric_tokens = {}\n\
         decomp = \"{}\"\nfuse = \"{}\"\nhalo = \"{}\"\ncheck = \"{}\"\nsteps = {}\n",
        m.clock_ghz,
        m.grid_rows,
        m.grid_cols,
        m.mac_pes,
        m.bw_gbps,
        m.dram_latency,
        m.cache_kib,
        m.cache_line,
        m.cache_hit_latency,
        m.mshr_per_load,
        m.max_instr_per_pe,
        m.hops_per_cycle,
        m.link_words_per_cycle,
        o.workers,
        o.tiles,
        o.fabric_tokens,
        o.decomp,
        o.fuse,
        o.halo,
        o.check,
        steps,
    )
}

fn cfg_str<'a>(c: &'a Config, sect: &str, key: &str) -> Result<&'a str> {
    c.get(sect, key)
        .with_context(|| format!("compiled artifact missing [{sect}] {key}"))
}

fn cfg_num<T: std::str::FromStr>(c: &Config, sect: &str, key: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    let v = cfg_str(c, sect, key)?;
    v.parse()
        .map_err(|e| anyhow::anyhow!("compiled artifact [{sect}] {key} = {v}: {e}"))
}

fn spec_from_config(c: &Config) -> Result<StencilSpec> {
    let shape = match cfg_str(c, "spec", "shape")? {
        "star" => StencilShape::Star,
        "box" => StencilShape::Box,
        other => bail!("unknown spec shape `{other}`"),
    };
    Ok(StencilSpec {
        shape,
        nx: cfg_num(c, "spec", "nx")?,
        ny: cfg_num(c, "spec", "ny")?,
        nz: cfg_num(c, "spec", "nz")?,
        rx: cfg_num(c, "spec", "rx")?,
        ry: cfg_num(c, "spec", "ry")?,
        rz: cfg_num(c, "spec", "rz")?,
        cx: csv_bits(cfg_str(c, "spec", "cx")?)?,
        cy: csv_bits(cfg_str(c, "spec", "cy")?)?,
        cz: csv_bits(cfg_str(c, "spec", "cz")?)?,
        box_taps: csv_bits(cfg_str(c, "spec", "box_taps")?)?,
    })
}

/// Upper bound on the grid a parsed artifact (or compile request) may
/// declare: 2^30 points = 8 GiB per f64 grid copy. Anything larger is
/// a corrupt or hostile artifact, not a plausible workload.
pub const MAX_GRID_POINTS: u128 = 1 << 30;

/// Re-establish the invariants the [`StencilSpec`] constructors
/// enforce. [`spec_from_config`] builds the struct field-by-field from
/// untrusted text, so without this a bit-flipped artifact could
/// smuggle in a spec whose radii/tap/extent inconsistencies only
/// surface as panics (or huge allocations) deep inside planning.
fn validate_parsed_spec(s: &StencilSpec) -> Result<()> {
    let (nx, ny, nz) = (s.nx, s.ny, s.nz);
    ensure!(
        nx >= 1 && ny >= 1 && nz >= 1,
        "spec has an empty dimension ({nx}x{ny}x{nz})"
    );
    let points = nx as u128 * ny as u128 * nz as u128;
    ensure!(
        points <= MAX_GRID_POINTS,
        "spec grid {nx}x{ny}x{nz} = {points} points exceeds the {MAX_GRID_POINTS}-point cap"
    );
    // Overflow-safe radius checks: a parsed radius can be any usize.
    let fits = |n: usize, r: usize| r.checked_mul(2).map_or(false, |d| n > d);
    ensure!(fits(nx, s.rx), "nx {nx} too small for rx {}", s.rx);
    ensure!(fits(ny, s.ry), "ny {ny} too small for ry {}", s.ry);
    ensure!(fits(nz, s.rz), "nz {nz} too small for rz {}", s.rz);
    match s.shape {
        StencilShape::Star => {
            ensure!(
                s.cx.len() == 2 * s.rx + 1 && s.rx >= 1,
                "star cx has {} taps for rx {}",
                s.cx.len(),
                s.rx
            );
            ensure!(
                s.cy.len() == 2 * s.ry,
                "star cy has {} taps for ry {}",
                s.cy.len(),
                s.ry
            );
            ensure!(
                s.cz.len() == 2 * s.rz,
                "star cz has {} taps for rz {}",
                s.cz.len(),
                s.rz
            );
            ensure!(s.box_taps.is_empty(), "star spec carries box taps");
        }
        StencilShape::Box => {
            ensure!(s.rx >= 1 && s.ry >= 1, "box radii must be >= 1");
            let want = (2 * s.rx + 1) * (2 * s.ry + 1) * (2 * s.rz + 1);
            ensure!(
                s.box_taps.len() == want,
                "box window needs {want} taps, got {}",
                s.box_taps.len()
            );
            ensure!(
                s.cx.is_empty() && s.cy.is_empty() && s.cz.is_empty(),
                "box spec carries star taps"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_schedule_is_one_stage_per_workload() {
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_fuse(FuseMode::Host);
        let c = compile(&spec, 3, &opts).unwrap();
        assert_eq!(c.stages.len(), 1);
        assert_eq!(c.stages[0].plan.fused_steps, 1);
        assert_eq!(c.stages[0].repeats, 3);
        assert_eq!(c.total_chunks(), 3);
        assert_eq!(c.workers, 2);
        assert_eq!(c.plan().workers, 2, "plans are self-describing");
    }

    #[test]
    fn spatial_schedule_covers_steps_exactly_with_a_tail() {
        let spec = StencilSpec::heat2d(40, 24, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_fuse(FuseMode::Spatial);
        let steps = 7;
        let c = compile(&spec, steps, &opts).unwrap();
        let covered: usize = c.stages.iter().map(|s| s.steps()).sum();
        assert_eq!(covered, steps);
        assert!(c.fused_steps() > 1, "budget admits fusion here");
        if c.steps % c.fused_steps() != 0 {
            assert_eq!(c.stages.len(), 2);
            assert_eq!(c.stages[1].plan.fused_steps, steps % c.fused_steps());
            assert_eq!(c.stages[1].repeats, 1);
        }
    }

    #[test]
    fn auto_falls_back_to_host_when_grid_cannot_deepen() {
        // 4-wide grid, r = 1: the trapezoid admits only depth 1.
        let spec = StencilSpec::heat2d(4, 4, 0.2);
        let opts = CompileOptions::default().with_workers(1);
        let c = compile(&spec, 2, &opts).unwrap();
        assert_eq!(c.stages.len(), 1);
        assert_eq!(c.fused_steps(), 1);
        assert_eq!(c.stages[0].repeats, 2);
    }

    #[test]
    fn graphs_are_deduped_per_tile_shape() {
        let spec = StencilSpec::heat2d(64, 20, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(4);
        let c = compile(&spec, 1, &opts).unwrap();
        assert!(c.plan().tiles.len() >= 4);
        assert!(
            c.graph_count() < c.plan().tiles.len(),
            "{} graphs for {} tiles",
            c.graph_count(),
            c.plan().tiles.len()
        );
    }

    #[test]
    fn zero_workers_resolves_via_roofline() {
        let spec = StencilSpec::paper_2d();
        let opts = CompileOptions::default();
        let c = compile(&spec, 1, &opts).unwrap();
        assert_eq!(c.workers, roofline::optimal_workers(&spec, &opts.machine));
        assert!(c.workers >= 1);
    }

    #[test]
    fn artifact_text_round_trips() {
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let c = compile(&spec, 2, &opts).unwrap();
        let text = c.to_text();
        let back = CompiledStencil::parse(&text).unwrap();
        assert_eq!(back.spec, c.spec);
        assert_eq!(back.steps, c.steps);
        assert_eq!(back.workers, c.workers);
        assert_eq!(back.options, c.options);
        assert_eq!(back.stages.len(), c.stages.len());
        for (a, b) in back.stages.iter().zip(&c.stages) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.repeats, b.repeats);
        }
        assert_eq!(back.analysis, c.analysis);
    }

    #[test]
    fn artifact_header_is_the_runtime_manifest_schema() {
        let spec = StencilSpec::heat3d(10, 8, 6, 0.1);
        let c = compile(&spec, 1, &CompileOptions::default().with_workers(2)).unwrap();
        let meta = c.manifest_meta();
        let parsed = Manifest::parse(&meta.to_line()).unwrap();
        assert_eq!(parsed.entries[0], meta);
        assert_eq!(parsed.entries[0].out_shape, vec![6, 8, 10]);
    }

    #[test]
    fn coefficient_bits_round_trip() {
        let v = vec![0.1, -3.25, 1.0 / 3.0, f64::MIN_POSITIVE];
        assert_eq!(csv_bits(&bits_csv(&v)).unwrap(), v);
        assert_eq!(csv_bits("").unwrap(), Vec::<f64>::new());
        assert!(csv_bits("zz").is_err());
    }

    #[test]
    fn cache_hits_share_the_artifact_and_lru_evicts() {
        let cache = CompileCache::new(2);
        let opts = CompileOptions::default().with_workers(1);
        let a = StencilSpec::heat2d(10, 8, 0.2);
        let b = StencilSpec::heat2d(12, 8, 0.2);
        let c_spec = StencilSpec::heat2d(14, 8, 0.2);
        let a1 = cache.get_or_compile(&a, 1, &opts).unwrap();
        let b1 = cache.get_or_compile(&b, 1, &opts).unwrap();
        // Touch `a`, insert a third: `b` is the LRU victim.
        let a2 = cache.get_or_compile(&a, 1, &opts).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2));
        let _c1 = cache.get_or_compile(&c_spec, 1, &opts).unwrap();
        assert_eq!(cache.len(), 2);
        let b2 = cache.get_or_compile(&b, 1, &opts).unwrap();
        assert!(!Arc::ptr_eq(&b1, &b2), "evicted entries recompile");
        // Different steps / options are different keys.
        let a3 = cache.get_or_compile(&a, 2, &opts).unwrap();
        assert!(!Arc::ptr_eq(&a2, &a3));
    }

    #[test]
    fn cache_key_distinguishes_bitwise_different_machine_floats() {
        // `Display` collapses every NaN payload to "NaN" and (on older
        // toolchains) -0.0 to "0"; the key must keep the exact bits so
        // such machines never share an artifact.
        let spec = StencilSpec::heat2d(10, 8, 0.2);
        let nan_a = f64::from_bits(0x7ff8_0000_0000_0000);
        let nan_b = f64::from_bits(0x7ff8_0000_0000_0001);
        for (x, y) in [(nan_a, nan_b), (0.0, -0.0)] {
            let mk = |bw: f64| {
                CompileOptions::default()
                    .with_workers(1)
                    .with_machine(Machine { bw_gbps: bw, ..Machine::paper() })
            };
            let ka = cache_key(&spec, 1, &mk(x));
            let kb = cache_key(&spec, 1, &mk(y));
            assert_ne!(ka, kb, "bits {:016x} vs {:016x}", x.to_bits(), y.to_bits());
            let cache = CompileCache::new(4);
            let ca = cache.get_or_compile(&spec, 1, &mk(x)).unwrap();
            let cb = cache.get_or_compile(&spec, 1, &mk(y)).unwrap();
            assert!(!Arc::ptr_eq(&ca, &cb), "distinct machines collided");
            assert_eq!(cache.len(), 2);
        }
    }

    #[test]
    fn typed_errors_classify_compile_and_artifact_failures() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        // Zero steps: infeasible, not a prose-only failure.
        let e = compile(&spec, 0, &CompileOptions::default().with_workers(1)).unwrap_err();
        assert_eq!(e.kind(), "infeasible-spec");
        let c = compile(&spec, 1, &CompileOptions::default().with_workers(1)).unwrap();
        // Wrong version header: malformed.
        let text = c.to_text().replace("artifact v1", "artifact v9");
        let e = CompiledStencil::parse(&text).unwrap_err();
        assert_eq!(e.kind(), "malformed-artifact");
        assert!(e.to_string().contains("version") || e.to_string().contains("header"), "{e}");
        // Truncation inside the manifest line: malformed.
        let full = c.to_text();
        assert_eq!(
            CompiledStencil::parse(&full[..60]).unwrap_err().kind(),
            "malformed-artifact"
        );
        // Absurd declared geometry: malformed (and no huge allocation).
        let huge = full.replace("nx = 16", "nx = 123456789123");
        assert_eq!(CompiledStencil::parse(&huge).unwrap_err().kind(), "malformed-artifact");
        // Missing file: io.
        let e = CompiledStencil::load("/nonexistent/scgra.artifact").unwrap_err();
        assert_eq!(e.kind(), "io");
    }

    #[test]
    fn fused_stages_carry_ring_and_exchange_schedules() {
        let spec = StencilSpec::heat2d(40, 24, 0.2);
        let opts = CompileOptions::default()
            .with_workers(2)
            .with_tiles(2)
            .with_fuse(FuseMode::Spatial);
        let c = compile(&spec, 7, &opts).unwrap();
        let depth = c.fused_steps();
        assert!(depth > 1);
        let st = &c.stages[0];
        assert_eq!(st.ring.len(), depth);
        assert_eq!(st.ring_points(), temporal::ring_point_count(&spec, depth));
        // Every ring tile has a placed depth-1 graph and stays in-grid.
        for t in st.ring.iter().flatten() {
            let dims = [t.in_extent(0), t.in_extent(1), t.in_extent(2)];
            assert!(st.ring_graphs.contains_key(&dims));
            assert!(t.in_hi[0] <= spec.nx && t.in_hi[1] <= spec.ny);
        }
        // Exchange: stage 0 has no entry (cold chunk); the tail stage
        // enters from stage 0's plan.
        assert!(st.entry_exchange.is_none());
        assert_eq!(st.intra_exchange.tiles.len(), st.plan.tiles.len());
        if c.stages.len() == 2 {
            assert!(c.stages[1].entry_exchange.is_some());
        }
        // Host chunks have no ring.
        let host = compile(
            &spec,
            2,
            &CompileOptions::default().with_workers(2).with_fuse(FuseMode::Host),
        )
        .unwrap();
        assert!(host.stages[0].ring.is_empty());
        assert_eq!(host.stages[0].ring_points(), 0);
    }

    #[test]
    fn artifact_round_trip_preserves_halo_ring_and_exchange() {
        let spec = StencilSpec::heat2d(40, 24, 0.2);
        let opts = CompileOptions::default()
            .with_workers(2)
            .with_tiles(2)
            .with_fuse(FuseMode::Spatial)
            .with_halo(HaloMode::Reload);
        let c = compile(&spec, 7, &opts).unwrap();
        let back = CompiledStencil::parse(&c.to_text()).unwrap();
        assert_eq!(back.options.halo, HaloMode::Reload);
        for (a, b) in back.stages.iter().zip(&c.stages) {
            assert_eq!(a.ring, b.ring);
            assert_eq!(a.intra_exchange, b.intra_exchange);
            assert_eq!(a.entry_exchange, b.entry_exchange);
            assert_eq!(a.ring_graphs.len(), b.ring_graphs.len());
            assert_eq!(a.residency, b.residency);
        }
        // Artifacts that predate the halo line parse to the default.
        let stripped: String = c
            .to_text()
            .lines()
            .filter(|l| !l.starts_with("halo = "))
            .map(|l| format!("{l}\n"))
            .collect();
        let old = CompiledStencil::parse(&stripped).unwrap();
        assert_eq!(old.options.halo, HaloMode::Exchange);
    }

    #[test]
    fn halo_mode_exchange_free_parses_displays_and_round_trips() {
        assert_eq!(HaloMode::parse("exchange-free").unwrap(), HaloMode::ExchangeFree);
        assert_eq!(HaloMode::ExchangeFree.to_string(), "exchange-free");
        assert!(HaloMode::Exchange.is_exchange());
        assert!(HaloMode::ExchangeFree.is_exchange());
        assert!(!HaloMode::Reload.is_exchange());
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let opts = CompileOptions::default()
            .with_workers(2)
            .with_halo(HaloMode::ExchangeFree);
        let c = compile(&spec, 2, &opts).unwrap();
        let back = CompiledStencil::parse(&c.to_text()).unwrap();
        assert_eq!(back.options.halo, HaloMode::ExchangeFree);
        assert_eq!(
            back.options.machine.link_words_per_cycle,
            c.options.machine.link_words_per_cycle
        );
        // Artifacts that predate the link-bandwidth field parse to the
        // paper default.
        let stripped: String = c
            .to_text()
            .lines()
            .filter(|l| !l.starts_with("link_words_per_cycle"))
            .map(|l| format!("{l}\n"))
            .collect();
        let old = CompiledStencil::parse(&stripped).unwrap();
        assert_eq!(
            old.options.machine.link_words_per_cycle,
            Machine::paper().link_words_per_cycle
        );
    }

    #[test]
    fn compile_rejects_a_degenerate_machine_with_a_typed_error() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let m = Machine { hops_per_cycle: 0, ..Machine::paper() };
        let opts = CompileOptions::default().with_workers(1).with_machine(m);
        let e = compile(&spec, 1, &opts).unwrap_err();
        assert_eq!(e.kind(), "invalid-machine");
        assert!(e.to_string().contains("hops_per_cycle"), "{e}");
        assert!(!e.is_transient());
    }

    #[test]
    fn residency_plan_spills_when_the_box_overflows_the_budget() {
        let spec = StencilSpec::heat2d(40, 24, 0.2);
        let opts = CompileOptions::default()
            .with_workers(2)
            .with_tiles(2)
            .with_fuse(FuseMode::Spatial);
        let c = compile(&spec, 6, &opts).unwrap();
        let st = &c.stages[0];
        // The default budget holds every tile's input box.
        assert!(st.residency.fully_resident());
        assert_eq!(st.residency.resident.len(), st.plan.tiles.len());
        assert_eq!(c.analysis.spilled_points, 0);
        // Against a budget that cannot hold any box, every tile spills
        // and the point count is exact.
        let tight = ResidencyPlan::build(&spec, &st.plan, 0);
        assert!(tight.resident.iter().all(|r| !r));
        assert!(!tight.fully_resident());
        assert_eq!(tight.spilled_points, st.plan.total_input_points());
        // The spill feeds the roofline's warm-chunk byte count: the
        // effective intensity drops below the clean exchange value.
        let m = &c.options.machine;
        let clean = roofline::analyze_tiled_halo(
            &spec, m, c.workers, &st.plan, 2, HaloMode::Exchange, 0,
        );
        let spilled = roofline::analyze_tiled_halo(
            &spec, m, c.workers, &st.plan, 2, HaloMode::Exchange, tight.spilled_points,
        );
        assert!(spilled.effective_ai < clean.effective_ai);
        assert_eq!(spilled.spilled_points, tight.spilled_points);
    }
}
