//! Config system: a small TOML-subset parser (sections, `key = value`
//! with numbers / strings / booleans, `#` comments) mapped onto the
//! machine and run descriptions. No external crates are available in the
//! offline vendor set, so the parser lives here; `configs/*.toml` ship
//! ready-made machine and experiment files.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::analysis::CheckLevel;
use crate::cgra::{Machine, SimCore};
use crate::compile::{CompileOptions, FuseMode, HaloMode};
use crate::stencil::decomp::{self, DecompKind};
use crate::stencil::StencilSpec;
use crate::util::fault::{FaultPlan, MAX_STALL_EXTRA};

/// Parsed key-value configuration grouped by `[section]`.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: HashMap<String, HashMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut sections: HashMap<String, HashMap<String, String>> = HashMap::new();
        let mut current = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let val = v.trim().trim_matches('"').to_string();
                sections
                    .entry(current.clone())
                    .or_default()
                    .insert(k.trim().to_string(), val);
            } else {
                bail!("config line {}: expected `key = value` or `[section]`", i + 1);
            }
        }
        Ok(Self { sections })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn num<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("[{section}] {key} = {v}: {e}")),
        }
    }

    /// Build a [`Machine`] from `[machine]`, defaulting to the paper's.
    /// The result is validated ([`Machine::validate`]) so degenerate
    /// fields — a zero `hops_per_cycle` that later arithmetic divides
    /// by, a non-positive clock — are rejected here, at the config
    /// boundary, instead of panicking mid-placement.
    pub fn machine(&self) -> Result<Machine> {
        let d = Machine::paper();
        let m = Machine {
            clock_ghz: self.num("machine", "clock_ghz", d.clock_ghz)?,
            grid_rows: self.num("machine", "grid_rows", d.grid_rows)?,
            grid_cols: self.num("machine", "grid_cols", d.grid_cols)?,
            mac_pes: self.num("machine", "mac_pes", d.mac_pes)?,
            bw_gbps: self.num("machine", "bw_gbps", d.bw_gbps)?,
            dram_latency: self.num("machine", "dram_latency", d.dram_latency)?,
            cache_kib: self.num("machine", "cache_kib", d.cache_kib)?,
            cache_line: self.num("machine", "cache_line", d.cache_line)?,
            cache_hit_latency: self.num("machine", "cache_hit_latency", d.cache_hit_latency)?,
            mshr_per_load: self.num("machine", "mshr_per_load", d.mshr_per_load)?,
            max_instr_per_pe: self.num("machine", "max_instr_per_pe", d.max_instr_per_pe)?,
            hops_per_cycle: self.num("machine", "hops_per_cycle", d.hops_per_cycle)?,
            link_words_per_cycle: self.num(
                "machine",
                "link_words_per_cycle",
                d.link_words_per_cycle,
            )?,
        };
        m.validate()?;
        Ok(m)
    }

    /// Build a [`StencilSpec`] from `[stencil]`:
    /// `preset = paper1d|paper2d|heat2d|heat3d`, or explicit
    /// `nx/ny/nz/rx/ry/rz` (+ `shape = star|box`) with generated
    /// normalized taps.
    pub fn stencil(&self) -> Result<StencilSpec> {
        if let Some(p) = self.get("stencil", "preset") {
            return match p {
                "paper1d" => Ok(StencilSpec::paper_1d()),
                "paper2d" => Ok(StencilSpec::paper_2d()),
                "heat2d" => {
                    let nx = self.num("stencil", "nx", 96usize)?;
                    let ny = self.num("stencil", "ny", 96usize)?;
                    let alpha = self.num("stencil", "alpha", 0.2f64)?;
                    Ok(StencilSpec::heat2d(nx, ny, alpha))
                }
                "heat3d" => {
                    let nx = self.num("stencil", "nx", 48usize)?;
                    let ny = self.num("stencil", "ny", 48usize)?;
                    let nz = self.num("stencil", "nz", 48usize)?;
                    let alpha = self.num("stencil", "alpha", 0.1f64)?;
                    Ok(StencilSpec::heat3d(nx, ny, nz, alpha))
                }
                other => bail!("unknown stencil preset `{other}`"),
            };
        }
        let nx = self.num("stencil", "nx", 4096usize)?;
        let ny = self.num("stencil", "ny", 1usize)?;
        let nz = self.num("stencil", "nz", 1usize)?;
        let rx = self.num("stencil", "rx", 1usize)?;
        // Radii default to 1 along any extended dimension so that a
        // config naming only nx/ny/nz is valid out of the box.
        let ry = self.num("stencil", "ry", usize::from(ny > 1))?;
        let rz = self.num("stencil", "rz", usize::from(nz > 1))?;
        let shape = self.get("stencil", "shape").unwrap_or("star");
        if nz > 1 && ny <= 1 {
            bail!("[stencil] nz = {nz} needs ny > 1 (a 3-D grid has all three extents)");
        }
        match shape {
            "box" if nz > 1 => StencilSpec::box3d(
                nx,
                ny,
                nz,
                rx,
                ry,
                rz,
                crate::stencil::spec::uniform_box_taps(rx, ry, rz),
            ),
            "box" => StencilSpec::box2d(
                nx,
                ny,
                rx,
                ry,
                crate::stencil::spec::uniform_box_taps(rx, ry, 0),
            ),
            "star" if nz > 1 => StencilSpec::dim3(
                nx,
                ny,
                nz,
                crate::stencil::spec::symmetric_taps(rx),
                crate::stencil::spec::y_taps(ry),
                crate::stencil::spec::z_taps(rz),
            ),
            "star" if ny <= 1 || ry == 0 => {
                StencilSpec::dim1(nx, crate::stencil::spec::symmetric_taps(rx))
            }
            "star" => StencilSpec::dim2(
                nx,
                ny,
                crate::stencil::spec::symmetric_taps(rx),
                crate::stencil::spec::y_taps(ry),
            ),
            other => bail!("unknown stencil shape `{other}` (star|box)"),
        }
    }

    /// Build a [`FaultPlan`] from the `[fault]` section, if present.
    /// Keys mirror the one-line spec syntax (`FaultPlan::parse`):
    /// `seed`, `fill`, `stall`, `extra`, `slow`, `epoch`. A section
    /// with no keys yields the unarmed default plan — `Session`
    /// filters unarmed plans, so listing `[fault]` alone is a no-op.
    pub fn fault_plan(&self) -> Result<Option<FaultPlan>> {
        if self.sections.get("fault").is_none() {
            return Ok(None);
        }
        let d = FaultPlan::default();
        let plan = FaultPlan {
            seed: self.num("fault", "seed", d.seed)?,
            fill_fail_pct: self.num("fault", "fill", d.fill_fail_pct)?,
            stall_pct: self.num("fault", "stall", d.stall_pct)?,
            stall_extra: self.num("fault", "extra", d.stall_extra)?,
            slow_pct: self.num("fault", "slow", d.slow_pct)?,
            epoch_cycles: self.num("fault", "epoch", d.epoch_cycles)?,
        };
        for (k, v) in [
            ("fill", plan.fill_fail_pct),
            ("stall", plan.stall_pct),
            ("slow", plan.slow_pct),
        ] {
            if v > 100 {
                bail!("[fault] {k} = {v}: percentage must be <= 100");
            }
        }
        if plan.stall_extra > MAX_STALL_EXTRA {
            bail!(
                "[fault] extra = {}: must be <= {MAX_STALL_EXTRA}",
                plan.stall_extra
            );
        }
        if plan.epoch_cycles == 0 {
            bail!("[fault] epoch = 0: epoch length must be >= 1 cycle");
        }
        Ok(Some(plan))
    }

    /// `[run]` knobs: workers (0 = roofline-optimal), tiles, steps,
    /// decomposition kind (`decomp = "slab|pencil|block|auto"`),
    /// simulator core (`sim_core = "dense|event"`), §IV fuse mode
    /// (`fuse = "host|spatial|auto"`, default auto), halo mode
    /// (`halo = "exchange|reload"`, default exchange), deterministic
    /// tracing (`trace = "record PATH"` / `"replay PATH"`; validated by
    /// `TraceMode::parse` at use), a wall-clock run deadline
    /// (`deadline = MILLISECONDS`), and the `[fault]` injection plan.
    pub fn run_params(&self) -> Result<RunParams> {
        let decomp = match self.get("run", "decomp") {
            None => DecompKind::Auto,
            Some(v) => DecompKind::parse(v)?,
        };
        let sim_core = match self.get("run", "sim_core") {
            None => SimCore::default(),
            Some(v) => SimCore::parse(v)?,
        };
        let fuse = match self.get("run", "fuse") {
            None => FuseMode::Auto,
            Some(v) => FuseMode::parse(v)?,
        };
        let halo = match self.get("run", "halo") {
            None => HaloMode::default(),
            Some(v) => HaloMode::parse(v)?,
        };
        let check = match self.get("run", "check") {
            None => CheckLevel::default(),
            Some(v) => CheckLevel::parse(v)?,
        };
        let deadline_ms = match self.get("run", "deadline") {
            None => None,
            Some(v) => {
                let ms: u64 = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("[run] deadline = {v}: {e}"))?;
                if ms == 0 {
                    bail!("[run] deadline = 0: a zero deadline cancels every run at submit");
                }
                Some(ms)
            }
        };
        Ok(RunParams {
            workers: self.num("run", "workers", 0usize)?,
            tiles: self.num("run", "tiles", 1usize)?,
            steps: self.num("run", "steps", 1usize)?,
            seed: self.num("run", "seed", 42u64)?,
            decomp,
            sim_core,
            fuse,
            halo,
            check,
            trace: self.get("run", "trace").map(|s| s.to_string()),
            deadline_ms,
            fault: self.fault_plan()?,
        })
    }

    /// [`CompileOptions`] for this config: the `[machine]` section plus
    /// the compile-relevant `[run]` knobs — the config-file twin of the
    /// CLI's `CompileOptions::from_args`.
    pub fn compile_options(&self) -> Result<CompileOptions> {
        let p = self.run_params()?;
        Ok(CompileOptions {
            machine: self.machine()?,
            workers: p.workers,
            tiles: p.tiles,
            fabric_tokens: decomp::DEFAULT_FABRIC_TOKENS,
            decomp: p.decomp,
            fuse: p.fuse,
            halo: p.halo,
            check: p.check,
        })
    }
}

/// `[run]` section contents.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunParams {
    /// 0 means "choose via the §VI roofline".
    pub workers: usize,
    pub tiles: usize,
    pub steps: usize,
    pub seed: u64,
    /// Multi-tile cut strategy.
    pub decomp: DecompKind,
    /// Simulator scheduler core (bit-identical; `event` is the default).
    pub sim_core: SimCore,
    /// §IV temporal traversal for multi-step runs (default auto: fuse
    /// spatially when the fabric budget admits depth >= 2).
    pub fuse: FuseMode,
    /// Chunk-boundary halo movement (default exchange: in-fabric
    /// channels, no redundant DRAM reads after the cold chunk).
    pub halo: HaloMode,
    /// Static-analysis level the compile runs at
    /// (`check = "off|errors|full"`, default per build profile).
    pub check: CheckLevel,
    /// Deterministic trace capture/replay: `record PATH` or
    /// `replay PATH` (see [`crate::util::trace::TraceMode`]); `None`
    /// runs untraced.
    pub trace: Option<String>,
    /// Wall-clock run deadline in milliseconds; `None` runs unbounded.
    /// On expiry in-flight tile tasks are cancelled and the run
    /// reports a partial [`crate::session::Outcome::DeadlineExceeded`].
    pub deadline_ms: Option<u64>,
    /// Deterministic fault-injection plan from `[fault]`; `None` (or
    /// an unarmed plan) runs fault-free with zero hot-path overhead.
    pub fault: Option<FaultPlan>,
}

impl Default for RunParams {
    /// The flag-free defaults every entry point shares: roofline-picked
    /// workers, one tile, one step, seed 42, auto decomposition/fusion,
    /// event core.
    fn default() -> Self {
        Self {
            workers: 0,
            tiles: 1,
            steps: 1,
            seed: 42,
            decomp: DecompKind::Auto,
            sim_core: SimCore::default(),
            fuse: FuseMode::Auto,
            halo: HaloMode::default(),
            check: CheckLevel::default(),
            trace: None,
            deadline_ms: None,
            fault: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample experiment
[machine]
clock_ghz = 1.2
mac_pes = 256
bw_gbps = 100  # one tile

[stencil]
preset = "paper2d"

[run]
workers = 5
tiles = 16
"#;

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("machine", "mac_pes"), Some("256"));
        assert_eq!(c.get("run", "tiles"), Some("16"));
        assert_eq!(c.get("machine", "bw_gbps"), Some("100"));
    }

    #[test]
    fn machine_round_trip() {
        let c = Config::parse(SAMPLE).unwrap();
        let m = c.machine().unwrap();
        assert_eq!(m.mac_pes, 256);
        assert!((m.peak_gflops() - 614.4).abs() < 0.1);
    }

    #[test]
    fn stencil_preset() {
        let c = Config::parse(SAMPLE).unwrap();
        let s = c.stencil().unwrap();
        assert_eq!(s.points(), 49);
    }

    #[test]
    fn explicit_stencil_params() {
        let c = Config::parse("[stencil]\nnx = 128\nny = 64\nrx = 2\nry = 3\n").unwrap();
        let s = c.stencil().unwrap();
        assert_eq!((s.nx, s.ny, s.rx, s.ry), (128, 64, 2, 3));
    }

    #[test]
    fn explicit_3d_and_box_params() {
        let c = Config::parse(
            "[stencil]\nnx = 32\nny = 24\nnz = 16\nrx = 1\nry = 1\nrz = 1\n",
        )
        .unwrap();
        let s = c.stencil().unwrap();
        assert!(s.is_3d() && !s.is_box());
        assert_eq!((s.nx, s.ny, s.nz), (32, 24, 16));

        let c = Config::parse(
            "[stencil]\nshape = \"box\"\nnx = 32\nny = 24\nrx = 1\nry = 1\n",
        )
        .unwrap();
        let s = c.stencil().unwrap();
        assert!(s.is_box() && s.is_2d());
        assert_eq!(s.points(), 9);

        let c = Config::parse("[stencil]\npreset = \"heat3d\"\nnz = 16\n").unwrap();
        assert_eq!(c.stencil().unwrap().points(), 7);
    }

    #[test]
    fn radii_default_to_one_along_extended_dims() {
        // Naming only the extents must be enough for a 3-D spec.
        let c = Config::parse("[stencil]\nnx = 32\nny = 24\nnz = 16\n").unwrap();
        let s = c.stencil().unwrap();
        assert!(s.is_3d());
        assert_eq!((s.rx, s.ry, s.rz), (1, 1, 1));
    }

    #[test]
    fn defaults_when_sections_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.machine().unwrap(), Machine::paper());
        assert_eq!(c.run_params().unwrap().tiles, 1);
        assert_eq!(c.run_params().unwrap().decomp, DecompKind::Auto);
    }

    #[test]
    fn decomp_kind_parses_and_rejects() {
        let c = Config::parse("[run]\ndecomp = \"pencil\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().decomp, DecompKind::Pencil);
        let c = Config::parse("[run]\ndecomp = \"diagonal\"\n").unwrap();
        assert!(c.run_params().is_err());
    }

    #[test]
    fn sim_core_parses_defaults_and_rejects() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().sim_core, SimCore::Event);
        let c = Config::parse("[run]\nsim_core = \"dense\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().sim_core, SimCore::Dense);
        let c = Config::parse("[run]\nsim_core = \"quantum\"\n").unwrap();
        assert!(c.run_params().is_err());
    }

    #[test]
    fn fuse_mode_parses_defaults_and_rejects() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().fuse, FuseMode::Auto);
        let c = Config::parse("[run]\nfuse = \"spatial\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().fuse, FuseMode::Spatial);
        let c = Config::parse("[run]\nfuse = \"host\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().fuse, FuseMode::Host);
        let c = Config::parse("[run]\nfuse = \"temporal\"\n").unwrap();
        assert!(c.run_params().is_err());
    }

    #[test]
    fn halo_mode_parses_defaults_and_rejects() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().halo, HaloMode::Exchange);
        let c = Config::parse("[run]\nhalo = \"reload\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().halo, HaloMode::Reload);
        let c = Config::parse("[run]\nhalo = \"exchange\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().halo, HaloMode::Exchange);
        let c = Config::parse("[run]\nhalo = \"exchange-free\"\n").unwrap();
        assert_eq!(c.run_params().unwrap().halo, HaloMode::ExchangeFree);
        let c = Config::parse("[run]\nhalo = \"teleport\"\n").unwrap();
        assert!(c.run_params().is_err());
    }

    #[test]
    fn degenerate_machine_toml_is_a_typed_rejection_not_a_panic() {
        // hops_per_cycle = 0 used to survive parsing and only blow up
        // as a divide-by-zero deep inside placement; the config
        // boundary now rejects it with the offending field named.
        for (toml, field) in [
            ("[machine]\nhops_per_cycle = 0\n", "hops_per_cycle"),
            ("[machine]\nlink_words_per_cycle = 0\n", "link_words_per_cycle"),
            ("[machine]\nclock_ghz = 0.0\n", "clock_ghz"),
            ("[machine]\nbw_gbps = -1.0\n", "bw_gbps"),
            ("[machine]\ngrid_rows = 0\n", "grid_rows"),
        ] {
            let c = Config::parse(toml).unwrap();
            let e = c.machine().unwrap_err().to_string();
            assert!(e.contains(field), "`{e}` should name `{field}`");
        }
        // The paper default (empty TOML) still passes validation.
        assert!(Config::parse("").unwrap().machine().is_ok());
    }

    #[test]
    fn trace_param_defaults_off_and_passes_through() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().trace, None);
        let c = Config::parse("[run]\ntrace = \"record /tmp/run.trace\"\n").unwrap();
        assert_eq!(
            c.run_params().unwrap().trace.as_deref(),
            Some("record /tmp/run.trace")
        );
    }

    #[test]
    fn compile_options_mirror_machine_and_run_sections() {
        let c = Config::parse(SAMPLE).unwrap();
        let o = c.compile_options().unwrap();
        assert_eq!(o.workers, 5);
        assert_eq!(o.tiles, 16);
        assert_eq!(o.machine.mac_pes, 256);
        assert_eq!(o.decomp, DecompKind::Auto);
        assert_eq!(o.fuse, FuseMode::Auto);
    }

    #[test]
    fn fault_section_builds_a_plan_and_validates_ranges() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().fault, None);

        let c = Config::parse("[fault]\nseed = 9\nfill = 20\nstall = 10\nextra = 4\n").unwrap();
        let p = c.run_params().unwrap().fault.unwrap();
        assert_eq!((p.seed, p.fill_fail_pct, p.stall_pct, p.stall_extra), (9, 20, 10, 4));
        assert!(p.armed());

        // A bare section is the unarmed default plan, not an error.
        let c = Config::parse("[fault]\n").unwrap();
        assert!(!c.run_params().unwrap().fault.unwrap().armed());

        for bad in [
            "[fault]\nfill = 101\n",
            "[fault]\nstall = 200\n",
            "[fault]\nextra = 100000\n",
            "[fault]\nepoch = 0\n",
            "[fault]\nfill = lots\n",
        ] {
            assert!(Config::parse(bad).unwrap().run_params().is_err(), "{bad}");
        }
    }

    #[test]
    fn deadline_parses_in_milliseconds_and_rejects_zero() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.run_params().unwrap().deadline_ms, None);
        let c = Config::parse("[run]\ndeadline = 1500\n").unwrap();
        assert_eq!(c.run_params().unwrap().deadline_ms, Some(1500));
        for bad in ["[run]\ndeadline = 0\n", "[run]\ndeadline = soon\n"] {
            assert!(Config::parse(bad).unwrap().run_params().is_err(), "{bad}");
        }
    }

    #[test]
    fn bad_line_is_error() {
        assert!(Config::parse("not a kv line").is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let c = Config::parse("[machine]\nmac_pes = many\n").unwrap();
        assert!(c.machine().is_err());
    }
}
