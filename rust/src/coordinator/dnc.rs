//! §IV — recursive divide-and-conquer decomposition and the hybrid
//! CPU + CGRA execution mode.
//!
//! "A recursive divide-and-conquer algorithm can be used to generate
//! small stencil subtasks which can then be offloaded to a CGRA. If
//! multiple CGRA chips are available, a hybrid CPU + CGRA algorithm can
//! be designed where multiple CPU cores sharing the same last level cache
//! can offload independent stencil tasks to the CGRAs."
//!
//! [`decompose`] splits the interior recursively (halving) until every
//! leaf fits `max_width`, producing cache-friendly, fabric-sized subtasks
//! in recursion order. [`HybridRunner`] executes a decomposition with
//! `tiles` simulated-CGRA executors plus optional CPU executors that
//! compute leftover strips natively — demonstrating the work-stealing
//! behaviour of the shared queue.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::cgra::Machine;
use crate::stencil::blocking::Strip;
use crate::stencil::StencilSpec;
use crate::verify::golden::{run_sim, stencil_ref};

/// Recursively split the output interval `[rx, nx-rx)` until each leaf is
/// at most `max_width` wide. Leaves carry `rx`-wide halos like
/// [`crate::stencil::blocking::strips_for_width`], but boundaries follow
/// the recursion (power-of-two-ish), which is what keeps the CPU-side
/// working sets nested inside shared caches (§IV).
pub fn decompose(spec: &StencilSpec, max_width: usize) -> Vec<Strip> {
    fn rec(lo: usize, hi: usize, rx: usize, max_width: usize, out: &mut Vec<Strip>) {
        if hi - lo <= max_width {
            out.push(Strip {
                out_lo: lo,
                out_hi: hi,
                in_lo: lo - rx,
                in_hi: hi + rx,
            });
        } else {
            let mid = lo + (hi - lo) / 2;
            rec(lo, mid, rx, max_width, out);
            rec(mid, hi, rx, max_width, out);
        }
    }
    let mut out = Vec::new();
    rec(spec.rx, spec.nx - spec.rx, spec.rx, max_width.max(1), &mut out);
    out
}

/// Which executor handled a strip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    Cgra(usize),
    Cpu(usize),
}

/// Outcome of a hybrid run.
#[derive(Debug, Clone)]
pub struct HybridReport {
    pub output: Vec<f64>,
    pub assignments: Vec<(usize, Executor)>,
    pub cgra_strips: usize,
    pub cpu_strips: usize,
    /// Parallel makespan over the CGRA tiles (cycles); CPU work is
    /// accounted separately (it runs on the host, not the fabric).
    pub makespan_cycles: u64,
}

/// Hybrid CPU + CGRA executor pool over a shared work queue.
pub struct HybridRunner {
    pub machine: Machine,
    pub tiles: usize,
    pub cpu_workers: usize,
}

impl HybridRunner {
    pub fn new(tiles: usize, cpu_workers: usize, machine: Machine) -> Self {
        Self {
            machine,
            tiles,
            cpu_workers,
        }
    }

    /// Execute `strips` of a 2-D stencil; CGRA tiles simulate, CPU
    /// workers compute natively. Both pull from the same queue (work
    /// stealing); results merge identically.
    pub fn run(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        strips: Vec<Strip>,
    ) -> Result<HybridReport> {
        ensure!(!spec.is_1d(), "hybrid runner demonstrates the 2-D case");
        let queue: Arc<Mutex<VecDeque<(usize, Strip)>>> =
            Arc::new(Mutex::new(strips.iter().copied().enumerate().collect()));
        let (tx, rx) = mpsc::channel();
        let mut handles = Vec::new();

        for t in 0..self.tiles {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let machine = self.machine.clone();
            let spec = spec.clone();
            let input = input.to_vec();
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some((id, s)) = item else { break };
                    let sub = spec.strip(s.in_lo, s.in_hi);
                    let sub_in = extract(&spec, &input, &s);
                    let res = run_sim(&sub, w, &machine, &sub_in)?;
                    tx.send((id, s, Executor::Cgra(t), res.output, res.stats.cycles))
                        .ok();
                }
                Ok(())
            }));
        }
        for c in 0..self.cpu_workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let spec = spec.clone();
            let input = input.to_vec();
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some((id, s)) = item else { break };
                    let sub = spec.strip(s.in_lo, s.in_hi);
                    let sub_in = extract(&spec, &input, &s);
                    let out = stencil_ref(&sub_in, &sub);
                    tx.send((id, s, Executor::Cpu(c), out, 0)).ok();
                }
                Ok(())
            }));
        }
        drop(tx);

        let mut output = input.to_vec();
        let mut assignments = Vec::new();
        let mut tile_cycles = vec![0u64; self.tiles];
        let (mut cgra_strips, mut cpu_strips) = (0usize, 0usize);
        for (id, s, exec, sub_out, cycles) in rx {
            merge(spec, &mut output, &s, &sub_out);
            match exec {
                Executor::Cgra(t) => {
                    cgra_strips += 1;
                    tile_cycles[t] += cycles;
                }
                Executor::Cpu(_) => cpu_strips += 1,
            }
            assignments.push((id, exec));
        }
        for h in handles {
            h.join().expect("executor thread panicked")?;
        }
        assignments.sort_by_key(|(id, _)| *id);
        Ok(HybridReport {
            output,
            assignments,
            cgra_strips,
            cpu_strips,
            makespan_cycles: tile_cycles.into_iter().max().unwrap_or(0),
        })
    }
}

fn extract(spec: &StencilSpec, input: &[f64], s: &Strip) -> Vec<f64> {
    let mut out = Vec::with_capacity(s.in_width() * spec.ny);
    for row in 0..spec.ny {
        out.extend_from_slice(&input[row * spec.nx + s.in_lo..row * spec.nx + s.in_hi]);
    }
    out
}

fn merge(spec: &StencilSpec, global: &mut [f64], s: &Strip, sub_out: &[f64]) {
    let sub_nx = s.in_width();
    for row in spec.ry..spec.ny - spec.ry {
        let src = &sub_out[row * sub_nx + spec.rx..row * sub_nx + spec.rx + s.out_width()];
        global[row * spec.nx + s.out_lo..row * spec.nx + s.out_hi].copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::verify::golden::max_abs_diff;

    #[test]
    fn decompose_covers_interior_disjointly() {
        let spec = StencilSpec::paper_2d();
        for mw in [50, 128, 936, 2000] {
            let strips = decompose(&spec, mw);
            assert_eq!(strips[0].out_lo, spec.rx);
            assert_eq!(strips.last().unwrap().out_hi, spec.nx - spec.rx);
            for p in strips.windows(2) {
                assert_eq!(p[0].out_hi, p[1].out_lo);
            }
            for s in &strips {
                assert!(s.out_width() <= mw);
            }
        }
    }

    #[test]
    fn decompose_halves_recursively() {
        let spec = StencilSpec::dim2(
            100,
            12,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(1),
        )
        .unwrap();
        // Interior 96 with max 24 -> 4 leaves of 24.
        let strips = decompose(&spec, 24);
        assert_eq!(strips.len(), 4);
        assert!(strips.iter().all(|s| s.out_width() == 24));
    }

    #[test]
    fn hybrid_run_matches_oracle_and_uses_both_executors() {
        let spec = StencilSpec::heat2d(60, 14, 0.2);
        let mut rng = XorShift::new(0xFACE);
        let x = rng.normal_vec(60 * 14);
        let strips = decompose(&spec, 8); // 8 leaves -> contention
        let runner = HybridRunner::new(2, 2, Machine::paper());
        let rep = runner.run(&spec, 2, &x, strips).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
        assert_eq!(rep.cgra_strips + rep.cpu_strips, rep.assignments.len());
        // With a slow simulator and fast CPU oracle both should get work;
        // at minimum the counts must be consistent.
        assert!(rep.cgra_strips + rep.cpu_strips >= 8);
    }
}
