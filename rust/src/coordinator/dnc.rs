//! §IV — recursive divide-and-conquer decomposition and the hybrid
//! CPU + CGRA execution mode.
//!
//! "A recursive divide-and-conquer algorithm can be used to generate
//! small stencil subtasks which can then be offloaded to a CGRA. If
//! multiple CGRA chips are available, a hybrid CPU + CGRA algorithm can
//! be designed where multiple CPU cores sharing the same last level cache
//! can offload independent stencil tasks to the CGRAs."
//!
//! [`decompose`] splits the interior box recursively (halving the
//! longest axis) until every leaf's output extent fits `max_extent`,
//! producing cache-friendly, fabric-sized subtasks in recursion order.
//! [`HybridRunner`] executes a decomposition with `tiles` simulated-CGRA
//! executors plus optional CPU executors that compute leftover tiles
//! natively — demonstrating the work-stealing behaviour of the shared
//! queue.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cgra::{Machine, SimCore, Simulator};
use crate::compile;
use crate::stencil::decomp::Tile;
use crate::stencil::StencilSpec;
use crate::verify::golden::stencil_ref;

/// Recursively bisect the interior box until every leaf's output extent
/// along every axis is at most `max_extent`. Leaves carry radius-wide
/// halos like [`crate::stencil::decomp::tiles_for_cuts`], but boundaries
/// follow the recursion (power-of-two-ish), which is what keeps the
/// CPU-side working sets nested inside shared caches (§IV).
pub fn decompose(spec: &StencilSpec, max_extent: usize) -> Vec<Tile> {
    fn rec(
        lo: [usize; 3],
        hi: [usize; 3],
        r: [usize; 3],
        max_extent: usize,
        out: &mut Vec<Tile>,
    ) {
        // Split the longest axis still exceeding the leaf size.
        let mut axis = None;
        let mut best = max_extent;
        for a in 0..3 {
            if hi[a] - lo[a] > best {
                best = hi[a] - lo[a];
                axis = Some(a);
            }
        }
        match axis {
            None => out.push(Tile::with_halo(lo, hi, r)),
            Some(a) => {
                let mid = lo[a] + (hi[a] - lo[a]) / 2;
                let mut first_hi = hi;
                first_hi[a] = mid;
                let mut second_lo = lo;
                second_lo[a] = mid;
                rec(lo, first_hi, r, max_extent, out);
                rec(second_lo, hi, r, max_extent, out);
            }
        }
    }
    let r = [spec.rx, spec.ry, spec.rz];
    let n = [spec.nx, spec.ny, spec.nz];
    let lo = r;
    let hi = [n[0] - r[0], n[1] - r[1], n[2] - r[2]];
    let mut out = Vec::new();
    rec(lo, hi, r, max_extent.max(1), &mut out);
    out
}

/// Which executor handled a tile task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    Cgra(usize),
    Cpu(usize),
}

/// Outcome of a hybrid run.
#[derive(Debug, Clone)]
pub struct HybridReport {
    pub output: Vec<f64>,
    pub assignments: Vec<(usize, Executor)>,
    pub cgra_strips: usize,
    pub cpu_strips: usize,
    /// Parallel makespan over the CGRA tiles (cycles); CPU work is
    /// accounted separately (it runs on the host, not the fabric).
    pub makespan_cycles: u64,
}

/// Hybrid CPU + CGRA executor pool over a shared work queue.
pub struct HybridRunner {
    pub machine: Machine,
    pub tiles: usize,
    pub cpu_workers: usize,
    /// Scheduler core the CGRA executors simulate with.
    pub sim_core: SimCore,
}

impl HybridRunner {
    pub fn new(tiles: usize, cpu_workers: usize, machine: Machine) -> Self {
        Self {
            machine,
            tiles,
            cpu_workers,
            sim_core: SimCore::default(),
        }
    }

    /// Override the simulator core (builder style).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Execute `tiles` of a stencil (any dimensionality); CGRA tiles
    /// simulate, CPU workers compute natively. Both pull from the same
    /// queue (work stealing); results merge identically. The CGRA side
    /// shares the compile phase's placed graphs: one placement per
    /// distinct tile shape up front, zero mapping work per pull.
    pub fn run(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        tiles: Vec<Tile>,
    ) -> Result<HybridReport> {
        let graphs = Arc::new(compile::placed_graphs(spec, w, 1, &tiles, &self.machine)?);
        let queue: Arc<Mutex<VecDeque<(usize, Tile)>>> =
            Arc::new(Mutex::new(tiles.iter().copied().enumerate().collect()));
        let (tx, rx) = mpsc::channel();
        let mut handles = Vec::new();

        for t in 0..self.tiles {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let machine = self.machine.clone();
            let spec = spec.clone();
            let input = input.to_vec();
            let core = self.sim_core;
            let graphs = Arc::clone(&graphs);
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some((id, tile)) = item else { break };
                    let sub_in = tile.extract(&spec, &input);
                    let pg = &graphs
                        [&[tile.in_extent(0), tile.in_extent(1), tile.in_extent(2)]];
                    let res = Simulator::from_placed(pg, &machine, sub_in.clone(), sub_in)
                        .with_core(core)
                        .run()?;
                    tx.send((id, tile, Executor::Cgra(t), res.output, res.stats.cycles))
                        .ok();
                }
                Ok(())
            }));
        }
        for c in 0..self.cpu_workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let spec = spec.clone();
            let input = input.to_vec();
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some((id, tile)) = item else { break };
                    let sub = tile.sub_spec(&spec);
                    let sub_in = tile.extract(&spec, &input);
                    let out = stencil_ref(&sub_in, &sub);
                    tx.send((id, tile, Executor::Cpu(c), out, 0)).ok();
                }
                Ok(())
            }));
        }
        drop(tx);

        let mut output = input.to_vec();
        let mut assignments = Vec::new();
        let mut tile_cycles = vec![0u64; self.tiles];
        let (mut cgra_strips, mut cpu_strips) = (0usize, 0usize);
        for (id, tile, exec, sub_out, cycles) in rx {
            tile.merge(spec, &mut output, &sub_out);
            match exec {
                Executor::Cgra(t) => {
                    cgra_strips += 1;
                    tile_cycles[t] += cycles;
                }
                Executor::Cpu(_) => cpu_strips += 1,
            }
            assignments.push((id, exec));
        }
        for h in handles {
            h.join().expect("executor thread panicked")?;
        }
        assignments.sort_by_key(|(id, _)| *id);
        Ok(HybridReport {
            output,
            assignments,
            cgra_strips,
            cpu_strips,
            makespan_cycles: tile_cycles.into_iter().max().unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::verify::golden::max_abs_diff;

    #[test]
    fn decompose_covers_interior_disjointly() {
        let spec = StencilSpec::paper_2d();
        for me in [50, 128, 936, 2000] {
            let tiles = decompose(&spec, me);
            assert_eq!(tiles[0].out_lo[0], spec.rx);
            assert_eq!(tiles.last().unwrap().out_hi[0], spec.nx - spec.rx);
            let total: usize = tiles.iter().map(|t| t.out_points()).sum();
            assert_eq!(total, spec.interior_outputs(), "max_extent={me}");
            for t in &tiles {
                for a in 0..3 {
                    assert!(t.out_extent(a) <= me);
                }
            }
            // Pairwise disjoint output boxes.
            for (i, a) in tiles.iter().enumerate() {
                for b in tiles.iter().skip(i + 1) {
                    let overlap = (0..3).all(|ax| {
                        a.out_lo[ax] < b.out_hi[ax] && b.out_lo[ax] < a.out_hi[ax]
                    });
                    assert!(!overlap, "leaves overlap");
                }
            }
        }
    }

    #[test]
    fn decompose_halves_recursively() {
        let spec = StencilSpec::dim2(
            100,
            12,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(1),
        )
        .unwrap();
        // Interior 96 x 10 with max 24 -> x splits into 4, y untouched.
        let tiles = decompose(&spec, 24);
        assert_eq!(tiles.len(), 4);
        assert!(tiles.iter().all(|t| t.out_extent(0) == 24));
        assert!(tiles.iter().all(|t| t.out_extent(1) == 10));
    }

    #[test]
    fn decompose_splits_all_axes_of_a_volume() {
        let spec = StencilSpec::heat3d(20, 20, 20, 0.1); // interior 18^3
        let tiles = decompose(&spec, 9);
        assert_eq!(tiles.len(), 8, "each axis halves once");
        let total: usize = tiles.iter().map(|t| t.out_points()).sum();
        assert_eq!(total, spec.interior_outputs());
    }

    #[test]
    fn hybrid_run_matches_oracle_and_uses_both_executors() {
        let spec = StencilSpec::heat2d(60, 14, 0.2);
        let mut rng = XorShift::new(0xFACE);
        let x = rng.normal_vec(60 * 14);
        let tiles = decompose(&spec, 8); // many leaves -> contention
        let n_tiles = tiles.len();
        assert!(n_tiles >= 8);
        let runner = HybridRunner::new(2, 2, Machine::paper());
        let rep = runner.run(&spec, 2, &x, tiles).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
        assert_eq!(rep.cgra_strips + rep.cpu_strips, rep.assignments.len());
        // With a slow simulator and fast CPU oracle both should get work;
        // at minimum the counts must be consistent.
        assert_eq!(rep.cgra_strips + rep.cpu_strips, n_tiles);
    }

    #[test]
    fn hybrid_run_covers_3d_volumes() {
        let spec = StencilSpec::heat3d(12, 9, 7, 0.1);
        let mut rng = XorShift::new(0xB10C);
        let x = rng.normal_vec(12 * 9 * 7);
        let tiles = decompose(&spec, 5);
        let runner = HybridRunner::new(1, 1, Machine::paper());
        let rep = runner.run(&spec, 2, &x, tiles).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }
}
