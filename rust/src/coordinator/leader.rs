//! Legacy one-call coordinator — now a thin shim over the
//! compile-once/execute-many API.
//!
//! [`Coordinator`] predates the [`mod@crate::compile`]/[`crate::session`]
//! split: every call re-planned the decomposition and rebuilt the tile
//! DFGs. It survives as a deprecated convenience wrapper that compiles
//! an artifact and executes it through a [`Session`] in one breath —
//! byte-for-byte the same plans, graphs and results as the two-phase
//! API, because it *is* the two-phase API. New code (and anything on a
//! serve path) should call [`crate::compile::compile`] once and reuse
//! the [`crate::compile::CompiledStencil`] across runs instead.

use std::sync::Arc;

use anyhow::Result;

use crate::analysis::CheckLevel;
use crate::cgra::{Machine, SimCore};
use crate::compile::{self, CompileOptions};
use crate::session::{RunReport, Session};
use crate::stencil::decomp::{self, DecompKind, DecompPlan};
use crate::stencil::StencilSpec;

pub use crate::compile::{FuseMode, HaloMode};
pub use crate::session::{TileReport, TileTask};

/// Deprecated one-call wrapper around [`compile`](crate::compile::compile)
/// + [`Session`]: each `run`/`run_steps` compiles a fresh artifact and
/// executes it once. Prefer the two-phase API wherever the same
/// workload runs more than once.
#[derive(Debug, Clone)]
pub struct Coordinator {
    pub machine: Machine,
    pub tiles: usize,
    /// On-fabric token budget per tile (drives the decomposition).
    pub fabric_tokens: usize,
    /// Cut strategy ([`DecompKind::Auto`] picks per dimensionality).
    pub decomp: DecompKind,
    /// Scheduler core every tile simulation runs on (bit-identical
    /// either way; `Event` is the default and the fast one).
    pub sim_core: SimCore,
    /// How [`Self::run_steps`] traverses time.
    pub fuse: FuseMode,
    /// How chunk-boundary halos move: in-fabric exchange or DRAM reload.
    pub halo: HaloMode,
}

impl Coordinator {
    pub fn new(tiles: usize, machine: Machine) -> Self {
        Self {
            machine,
            tiles,
            fabric_tokens: decomp::DEFAULT_FABRIC_TOKENS,
            decomp: DecompKind::Auto,
            sim_core: SimCore::default(),
            fuse: FuseMode::default(),
            halo: HaloMode::default(),
        }
    }

    /// The Table-I configuration: 16 tiles of the §VI machine.
    pub fn paper() -> Self {
        Self::new(16, Machine::paper())
    }

    /// Override the cut strategy (builder style).
    pub fn with_decomp(mut self, kind: DecompKind) -> Self {
        self.decomp = kind;
        self
    }

    /// Override the simulator core (builder style).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the §IV fuse mode (builder style).
    pub fn with_fuse(mut self, fuse: FuseMode) -> Self {
        self.fuse = fuse;
        self
    }

    /// Override the halo mode (builder style).
    pub fn with_halo(mut self, halo: HaloMode) -> Self {
        self.halo = halo;
        self
    }

    /// The [`CompileOptions`] equivalent of this coordinator's builder
    /// state — the bridge old call sites cross to the new API.
    pub fn compile_options(&self, w: usize) -> CompileOptions {
        CompileOptions {
            machine: self.machine.clone(),
            workers: w,
            tiles: self.tiles,
            fabric_tokens: self.fabric_tokens,
            decomp: self.decomp,
            fuse: self.fuse,
            halo: self.halo,
            check: CheckLevel::default(),
        }
    }

    /// Plan the decomposition: enough tiles to feed the array, each
    /// small enough to fit the per-tile fabric budget.
    pub fn plan(&self, spec: &StencilSpec, w: usize) -> Result<DecompPlan> {
        decomp::plan(spec, w, self.fabric_tokens, self.decomp, self.tiles)
    }

    fn session(&self, spec: &StencilSpec, w: usize, steps: usize) -> Result<Session> {
        let compiled = compile::compile(spec, steps, &self.compile_options(w))?;
        Ok(Session::new(Arc::new(compiled), self.machine.clone()).with_sim_core(self.sim_core))
    }

    /// Run one stencil application across the tile array: compile a
    /// single-step artifact and execute it once. Supports any spec the
    /// mapper supports: 1-D, 2-D and 3-D, star or box.
    pub fn run(&self, spec: &StencilSpec, w: usize, input: &[f64]) -> Result<RunReport> {
        let outcome = self.session(spec, w, 1)?.run(input)?;
        Ok(outcome.reports.into_iter().next().expect("one chunk for one step"))
    }

    /// Multi-step run: compile a `steps`-deep artifact (the [`FuseMode`]
    /// decides the schedule — host-driven steps or §IV fused chunks with
    /// a shallower tail) and execute it once. Returns the final grid and
    /// one [`RunReport`] per executed chunk.
    pub fn run_steps(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        steps: usize,
    ) -> Result<(Vec<f64>, Vec<RunReport>)> {
        if steps == 0 {
            return Ok((input.to_vec(), Vec::new()));
        }
        let outcome = self.session(spec, w, steps)?.run(input)?;
        Ok((outcome.output, outcome.reports))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::temporal;
    use crate::util::rng::XorShift;
    use crate::verify::golden::{
        max_abs_diff, stencil1d_ref, stencil2d_ref, stencil_ref, stencil_ref_steps,
    };

    #[test]
    fn multitile_2d_matches_oracle() {
        let spec = StencilSpec::dim2(
            64,
            20,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let mut rng = XorShift::new(0xC0DE);
        let x = rng.normal_vec(64 * 20);
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.strips >= 4);
        assert_eq!(rep.kind, DecompKind::Slab);
        assert!(rep.halo_points > 0, "multi-tile runs re-read halos");
        assert!(rep.redundant_read_fraction > 0.0);
        let want = stencil2d_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
        // All tasks landed on some tile (pull-based balancing may let a
        // fast tile take most of a small queue, so >=1 tile is the only
        // portable claim).
        let used = rep.per_tile.iter().filter(|t| t.strips > 0).count();
        assert!(used >= 1);
        assert_eq!(
            rep.per_tile.iter().map(|t| t.strips).sum::<usize>(),
            rep.strips
        );
        assert_eq!(
            rep.per_tile.iter().map(|t| t.halo_points).sum::<u64>(),
            rep.halo_points
        );
    }

    #[test]
    fn multitile_1d_matches_oracle() {
        let spec = StencilSpec::dim1(300, crate::stencil::spec::symmetric_taps(4)).unwrap();
        let mut rng = XorShift::new(0xD00D);
        let x = rng.normal_vec(300);
        let coord = Coordinator::new(3, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        let want = stencil1d_ref(&x, &spec.cx);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }

    #[test]
    fn multitile_3d_matches_oracle() {
        let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
        let mut rng = XorShift::new(0x3D0);
        let x = rng.normal_vec(12 * 10 * 8);
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.strips > 1, "3-D grids decompose multi-tile now");
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }

    #[test]
    fn makespan_not_exceeding_total() {
        let spec = StencilSpec::heat2d(40, 16, 0.2);
        let x = vec![1.0; 40 * 16];
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.makespan_cycles <= rep.total_cycles);
        assert!(rep.makespan_cycles > 0);
        assert!(rep.gflops > 0.0);
    }

    #[test]
    fn run_steps_equals_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let coord = Coordinator::new(2, Machine::paper());
        let (out, reports) = coord.run_steps(&spec, 2, &x, 3).unwrap();
        assert_eq!(reports.len(), 3);
        // Every step's report keeps its own output (the residual-curve
        // contract the examples rely on).
        assert_eq!(reports[2].output, out);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out, &want) < 1e-11);
    }

    #[test]
    fn fused_run_steps_matches_oracle_on_full_grid() {
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let mut rng = XorShift::new(0xF0F0);
        let x = rng.normal_vec(24 * 16);
        let steps = 4;
        let host = Coordinator::new(2, Machine::paper()).with_fuse(FuseMode::Host);
        let (_, hreps) = host.run_steps(&spec, 2, &x, steps).unwrap();
        let fused = Coordinator::new(2, Machine::paper()).with_fuse(FuseMode::Spatial);
        let (fout, freps) = fused.run_steps(&spec, 2, &x, steps).unwrap();
        assert_eq!(freps.iter().map(|r| r.fused_steps).sum::<usize>(), steps);
        assert!(freps.len() < hreps.len(), "fusion must shrink the chunk count");
        // Bitwise equality against the iterated oracle on the FULL grid:
        // the trapezoid covers the valid box, the time-tiled ring stages
        // cover the boundary ring, and the frame is the Dirichlet copy.
        let want = crate::verify::golden::stencil_ref_steps(&spec, &x, steps);
        for y in 0..spec.ny {
            for c in 0..spec.nx {
                let i = y * spec.nx + c;
                assert_eq!(fout[i], want[i], "y={y} c={c}");
            }
        }
        // The chunks did compute a ring (depth > 1 somewhere).
        assert!(freps.iter().any(|r| r.ring_points > 0));
        let ring_expect: u64 = freps
            .iter()
            .map(|r| temporal::ring_point_count(&spec, r.fused_steps) as u64)
            .sum();
        assert_eq!(freps.iter().map(|r| r.ring_points).sum::<u64>(), ring_expect);
        // §IV data reuse: strictly fewer loads than the host loop.
        let host_loads: u64 = hreps.iter().map(|r| r.total_loads()).sum();
        let fused_loads: u64 = freps.iter().map(|r| r.total_loads()).sum();
        assert!(fused_loads < host_loads, "{fused_loads} !< {host_loads}");
    }

    #[test]
    fn auto_fuse_falls_back_to_host_when_grid_cannot_deepen() {
        // 4-wide grid, r = 1: the trapezoid admits only depth 1, so Auto
        // must take the host path (one report per step, depth 1 each).
        let spec = StencilSpec::heat2d(4, 4, 0.2);
        let x = vec![1.0; 16];
        let coord = Coordinator::new(1, Machine::paper()).with_fuse(FuseMode::Auto);
        let (_, reports) = coord.run_steps(&spec, 1, &x, 2).unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.fused_steps == 1));
    }

    #[test]
    fn single_tile_still_works() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let x = vec![0.5; 160];
        let coord = Coordinator::new(1, Machine::paper());
        let rep = coord.run(&spec, 1, &x).unwrap();
        assert_eq!(rep.per_tile[0].strips, rep.strips);
        assert_eq!(rep.halo_points, 0, "one tile loads no halo");
    }

    #[test]
    fn rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let coord = Coordinator::new(1, Machine::paper());
        assert!(coord.run(&spec, 1, &[0.0; 3]).is_err());
    }

    #[test]
    fn shim_equals_two_phase_api_bitwise() {
        // The coordinator is the compile+session API; pin it.
        let spec = StencilSpec::heat2d(28, 14, 0.2);
        let mut rng = XorShift::new(0x2FA5);
        let x = rng.normal_vec(28 * 14);
        let coord = Coordinator::new(2, Machine::paper());
        let (out, reports) = coord.run_steps(&spec, 2, &x, 2).unwrap();
        let compiled = compile::compile(&spec, 2, &coord.compile_options(2)).unwrap();
        let session = Session::new(Arc::new(compiled), Machine::paper());
        let outcome = session.run(&x).unwrap();
        assert_eq!(outcome.output, out);
        assert_eq!(outcome.reports.len(), reports.len());
        for (a, b) in outcome.reports.iter().zip(&reports) {
            assert_eq!(a.output, b.output);
            assert_eq!(a.makespan_cycles, b.makespan_cycles);
        }
    }
}
