//! Leader/worker execution of tile tasks over simulated CGRA tiles.
//!
//! The leader decomposes the grid into halo-padded N-dim tiles
//! ([`crate::stencil::decomp`]), pushes [`TileTask`]s into a shared
//! queue, and spawns one OS thread per hardware tile. Tiles pull
//! greedily (natural load balancing — the same work-stealing effect
//! §IV's hybrid algorithm relies on), simulate, and send results back
//! over a channel. The leader merges owned outputs into the global grid
//! and accounts per-tile cycles; the reported makespan is the slowest
//! tile's total, which is what 16 parallel tiles would take on silicon.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::{Machine, SimCore, Simulator};
use crate::dfg::Graph;
use crate::stencil::decomp::{self, DecompKind, DecompPlan, Tile};
use crate::stencil::{build_graph, temporal, StencilSpec};

/// How a multi-step run traverses time (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FuseMode {
    /// One decomposition pass per step: every step reads the grid from
    /// DRAM and writes it back (the paper's single-step use-case
    /// iterated by the host).
    #[default]
    Host,
    /// Fuse as many steps as the per-tile token budget admits into one
    /// spatial pipeline per tile ([`temporal::build_nd`]); the host
    /// loops over the fused chunks. Only the first layer loads and only
    /// the last layer stores, so DRAM traffic drops by ~the fused depth.
    Spatial,
    /// [`FuseMode::Spatial`] when the budget admits depth >= 2, else
    /// [`FuseMode::Host`].
    Auto,
}

impl FuseMode {
    /// Parse a CLI/config value (`host|spatial|auto`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "host" => FuseMode::Host,
            "spatial" => FuseMode::Spatial,
            "auto" => FuseMode::Auto,
            other => bail!("unknown fuse mode `{other}` (host|spatial|auto)"),
        })
    }
}

impl std::fmt::Display for FuseMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            FuseMode::Host => "host",
            FuseMode::Spatial => "spatial",
            FuseMode::Auto => "auto",
        })
    }
}

/// One unit of work: a halo-padded tile of the global grid.
#[derive(Debug, Clone)]
pub struct TileTask {
    pub id: usize,
    pub tile: Tile,
    /// Contiguous copy of the tile's input box.
    pub input: Vec<f64>,
    /// Pre-built DFG for the tile's shape — shared by every tile with
    /// the same input extents (the graph depends only on dims and `w`,
    /// not the data), so a 16-pencil plan builds at most a few graphs.
    pub graph: Arc<Graph>,
}

/// Per-hardware-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    /// Tile tasks executed on this hardware tile.
    pub strips: usize,
    /// Sum of simulated cycles over this tile's tasks.
    pub cycles: u64,
    /// Halo points this tile loaded beyond the outputs it owned.
    pub halo_points: u64,
    pub mem: MemStats,
}

/// Result of a coordinated run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    /// Number of tile tasks the decomposition produced.
    pub strips: usize,
    /// Resolved decomposition strategy.
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`.
    pub cuts: [usize; 3],
    /// §IV time-steps fused into each tile's pipeline this pass (1 =
    /// single-step; deeper fusion grows the per-tile halos by
    /// `radii * fused_steps` — visible in [`Self::halo_points`] — and
    /// divides the per-step DRAM traffic by the depth).
    pub fused_steps: usize,
    /// Total halo points loaded across tasks (redundant-load overhead).
    pub halo_points: u64,
    /// Fraction of the grid read more than once because of halo overlap.
    pub redundant_read_fraction: f64,
    /// Slowest tile's total cycles — the parallel makespan.
    pub makespan_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl RunReport {
    /// Total grid-point loads across the tile array — the §IV currency:
    /// a fused chunk loads its input once regardless of depth, so at
    /// equal total steps a spatially-fused run loads strictly less than
    /// the host-driven loop.
    pub fn total_loads(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.loads).sum()
    }
}

/// Multi-tile coordinator.
#[derive(Debug, Clone)]
pub struct Coordinator {
    pub machine: Machine,
    pub tiles: usize,
    /// On-fabric token budget per tile (drives the decomposition).
    pub fabric_tokens: usize,
    /// Cut strategy ([`DecompKind::Auto`] picks per dimensionality).
    pub decomp: DecompKind,
    /// Scheduler core every tile simulation runs on (bit-identical
    /// either way; `Event` is the default and the fast one).
    pub sim_core: SimCore,
    /// How [`Self::run_steps`] traverses time (default: host-driven).
    pub fuse: FuseMode,
}

impl Coordinator {
    pub fn new(tiles: usize, machine: Machine) -> Self {
        Self {
            machine,
            tiles,
            fabric_tokens: decomp::DEFAULT_FABRIC_TOKENS,
            decomp: DecompKind::Auto,
            sim_core: SimCore::default(),
            fuse: FuseMode::default(),
        }
    }

    /// The Table-I configuration: 16 tiles of the §VI machine.
    pub fn paper() -> Self {
        Self::new(16, Machine::paper())
    }

    /// Override the cut strategy (builder style).
    pub fn with_decomp(mut self, kind: DecompKind) -> Self {
        self.decomp = kind;
        self
    }

    /// Override the simulator core (builder style).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the §IV fuse mode (builder style).
    pub fn with_fuse(mut self, fuse: FuseMode) -> Self {
        self.fuse = fuse;
        self
    }

    /// Plan the decomposition: enough tiles to feed the array, each
    /// small enough to fit the per-tile fabric budget.
    pub fn plan(&self, spec: &StencilSpec, w: usize) -> Result<DecompPlan> {
        decomp::plan(spec, w, self.fabric_tokens, self.decomp, self.tiles)
    }

    /// One DFG per distinct tile shape in the plan: same-extent tiles
    /// share it (cloned only at simulator construction). Plans with a
    /// fused depth > 1 map each tile through the §IV temporal pipeline
    /// instead of the single-step mapper.
    fn build_graphs(
        &self,
        spec: &StencilSpec,
        w: usize,
        plan: &DecompPlan,
    ) -> Result<HashMap<[usize; 3], Arc<Graph>>> {
        let mut graphs: HashMap<[usize; 3], Arc<Graph>> = HashMap::new();
        for t in &plan.tiles {
            let dims = [t.in_extent(0), t.in_extent(1), t.in_extent(2)];
            if !graphs.contains_key(&dims) {
                let sub = t.sub_spec(spec);
                let g = if plan.fused_steps > 1 {
                    temporal::build_nd(&sub, w, plan.fused_steps)?
                } else {
                    build_graph(&sub, w)?
                };
                graphs.insert(dims, Arc::new(g));
            }
        }
        Ok(graphs)
    }

    /// Run one stencil application across the tile array. Supports any
    /// spec `build_graph` supports: 1-D, 2-D and 3-D, star or box.
    pub fn run(&self, spec: &StencilSpec, w: usize, input: &[f64]) -> Result<RunReport> {
        let plan = self.plan(spec, w)?;
        let graphs = self.build_graphs(spec, w, &plan)?;
        self.run_planned(spec, input, &plan, &graphs)
    }

    /// Execute a pre-planned decomposition with pre-built graphs — the
    /// shared core of [`Self::run`] and [`Self::run_steps`] (which plans
    /// and maps once across all steps).
    fn run_planned(
        &self,
        spec: &StencilSpec,
        input: &[f64],
        plan: &DecompPlan,
        graphs: &HashMap<[usize; 3], Arc<Graph>>,
    ) -> Result<RunReport> {
        ensure!(
            input.len() == spec.grid_points(),
            "input length {} != grid {}",
            input.len(),
            spec.grid_points()
        );
        let t0 = std::time::Instant::now();
        let tasks: VecDeque<TileTask> = plan
            .tiles
            .iter()
            .enumerate()
            .map(|(id, t)| TileTask {
                id,
                tile: *t,
                input: t.extract(spec, input),
                graph: Arc::clone(
                    &graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]],
                ),
            })
            .collect();
        let n_tasks = tasks.len();

        let queue = Arc::new(Mutex::new(tasks));
        let (tx, rx) = mpsc::channel();
        let mut handles = Vec::new();
        for tile_id in 0..self.tiles.min(n_tasks).max(1) {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let machine = self.machine.clone();
            let core = self.sim_core;
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let task = { queue.lock().unwrap().pop_front() };
                    let Some(task) = task else { break };
                    let res = Simulator::build(
                        task.graph.as_ref().clone(),
                        &machine,
                        task.input.clone(),
                        task.input,
                    )
                    .and_then(|sim| sim.with_core(core).run())
                    .with_context(|| format!("tile task {}", task.id))?;
                    tx.send((tile_id, task.tile, res)).ok();
                }
                Ok(())
            }));
        }
        drop(tx);

        // Merge owned outputs into the global grid (boundary = input copy).
        let mut output = input.to_vec();
        let mut per_tile = vec![TileReport::default(); self.tiles];
        let mut received = 0;
        for (tile_id, tile, res) in rx {
            tile.merge(spec, &mut output, &res.output);
            let rep = &mut per_tile[tile_id];
            rep.strips += 1;
            rep.cycles += res.stats.cycles;
            rep.halo_points += tile.halo_points() as u64;
            rep.mem.accumulate(&res.stats.mem);
            received += 1;
        }
        for h in handles {
            h.join().expect("tile thread panicked")?;
        }
        ensure!(received == n_tasks, "lost tile results: {received}/{n_tasks}");

        // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output;
        // fused plans sum the per-layer trapezoid interiors).
        let total_flops = temporal::total_flops(spec, plan.fused_steps);

        let makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
        let total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum();
        let gflops = if makespan > 0 {
            total_flops * self.machine.clock_ghz / makespan as f64
        } else {
            0.0
        };
        Ok(RunReport {
            output,
            strips: n_tasks,
            kind: plan.kind,
            cuts: plan.cuts,
            fused_steps: plan.fused_steps,
            halo_points: plan.halo_points() as u64,
            redundant_read_fraction: plan.redundant_read_fraction(spec),
            makespan_cycles: makespan,
            total_cycles,
            total_flops,
            per_tile,
            gflops,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Multi-step run. The [`FuseMode`] decides how time is traversed:
    ///
    /// * [`FuseMode::Host`] — one decomposition pass per step (full
    ///   DRAM round-trip between steps); one [`RunReport`] per step.
    /// * [`FuseMode::Spatial`] — §IV fused chunks: the decomposition
    ///   planner picks the deepest depth `T` the per-tile token budget
    ///   admits, each tile computes `T` steps on-fabric, and the host
    ///   loops over `ceil(steps / T)` chunks; one report per chunk
    ///   (`RunReport::fused_steps` tells its depth). The grid is valid
    ///   on [`temporal::valid_box`]`(spec, steps)` — the ring outside
    ///   it keeps chunk-input values (the trapezoid's price).
    /// * [`FuseMode::Auto`] — `Spatial` when the budget admits a depth
    ///   of at least 2, else `Host`.
    pub fn run_steps(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        steps: usize,
    ) -> Result<(Vec<f64>, Vec<RunReport>)> {
        if steps == 0 {
            return Ok((input.to_vec(), Vec::new()));
        }
        match self.fuse {
            FuseMode::Host => self.run_steps_host(spec, w, input, steps),
            FuseMode::Spatial => self.run_steps_fused(spec, w, input, steps, None),
            FuseMode::Auto => {
                let probe = decomp::plan_fused(
                    spec,
                    w,
                    self.fabric_tokens,
                    self.decomp,
                    self.tiles,
                    steps,
                )?;
                if probe.fused_steps > 1 {
                    // Hand the probe plan over as the first chunk's
                    // cache so it is not planned twice.
                    let graphs = self.build_graphs(spec, w, &probe)?;
                    self.run_steps_fused(spec, w, input, steps, Some((probe, graphs)))
                } else {
                    self.run_steps_host(spec, w, input, steps)
                }
            }
        }
    }

    /// Host-driven multi-step run (the paper's single-time-step use-case
    /// iterated by the host). The decomposition is planned and the tile
    /// DFGs are built once for all steps (they depend only on the spec
    /// and `w`, not the data), and each step reads the previous report's
    /// output in place — no per-step copy of the grid; the returned
    /// final grid is the only whole-grid copy made here.
    fn run_steps_host(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        steps: usize,
    ) -> Result<(Vec<f64>, Vec<RunReport>)> {
        let plan = self.plan(spec, w)?;
        let graphs = self.build_graphs(spec, w, &plan)?;
        let mut reports: Vec<RunReport> = Vec::with_capacity(steps);
        for _ in 0..steps {
            let rep = match reports.last() {
                None => self.run_planned(spec, input, &plan, &graphs)?,
                Some(prev) => self.run_planned(spec, &prev.output, &plan, &graphs)?,
            };
            reports.push(rep);
        }
        let grid = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok((grid, reports))
    }

    /// §IV fused chunks with a host loop over chunks. The plan (and its
    /// tile graphs) is reused while whole chunks of its depth remain
    /// (`cached` may arrive pre-seeded from the Auto probe); a shallower
    /// tail chunk replans once. Each chunk reads the previous report's
    /// output in place — like the host path, no per-chunk grid copy.
    fn run_steps_fused(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        steps: usize,
        mut cached: Option<(DecompPlan, HashMap<[usize; 3], Arc<Graph>>)>,
    ) -> Result<(Vec<f64>, Vec<RunReport>)> {
        let mut reports: Vec<RunReport> = Vec::new();
        let mut remaining = steps;
        while remaining > 0 {
            let stale = match &cached {
                None => true,
                Some((p, _)) => p.fused_steps > remaining,
            };
            if stale {
                let plan = decomp::plan_fused(
                    spec,
                    w,
                    self.fabric_tokens,
                    self.decomp,
                    self.tiles,
                    remaining,
                )?;
                let graphs = self.build_graphs(spec, w, &plan)?;
                cached = Some((plan, graphs));
            }
            let (plan, graphs) = cached.as_ref().expect("plan cached above");
            let src: &[f64] = match reports.last() {
                None => input,
                Some(prev) => prev.output.as_slice(),
            };
            let rep = self.run_planned(spec, src, plan, graphs)?;
            remaining -= plan.fused_steps;
            reports.push(rep);
        }
        let grid = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok((grid, reports))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::verify::golden::{
        max_abs_diff, stencil1d_ref, stencil2d_ref, stencil_ref, stencil_ref_steps,
    };

    #[test]
    fn multitile_2d_matches_oracle() {
        let spec = StencilSpec::dim2(
            64,
            20,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let mut rng = XorShift::new(0xC0DE);
        let x = rng.normal_vec(64 * 20);
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.strips >= 4);
        assert_eq!(rep.kind, DecompKind::Slab);
        assert!(rep.halo_points > 0, "multi-tile runs re-read halos");
        assert!(rep.redundant_read_fraction > 0.0);
        let want = stencil2d_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
        // All tasks landed on some tile (pull-based balancing may let a
        // fast tile take most of a small queue, so >=1 tile is the only
        // portable claim).
        let used = rep.per_tile.iter().filter(|t| t.strips > 0).count();
        assert!(used >= 1);
        assert_eq!(
            rep.per_tile.iter().map(|t| t.strips).sum::<usize>(),
            rep.strips
        );
        assert_eq!(
            rep.per_tile.iter().map(|t| t.halo_points).sum::<u64>(),
            rep.halo_points
        );
    }

    #[test]
    fn multitile_1d_matches_oracle() {
        let spec = StencilSpec::dim1(300, crate::stencil::spec::symmetric_taps(4)).unwrap();
        let mut rng = XorShift::new(0xD00D);
        let x = rng.normal_vec(300);
        let coord = Coordinator::new(3, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        let want = stencil1d_ref(&x, &spec.cx);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }

    #[test]
    fn multitile_3d_matches_oracle() {
        let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
        let mut rng = XorShift::new(0x3D0);
        let x = rng.normal_vec(12 * 10 * 8);
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.strips > 1, "3-D grids decompose multi-tile now");
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }

    #[test]
    fn makespan_not_exceeding_total() {
        let spec = StencilSpec::heat2d(40, 16, 0.2);
        let x = vec![1.0; 40 * 16];
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.makespan_cycles <= rep.total_cycles);
        assert!(rep.makespan_cycles > 0);
        assert!(rep.gflops > 0.0);
    }

    #[test]
    fn run_steps_equals_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let coord = Coordinator::new(2, Machine::paper());
        let (out, reports) = coord.run_steps(&spec, 2, &x, 3).unwrap();
        assert_eq!(reports.len(), 3);
        // Every step's report keeps its own output (the residual-curve
        // contract the examples rely on).
        assert_eq!(reports[2].output, out);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out, &want) < 1e-11);
    }

    #[test]
    fn fused_run_steps_matches_oracle_on_valid_interior() {
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let mut rng = XorShift::new(0xF0F0);
        let x = rng.normal_vec(24 * 16);
        let steps = 4;
        let host = Coordinator::new(2, Machine::paper());
        let (_, hreps) = host.run_steps(&spec, 2, &x, steps).unwrap();
        let fused = Coordinator::new(2, Machine::paper()).with_fuse(FuseMode::Spatial);
        let (fout, freps) = fused.run_steps(&spec, 2, &x, steps).unwrap();
        assert_eq!(freps.iter().map(|r| r.fused_steps).sum::<usize>(), steps);
        assert!(freps.len() < hreps.len(), "fusion must shrink the chunk count");
        // Bitwise equality against the iterated oracle on the valid
        // trapezoid interior (§IV acceptance contract).
        let want = crate::verify::golden::stencil_ref_steps(&spec, &x, steps);
        let (lo, hi) = temporal::valid_box(&spec, steps);
        for y in lo[1]..hi[1] {
            for c in lo[0]..hi[0] {
                let i = y * spec.nx + c;
                assert_eq!(fout[i], want[i], "y={y} c={c}");
            }
        }
        // §IV data reuse: strictly fewer loads than the host loop.
        let host_loads: u64 = hreps.iter().map(|r| r.total_loads()).sum();
        let fused_loads: u64 = freps.iter().map(|r| r.total_loads()).sum();
        assert!(fused_loads < host_loads, "{fused_loads} !< {host_loads}");
    }

    #[test]
    fn auto_fuse_falls_back_to_host_when_grid_cannot_deepen() {
        // 4-wide grid, r = 1: the trapezoid admits only depth 1, so Auto
        // must take the host path (one report per step, depth 1 each).
        let spec = StencilSpec::heat2d(4, 4, 0.2);
        let x = vec![1.0; 16];
        let coord = Coordinator::new(1, Machine::paper()).with_fuse(FuseMode::Auto);
        let (_, reports) = coord.run_steps(&spec, 1, &x, 2).unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.fused_steps == 1));
    }

    #[test]
    fn single_tile_still_works() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let x = vec![0.5; 160];
        let coord = Coordinator::new(1, Machine::paper());
        let rep = coord.run(&spec, 1, &x).unwrap();
        assert_eq!(rep.per_tile[0].strips, rep.strips);
        assert_eq!(rep.halo_points, 0, "one tile loads no halo");
    }

    #[test]
    fn rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let coord = Coordinator::new(1, Machine::paper());
        assert!(coord.run(&spec, 1, &[0.0; 3]).is_err());
    }
}
