//! Leader/worker execution of strip tasks over simulated CGRA tiles.
//!
//! The leader strip-mines the stencil, pushes [`StripTask`]s into a
//! shared queue, and spawns one OS thread per tile. Tiles pull greedily
//! (natural load balancing — the same work-stealing effect §IV's hybrid
//! algorithm relies on), simulate, and send results back over a channel.
//! The leader merges interior outputs into the global grid and accounts
//! per-tile cycles; the reported makespan is the slowest tile's total,
//! which is what 16 parallel tiles would take on silicon.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::Machine;
use crate::stencil::blocking::{self, Strip};
use crate::stencil::StencilSpec;
use crate::verify::golden::run_sim;

/// One unit of work: a vertical strip of the global grid.
#[derive(Debug, Clone)]
pub struct StripTask {
    pub id: usize,
    pub strip: Strip,
    /// Spec restricted to the strip's input columns.
    pub spec: StencilSpec,
    /// Contiguous copy of the strip's input columns (all rows).
    pub input: Vec<f64>,
}

/// Per-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    pub strips: usize,
    /// Sum of simulated cycles over this tile's strips.
    pub cycles: u64,
    pub mem: MemStats,
}

/// Result of a coordinated run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    pub strips: usize,
    /// Slowest tile's total cycles — the parallel makespan.
    pub makespan_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

/// Multi-tile coordinator.
#[derive(Debug, Clone)]
pub struct Coordinator {
    pub machine: Machine,
    pub tiles: usize,
    /// On-fabric token budget per tile (drives strip mining).
    pub fabric_tokens: usize,
}

impl Coordinator {
    pub fn new(tiles: usize, machine: Machine) -> Self {
        Self {
            machine,
            tiles,
            fabric_tokens: blocking::DEFAULT_FABRIC_TOKENS,
        }
    }

    /// The Table-I configuration: 16 tiles of the §VI machine.
    pub fn paper() -> Self {
        Self::new(16, Machine::paper())
    }

    /// Plan strips: enough to feed every tile, narrow enough to fit the
    /// fabric budget.
    pub fn plan_strips(&self, spec: &StencilSpec, w: usize) -> Result<Vec<Strip>> {
        let interior = spec.nx - 2 * spec.rx;
        let per_tile = interior.div_ceil(self.tiles).max(1);
        let width = if spec.is_1d() {
            per_tile
        } else {
            let (fit, _) = blocking::plan(spec, w, self.fabric_tokens)?;
            per_tile.min(fit)
        };
        Ok(blocking::strips_for_width(spec, width))
    }

    fn extract_strip(spec: &StencilSpec, input: &[f64], s: &Strip) -> Vec<f64> {
        let nx = spec.nx;
        let w = s.in_width();
        let mut out = Vec::with_capacity(w * spec.ny);
        for row in 0..spec.ny {
            out.extend_from_slice(&input[row * nx + s.in_lo..row * nx + s.in_hi]);
        }
        out
    }

    /// Run one stencil application across the tile array.
    pub fn run(&self, spec: &StencilSpec, w: usize, input: &[f64]) -> Result<RunReport> {
        ensure!(
            !spec.is_3d(),
            "coordinator strip-mining covers 1-D/2-D grids; run 3-D specs \
             through verify::golden::run_sim (see ROADMAP open items)"
        );
        ensure!(
            input.len() == spec.grid_points(),
            "input length {} != grid {}",
            input.len(),
            spec.grid_points()
        );
        let t0 = std::time::Instant::now();
        let strips = self.plan_strips(spec, w)?;
        let tasks: VecDeque<StripTask> = strips
            .iter()
            .enumerate()
            .map(|(id, s)| StripTask {
                id,
                strip: *s,
                spec: spec.strip(s.in_lo, s.in_hi),
                input: Self::extract_strip(spec, input, s),
            })
            .collect();
        let n_tasks = tasks.len();

        let queue = Arc::new(Mutex::new(tasks));
        let (tx, rx) = mpsc::channel();
        let mut handles = Vec::new();
        for tile_id in 0..self.tiles.min(n_tasks).max(1) {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let machine = self.machine.clone();
            let w = w;
            handles.push(std::thread::spawn(move || -> Result<()> {
                loop {
                    let task = { queue.lock().unwrap().pop_front() };
                    let Some(task) = task else { break };
                    let res = run_sim(&task.spec, w, &machine, &task.input)
                        .with_context(|| format!("strip {}", task.id))?;
                    tx.send((tile_id, task.id, task.strip, res)).ok();
                }
                Ok(())
            }));
        }
        drop(tx);

        // Merge interiors into the global output (boundary = input copy).
        let mut output = input.to_vec();
        let mut per_tile = vec![TileReport::default(); self.tiles];
        let mut received = 0;
        for (tile_id, _task_id, strip, res) in rx {
            let sub_nx = strip.in_width();
            let rx_ = spec.rx;
            let ry = spec.ry;
            for row in ry..spec.ny.saturating_sub(ry).max(ry) {
                let src = &res.output[row * sub_nx + rx_..row * sub_nx + rx_ + strip.out_width()];
                output[row * spec.nx + strip.out_lo..row * spec.nx + strip.out_hi]
                    .copy_from_slice(src);
            }
            let rep = &mut per_tile[tile_id];
            rep.strips += 1;
            rep.cycles += res.stats.cycles;
            rep.mem.loads += res.stats.mem.loads;
            rep.mem.stores += res.stats.mem.stores;
            rep.mem.hits += res.stats.mem.hits;
            rep.mem.misses += res.stats.mem.misses;
            rep.mem.merged += res.stats.mem.merged;
            rep.mem.conflict_misses += res.stats.mem.conflict_misses;
            rep.mem.dram_read_bytes += res.stats.mem.dram_read_bytes;
            rep.mem.dram_write_bytes += res.stats.mem.dram_write_bytes;
            received += 1;
        }
        for h in handles {
            h.join().expect("tile thread panicked")?;
        }
        ensure!(received == n_tasks, "lost strip results: {received}/{n_tasks}");

        // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output).
        let total_flops = spec.total_flops();

        let makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
        let total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum();
        let gflops = if makespan > 0 {
            total_flops * self.machine.clock_ghz / makespan as f64
        } else {
            0.0
        };
        Ok(RunReport {
            output,
            strips: n_tasks,
            makespan_cycles: makespan,
            total_cycles,
            total_flops,
            per_tile,
            gflops,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Host-driven multi-step run (the paper's single-time-step use-case
    /// iterated by the host, with buffer swap between steps).
    pub fn run_steps(
        &self,
        spec: &StencilSpec,
        w: usize,
        input: &[f64],
        steps: usize,
    ) -> Result<(Vec<f64>, Vec<RunReport>)> {
        let mut grid = input.to_vec();
        let mut reports = Vec::with_capacity(steps);
        for _ in 0..steps {
            let rep = self.run(spec, w, &grid)?;
            grid = rep.output.clone();
            reports.push(rep);
        }
        Ok((grid, reports))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::verify::golden::{max_abs_diff, stencil1d_ref, stencil2d_ref};

    #[test]
    fn multitile_2d_matches_oracle() {
        let spec = StencilSpec::dim2(
            64,
            20,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let mut rng = XorShift::new(0xC0DE);
        let x = rng.normal_vec(64 * 20);
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.strips >= 4);
        let want = stencil2d_ref(&x, &spec);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
        // All strips landed on some tile (pull-based balancing may let a
        // fast tile take most of a small queue, so >=1 tile is the only
        // portable claim).
        let used = rep.per_tile.iter().filter(|t| t.strips > 0).count();
        assert!(used >= 1);
        assert_eq!(
            rep.per_tile.iter().map(|t| t.strips).sum::<usize>(),
            rep.strips
        );
    }

    #[test]
    fn multitile_1d_matches_oracle() {
        let spec = StencilSpec::dim1(300, crate::stencil::spec::symmetric_taps(4)).unwrap();
        let mut rng = XorShift::new(0xD00D);
        let x = rng.normal_vec(300);
        let coord = Coordinator::new(3, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        let want = stencil1d_ref(&x, &spec.cx);
        assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    }

    #[test]
    fn makespan_not_exceeding_total() {
        let spec = StencilSpec::heat2d(40, 16, 0.2);
        let x = vec![1.0; 40 * 16];
        let coord = Coordinator::new(4, Machine::paper());
        let rep = coord.run(&spec, 2, &x).unwrap();
        assert!(rep.makespan_cycles <= rep.total_cycles);
        assert!(rep.makespan_cycles > 0);
        assert!(rep.gflops > 0.0);
    }

    #[test]
    fn run_steps_equals_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let coord = Coordinator::new(2, Machine::paper());
        let (out, reports) = coord.run_steps(&spec, 2, &x, 3).unwrap();
        assert_eq!(reports.len(), 3);
        let mut want = x.clone();
        for _ in 0..3 {
            want = stencil2d_ref(&want, &spec);
        }
        assert!(max_abs_diff(&out, &want) < 1e-11);
    }

    #[test]
    fn single_tile_still_works() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let x = vec![0.5; 160];
        let coord = Coordinator::new(1, Machine::paper());
        let rep = coord.run(&spec, 1, &x).unwrap();
        assert_eq!(rep.per_tile[0].strips, rep.strips);
    }

    #[test]
    fn rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let coord = Coordinator::new(1, Machine::paper());
        assert!(coord.run(&spec, 1, &[0.0; 3]).is_err());
    }
}
