//! L3 coordinator — legacy one-call wrappers over the two-phase API
//! (§IV, §VIII-A).
//!
//! Table I compares 16 CGRA tiles against one V100 ("16 CGRA units
//! should occupy the same chip area"). The multi-tile machinery that
//! actually runs those 16 tiles now lives behind the
//! compile-once/execute-many split: [`mod@crate::compile`] resolves the
//! decomposition and places one DFG per tile shape into an immutable
//! [`crate::compile::CompiledStencil`], and [`crate::session::Session`]
//! executes it — concurrently, any number of times, without ever
//! re-planning. This module keeps the older single-call surface on top
//! of that:
//!
//! * [`leader`] — [`Coordinator`], the deprecated compile-and-run-once
//!   shim (same plans, graphs and bitwise results as the two-phase
//!   API).
//! * [`dnc`] — §IV's recursive divide-and-conquer decomposition and the
//!   hybrid CPU+CGRA execution mode, sharing the compile phase's placed
//!   graphs.

pub mod dnc;
pub mod leader;

pub use crate::compile::FuseMode;
pub use crate::session::{RunReport, TileReport};
pub use leader::Coordinator;
