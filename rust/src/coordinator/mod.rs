//! L3 coordinator — the multi-tile runtime (§IV, §VIII-A).
//!
//! Table I compares 16 CGRA tiles against one V100 ("16 CGRA units should
//! occupy the same chip area"). The paper extrapolates a single-tile
//! simulation x16; this coordinator instead *actually runs* the 16 tiles:
//! the grid is decomposed into halo-padded N-dim tiles
//! ([`crate::stencil::decomp`] — slab/pencil/block cuts for 1-D, 2-D and
//! 3-D grids), tiles become tasks in a shared work queue, and one worker
//! thread per hardware tile pulls tasks, builds the sub-grid's DFG,
//! simulates it and returns the outputs to the leader, which stitches
//! the global grid. Each tile has its own 100 GB/s channel (aggregate
//! 1600 GB/s, the Table-I assumption); halo re-reads between neighboring
//! tiles are the decomposition's overhead and are accounted per run.
//!
//! * [`leader`] — the leader/worker engine: work queue, tile threads,
//!   result merge, per-tile cycle and halo accounting.
//! * [`dnc`] — §IV's recursive divide-and-conquer decomposition and the
//!   hybrid CPU+CGRA execution mode.

//! Multi-step runs traverse time per [`FuseMode`]: host-driven (one
//! decomposition pass per step) or §IV spatially fused (each tile runs
//! a `T`-deep temporal pipeline per memory round-trip; the host loops
//! over chunks).

pub mod dnc;
pub mod leader;

pub use leader::{Coordinator, FuseMode, RunReport, TileReport};
