//! High-level assembly emission — the text program the §V tool "emits for
//! the created DFG". One line per instruction with its immediates and
//! channel wiring; `# comments` carry stage/worker grouping.
//!
//! Format (stable; parsed back by [`parse`] for round-trip tests):
//!
//! ```text
//! pe <name> <mnemonic> [worker=<w>] [coeff=<f>]
//!    [filter=bits:m,n,p|rowcol:rl,rh,cl,ch|vol:zl,zh,yl,yh,cl,ch,ny]
//!    [agen=rl,rh,cs,ch,stride,width,ylo,yhi,ny] [expected=<n>]
//! chan <id> <src>:<port> -> <dst>:<port> cap=<c> lat=<l>
//! ```
//!
//! `agen` accepts the legacy 6-field (flat 1-D/2-D) form on input and
//! always emits the 9-field form (the last three are the §III plane-mode
//! extension for 3-D grids; 0,0,0 means flat).

use anyhow::{bail, Context, Result};

use super::graph::{Graph, DEFAULT_CAPACITY};
use super::node::{AddrIter, FilterSpec, Node, Op, Stage};

fn op_from_mnemonic(m: &str) -> Option<Op> {
    Some(match m {
        "mul" => Op::Mul,
        "mac" => Op::Mac,
        "add" => Op::Add,
        "copy" => Op::Copy,
        "filter" => Op::Filter,
        "mux" => Op::Mux,
        "demux" => Op::Demux,
        "cmp" => Op::Cmp,
        "or" => Op::Or,
        "shift" => Op::Shift,
        "ld" => Op::Load,
        "st" => Op::Store,
        "agen" => Op::AddrGen,
        "sync" => Op::SyncCount,
        "done" => Op::DoneTree,
        "const" => Op::Const,
        _ => return None,
    })
}

fn stage_from_name(s: &str) -> Option<Stage> {
    Some(match s {
        "control" => Stage::Control,
        "reader" => Stage::Reader,
        "compute" => Stage::Compute,
        "writer" => Stage::Writer,
        "sync" => Stage::Sync,
        _ => return None,
    })
}

/// Emit the high-level assembly program for a DFG.
pub fn to_asm(g: &Graph, title: &str) -> String {
    let mut s = String::new();
    s.push_str(&format!("# tia-asm: {title}\n"));
    s.push_str(&format!("# {}\n", g.summary()));
    for n in &g.nodes {
        s.push_str(&format!("pe {} {} stage={}", n.name, n.op.mnemonic(), n.stage.name()));
        if let Some(w) = n.worker {
            s.push_str(&format!(" worker={w}"));
        }
        if let Some(c) = n.coeff {
            s.push_str(&format!(" coeff={c:e}"));
        }
        match n.filter {
            Some(FilterSpec::Bits { m, n: nn, p }) => {
                s.push_str(&format!(" filter=bits:{m},{nn},{p}"))
            }
            Some(FilterSpec::RowCol { row_lo, row_hi, col_lo, col_hi }) => s.push_str(
                &format!(" filter=rowcol:{row_lo},{row_hi},{col_lo},{col_hi}"),
            ),
            Some(FilterSpec::Vol { z_lo, z_hi, y_lo, y_hi, col_lo, col_hi, ny }) => {
                s.push_str(&format!(
                    " filter=vol:{z_lo},{z_hi},{y_lo},{y_hi},{col_lo},{col_hi},{ny}"
                ))
            }
            None => {}
        }
        if let Some(a) = n.agen {
            s.push_str(&format!(
                " agen={},{},{},{},{},{},{},{},{}",
                a.row_lo,
                a.row_hi,
                a.col_start,
                a.col_hi,
                a.col_stride,
                a.width,
                a.y_lo,
                a.y_hi,
                a.ny
            ));
        }
        if let Some(e) = n.expected {
            s.push_str(&format!(" expected={e}"));
        }
        s.push('\n');
    }
    for c in &g.channels {
        s.push_str(&format!(
            "chan {} {}:{} -> {}:{} cap={} lat={}\n",
            c.id,
            g.node(c.src).name,
            c.src_port,
            g.node(c.dst).name,
            c.dst_port,
            c.capacity,
            c.latency
        ));
    }
    s
}

/// Parse the assembly format back into a graph (round-trip testing and a
/// path to feed externally-authored programs to the simulator).
pub fn parse(text: &str) -> Result<Graph> {
    let mut g = Graph::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next() {
            Some("pe") => {
                let name = it.next().context("pe: missing name")?;
                let mn = it.next().context("pe: missing mnemonic")?;
                let op = op_from_mnemonic(mn)
                    .with_context(|| format!("line {}: bad op `{mn}`", lineno + 1))?;
                let mut node = Node::new(0, name, op, Stage::Compute);
                for kv in it {
                    let (k, v) = kv
                        .split_once('=')
                        .with_context(|| format!("line {}: bad attr `{kv}`", lineno + 1))?;
                    match k {
                        "stage" => {
                            node.stage = stage_from_name(v)
                                .with_context(|| format!("bad stage `{v}`"))?
                        }
                        "worker" => node.worker = Some(v.parse()?),
                        "coeff" => node.coeff = Some(v.parse()?),
                        "expected" => node.expected = Some(v.parse()?),
                        "filter" => {
                            let (kind, args) =
                                v.split_once(':').context("bad filter")?;
                            let nums: Vec<u64> = args
                                .split(',')
                                .map(|x| x.parse::<u64>())
                                .collect::<std::result::Result<_, _>>()?;
                            let want = match kind {
                                "bits" => 3,
                                "rowcol" => 4,
                                "vol" => 7,
                                _ => bail!("bad filter kind `{kind}`"),
                            };
                            if nums.len() != want {
                                bail!(
                                    "line {}: filter={kind}: needs {want} fields, got {}",
                                    lineno + 1,
                                    nums.len()
                                );
                            }
                            if kind == "vol" && nums[6] == 0 {
                                bail!("line {}: filter=vol: ny must be > 0", lineno + 1);
                            }
                            node.filter = Some(match kind {
                                "bits" => FilterSpec::Bits {
                                    m: nums[0],
                                    n: nums[1],
                                    p: nums[2],
                                },
                                "rowcol" => FilterSpec::RowCol {
                                    row_lo: nums[0] as u32,
                                    row_hi: nums[1] as u32,
                                    col_lo: nums[2] as u32,
                                    col_hi: nums[3] as u32,
                                },
                                "vol" => FilterSpec::Vol {
                                    z_lo: nums[0] as u32,
                                    z_hi: nums[1] as u32,
                                    y_lo: nums[2] as u32,
                                    y_hi: nums[3] as u32,
                                    col_lo: nums[4] as u32,
                                    col_hi: nums[5] as u32,
                                    ny: nums[6] as u32,
                                },
                                _ => bail!("bad filter kind `{kind}`"),
                            });
                        }
                        "agen" => {
                            let nums: Vec<u32> = v
                                .split(',')
                                .map(|x| x.parse::<u32>())
                                .collect::<std::result::Result<_, _>>()?;
                            if nums.len() != 6 && nums.len() != 9 {
                                bail!(
                                    "line {}: agen needs 6 or 9 fields, got {}",
                                    lineno + 1,
                                    nums.len()
                                );
                            }
                            node.agen = Some(AddrIter {
                                row_lo: nums[0],
                                row_hi: nums[1],
                                col_start: nums[2],
                                col_hi: nums[3],
                                col_stride: nums[4],
                                width: nums[5],
                                y_lo: nums.get(6).copied().unwrap_or(0),
                                y_hi: nums.get(7).copied().unwrap_or(0),
                                ny: nums.get(8).copied().unwrap_or(0),
                            });
                        }
                        _ => bail!("line {}: unknown attr `{k}`", lineno + 1),
                    }
                }
                g.add_node(node);
            }
            Some("chan") => {
                let _id = it.next().context("chan: missing id")?;
                let src = it.next().context("chan: missing src")?;
                let arrow = it.next().context("chan: missing ->")?;
                if arrow != "->" {
                    bail!("line {}: expected ->", lineno + 1);
                }
                let dst = it.next().context("chan: missing dst")?;
                let mut cap = DEFAULT_CAPACITY;
                let mut lat = 1u32;
                for kv in it {
                    let (k, v) = kv.split_once('=').context("bad attr")?;
                    match k {
                        "cap" => cap = v.parse()?,
                        "lat" => lat = v.parse()?,
                        _ => bail!("unknown chan attr `{k}`"),
                    }
                }
                let (sn, sp) = src.rsplit_once(':').context("bad src")?;
                let (dn, dp) = dst.rsplit_once(':').context("bad dst")?;
                let s_id = g.find(sn).with_context(|| format!("unknown node `{sn}`"))?;
                let d_id = g.find(dn).with_context(|| format!("unknown node `{dn}`"))?;
                let ch = g.connect(s_id, sp.parse()?, d_id, dp.parse()?, cap);
                g.channels[ch].latency = lat;
            }
            Some(other) => bail!("line {}: unknown directive `{other}`", lineno + 1),
            None => {}
        }
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::builder::Dsl;

    fn sample() -> Graph {
        let mut d = Dsl::new();
        d.op("cu", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(1, 3, 10))
            .out("a");
        d.op("ld", Op::Load, Stage::Reader).worker(0).input(0, "a").out("d");
        d.op("f", Op::Filter, Stage::Compute)
            .worker(0)
            .filter(FilterSpec::Bits { m: 0, n: 8, p: 2 })
            .input(0, "d")
            .out("fd");
        d.op("m", Op::Mul, Stage::Compute)
            .worker(0)
            .coeff(0.5)
            .input_cap(0, "fd", 16)
            .out("p");
        d.op("sy", Op::SyncCount, Stage::Sync)
            .expected(8)
            .input(0, "p");
        d.build().unwrap()
    }

    #[test]
    fn asm_round_trips() {
        let g = sample();
        let text = to_asm(&g, "sample");
        let g2 = parse(&text).unwrap();
        assert_eq!(g.node_count(), g2.node_count());
        assert_eq!(g.channel_count(), g2.channel_count());
        assert_eq!(g.dp_ops(), g2.dp_ops());
        // Immediates survive.
        let m = g2.find("m").unwrap();
        assert_eq!(g2.node(m).coeff, Some(0.5));
        let f = g2.find("f").unwrap();
        assert_eq!(g2.node(f).filter, Some(FilterSpec::Bits { m: 0, n: 8, p: 2 }));
        let cu = g2.find("cu").unwrap();
        assert_eq!(g2.node(cu).agen, Some(AddrIter::dim1(1, 3, 10)));
        // Capacities survive.
        let mid = g2.find("m").unwrap();
        let ch = g2.input(mid, 0).unwrap();
        assert_eq!(g2.channels[ch].capacity, 16);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("bogus line here").is_err());
        assert!(parse("pe x unknown_op").is_err());
        assert!(parse("chan 0 a:0 -> b:0").is_err()); // unknown nodes
    }

    #[test]
    fn parse_rejects_malformed_filters() {
        // Wrong field counts must error, not panic.
        assert!(parse("pe f filter stage=compute filter=vol:1,2,3").is_err());
        assert!(parse("pe f filter stage=compute filter=rowcol:1,2,3").is_err());
        assert!(parse("pe f filter stage=compute filter=bits:1,2").is_err());
        // vol with ny = 0 would divide by zero in passes().
        assert!(parse("pe f filter stage=compute filter=vol:0,1,0,1,0,8,0").is_err());
        // Well-formed vol parses.
        let g = parse("pe f filter stage=compute filter=vol:0,1,0,1,0,8,4\n").unwrap();
        assert_eq!(
            g.node(g.find("f").unwrap()).filter,
            Some(FilterSpec::Vol {
                z_lo: 0,
                z_hi: 1,
                y_lo: 0,
                y_hi: 1,
                col_lo: 0,
                col_hi: 8,
                ny: 4
            })
        );
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let g = parse("# hi\n\n# more\n").unwrap();
        assert_eq!(g.node_count(), 0);
    }
}
