//! The §V DSL: a parametric builder that "provides essential APIs to add
//! PEs and connect their inputs and outputs ... and automatically connects
//! the operations internally based on the input/output names of each
//! operation".
//!
//! Ops publish named output *signals*; inputs reference signals by name.
//! Resolution is deferred to [`Dsl::build`], so declaration order does not
//! matter — exactly the auto-wiring behaviour the paper describes.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::graph::{Graph, DEFAULT_CAPACITY};
use super::node::{AddrIter, FilterSpec, Node, Op, Stage};

/// Deferred connection request: `signal -> (node, port, capacity)`.
#[derive(Debug, Clone)]
struct Pending {
    signal: String,
    dst_name: String,
    dst_port: u8,
    capacity: usize,
}

/// Signal-name based DFG builder.
#[derive(Debug, Default)]
pub struct Dsl {
    graph: Graph,
    /// signal name -> (producer node id, output port).
    signals: HashMap<String, (usize, u8)>,
    pending: Vec<Pending>,
}

/// Fluent handle for configuring one node.
pub struct NodeRef<'a> {
    dsl: &'a mut Dsl,
    id: usize,
}

impl Dsl {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a PE/instruction. `name` must be unique.
    pub fn op(&mut self, name: &str, op: Op, stage: Stage) -> NodeRef<'_> {
        let id = self.graph.add_node(Node::new(0, name, op, stage));
        NodeRef { dsl: self, id }
    }

    /// Number of nodes declared so far.
    pub fn len(&self) -> usize {
        self.graph.node_count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolve all deferred signal references and return the graph.
    pub fn build(mut self) -> Result<Graph> {
        for p in std::mem::take(&mut self.pending) {
            let &(src, src_port) = self
                .signals
                .get(&p.signal)
                .with_context(|| format!("unresolved signal `{}`", p.signal))?;
            let dst = self
                .graph
                .find(&p.dst_name)
                .with_context(|| format!("unknown node `{}`", p.dst_name))?;
            self.graph
                .connect(src, src_port, dst, p.dst_port, p.capacity);
        }
        // Arity check: every op must have its declared number of inputs.
        for n in &self.graph.nodes {
            let want = n.op.arity();
            let got = self.graph.input_count(n.id);
            if want != usize::MAX && got != want {
                bail!(
                    "node `{}` ({}) has {} inputs, expected {}",
                    n.name,
                    n.op.mnemonic(),
                    got,
                    want
                );
            }
            if n.op == Op::DoneTree {
                let exp = n.expected.unwrap_or(0) as usize;
                if got != exp {
                    bail!(
                        "done tree `{}` has {} inputs, expected {}",
                        n.name,
                        got,
                        exp
                    );
                }
            }
        }
        Ok(self.graph)
    }
}

impl<'a> NodeRef<'a> {
    fn node(&mut self) -> &mut Node {
        &mut self.dsl.graph.nodes[self.id]
    }

    /// Assign the logical worker this node belongs to.
    pub fn worker(mut self, w: usize) -> Self {
        self.node().worker = Some(w);
        self
    }

    /// Coefficient immediate (Mul/Mac/Const).
    pub fn coeff(mut self, c: f64) -> Self {
        self.node().coeff = Some(c);
        self
    }

    /// Filter configuration (Filter).
    pub fn filter(mut self, f: FilterSpec) -> Self {
        self.node().filter = Some(f);
        self
    }

    /// Address iterator (AddrGen).
    pub fn agen(mut self, a: AddrIter) -> Self {
        self.node().agen = Some(a);
        self
    }

    /// Expected count (SyncCount / DoneTree input count).
    pub fn expected(mut self, e: u64) -> Self {
        self.node().expected = Some(e);
        self
    }

    /// Publish output port 0 under `signal`.
    pub fn out(self, signal: &str) -> Self {
        self.out_port(0, signal)
    }

    /// Publish output port `port` under `signal`.
    pub fn out_port(self, port: u8, signal: &str) -> Self {
        let id = self.id;
        let prev = self.dsl.signals.insert(signal.to_string(), (id, port));
        assert!(prev.is_none(), "signal `{signal}` published twice");
        self
    }

    /// Connect input port (in declaration order) from `signal` with the
    /// default queue capacity.
    pub fn input(self, port: u8, signal: &str) -> Self {
        self.input_cap(port, signal, DEFAULT_CAPACITY)
    }

    /// Connect input port from `signal` with an explicit queue capacity
    /// (mandatory buffering, §III-B).
    pub fn input_cap(mut self, port: u8, signal: &str, capacity: usize) -> Self {
        let dst_name = self.node().name.clone();
        self.dsl.pending.push(Pending {
            signal: signal.to_string(),
            dst_name,
            dst_port: port,
            capacity,
        });
        self
    }

    pub fn id(&self) -> usize {
        self.id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_wires_by_signal_name() {
        let mut d = Dsl::new();
        d.op("r0", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 8))
            .out("addrs");
        d.op("ld", Op::Load, Stage::Reader)
            .input(0, "addrs")
            .out("data");
        d.op("m", Op::Mul, Stage::Compute)
            .coeff(2.0)
            .input(0, "data")
            .out("partial");
        let g = d.build().unwrap();
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.channel_count(), 2);
        let ld = g.find("ld").unwrap();
        let m = g.find("m").unwrap();
        assert_eq!(g.channels[g.input(m, 0).unwrap()].src, ld);
    }

    #[test]
    fn declaration_order_does_not_matter() {
        let mut d = Dsl::new();
        // Consumer first, producer second: §V auto-connect still works.
        d.op("consumer", Op::Load, Stage::Reader).input(0, "sig");
        d.op("producer", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("sig");
        let g = d.build().unwrap();
        assert_eq!(g.channel_count(), 1);
    }

    #[test]
    fn unresolved_signal_is_error() {
        let mut d = Dsl::new();
        d.op("ld", Op::Load, Stage::Reader).input(0, "missing");
        assert!(d.build().is_err());
    }

    #[test]
    fn arity_is_checked() {
        let mut d = Dsl::new();
        // Mac needs 2 inputs; give it 1.
        d.op("src", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("s");
        d.op("mac", Op::Mac, Stage::Compute).coeff(1.0).input(0, "s");
        let err = d.build().unwrap_err().to_string();
        assert!(err.contains("expected 2"), "{err}");
    }

    #[test]
    #[should_panic(expected = "published twice")]
    fn duplicate_signal_rejected() {
        let mut d = Dsl::new();
        d.op("a", Op::AddrGen, Stage::Control).out("s");
        d.op("b", Op::AddrGen, Stage::Control).out("s");
    }

    #[test]
    fn fan_out_from_one_signal() {
        let mut d = Dsl::new();
        d.op("g", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("s");
        d.op("a", Op::Load, Stage::Reader).input(0, "s");
        d.op("b", Op::Load, Stage::Reader).input(0, "s");
        let g = d.build().unwrap();
        let gid = g.find("g").unwrap();
        assert_eq!(g.outputs(gid, 0).len(), 2);
    }
}
