//! Graphviz emission for DFGs, following the Fig 7 legend: mux =
//! light-yellow, mul = orange, mac = red, demux = light-blue, add =
//! green, address generators/indices = cyan, everything else gray.

use std::fmt::Write;

use super::graph::Graph;
use super::node::Op;

fn color(op: Op) -> &'static str {
    match op {
        Op::Mux => "lightyellow",
        Op::Mul => "orange",
        Op::Mac => "red",
        Op::Demux => "lightblue",
        Op::Add => "green",
        Op::AddrGen | Op::Const => "cyan",
        Op::Load | Op::Store => "khaki",
        Op::Filter => "plum",
        Op::SyncCount | Op::DoneTree => "palegreen",
        _ => "gray",
    }
}

/// Render the graph as Graphviz dot, clustered by logical worker so the
/// layout mirrors Fig 7 / Fig 11.
pub fn to_dot(g: &Graph, title: &str) -> String {
    let mut s = String::new();
    writeln!(s, "digraph dfg {{").unwrap();
    writeln!(s, "  label=\"{}\\n{}\";", title, g.summary()).unwrap();
    writeln!(s, "  rankdir=TB; node [style=filled, shape=ellipse];").unwrap();

    // Cluster nodes per worker; worker-less nodes go to the top level.
    let max_worker = g.nodes.iter().filter_map(|n| n.worker).max();
    if let Some(mw) = max_worker {
        for w in 0..=mw {
            writeln!(s, "  subgraph cluster_w{w} {{").unwrap();
            writeln!(s, "    label=\"worker {w}\"; color=gray;").unwrap();
            for n in g.nodes.iter().filter(|n| n.worker == Some(w)) {
                writeln!(
                    s,
                    "    n{} [label=\"{}\\n{}\", fillcolor={}];",
                    n.id,
                    n.name,
                    n.op.mnemonic(),
                    color(n.op)
                )
                .unwrap();
            }
            writeln!(s, "  }}").unwrap();
        }
    }
    for n in g.nodes.iter().filter(|n| n.worker.is_none()) {
        writeln!(
            s,
            "  n{} [label=\"{}\\n{}\", fillcolor={}];",
            n.id,
            n.name,
            n.op.mnemonic(),
            color(n.op)
        )
        .unwrap();
    }
    for c in &g.channels {
        let cap = if c.capacity != super::graph::DEFAULT_CAPACITY {
            format!(" [label=\"cap={}\"]", c.capacity)
        } else {
            String::new()
        };
        writeln!(s, "  n{} -> n{}{};", c.src, c.dst, cap).unwrap();
    }
    writeln!(s, "}}").unwrap();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::builder::Dsl;
    use crate::dfg::node::{AddrIter, Op, Stage};

    fn tiny() -> Graph {
        let mut d = Dsl::new();
        d.op("g", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("a");
        d.op("ld", Op::Load, Stage::Reader).worker(0).input(0, "a").out("d");
        d.op("m", Op::Mul, Stage::Compute)
            .worker(0)
            .coeff(1.0)
            .input(0, "d")
            .out("p");
        d.build().unwrap()
    }

    #[test]
    fn dot_contains_nodes_edges_and_legend_colors() {
        let dot = to_dot(&tiny(), "tiny");
        assert!(dot.starts_with("digraph"));
        assert!(dot.contains("fillcolor=orange")); // mul
        assert!(dot.contains("cluster_w0"));
        assert!(dot.contains("->"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn dot_edge_count_matches_graph() {
        let g = tiny();
        let dot = to_dot(&g, "t");
        assert_eq!(dot.matches("->").count(), g.channel_count());
    }
}
