//! The dataflow graph container: nodes + channels, plus the structural
//! queries the mapper, placer, simulator and emitters rely on.

use std::collections::HashMap;

use super::node::{Node, Op, Stage};

pub type NodeId = usize;
pub type ChannelId = usize;

/// A producer→consumer FIFO edge. `capacity` includes any mandatory
/// buffering the mapper assigned (§III-B); `latency` is filled in by
/// placement (network hops) and defaults to 1 cycle.
#[derive(Debug, Clone)]
pub struct Channel {
    pub id: ChannelId,
    pub src: NodeId,
    pub src_port: u8,
    pub dst: NodeId,
    pub dst_port: u8,
    pub capacity: usize,
    pub latency: u32,
}

/// Default channel capacity: the paper's PEs have small input/output
/// queues; 4 matches the TIA evaluation's queue depth.
pub const DEFAULT_CAPACITY: usize = 4;

#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub channels: Vec<Channel>,
    /// Inputs of each node, indexed by input port: `ins[node][port]`.
    ins: Vec<Vec<Option<ChannelId>>>,
    /// Outputs of each node per output port (fan-out allowed):
    /// `outs[node][port] -> Vec<ChannelId>`.
    outs: Vec<Vec<Vec<ChannelId>>>,
    by_name: HashMap<String, NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node; names must be unique.
    pub fn add_node(&mut self, mut node: Node) -> NodeId {
        let id = self.nodes.len();
        node.id = id;
        assert!(
            self.by_name.insert(node.name.clone(), id).is_none(),
            "duplicate node name {}",
            node.name
        );
        self.nodes.push(node);
        self.ins.push(Vec::new());
        self.outs.push(Vec::new());
        id
    }

    /// Connect `src.out[src_port]` to `dst.in[dst_port]`.
    pub fn connect(
        &mut self,
        src: NodeId,
        src_port: u8,
        dst: NodeId,
        dst_port: u8,
        capacity: usize,
    ) -> ChannelId {
        let id = self.channels.len();
        self.channels.push(Channel {
            id,
            src,
            src_port,
            dst,
            dst_port,
            capacity,
            latency: 1,
        });
        let ins = &mut self.ins[dst];
        if ins.len() <= dst_port as usize {
            ins.resize(dst_port as usize + 1, None);
        }
        assert!(
            ins[dst_port as usize].is_none(),
            "input port {}:{} already connected",
            self.nodes[dst].name,
            dst_port
        );
        ins[dst_port as usize] = Some(id);
        let outs = &mut self.outs[src];
        if outs.len() <= src_port as usize {
            outs.resize(src_port as usize + 1, Vec::new());
        }
        outs[src_port as usize].push(id);
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn find(&self, name: &str) -> Option<NodeId> {
        self.by_name.get(name).copied()
    }

    /// Input channel on `port`, if connected.
    pub fn input(&self, node: NodeId, port: u8) -> Option<ChannelId> {
        self.ins[node].get(port as usize).copied().flatten()
    }

    /// All input channels of a node (ports in order, unconnected skipped).
    pub fn inputs(&self, node: NodeId) -> impl Iterator<Item = ChannelId> + '_ {
        self.ins[node].iter().filter_map(|c| *c)
    }

    /// Number of connected input ports.
    pub fn input_count(&self, node: NodeId) -> usize {
        self.ins[node].iter().filter(|c| c.is_some()).count()
    }

    /// Fan-out list of `node.out[port]`.
    pub fn outputs(&self, node: NodeId, port: u8) -> &[ChannelId] {
        self.outs[node]
            .get(port as usize)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// All outgoing channels of a node across ports.
    pub fn all_outputs(&self, node: NodeId) -> impl Iterator<Item = ChannelId> + '_ {
        self.outs[node].iter().flatten().copied()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn channel_count(&self) -> usize {
        self.channels.len()
    }

    /// Count of double-precision datapath ops (the §VI roofline count).
    pub fn dp_ops(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_dp()).count()
    }

    /// Node count per op kind.
    pub fn op_histogram(&self) -> HashMap<Op, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            *h.entry(n.op).or_insert(0) += 1;
        }
        h
    }

    /// Node count per stage.
    pub fn stage_histogram(&self) -> HashMap<Stage, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            *h.entry(n.stage).or_insert(0) += 1;
        }
        h
    }

    /// Topological order; `None` if the graph has a cycle. Stencil DFGs
    /// are pipelines and must be acyclic.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for c in &self.channels {
            indeg[c.dst] += 1;
        }
        let mut stack: Vec<NodeId> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = stack.pop() {
            order.push(u);
            for c in self.all_outputs(u) {
                let v = self.channels[c].dst;
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    stack.push(v);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Summary line used by the CLI and tests (mirrors Fig 7's caption:
    /// "17 point stencil 6 workers, 102 DP ops").
    pub fn summary(&self) -> String {
        format!(
            "{} nodes, {} channels, {} DP ops",
            self.node_count(),
            self.channel_count(),
            self.dp_ops()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::node::{Node, Op, Stage};

    fn n(g: &mut Graph, name: &str, op: Op) -> NodeId {
        g.add_node(Node::new(0, name, op, Stage::Compute))
    }

    #[test]
    fn connect_and_query() {
        let mut g = Graph::new();
        let a = n(&mut g, "a", Op::Mul);
        let b = n(&mut g, "b", Op::Mac);
        let c = g.connect(a, 0, b, 0, 4);
        assert_eq!(g.input(b, 0), Some(c));
        assert_eq!(g.outputs(a, 0), &[c]);
        assert_eq!(g.find("b"), Some(b));
        assert_eq!(g.input_count(b), 1);
    }

    #[test]
    fn fan_out_allowed() {
        let mut g = Graph::new();
        let a = n(&mut g, "a", Op::Load);
        let b = n(&mut g, "b", Op::Mul);
        let c = n(&mut g, "c", Op::Mul);
        g.connect(a, 0, b, 0, 4);
        g.connect(a, 0, c, 0, 4);
        assert_eq!(g.outputs(a, 0).len(), 2);
    }

    #[test]
    #[should_panic(expected = "already connected")]
    fn double_input_rejected() {
        let mut g = Graph::new();
        let a = n(&mut g, "a", Op::Load);
        let b = n(&mut g, "b", Op::Mul);
        g.connect(a, 0, b, 0, 4);
        g.connect(a, 0, b, 0, 4);
    }

    #[test]
    #[should_panic(expected = "duplicate node name")]
    fn duplicate_name_rejected() {
        let mut g = Graph::new();
        n(&mut g, "a", Op::Mul);
        n(&mut g, "a", Op::Mul);
    }

    #[test]
    fn topo_order_linear_chain() {
        let mut g = Graph::new();
        let a = n(&mut g, "a", Op::Mul);
        let b = n(&mut g, "b", Op::Mac);
        let c = n(&mut g, "c", Op::Mac);
        g.connect(a, 0, b, 0, 4);
        g.connect(b, 0, c, 0, 4);
        let order = g.topo_order().unwrap();
        let pos = |x: NodeId| order.iter().position(|&y| y == x).unwrap();
        assert!(pos(a) < pos(b) && pos(b) < pos(c));
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        let a = n(&mut g, "a", Op::Mac);
        let b = n(&mut g, "b", Op::Mac);
        g.connect(a, 0, b, 0, 4);
        g.connect(b, 0, a, 0, 4);
        assert!(g.topo_order().is_none());
    }

    #[test]
    fn dp_count() {
        let mut g = Graph::new();
        n(&mut g, "m", Op::Mul);
        n(&mut g, "f", Op::Filter);
        n(&mut g, "a", Op::Mac);
        assert_eq!(g.dp_ops(), 2);
    }
}
