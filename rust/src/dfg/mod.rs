//! Dataflow-graph IR for the CGRA (§II-A, §V).
//!
//! An algorithm for the CGRA is a graph whose nodes are instructions and
//! whose edges are producer→consumer channels (bounded FIFOs). The stencil
//! mapper ([`crate::stencil`]) builds these graphs through the [`builder`]
//! DSL; the simulator ([`crate::cgra`]) executes them; [`dot`] and [`asm`]
//! emit Graphviz and high-level assembly, the two artifact formats the
//! paper's §V tool produces.

pub mod asm;
pub mod builder;
pub mod dot;
pub mod graph;
pub mod node;
pub mod validate;

pub use builder::Dsl;
pub use graph::{Channel, ChannelId, Graph, NodeId};
pub use node::{AddrIter, FilterSpec, Node, Op, Stage};
