//! DFG node definitions: the instruction set the paper's PEs are
//! configured with (Fig 7 legend), plus the parameter blocks for the
//! control units (address generators), data filters and sync counters.

/// Operation kinds. The datapath ops (`Mul`, `Mac`, `Add`) are the
/// double-precision ops the roofline counts; the rest are stream plumbing
/// and control (gray/cyan/yellow/blue ovals in Fig 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// `out = coeff * in` — the first tap of a MAC chain.
    Mul,
    /// `out = partial + coeff * in` — fused multiply-add tap.
    Mac,
    /// `out = a + b` — partial-sum combination.
    Add,
    /// Repeater / explicit broadcast helper.
    Copy,
    /// Drop-or-pass by [`FilterSpec`] — the data-filtering PEs of §III-A.
    Filter,
    /// Merge control streams (Fig 7 light-yellow ovals).
    Mux,
    /// Distribute a stream (Fig 7 light-blue ovals).
    Demux,
    /// Compare (used by row-id filtering / loop control).
    Cmp,
    /// Logical or (done-signal combining).
    Or,
    /// Shift (index arithmetic in control units).
    Shift,
    /// Memory load: consumes an address token, produces a data token.
    Load,
    /// Memory store: consumes address + data tokens, produces an ack.
    Store,
    /// Control unit: generates (addr, row, col) tokens from an [`AddrIter`].
    AddrGen,
    /// Synchronization worker: counts acks, fires `done` at `expected`.
    SyncCount,
    /// Combines per-worker done signals into the host "done" (§III-A).
    DoneTree,
    /// Emits a compile-time constant stream (coefficient injection).
    Const,
}

impl Op {
    /// Is this one of the double-precision datapath ops the roofline
    /// model counts (1 MUL + 2r MACs per worker, §VI)?
    pub fn is_dp(self) -> bool {
        matches!(self, Op::Mul | Op::Mac | Op::Add)
    }

    /// Mnemonic used by the assembly emitter.
    pub fn mnemonic(self) -> &'static str {
        match self {
            Op::Mul => "mul",
            Op::Mac => "mac",
            Op::Add => "add",
            Op::Copy => "copy",
            Op::Filter => "filter",
            Op::Mux => "mux",
            Op::Demux => "demux",
            Op::Cmp => "cmp",
            Op::Or => "or",
            Op::Shift => "shift",
            Op::Load => "ld",
            Op::Store => "st",
            Op::AddrGen => "agen",
            Op::SyncCount => "sync",
            Op::DoneTree => "done",
            Op::Const => "const",
        }
    }

    /// Number of input ports the op consumes each firing.
    pub fn arity(self) -> usize {
        match self {
            Op::AddrGen | Op::Const => 0,
            Op::Mul | Op::Copy | Op::Filter | Op::Load | Op::SyncCount | Op::Shift
            | Op::Demux => 1,
            Op::Mac | Op::Add | Op::Store | Op::Cmp | Op::Or | Op::Mux => 2,
            Op::DoneTree => usize::MAX, // variadic; set per node
        }
    }
}

/// Pipeline stage a node belongs to (§III-A's four stages + control).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    Control,
    Reader,
    Compute,
    Writer,
    Sync,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Control => "control",
            Stage::Reader => "reader",
            Stage::Compute => "compute",
            Stage::Writer => "writer",
            Stage::Sync => "sync",
        }
    }
}

/// Data filter configuration (§III-A "Data-filtering PEs", Fig 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FilterSpec {
    /// Bit-pattern scheme: the stream is passed through the pattern
    /// `0^m 1^n 0^p`, repeated every `m + n + p` tokens (one repetition
    /// per grid row; a 1-D stencil uses a single repetition).
    Bits { m: u64, n: u64, p: u64 },
    /// Row/column-id scheme: pass tokens whose tags satisfy
    /// `row_lo <= row < row_hi && col_lo <= col < col_hi`.
    RowCol {
        row_lo: u32,
        row_hi: u32,
        col_lo: u32,
        col_hi: u32,
    },
    /// Volume (z/y/col) scheme for 3-D grids: the token's `row` tag is
    /// the flattened plane-row index `z * ny + y`; pass when
    /// `z ∈ [z_lo, z_hi) && y ∈ [y_lo, y_hi) && col ∈ [col_lo, col_hi)`.
    Vol {
        z_lo: u32,
        z_hi: u32,
        y_lo: u32,
        y_hi: u32,
        col_lo: u32,
        col_hi: u32,
        /// Grid height used to unflatten the row tag; must be > 0.
        ny: u32,
    },
}

impl FilterSpec {
    /// Does a token with stream index `idx` / tags `(row, col)` pass?
    #[inline]
    pub fn passes(&self, idx: u64, row: u32, col: u32) -> bool {
        match *self {
            FilterSpec::Bits { m, n, p } => {
                let period = m + n + p;
                debug_assert!(period > 0);
                let pos = idx % period;
                pos >= m && pos < m + n
            }
            FilterSpec::RowCol {
                row_lo,
                row_hi,
                col_lo,
                col_hi,
            } => row >= row_lo && row < row_hi && col >= col_lo && col < col_hi,
            FilterSpec::Vol {
                z_lo,
                z_hi,
                y_lo,
                y_hi,
                col_lo,
                col_hi,
                ny,
            } => {
                debug_assert!(ny > 0);
                let z = row / ny;
                let y = row % ny;
                z >= z_lo
                    && z < z_hi
                    && y >= y_lo
                    && y < y_hi
                    && col >= col_lo
                    && col < col_hi
            }
        }
    }
}

/// Address-stream generator for the control units attached to reader and
/// writer workers: iterates row-major over rows `[row_lo, row_hi)` and
/// columns `col_start, col_start + col_stride, ... < col_hi`, producing
/// `addr = row * width + col` plus the (row, col) tags.
///
/// A 1-D grid is the single-row case (`row_lo = 0, row_hi = 1,
/// width = n`). A 3-D grid sets `ny > 0` (plane mode): `row_lo/row_hi`
/// then range over z, `y_lo/y_hi` over the rows inside each plane, and
/// the emitted row tag is the flattened `z * ny + y` (matching
/// [`FilterSpec::Vol`]). `ny == 0` keeps the flat 1-D/2-D semantics and
/// ignores `y_lo`/`y_hi`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AddrIter {
    pub row_lo: u32,
    pub row_hi: u32,
    pub col_start: u32,
    pub col_hi: u32,
    pub col_stride: u32,
    pub width: u32,
    /// Plane mode only: first in-plane row.
    pub y_lo: u32,
    /// Plane mode only: one past the last in-plane row.
    pub y_hi: u32,
    /// Grid height; 0 selects flat (1-D/2-D) mode.
    pub ny: u32,
}

impl AddrIter {
    /// Single-row (1-D) iteration over `col_start, +stride, .. < n`.
    pub fn dim1(col_start: u32, col_stride: u32, n: u32) -> Self {
        Self {
            row_lo: 0,
            row_hi: 1,
            col_start,
            col_hi: n,
            col_stride,
            width: n,
            y_lo: 0,
            y_hi: 0,
            ny: 0,
        }
    }

    /// Plane-mode (3-D) iteration: z over `[z_lo, z_hi)`, y over
    /// `[y_lo, y_hi)` within each `ny`-row plane, columns as in 2-D.
    #[allow(clippy::too_many_arguments)]
    pub fn dim3(
        z_lo: u32,
        z_hi: u32,
        y_lo: u32,
        y_hi: u32,
        ny: u32,
        col_start: u32,
        col_hi: u32,
        col_stride: u32,
        width: u32,
    ) -> Self {
        debug_assert!(ny > 0);
        Self {
            row_lo: z_lo,
            row_hi: z_hi,
            col_start,
            col_hi,
            col_stride,
            width,
            y_lo,
            y_hi,
            ny,
        }
    }

    /// Rows the stream visits: plain rows in flat mode, `z_range *
    /// y_range` flattened rows in plane mode.
    fn row_count(&self) -> u64 {
        if self.row_hi <= self.row_lo {
            return 0;
        }
        let outer = (self.row_hi - self.row_lo) as u64;
        if self.ny == 0 {
            outer
        } else if self.y_hi <= self.y_lo {
            0
        } else {
            outer * (self.y_hi - self.y_lo) as u64
        }
    }

    /// Number of tokens the stream will produce.
    pub fn len(&self) -> u64 {
        if self.col_hi <= self.col_start {
            return 0;
        }
        let per_row =
            ((self.col_hi - self.col_start - 1) / self.col_stride + 1) as u64;
        per_row * self.row_count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `k`-th (row, col, addr) token, row-major. In plane mode the
    /// row tag is the flattened `z * ny + y`.
    #[inline]
    pub fn token(&self, k: u64) -> (u32, u32, u64) {
        let per_row = ((self.col_hi - self.col_start - 1) / self.col_stride + 1) as u64;
        let row_idx = k / per_row;
        let row = if self.ny == 0 {
            self.row_lo + row_idx as u32
        } else {
            let ys = (self.y_hi - self.y_lo) as u64;
            let z = self.row_lo as u64 + row_idx / ys;
            let y = self.y_lo as u64 + row_idx % ys;
            (z * self.ny as u64 + y) as u32
        };
        let col = self.col_start + (k % per_row) as u32 * self.col_stride;
        (row, col, row as u64 * self.width as u64 + col as u64)
    }
}

/// One DFG node: an instruction with its immediates.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    /// Unique hierarchical name, e.g. `w0.x.mac3` (worker 0, x chain).
    pub name: String,
    pub op: Op,
    pub stage: Stage,
    /// Logical worker index (§III-A), if the node belongs to one.
    pub worker: Option<usize>,
    /// Coefficient immediate for `Mul` / `Mac` / `Const`.
    pub coeff: Option<f64>,
    /// Filter configuration for `Filter`.
    pub filter: Option<FilterSpec>,
    /// Address iterator for `AddrGen`.
    pub agen: Option<AddrIter>,
    /// Expected ack count for `SyncCount` / input count for `DoneTree`.
    pub expected: Option<u64>,
}

impl Node {
    pub fn new(id: usize, name: impl Into<String>, op: Op, stage: Stage) -> Self {
        Self {
            id,
            name: name.into(),
            op,
            stage,
            worker: None,
            coeff: None,
            filter: None,
            agen: None,
            expected: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_filter_pattern() {
        // 0^1 1^3 0^2 over 6 tokens: pass indices 1,2,3.
        let f = FilterSpec::Bits { m: 1, n: 3, p: 2 };
        let got: Vec<bool> = (0..6).map(|i| f.passes(i, 0, 0)).collect();
        assert_eq!(got, vec![false, true, true, true, false, false]);
        // Repeats with the period (per-row in 2-D).
        assert!(f.passes(7, 0, 0));
        assert!(!f.passes(6, 0, 0));
    }

    #[test]
    fn rowcol_filter_interior() {
        let f = FilterSpec::RowCol {
            row_lo: 1,
            row_hi: 3,
            col_lo: 2,
            col_hi: 5,
        };
        assert!(f.passes(0, 1, 2));
        assert!(f.passes(0, 2, 4));
        assert!(!f.passes(0, 0, 2));
        assert!(!f.passes(0, 3, 2));
        assert!(!f.passes(0, 1, 1));
        assert!(!f.passes(0, 1, 5));
    }

    #[test]
    fn addr_iter_1d() {
        // Reader 1 of w=3 over n=10: cols 1,4,7.
        let it = AddrIter::dim1(1, 3, 10);
        assert_eq!(it.len(), 3);
        assert_eq!(it.token(0), (0, 1, 1));
        assert_eq!(it.token(1), (0, 4, 4));
        assert_eq!(it.token(2), (0, 7, 7));
    }

    #[test]
    fn addr_iter_2d_row_major() {
        let it = AddrIter {
            row_lo: 1,
            row_hi: 3,
            col_start: 0,
            col_hi: 4,
            col_stride: 2,
            width: 4,
            y_lo: 0,
            y_hi: 0,
            ny: 0,
        };
        // rows 1..3, cols {0, 2}: tokens (1,0) (1,2) (2,0) (2,2).
        assert_eq!(it.len(), 4);
        assert_eq!(it.token(0), (1, 0, 4));
        assert_eq!(it.token(1), (1, 2, 6));
        assert_eq!(it.token(2), (2, 0, 8));
        assert_eq!(it.token(3), (2, 2, 10));
    }

    #[test]
    fn addr_iter_empty() {
        let it = AddrIter::dim1(5, 1, 5);
        assert!(it.is_empty());
    }

    #[test]
    fn addr_iter_3d_plane_mode() {
        // 4-wide, ny = 3, nz = 2 grid; z in [0,2), y in [1,3), cols {1, 3}.
        let it = AddrIter::dim3(0, 2, 1, 3, 3, 1, 4, 2, 4);
        assert_eq!(it.len(), 2 * 2 * 2);
        // First tokens: z=0,y=1 -> flattened row 1.
        assert_eq!(it.token(0), (1, 1, 5));
        assert_eq!(it.token(1), (1, 3, 7));
        // Next row: z=0,y=2 -> flattened row 2.
        assert_eq!(it.token(2), (2, 1, 9));
        // Plane wrap: z=1,y=1 -> flattened row 4.
        assert_eq!(it.token(4), (4, 1, 17));
        assert_eq!(it.token(7), (5, 3, 23));
    }

    #[test]
    fn vol_filter_unflattens_row_tag() {
        // ny = 4: row tag 6 = (z=1, y=2).
        let f = FilterSpec::Vol {
            z_lo: 1,
            z_hi: 2,
            y_lo: 2,
            y_hi: 3,
            col_lo: 0,
            col_hi: 8,
            ny: 4,
        };
        assert!(f.passes(0, 6, 0));
        assert!(!f.passes(0, 5, 0)); // y = 1
        assert!(!f.passes(0, 2, 0)); // z = 0
        assert!(!f.passes(0, 10, 0)); // z = 2
        assert!(!f.passes(0, 6, 8)); // col out of window
    }

    #[test]
    fn dp_ops_classified() {
        assert!(Op::Mul.is_dp());
        assert!(Op::Mac.is_dp());
        assert!(Op::Add.is_dp());
        assert!(!Op::Filter.is_dp());
        assert!(!Op::Load.is_dp());
    }
}
