//! Structural validation of DFGs before simulation: arity, acyclicity,
//! parameter presence, and reachability. The mapper's output must always
//! pass; the checks exist to catch hand-authored assembly and future
//! mapper bugs early, with actionable messages.

use anyhow::{bail, Result};

use super::graph::Graph;
use super::node::Op;

/// All validation errors found in `g` (empty = valid).
pub fn check(g: &Graph) -> Vec<String> {
    let mut errs = Vec::new();

    for n in &g.nodes {
        let want = n.op.arity();
        let got = g.input_count(n.id);
        if want != usize::MAX && got != want {
            errs.push(format!(
                "node `{}` ({}): {} inputs, expected {}",
                n.name,
                n.op.mnemonic(),
                got,
                want
            ));
        }
        match n.op {
            Op::Mul | Op::Mac if n.coeff.is_none() => {
                errs.push(format!("node `{}`: missing coeff", n.name))
            }
            Op::Filter if n.filter.is_none() => {
                errs.push(format!("node `{}`: missing filter spec", n.name))
            }
            Op::AddrGen if n.agen.is_none() => {
                errs.push(format!("node `{}`: missing agen spec", n.name))
            }
            Op::SyncCount | Op::DoneTree if n.expected.is_none() => {
                errs.push(format!("node `{}`: missing expected count", n.name))
            }
            _ => {}
        }
        // Every non-sink op must drive something.
        let has_out = g.all_outputs(n.id).next().is_some();
        let is_sink = matches!(n.op, Op::Store | Op::SyncCount | Op::DoneTree);
        if !has_out && !is_sink {
            errs.push(format!(
                "node `{}` ({}) drives nothing",
                n.name,
                n.op.mnemonic()
            ));
        }
    }

    if g.topo_order().is_none() {
        errs.push("graph has a cycle".to_string());
    }

    for c in &g.channels {
        if c.capacity == 0 {
            errs.push(format!(
                "channel {} ({} -> {}): zero capacity deadlocks",
                c.id,
                g.node(c.src).name,
                g.node(c.dst).name
            ));
        }
    }
    errs
}

/// Validate or fail with every finding listed.
pub fn validate(g: &Graph) -> Result<()> {
    let errs = check(g);
    if errs.is_empty() {
        Ok(())
    } else {
        bail!("DFG validation failed:\n  {}", errs.join("\n  "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::builder::Dsl;
    use crate::dfg::node::{AddrIter, Node, Op, Stage};

    #[test]
    fn valid_pipeline_passes() {
        let mut d = Dsl::new();
        d.op("g", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("a");
        d.op("ld", Op::Load, Stage::Reader).input(0, "a").out("d");
        d.op("m", Op::Mul, Stage::Compute).coeff(1.0).input(0, "d").out("p");
        d.op("st_a", Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(0, 1, 4))
            .out("wa");
        d.op("st", Op::Store, Stage::Writer)
            .input(0, "wa")
            .input(1, "p")
            .out("ack");
        d.op("sy", Op::SyncCount, Stage::Sync).expected(4).input(0, "ack");
        let g = d.build().unwrap();
        assert!(validate(&g).is_ok(), "{:?}", check(&g));
    }

    #[test]
    fn missing_coeff_flagged() {
        let mut g = Graph::new();
        let a = g.add_node(Node::new(0, "g", Op::AddrGen, Stage::Control));
        g.nodes[a].agen = Some(AddrIter::dim1(0, 1, 4));
        let m = g.add_node(Node::new(0, "m", Op::Mul, Stage::Compute));
        let s = g.add_node(Node::new(0, "s", Op::SyncCount, Stage::Sync));
        g.nodes[s].expected = Some(4);
        g.connect(a, 0, m, 0, 4);
        g.connect(m, 0, s, 0, 4);
        let errs = check(&g);
        assert!(errs.iter().any(|e| e.contains("missing coeff")), "{errs:?}");
    }

    #[test]
    fn dangling_output_flagged() {
        let mut g = Graph::new();
        let a = g.add_node(Node::new(0, "g", Op::AddrGen, Stage::Control));
        g.nodes[a].agen = Some(AddrIter::dim1(0, 1, 4));
        let errs = check(&g);
        assert!(errs.iter().any(|e| e.contains("drives nothing")), "{errs:?}");
    }

    #[test]
    fn zero_capacity_flagged() {
        let mut g = Graph::new();
        let a = g.add_node(Node::new(0, "g", Op::AddrGen, Stage::Control));
        g.nodes[a].agen = Some(AddrIter::dim1(0, 1, 4));
        let s = g.add_node(Node::new(0, "s", Op::SyncCount, Stage::Sync));
        g.nodes[s].expected = Some(4);
        g.connect(a, 0, s, 0, 0);
        let errs = check(&g);
        assert!(errs.iter().any(|e| e.contains("zero capacity")), "{errs:?}");
    }
}
