//! Typed public error surface.
//!
//! Internally the crate uses the vendored string-backed `anyhow` shim —
//! cheap context chaining, one rendered message. At the *public*
//! boundaries (`compile`, `CompiledStencil::{save,load,parse}`,
//! `Session::run`, the CLI) callers need to branch on failure class
//! without parsing prose: a serving daemon retries a transient fault,
//! rejects a malformed artifact permanently, and sheds load on
//! `DeadlineExceeded`. [`ScgraError`] is that classification.
//!
//! Conversions are two-way and free of churn at internal call sites:
//! `ScgraError` implements `std::error::Error`, so the shim's blanket
//! `From<E: Error>` lifts it into `anyhow::Error` wherever `?` is used
//! inside an `anyhow` function, and [`ScgraError::classify`] maps a
//! rendered internal error back into the best-fitting variant at the
//! boundary (structured variants are constructed directly where the
//! failure is detected; `classify` only catches what bubbled up as
//! prose).

use std::fmt;

/// The public failure classification for the compile/execute API.
#[derive(Debug, Clone, PartialEq)]
pub enum ScgraError {
    /// A saved artifact failed structural validation: truncated file,
    /// wrong version line, unparseable manifest or config body, or a
    /// parsed spec that is internally inconsistent (radii vs extents,
    /// tap counts, grids that would over-allocate).
    MalformedArtifact(String),
    /// The stencil specification itself is unusable: empty or
    /// degenerate dims, radii that leave no interior, mismatched taps.
    InfeasibleSpec(String),
    /// The workload is structurally fine but exceeds a budget: grid
    /// larger than the serve path will buffer, or no decomposition
    /// fits the fabric token budget.
    OverBudget(String),
    /// The machine description is unusable: a zero `hops_per_cycle`
    /// (a divisor in hop-latency math), a non-positive clock or
    /// bandwidth, an empty PE grid. Rejected at the `compile`/config
    /// boundary, before any planning arithmetic can divide by it.
    InvalidMachine(String),
    /// Filesystem failure while reading or writing an artifact.
    Io(String),
    /// A tile task panicked (the pool itself recovers and respawns —
    /// this reports the failed *run*, not a dead executor).
    PoolPoisoned(String),
    /// The simulator made no progress for the quiet period; the
    /// message is the full forensic report (blocked nodes, full/empty
    /// channels with endpoint ids, oldest outstanding memory ticket).
    Deadlock(String),
    /// The run's wall-clock deadline expired; in-flight tile tasks
    /// were cancelled. Carries how far the run got.
    DeadlineExceeded {
        completed_tasks: usize,
        total_tasks: usize,
        deadline_ms: u64,
    },
    /// Static analysis rejected the compiled artifact: the message is
    /// the denied `scgra check` diagnostics (rule ids, locations,
    /// one-line findings), rendered worst-first.
    AnalysisFailed(String),
    /// Command-line usage error (unknown flag, malformed value).
    Usage(String),
    /// Anything else that escaped classification.
    Internal(String),
}

impl ScgraError {
    /// Stable machine-readable tag for logs and protocol error codes.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::MalformedArtifact(_) => "malformed-artifact",
            Self::InfeasibleSpec(_) => "infeasible-spec",
            Self::OverBudget(_) => "over-budget",
            Self::InvalidMachine(_) => "invalid-machine",
            Self::Io(_) => "io",
            Self::PoolPoisoned(_) => "pool-poisoned",
            Self::Deadlock(_) => "deadlock",
            Self::DeadlineExceeded { .. } => "deadline-exceeded",
            Self::AnalysisFailed(_) => "analysis-failed",
            Self::Usage(_) => "usage",
            Self::Internal(_) => "internal",
        }
    }

    /// True for failures a serving layer may retry verbatim (transient
    /// by construction), false for permanent rejections.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Self::PoolPoisoned(_) | Self::DeadlineExceeded { .. }
        )
    }

    /// Map a rendered internal error onto the best-fitting variant.
    /// Structured failures are constructed at their detection site;
    /// this only classifies prose that crossed the boundary, keyed on
    /// the stable prefixes the simulator and pool emit.
    pub(crate) fn classify(e: anyhow::Error) -> Self {
        let msg = e.to_string();
        if msg.contains("deadlock: no progress") {
            Self::Deadlock(msg)
        } else if msg.contains("tile task") && msg.contains("panicked") {
            Self::PoolPoisoned(msg)
        } else {
            Self::Internal(msg)
        }
    }
}

impl fmt::Display for ScgraError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::MalformedArtifact(m)
            | Self::InfeasibleSpec(m)
            | Self::OverBudget(m)
            | Self::InvalidMachine(m)
            | Self::Io(m)
            | Self::PoolPoisoned(m)
            | Self::Deadlock(m)
            | Self::AnalysisFailed(m)
            | Self::Usage(m)
            | Self::Internal(m) => f.write_str(m),
            Self::DeadlineExceeded {
                completed_tasks,
                total_tasks,
                deadline_ms,
            } => write!(
                f,
                "deadline exceeded: {completed_tasks}/{total_tasks} tile tasks \
                 completed within {deadline_ms} ms; in-flight tasks cancelled"
            ),
        }
    }
}

impl std::error::Error for ScgraError {}

impl From<anyhow::Error> for ScgraError {
    fn from(e: anyhow::Error) -> Self {
        Self::classify(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_keys_on_stable_prefixes() {
        let d = ScgraError::classify(anyhow::anyhow!(
            "deadlock: no progress for 360 cycles (at cycle 512)"
        ));
        assert_eq!(d.kind(), "deadlock");
        let p = ScgraError::classify(anyhow::anyhow!("tile task 3 panicked: boom"));
        assert_eq!(p.kind(), "pool-poisoned");
        assert!(p.is_transient());
        let o = ScgraError::classify(anyhow::anyhow!("anything else"));
        assert_eq!(o.kind(), "internal");
        assert!(!o.is_transient());
    }

    #[test]
    fn round_trips_through_the_anyhow_shim() {
        fn inner() -> Result<(), ScgraError> {
            Err(ScgraError::OverBudget("grid too large".into()))
        }
        fn outer() -> anyhow::Result<()> {
            inner()?; // blanket From<E: std::error::Error> lifts it
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "grid too large");
    }

    #[test]
    fn deadline_display_carries_progress() {
        let e = ScgraError::DeadlineExceeded {
            completed_tasks: 3,
            total_tasks: 16,
            deadline_ms: 50,
        };
        let s = e.to_string();
        assert!(s.contains("3/16"), "{s}");
        assert!(s.contains("50 ms"), "{s}");
    }
}
