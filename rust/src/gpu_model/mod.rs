//! §VII — the NVIDIA V100 baseline as an analytical model.
//!
//! The paper compares against hand-optimized CUDA kernels measured on a
//! physical V100. No V100 exists in this environment, so per the
//! substitution rule (DESIGN.md #3) this module models the two
//! implementations §VII describes:
//!
//! * **SMEM kernel** — one thread per output cell, explicit shared-memory
//!   tiles; bound by redundant SMEM traffic at ~60 % SMEM bandwidth
//!   utilization (the paper measured 1900 GFLOPS for the 2-D stencil).
//! * **Register-caching kernel** — each warp computes a 32x8 block, 8
//!   outputs per thread, circular register shifts; bound by the register
//!   file limiting resident warps (2300 GFLOPS measured).
//!
//! The occupancy model is mechanistic (registers/thread and SMEM/block →
//! resident warps → latency-hiding efficiency x a fixed 0.9 sync/bank-
//! conflict discount); its constants were chosen once so the paper's
//! published anchors fall out within ~10 %:
//! 90 % of roofline (1-D r8 DP), 87 % (2-D r2 DP), 48 % (2-D r12 DP,
//! = 2300/4800), 77/80 % (Maruyama 3-D r4 SP/DP), 56 % (3-D r8 SP),
//! 36 % (3-D r12 SP). Tests pin each anchor.

pub mod v100;

pub use v100::{Occupancy, V100};

use crate::stencil::StencilSpec;

/// Floating-point precision of a GPU kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    pub fn bytes(self) -> f64 {
        match self {
            Precision::F32 => 4.0,
            Precision::F64 => 8.0,
        }
    }
}

/// Stencil descriptor for the GPU model — unlike [`StencilSpec`] it
/// predates the shape generalization and always carried the 3-D
/// configurations §VII reports; `dense` marks a box (full-window)
/// neighborhood.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuStencil {
    /// 1, 2 or 3 dimensions.
    pub dims: u8,
    /// Radius per dimension (unused dims = 0).
    pub r: [usize; 3],
    /// Grid extent per dimension (unused dims = 1).
    pub grid: [usize; 3],
    pub precision: Precision,
    /// Dense box window instead of a star.
    pub dense: bool,
}

impl GpuStencil {
    pub fn d1(n: usize, r: usize, p: Precision) -> Self {
        Self { dims: 1, r: [r, 0, 0], grid: [n, 1, 1], precision: p, dense: false }
    }

    pub fn d2(nx: usize, ny: usize, rx: usize, ry: usize, p: Precision) -> Self {
        Self { dims: 2, r: [rx, ry, 0], grid: [nx, ny, 1], precision: p, dense: false }
    }

    pub fn d3(n: [usize; 3], r: usize, p: Precision) -> Self {
        Self { dims: 3, r: [r, r, r], grid: n, precision: p, dense: false }
    }

    /// Mark the neighborhood as a dense box window.
    pub fn dense(mut self) -> Self {
        self.dense = true;
        self
    }

    /// Taps per output. Star: `(2rx+1) + 2ry + 2rz`; box: the dense
    /// `(2rx+1)(2ry+1)(2rz+1)` window.
    pub fn taps(&self) -> usize {
        if self.dense {
            self.r.iter().map(|&r| 2 * r + 1).product()
        } else {
            2 * self.r[0] + 1 + 2 * self.r[1] + 2 * self.r[2]
        }
    }

    /// FLOPs per computed output (`2*taps - 1`).
    pub fn flops_per_output(&self) -> f64 {
        2.0 * self.taps() as f64 - 1.0
    }

    pub fn grid_points(&self) -> f64 {
        self.grid.iter().product::<usize>() as f64
    }

    pub fn interior_outputs(&self) -> f64 {
        (0..3)
            .map(|d| (self.grid[d].saturating_sub(2 * self.r[d])).max(1) as f64)
            .product()
    }

    /// Arithmetic intensity with read-once/write-once traffic — the same
    /// §VI formula the CGRA roofline uses.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops_per_output() * self.interior_outputs()
            / (2.0 * self.grid_points() * self.precision.bytes())
    }

    /// Arithmetic intensity when a fraction `redundant` of the grid is
    /// re-read (the halo re-loads of a tile-decomposed run): read
    /// `1 + redundant` grids, write one. Used to compare the GPU model
    /// like-for-like against a decomposed CGRA array, whose
    /// `RunReport::redundant_read_fraction` reports the same quantity.
    pub fn arithmetic_intensity_with_redundancy(&self, redundant: f64) -> f64 {
        self.flops_per_output() * self.interior_outputs()
            / ((2.0 + redundant) * self.grid_points() * self.precision.bytes())
    }

    /// The GPU-side descriptor for the same workload as a CGRA spec —
    /// any dimensionality, star or box.
    pub fn from_spec(s: &StencilSpec, p: Precision) -> Self {
        let mut g = if s.is_1d() {
            Self::d1(s.nx, s.rx, p)
        } else if s.is_3d() {
            Self {
                dims: 3,
                r: [s.rx, s.ry, s.rz],
                grid: [s.nx, s.ny, s.nz],
                precision: p,
                dense: false,
            }
        } else {
            Self::d2(s.nx, s.ny, s.rx, s.ry, p)
        };
        g.dense = s.is_box();
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_arithmetic_intensities_match_cgra_side() {
        let s1 = GpuStencil::from_spec(&StencilSpec::paper_1d(), Precision::F64);
        assert!((s1.arithmetic_intensity() - 2.06).abs() < 0.01);
        let s2 = GpuStencil::from_spec(&StencilSpec::paper_2d(), Precision::F64);
        assert!((s2.arithmetic_intensity() - 5.59).abs() < 0.01);
    }

    #[test]
    fn taps_3d() {
        let s = GpuStencil::d3([384, 384, 384], 8, Precision::F32);
        assert_eq!(s.taps(), 17 + 16 + 16);
    }

    #[test]
    fn redundancy_deflates_intensity() {
        let g = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
        assert!(
            (g.arithmetic_intensity_with_redundancy(0.0) - g.arithmetic_intensity())
                .abs()
                < 1e-12
        );
        assert!(g.arithmetic_intensity_with_redundancy(0.5) < g.arithmetic_intensity());
    }

    #[test]
    fn f32_doubles_intensity() {
        let a = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
        let b = GpuStencil::d2(960, 449, 12, 12, Precision::F32);
        assert!((b.arithmetic_intensity() / a.arithmetic_intensity() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dense_box_taps_and_intensity() {
        let star = GpuStencil::d2(64, 64, 1, 1, Precision::F64);
        let boxed = star.dense();
        assert_eq!(star.taps(), 5);
        assert_eq!(boxed.taps(), 9);
        assert!(boxed.arithmetic_intensity() > star.arithmetic_intensity());
    }

    #[test]
    fn from_spec_covers_3d_and_box() {
        let s3 = StencilSpec::heat3d(32, 24, 16, 0.1);
        let g3 = GpuStencil::from_spec(&s3, Precision::F64);
        assert_eq!(g3.dims, 3);
        assert_eq!(g3.taps(), 7);
        assert!(
            (g3.arithmetic_intensity() - s3.arithmetic_intensity()).abs() < 1e-12,
            "GPU and CGRA AI must agree for the same workload"
        );

        let sb = StencilSpec::box2d(
            48,
            32,
            1,
            1,
            crate::stencil::spec::uniform_box_taps(1, 1, 0),
        )
        .unwrap();
        let gb = GpuStencil::from_spec(&sb, Precision::F64);
        assert!(gb.dense);
        assert_eq!(gb.taps(), 9);
        assert!((gb.arithmetic_intensity() - sb.arithmetic_intensity()).abs() < 1e-12);
    }
}
