//! The V100 occupancy + kernel performance model (§VII).
//!
//! Modeling chain for the register-caching kernel:
//!
//! 1. registers/thread grow with the taps held in registers
//!    (`48 + 4 * (rx + ry + rz)` — the circular shift window per dim);
//! 2. SMEM/block is the halo'd 32x8 tile the warp stages;
//! 3. resident warps = min(register-file limit, SMEM limit, HW max);
//! 4. SMEM latency (~25 cycles, §VII) needs ~25 eligible warps to hide;
//!    efficiency = 0.9 * min(1, warps / 25) — the 0.9 covers
//!    `__syncthreads` and residual bank conflicts;
//! 5. GFLOPS = efficiency * roofline(AI).
//!
//! The SMEM (thread-per-cell) kernel is instead bound by redundant SMEM
//! traffic: every output re-reads all `taps` neighbours from SMEM at the
//! ~60 % utilization the paper measured.

use super::{GpuStencil, Precision};

/// V100 hardware constants (SXM2).
#[derive(Debug, Clone, PartialEq)]
pub struct V100 {
    /// Peak copy bandwidth assumed by the paper (GB/s).
    pub bw_gbps: f64,
    /// Peak FP64 GFLOPS.
    pub peak_dp_gflops: f64,
    /// Peak FP32 GFLOPS.
    pub peak_sp_gflops: f64,
    pub sms: usize,
    pub regs_per_sm: usize,
    pub smem_kib_per_sm: usize,
    pub max_warps_per_sm: usize,
    /// SMEM read latency in cycles (§VII: "more than 25 clocks").
    pub smem_latency: f64,
    /// SMEM bytes per SM per clock.
    pub smem_bytes_per_clk: f64,
    /// Core clock (GHz).
    pub clock_ghz: f64,
    /// Measured SMEM bandwidth utilization (§VII: "around 60%").
    pub smem_utilization: f64,
    /// Sync + residual-bank-conflict discount.
    pub sync_discount: f64,
}

impl Default for V100 {
    fn default() -> Self {
        Self::paper()
    }
}

impl V100 {
    pub fn paper() -> Self {
        Self {
            bw_gbps: 850.0,
            peak_dp_gflops: 7800.0,
            peak_sp_gflops: 15700.0,
            sms: 80,
            regs_per_sm: 65536,
            smem_kib_per_sm: 96,
            max_warps_per_sm: 64,
            smem_latency: 25.0,
            smem_bytes_per_clk: 128.0,
            clock_ghz: 1.38,
            smem_utilization: 0.6,
            sync_discount: 0.9,
        }
    }

    fn peak(&self, p: Precision) -> f64 {
        match p {
            Precision::F32 => self.peak_sp_gflops,
            Precision::F64 => self.peak_dp_gflops,
        }
    }

    /// Memory roofline for the workload: `min(BW * AI, peak)` — the
    /// Table-I "peak" (4.8 TFLOPS for the 2-D stencil at AI 5.59).
    pub fn roofline_gflops(&self, s: &GpuStencil) -> f64 {
        (self.bw_gbps * s.arithmetic_intensity()).min(self.peak(s.precision))
    }

    /// Occupancy of the register-caching kernel.
    pub fn occupancy(&self, s: &GpuStencil) -> Occupancy {
        let r_sum: usize = s.r.iter().sum();
        let regs_per_thread = 48 + 4 * r_sum;
        let warps_reg = self.regs_per_sm / (32 * regs_per_thread);
        // 32x8-element tile + halo staged in SMEM per 256-thread block.
        let tile_b =
            ((32 + 2 * s.r[0]) * (8 + 2 * s.r[1])) as f64 * s.precision.bytes();
        let blocks_smem =
            ((self.smem_kib_per_sm * 1024) as f64 / tile_b).floor().max(1.0) as usize;
        let warps_smem = blocks_smem * 8; // 256 threads = 8 warps/block
        let warps = warps_reg.min(warps_smem).min(self.max_warps_per_sm);
        Occupancy {
            regs_per_thread,
            warps_reg,
            smem_per_block_bytes: tile_b as usize,
            warps_smem,
            warps,
        }
    }

    /// Fraction of the roofline the register-caching kernel achieves.
    pub fn regcache_efficiency(&self, s: &GpuStencil) -> f64 {
        let occ = self.occupancy(s);
        self.sync_discount * (occ.warps as f64 / self.smem_latency).min(1.0)
    }

    /// Register-caching kernel GFLOPS (the §VII "2300 GFLOPS" kernel).
    pub fn regcache_gflops(&self, s: &GpuStencil) -> f64 {
        self.regcache_efficiency(s) * self.roofline_gflops(s)
    }

    /// SMEM (thread-per-cell) kernel GFLOPS (the §VII "1900 GFLOPS"
    /// kernel): redundant-SMEM-traffic bound.
    pub fn smem_gflops(&self, s: &GpuStencil) -> f64 {
        let smem_bw = self.sms as f64
            * self.smem_bytes_per_clk
            * self.clock_ghz
            * self.smem_utilization; // GB/s of usable SMEM bandwidth
        let bytes_per_output = s.taps() as f64 * s.precision.bytes();
        let smem_bound = smem_bw / bytes_per_output * s.flops_per_output();
        // Sync + bank-conflict discount applies to whichever roof binds:
        // even a bandwidth-bound SMEM kernel pays __syncthreads.
        smem_bound.min(self.roofline_gflops(s)) * self.sync_discount
    }

    /// The best GPU implementation — what Table I compares against.
    pub fn best_gflops(&self, s: &GpuStencil) -> f64 {
        self.regcache_gflops(s).max(self.smem_gflops(s))
    }
}

/// Occupancy breakdown of the register-caching kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Occupancy {
    pub regs_per_thread: usize,
    pub warps_reg: usize,
    pub smem_per_block_bytes: usize,
    pub warps_smem: usize,
    /// Resident warps per SM after all limits.
    pub warps: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: fn() -> V100 = V100::paper;

    #[test]
    fn anchor_2d_r12_dp_is_48_pct_and_2300_gflops() {
        // Table I: V100 achieves 48% of the 4.8 TFLOPS roofline = 2.3 TF.
        let s = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
        let roof = V().roofline_gflops(&s);
        assert!((roof - 4750.0).abs() < 60.0, "roof {roof}");
        let eff = V().regcache_efficiency(&s);
        assert!((eff - 0.48).abs() < 0.08, "eff {eff}");
        let g = V().regcache_gflops(&s);
        assert!((g - 2300.0).abs() < 200.0, "gflops {g}");
    }

    #[test]
    fn anchor_1d_r8_dp_is_90_pct() {
        let s = GpuStencil::d1(194400, 8, Precision::F64);
        let eff = V().regcache_efficiency(&s);
        assert!((eff - 0.90).abs() < 0.05, "eff {eff}");
    }

    #[test]
    fn anchor_2d_r2_dp_is_87_pct() {
        // §VIII-A: "a 2D stencil with rx=ry=2 achieved 87% of peak".
        let s = GpuStencil::d2(960, 449, 2, 2, Precision::F64);
        let eff = V().regcache_efficiency(&s);
        assert!((eff - 0.87).abs() < 0.05, "eff {eff}");
    }

    #[test]
    fn anchor_3d_r8_sp_is_56_pct() {
        let s = GpuStencil::d3([384, 384, 384], 8, Precision::F32);
        let eff = V().regcache_efficiency(&s);
        assert!((eff - 0.56).abs() < 0.08, "eff {eff}");
    }

    #[test]
    fn anchor_3d_r12_sp_is_36_pct() {
        let s = GpuStencil::d3([512, 512, 512], 12, Precision::F32);
        let eff = V().regcache_efficiency(&s);
        assert!((eff - 0.36).abs() < 0.06, "eff {eff}");
    }

    #[test]
    fn anchor_maruyama_3d_r4() {
        // §VII: 77% SP / 80% DP on the 384x384x128 grid, r=4.
        let sp = GpuStencil::d3([384, 384, 128], 4, Precision::F32);
        let dp = GpuStencil::d3([384, 384, 128], 4, Precision::F64);
        let esp = V().regcache_efficiency(&sp);
        let edp = V().regcache_efficiency(&dp);
        assert!((esp - 0.77).abs() < 0.08, "sp {esp}");
        assert!((edp - 0.80).abs() < 0.08, "dp {edp}");
    }

    #[test]
    fn smem_kernel_is_slower_than_regcache_for_2d_r12() {
        // §VII: 1900 (SMEM) vs 2300 (register caching).
        let s = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
        let smem = V().smem_gflops(&s);
        let reg = V().regcache_gflops(&s);
        assert!(smem < reg, "{smem} !< {reg}");
        assert!((smem - 1900.0).abs() < 300.0, "smem {smem}");
    }

    #[test]
    fn efficiency_declines_with_radius() {
        let mut last = f64::INFINITY;
        for r in [2usize, 4, 8, 12] {
            let s = GpuStencil::d2(960, 449, r, r, Precision::F64);
            let e = V().regcache_efficiency(&s);
            assert!(e <= last + 1e-12, "r={r}: {e} > {last}");
            last = e;
        }
    }

    #[test]
    fn occupancy_limits_identified() {
        let s = GpuStencil::d2(960, 449, 12, 12, Precision::F64);
        let o = V().occupancy(&s);
        // §VII: "the bottleneck is the register file size".
        assert!(o.warps_reg < o.warps_smem, "{o:?}");
        assert_eq!(o.warps, o.warps_reg.min(o.warps_smem).min(64));
    }
}
