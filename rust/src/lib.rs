//! # stencil-cgra
//!
//! Reproduction of *"Mapping Stencils on Coarse-grained Reconfigurable
//! Spatial Architecture"* (Tithi et al., 2020), grown from the paper's
//! two Table-I workloads into a **general stencil-mapping system**: any
//! 1-D/2-D/3-D grid, star or dense-box neighborhood, described by one
//! shape-based specification and compiled to the same
//! reader / compute / writer / sync dataflow the paper derives in §III.
//!
//! ## The shape model
//!
//! [`StencilSpec`] carries a [`stencil::spec::StencilShape`] (`Star` or
//! `Box`), per-dimension extents (`dims()`) and radii (`radii()`), and
//! per-tap coefficients. [`StencilSpec::chain_taps`] linearizes the
//! neighborhood into the fused MUL + MAC chain order used everywhere —
//! by the DFG builders, the cycle simulator and the golden oracles — so
//! all layers accumulate in the same f64 association order and agree
//! bitwise. Mappings by dimensionality:
//!
//! * **1-D** ([`stencil::map1d`], §III-A): `w` interleaved readers
//!   broadcast to per-tap data filters (`0^m 1^n 0^p` bit patterns) in
//!   front of each worker's MAC chain.
//! * **2-D** ([`stencil::map2d`], §III-B): shared readers feed `2*ry`
//!   row-sized delay-line stages (mandatory buffering); row/col-id
//!   filters select each tap's shifted interior window. Box windows run
//!   the same front end with one fused chain over the dense window.
//! * **3-D** ([`stencil::map3d`]): *plane buffering* — a z-neighbor
//!   lives `ny` rows away in the row-major stream, so a plane buffer is
//!   `ny` row buffers; a tap at offset `(dz, dy, dx)` reads its
//!   reader's delay line at stage `(rz*ny + ry) - (dz*ny + dy)` through
//!   a volume filter that unflattens the `z*ny + y` row tag.
//!
//! [`stencil::build_graph`] dispatches any spec to its mapping.
//!
//! ## Compile once, execute many
//!
//! The public API splits the paper's flow (§III map once, stream many
//! grids) into two phases:
//!
//! ```text
//! let artifact = Arc::new(compile(&spec, steps, &CompileOptions::default())?);
//! let session  = Session::new(artifact, Machine::paper());
//! let outcome  = session.run(&grid)?;          // &self — call from any thread
//! ```
//!
//! [`compile::compile`] does everything data-independent exactly once:
//! resolves the worker count, plans the N-dim tile decomposition
//! (including the §IV fused depth and a shallower tail chunk), builds
//! and **places** one DFG per distinct tile shape
//! ([`cgra::PlacedGraph`]), and computes the halo-adjusted roofline.
//! The resulting [`compile::CompiledStencil`] is immutable and
//! `Arc`-shareable; [`session::Session`] executes it concurrently
//! without ever re-planning (pinned by work counters in
//! [`stencil::metrics`]). [`compile::CompileCache`] adds an LRU keyed
//! by `(spec, steps, options)` for serve paths, and
//! [`compile::CompiledStencil::save`]/`load` serialize artifacts in the
//! `runtime` manifest schema.
//!
//! ## Layers
//!
//! * [`dfg`] — the dataflow-graph IR and the §V DSL builder that emits
//!   high-level assembly and Graphviz dot.
//! * [`stencil`] — the mappings above plus [`stencil::decomp`], the
//!   N-dim tile-decomposition subsystem (slab/pencil/block cuts with
//!   per-axis halos, budget-checked against the §III-B capacity math),
//!   and the shape-generic §IV temporal pipeline
//!   ([`stencil::temporal::build_nd`]: `T` fused time-steps of any
//!   star/box spec, one grid load per chunk; `decomp::plan_fused`
//!   searches the deepest depth a tile's token budget admits).
//! * [`cgra`] — a functional + timing cycle simulator of the target
//!   triggered-instruction CGRA (PEs, bounded channels, mesh placement,
//!   scratchpad, cache and a bandwidth-limited DRAM channel).
//! * [`roofline`] — the §VI roofline model and worker-count optimizer,
//!   shape-aware through the spec's arithmetic-intensity math, plus the
//!   halo-adjusted multi-tile view ([`roofline::analyze_tiled`]).
//! * [`gpu_model`] — the §VII analytical NVIDIA V100 baseline, covering
//!   the paper's 1-D/2-D/3-D anchors and the box-window extension.
//! * [`mod@compile`] — phase 1: planning. [`compile::compile`] turns
//!   `(spec, steps, options)` into an immutable
//!   [`compile::CompiledStencil`] (plan + placed per-tile-shape DFGs +
//!   roofline analysis), with an LRU [`compile::CompileCache`] and
//!   save/load in the runtime's manifest schema.
//! * [`analysis`] — the static verifier behind `scgra check`: four rule
//!   families (deadlock buffering, exchange-partition soundness,
//!   residency feasibility, plan lints) proving a compiled artifact
//!   executable *before* any simulation, gated at compile/load time by
//!   [`analysis::CheckLevel`].
//! * [`session`] — phase 2: execution. [`session::Session`] is a
//!   `Send + Sync` executor over a compiled artifact: the 16-tile
//!   leader/worker engine with halo/redundant-load accounting per
//!   chunk, callable concurrently through `&self`.
//! * [`coordinator`] — the legacy one-call wrappers: a deprecated
//!   compile-and-run-once [`coordinator::Coordinator`] shim plus the
//!   §IV divide-and-conquer / hybrid CPU+CGRA mode.
//! * [`runtime`] — the artifact runtime: reads `artifacts/manifest.txt`
//!   and executes each named kernel with a native interpreter backed by
//!   the golden oracles (the PJRT/XLA path is an offline substitution;
//!   see `runtime`'s module docs).
//! * [`verify`] — golden oracles for every shape
//!   ([`verify::golden::stencil_ref`]) and one-call simulate-and-check
//!   helpers; `rust/tests/differential.rs` fuzzes random specs through
//!   the full mapper → placer → simulator stack against them.
//!
//! ## Quick start
//!
//! ```text
//! scgra run --shape star --dims 48,32,24 --radii 2,2,2 --tiles 16 --decomp pencil
//! ```
//!
//! pencil-decomposes a 13-point 3-D star across 16 simulated CGRA
//! tiles (plane buffering per pencil), simulates them cycle-by-cycle,
//! reports achieved GFLOPS and halo overhead against the roofline and
//! checks the stitched output against the oracle. See
//! `examples/acoustic_3d.rs` for the library-level version.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod cgra;
pub mod cli;
pub mod compile;
pub mod config;
pub mod coordinator;
pub mod dfg;
pub mod error;
pub mod gpu_model;
pub mod roofline;
pub mod runtime;
pub mod session;
pub mod stencil;
pub mod util;
pub mod verify;

pub use analysis::{check, CheckLevel, Diagnostic, Report, Severity};
pub use compile::{compile, CompileCache, CompileOptions, CompiledStencil, FuseMode};
pub use error::ScgraError;
pub use session::{ExecMode, Outcome, RunOutcome, RunReport, Session};
pub use stencil::spec::{StencilShape, StencilSpec};
pub use util::fault::FaultPlan;
pub use util::trace::{Trace, TraceMode};
