//! # stencil-cgra
//!
//! Reproduction of *"Mapping Stencils on Coarse-grained Reconfigurable
//! Spatial Architecture"* (Tithi et al., 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate implements, from scratch:
//!
//! * [`dfg`] — the dataflow-graph IR and the §V DSL builder that emits
//!   high-level assembly and Graphviz dot.
//! * [`stencil`] — the §III mapping algorithm: 1-D and 2-D star stencils
//!   decomposed into reader / compute / writer / sync workers with data
//!   filtering, mandatory buffering and strip-mining, plus the §IV
//!   temporal (multi-time-step) extension.
//! * [`cgra`] — a functional + timing cycle simulator of the target
//!   triggered-instruction CGRA (PEs, bounded channels, mesh placement,
//!   scratchpad, cache and a bandwidth-limited DRAM channel).
//! * [`roofline`] — the §VI roofline model and worker-count optimizer.
//! * [`gpu_model`] — the §VII analytical NVIDIA V100 baseline (SMEM and
//!   register-caching CUDA kernels), calibrated to the paper's anchors.
//! * [`coordinator`] — the L3 runtime: a 16-tile leader/worker manager
//!   with §IV divide-and-conquer task decomposition.
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` (AOT
//!   JAX/Pallas lowerings) and executes them as the golden numeric
//!   reference.
//! * [`verify`] — cross-checking of simulator vs native oracle vs PJRT.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! measured reproduction of every table and figure.

pub mod cgra;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dfg;
pub mod gpu_model;
pub mod roofline;
pub mod runtime;
pub mod stencil;
pub mod util;
pub mod verify;

pub use stencil::spec::StencilSpec;
