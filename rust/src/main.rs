//! `scgra` — launcher for the stencil-CGRA reproduction.
//!
//! See `scgra help` (or `rust/src/cli/mod.rs`) for the subcommands; the
//! library documentation lives on [`stencil_cgra`].

use anyhow::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    stencil_cgra::cli::run(&argv)
}
