//! §VI — roofline performance analysis.
//!
//! "The roofline analysis helps us to choose the optimal number of
//! workers for a given stencil based on its arithmetic_intensity and the
//! compute and bandwidth capacity of the target CGRA."
//!
//! The model has two roofs: the bandwidth roof `BW * AI` and the compute
//! roof `2 * #MACs * clock` (614 GFLOPS for the §VI machine). A worker
//! executes `2*(points-1) + 1` FLOPs per cycle when fully fed, so `w`
//! workers demand `w * flops_per_output * clock` GFLOPS; the optimizer
//! picks the smallest `w` that saturates the attainable roof, capped by
//! the MAC budget (`#MACs / points` workers fit).

use crate::cgra::Machine;
use crate::compile::HaloMode;
use crate::stencil::decomp::DecompPlan;
use crate::stencil::spec::BYTES_PER_POINT;
use crate::stencil::{temporal, StencilSpec};

/// One point of the roofline analysis for a given stencil + machine.
#[derive(Debug, Clone, PartialEq)]
pub struct Analysis {
    pub arithmetic_intensity: f64,
    /// Bandwidth-bound GFLOPS (`BW * AI`).
    pub bw_gflops: f64,
    /// Machine compute roof.
    pub peak_gflops: f64,
    /// `min(bw, peak)` — Fig 12's attainable point.
    pub attainable_gflops: f64,
    /// GFLOPS demanded by `w` workers at full rate.
    pub demand_gflops: f64,
    /// Chosen worker count.
    pub workers: usize,
    /// Maximum workers the MAC budget allows.
    pub max_workers: usize,
}

/// GFLOPS a single worker demands when firing every cycle.
pub fn worker_demand_gflops(spec: &StencilSpec, m: &Machine) -> f64 {
    spec.flops_per_output() * m.clock_ghz
}

/// Maximum workers that fit the MAC budget (§VI: `Y / #MACs_per_worker`).
pub fn max_workers(spec: &StencilSpec, m: &Machine) -> usize {
    (m.mac_pes / spec.points()).max(1)
}

/// Smallest worker count whose demand saturates the attainable roof,
/// capped by the MAC budget — §VI's "6 workers should be good enough to
/// saturate the achievable memory bandwidth" for the 17-pt 1-D stencil.
pub fn optimal_workers(spec: &StencilSpec, m: &Machine) -> usize {
    let attainable = m.roofline_gflops(spec.arithmetic_intensity());
    let per_worker = worker_demand_gflops(spec, m);
    let need = (attainable / per_worker).ceil() as usize;
    need.clamp(1, max_workers(spec, m))
}

/// Full §VI analysis for `spec` with `w` workers (pass
/// [`optimal_workers`] for the paper's choice).
pub fn analyze(spec: &StencilSpec, m: &Machine, w: usize) -> Analysis {
    let ai = spec.arithmetic_intensity();
    Analysis {
        arithmetic_intensity: ai,
        bw_gflops: m.bw_gbps * ai,
        peak_gflops: m.peak_gflops(),
        attainable_gflops: m.roofline_gflops(ai),
        demand_gflops: w as f64 * worker_demand_gflops(spec, m),
        workers: w,
        max_workers: max_workers(spec, m),
    }
}

/// Roofline view of a decomposed multi-tile run: halo re-reads inflate
/// DRAM traffic, deflating the effective arithmetic intensity — and with
/// it the per-tile bandwidth roof — relative to the whole-grid
/// [`Analysis`]. §IV temporal fusion pulls the other way: a `T`-deep
/// plan does ~`T` steps of FLOPs per grid round-trip, multiplying the
/// effective intensity (the fused-depth term below).
#[derive(Debug, Clone, PartialEq)]
pub struct TiledAnalysis {
    /// Whole-grid (halo-free, single-step) analysis.
    pub base: Analysis,
    /// Tile tasks in the plan.
    pub tasks: usize,
    /// §IV fused depth of the plan (1 = single-step).
    pub fused_steps: usize,
    /// Points loaded but not owned, summed over tiles.
    pub halo_points: usize,
    /// Fraction of the grid read more than once (`Σ inputs / grid - 1`).
    pub redundant_read_fraction: f64,
    /// Input points of tiles the residency plan spills under exchange
    /// (0 under reload, where every point reloads anyway).
    pub spilled_points: usize,
    /// Arithmetic intensity with halo re-reads *and* the fused depth
    /// accounted: all fused layers' FLOPs against one grid round-trip.
    pub effective_ai: f64,
    /// Attainable GFLOPS of one tile at the effective intensity.
    pub attainable_gflops_tile: f64,
    /// Attainable GFLOPS of the whole array (`array_tiles` x tile roof).
    pub attainable_gflops_array: f64,
}

/// §VI analysis of a [`DecompPlan`] on an `array_tiles`-tile array:
/// the redundant halo loads are charged against the bandwidth roof and
/// the §IV fused depth credits all fused layers' FLOPs to the single
/// chunk round-trip (`fused_steps = 1` reduces to the plain halo math).
pub fn analyze_tiled(
    spec: &StencilSpec,
    m: &Machine,
    w: usize,
    plan: &DecompPlan,
    array_tiles: usize,
) -> TiledAnalysis {
    analyze_tiled_halo(spec, m, w, plan, array_tiles, HaloMode::Reload, 0)
}

/// [`analyze_tiled`] with the halo mode made explicit: under either
/// exchange flavour the geometric overlap moves over in-fabric channels
/// instead of DRAM, so the redundant-read term drops out of the
/// steady-state byte count and the effective intensity recovers the
/// halo-free fused value. `Reload` charges the plan's full overlap — the
/// differential baseline.
///
/// `spilled_points` is the residency plan's warm-chunk DRAM consequence
/// ([`crate::compile::ResidencyPlan::spilled_points`]): input points of
/// tiles whose boxes do not fit on fabric, which re-read through the
/// cache every warm chunk even under exchange. Under `Reload` the term
/// is ignored — every point already reloads.
#[allow(clippy::too_many_arguments)]
pub fn analyze_tiled_halo(
    spec: &StencilSpec,
    m: &Machine,
    w: usize,
    plan: &DecompPlan,
    array_tiles: usize,
    halo: HaloMode,
    spilled_points: usize,
) -> TiledAnalysis {
    let base = analyze(spec, m, w);
    let grid = spec.grid_points() as f64;
    let (redundant, spilled) = match halo {
        HaloMode::Reload => (plan.redundant_read_fraction(spec), 0),
        HaloMode::Exchange | HaloMode::ExchangeFree => (0.0, spilled_points),
    };
    let fused_steps = plan.fused_steps.max(1);
    // One fused chunk: read the grid (1 + redundant) times plus the
    // spilled boxes, write it once, compute fused_steps trapezoid
    // layers.
    let bytes = (2.0 + redundant + spilled as f64 / grid) * grid * BYTES_PER_POINT;
    let effective_ai = temporal::total_flops(spec, fused_steps) / bytes;
    let tile_roof = m.roofline_gflops(effective_ai);
    TiledAnalysis {
        base,
        tasks: plan.tiles.len(),
        fused_steps,
        halo_points: plan.halo_points(),
        redundant_read_fraction: redundant,
        spilled_points: spilled,
        effective_ai,
        attainable_gflops_tile: tile_roof,
        attainable_gflops_array: array_tiles as f64 * tile_roof,
    }
}

/// The (AI, attainable-GFLOPS) series of Fig 12: log-spaced arithmetic
/// intensities from `lo` to `hi`.
pub fn roofline_series(m: &Machine, lo: f64, hi: f64, points: usize) -> Vec<(f64, f64)> {
    assert!(points >= 2 && lo > 0.0 && hi > lo);
    let step = (hi / lo).powf(1.0 / (points - 1) as f64);
    (0..points)
        .map(|i| {
            let ai = lo * step.powi(i as i32);
            (ai, m.roofline_gflops(ai))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_1d_worker_choice_is_6() {
        let spec = StencilSpec::paper_1d();
        let m = Machine::paper();
        assert_eq!(optimal_workers(&spec, &m), 6);
        let a = analyze(&spec, &m, 6);
        // §VI: 6 workers demand 237 GFLOPS >= the 206 GFLOPS bw roof.
        assert!((a.demand_gflops - 237.6).abs() < 0.5, "{}", a.demand_gflops);
        assert!((a.attainable_gflops - 206.0).abs() < 1.0);
        assert!(a.demand_gflops >= a.attainable_gflops);
    }

    #[test]
    fn paper_2d_worker_choice_is_5() {
        let spec = StencilSpec::paper_2d();
        let m = Machine::paper();
        // §VI: only 5 workers fit (5 * 49 = 245 <= 256 MACs).
        assert_eq!(max_workers(&spec, &m), 5);
        assert_eq!(optimal_workers(&spec, &m), 5);
        let a = analyze(&spec, &m, 5);
        // §VI: 1.2 * (48*2*5 + 5) = 582 GFLOPS demand, 559 attainable.
        assert!((a.demand_gflops - 582.0).abs() < 0.5, "{}", a.demand_gflops);
        assert!((a.attainable_gflops - 559.0).abs() < 1.0);
    }

    #[test]
    fn one_extra_worker_would_not_fit_2d() {
        let spec = StencilSpec::paper_2d();
        let m = Machine::paper();
        assert!(6 * spec.points() > m.mac_pes);
    }

    #[test]
    fn series_is_monotone_then_flat() {
        let m = Machine::paper();
        let s = roofline_series(&m, 0.1, 100.0, 32);
        assert_eq!(s.len(), 32);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9);
        }
        assert!((s.last().unwrap().1 - m.peak_gflops()).abs() < 1e-9);
    }

    #[test]
    fn low_ai_is_bw_bound_high_ai_compute_bound() {
        let m = Machine::paper();
        let spec = StencilSpec::paper_1d();
        let a = analyze(&spec, &m, 6);
        assert!(a.bw_gflops < a.peak_gflops); // bw-bound workload
        assert_eq!(a.attainable_gflops, a.bw_gflops);
    }

    #[test]
    fn optimal_workers_at_least_one() {
        let spec = StencilSpec::dim1(64, vec![0.2, 0.2, 0.2]).unwrap();
        let m = Machine::paper();
        assert!(optimal_workers(&spec, &m) >= 1);
    }

    #[test]
    fn tiled_analysis_charges_halo_rereads() {
        use crate::stencil::decomp::{self, DecompKind};
        let spec = StencilSpec::heat3d(24, 20, 16, 0.1);
        let m = Machine::paper();
        let w = 2;
        let single =
            decomp::plan(&spec, w, decomp::DEFAULT_FABRIC_TOKENS, DecompKind::Auto, 1)
                .unwrap();
        let one = analyze_tiled(&spec, &m, w, &single, 1);
        assert_eq!(one.tasks, 1);
        assert_eq!(one.halo_points, 0);
        assert!((one.effective_ai - one.base.arithmetic_intensity).abs() < 1e-12);

        let multi =
            decomp::plan(&spec, w, decomp::DEFAULT_FABRIC_TOKENS, DecompKind::Pencil, 16)
                .unwrap();
        let sixteen = analyze_tiled(&spec, &m, w, &multi, 16);
        assert!(sixteen.tasks >= 16);
        assert!(sixteen.halo_points > 0);
        assert!(sixteen.redundant_read_fraction > 0.0);
        assert!(sixteen.effective_ai < sixteen.base.arithmetic_intensity);
        // The array roof still dwarfs one tile's.
        assert!(sixteen.attainable_gflops_array > sixteen.attainable_gflops_tile);
        assert!(
            (sixteen.attainable_gflops_array
                - 16.0 * sixteen.attainable_gflops_tile)
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn exchange_recovers_the_halo_free_intensity() {
        use crate::stencil::decomp::{self, DecompKind};
        let spec = StencilSpec::heat3d(24, 20, 16, 0.1);
        let m = Machine::paper();
        let w = 2;
        let multi =
            decomp::plan(&spec, w, decomp::DEFAULT_FABRIC_TOKENS, DecompKind::Pencil, 16)
                .unwrap();
        let reload = analyze_tiled_halo(&spec, &m, w, &multi, 16, HaloMode::Reload, 0);
        let exch = analyze_tiled_halo(&spec, &m, w, &multi, 16, HaloMode::Exchange, 0);
        assert!(reload.redundant_read_fraction > 0.0);
        assert_eq!(exch.redundant_read_fraction, 0.0);
        assert!(exch.effective_ai > reload.effective_ai);
        // With the overlap gone, the effective intensity is the
        // whole-grid single-step value again.
        assert!((exch.effective_ai - exch.base.arithmetic_intensity).abs() < 1e-12);
        assert!(exch.attainable_gflops_tile >= reload.attainable_gflops_tile);
        // The free-pricing flavour keeps exchange's byte model: pricing
        // changes cycles, never traffic.
        let free = analyze_tiled_halo(&spec, &m, w, &multi, 16, HaloMode::ExchangeFree, 0);
        assert_eq!(free, exch);
        // Spilled boxes re-read through the cache every warm chunk, so
        // they deflate the effective intensity; reload ignores the term
        // (every point reloads anyway).
        let spill = analyze_tiled_halo(&spec, &m, w, &multi, 16, HaloMode::Exchange, 1000);
        assert_eq!(spill.spilled_points, 1000);
        assert!(spill.effective_ai < exch.effective_ai);
        let rl = analyze_tiled_halo(&spec, &m, w, &multi, 16, HaloMode::Reload, 1000);
        assert_eq!(rl.spilled_points, 0);
        assert_eq!(rl, reload);
    }

    #[test]
    fn fused_depth_raises_effective_intensity() {
        use crate::stencil::decomp::{self, DecompKind};
        let spec = StencilSpec::heat2d(48, 32, 0.2);
        let m = Machine::paper();
        let w = 2;
        let single =
            decomp::plan(&spec, w, decomp::DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 1)
                .unwrap();
        let fused = decomp::plan_fused(
            &spec,
            w,
            decomp::DEFAULT_FABRIC_TOKENS,
            DecompKind::Slab,
            1,
            4,
        )
        .unwrap();
        assert!(fused.fused_steps > 1);
        let a1 = analyze_tiled(&spec, &m, w, &single, 1);
        let af = analyze_tiled(&spec, &m, w, &fused, 1);
        assert_eq!(a1.fused_steps, 1);
        assert_eq!(af.fused_steps, fused.fused_steps);
        // All fused layers' FLOPs against one round-trip: the effective
        // intensity grows ~linearly with depth (minus trapezoid taper).
        assert!(af.effective_ai > 1.5 * a1.effective_ai);
        assert!(af.attainable_gflops_tile >= a1.attainable_gflops_tile);
    }

    #[test]
    fn heat3d_analysis() {
        // 7-pt 3-D star: 13 FLOPs/output. AI = 13 * interior / (2*grid*8).
        let spec = StencilSpec::heat3d(96, 96, 96, 0.1);
        let m = Machine::paper();
        assert_eq!(spec.points(), 7);
        let a = analyze(&spec, &m, optimal_workers(&spec, &m));
        let want_ai = 13.0 * (94.0 * 94.0 * 94.0) / (2.0 * 96.0 * 96.0 * 96.0 * 8.0);
        assert!((a.arithmetic_intensity - want_ai).abs() < 1e-12);
        // Low-AI workload: bandwidth-bound, so the bw roof is attainable.
        assert_eq!(a.attainable_gflops, a.bw_gflops);
        // 7-pt workers are cheap; the MAC budget allows 256/7 = 36.
        assert_eq!(a.max_workers, 36);
        assert!(a.demand_gflops >= a.attainable_gflops);
    }

    #[test]
    fn box_worker_budget_counts_dense_window() {
        // 5x5x5 dense box: 125 DP ops per worker -> only 2 workers fit.
        let spec = StencilSpec::box3d(
            32,
            32,
            32,
            2,
            2,
            2,
            crate::stencil::spec::uniform_box_taps(2, 2, 2),
        )
        .unwrap();
        let m = Machine::paper();
        assert_eq!(spec.points(), 125);
        assert_eq!(max_workers(&spec, &m), 2);
        let w = optimal_workers(&spec, &m);
        assert!(w >= 1 && w <= 2);
    }
}
