//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes one line per artifact:
//!
//! ```text
//! name|file.hlo.txt|dtype|in0,in1,...|out
//! ```
//!
//! where each shape is `x`-separated dims (`96x96`, `25`) or `s` for a
//! scalar.

use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub dtype: String,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactMeta>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "s" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().with_context(|| format!("bad dim `{d}`")))
        .collect()
}

fn shape_text(s: &[usize]) -> String {
    if s.is_empty() {
        "s".to_string()
    } else {
        s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
    }
}

impl ArtifactMeta {
    /// Render back to the one-line schema [`Manifest::parse`] reads —
    /// the inverse of parsing, used by the CGRA compile phase so its
    /// saved artifacts share this manifest format.
    pub fn to_line(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}",
            self.name,
            self.file,
            self.dtype,
            self.in_shapes.iter().map(|s| shape_text(s)).collect::<Vec<_>>().join(","),
            shape_text(&self.out_shape)
        )
    }
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 5 {
                bail!("manifest line {}: expected 5 fields, got {}", i + 1, parts.len());
            }
            entries.push(ArtifactMeta {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                dtype: parts[2].to_string(),
                in_shapes: parts[3]
                    .split(',')
                    .map(parse_shape)
                    .collect::<Result<_>>()?,
                out_shape: parse_shape(parts[4])?,
            });
        }
        Ok(Self { entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_aot_schema() {
        let m = Manifest::parse(
            "stencil2d_r12_96x96|stencil2d_r12_96x96.hlo.txt|f64|96x96,25,24|96x96\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.name, "stencil2d_r12_96x96");
        assert_eq!(e.in_shapes, vec![vec![96, 96], vec![25], vec![24]]);
        assert_eq!(e.out_shape, vec![96, 96]);
    }

    #[test]
    fn scalar_shape() {
        assert_eq!(parse_shape("s").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_shape("194400").unwrap(), vec![194400]);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("just|three|fields").is_err());
        assert!(Manifest::parse("a|b|c|1xq|2").is_err());
    }

    #[test]
    fn to_line_round_trips_through_parse() {
        let line = "stencil2d_r12_96x96|stencil2d_r12_96x96.hlo.txt|f64|96x96,25,24|96x96";
        let m = Manifest::parse(line).unwrap();
        assert_eq!(m.entries[0].to_line(), line);
        let scalar = ArtifactMeta {
            name: "n".into(),
            file: "f".into(),
            dtype: "f64".into(),
            in_shapes: vec![vec![]],
            out_shape: vec![4, 2],
        };
        let re = Manifest::parse(&scalar.to_line()).unwrap();
        assert_eq!(re.entries[0], scalar);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# header\n\nn|f|f64|4|4\n").unwrap();
        assert_eq!(m.entries.len(), 1);
    }
}
