//! Artifact runtime — the L3↔L2 bridge.
//!
//! The original design loaded HLO-text artifacts (`python/compile/aot.py`
//! lowers the JAX model with the Pallas kernels inlined) and executed
//! them through the PJRT CPU client via the `xla` bindings. Those
//! bindings are not available in the offline build environment, so per
//! the substitution rule the default backend here is a **native
//! interpreter**: it reads the same `artifacts/manifest.txt` schema,
//! enforces the same input-count/shape contract, and evaluates each
//! artifact with the [`crate::verify::golden`] oracles — which are
//! checked (in pytest, against the Pallas kernels) to agree with the JAX
//! lowerings to ~1e-12. Artifact names encode their kernel:
//!
//! * `stencil1d_*` — inputs `x, coeffs`; 1-D star.
//! * `stencil2d_*` / `stencil2d_ref_*` — inputs `x, cx, cy`; 2-D star.
//! * `stencil3d_*` — inputs `x, cx, cy, cz`; 3-D star.
//! * `box2d_*` — inputs `x, window`; 2-D dense box.
//! * `heat2d_step_*` — input `x`; one 5-pt Jacobi step (alpha = 0.2).
//! * `heat2d_run<N>_*` — input `x`; `N` fused Jacobi steps.
//!
//! Re-enabling a real PJRT backend is a matter of swapping
//! [`Runtime::execute`]'s interpreter for the compiled executable cache;
//! the manifest and call sites need no change.
//!
//! The manifest schema is shared with the CGRA compile phase:
//! `crate::compile::CompiledStencil::save` writes its header in exactly
//! this line format ([`ArtifactMeta::to_line`]), so both runtimes
//! consume one artifact-description format.

pub mod artifact;

pub use artifact::{ArtifactMeta, Manifest};

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::stencil::StencilSpec;
use crate::verify::golden::{heat2d_step_ref, stencil1d_ref, stencil_ref};

/// Manifest-driven, natively-interpreted artifact runtime.
pub struct Runtime {
    manifest: Manifest,
    #[allow(dead_code)]
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Ok(Self { manifest, dir })
    }

    /// The default artifact location relative to the crate root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Execution backend identifier.
    pub fn platform(&self) -> String {
        "native-interpreter".to_string()
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.entries.iter().find(|e| e.name == name)
    }

    /// Execute artifact `name` on f64 inputs (shapes per the manifest).
    /// Returns the flattened f64 output. Takes `&self`: the runtime
    /// holds only the immutable manifest, so one shared instance can
    /// serve concurrent callers (the `Session` serve path does).
    pub fn execute(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<f64>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("unknown artifact `{name}`"))?
            .clone();
        if inputs.len() != meta.in_shapes.len() {
            bail!(
                "`{name}` expects {} inputs, got {}",
                meta.in_shapes.len(),
                inputs.len()
            );
        }
        for (i, (data, shape)) in inputs.iter().zip(&meta.in_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("`{name}` input {i}: {} elements, expected {want}", data.len());
            }
        }
        let out = interpret(&meta, inputs)?;
        let want: usize = meta.out_shape.iter().product();
        if out.len() != want {
            bail!("`{name}` produced {} elements, expected {want}", out.len());
        }
        Ok(out)
    }
}

/// Grid extents from a manifest shape (slowest dim first, x last).
fn grid_dims(shape: &[usize]) -> (usize, usize, usize) {
    match *shape {
        [nx] => (nx, 1, 1),
        [ny, nx] => (nx, ny, 1),
        [nz, ny, nx] => (nx, ny, nz),
        _ => (shape.iter().product(), 1, 1),
    }
}

fn interpret(meta: &ArtifactMeta, inputs: &[&[f64]]) -> Result<Vec<f64>> {
    let name = meta.name.as_str();
    let need = |n: usize| -> Result<()> {
        if inputs.len() < n {
            bail!("artifact `{name}`: kernel family needs {n} inputs, manifest declares {}",
                inputs.len());
        }
        Ok(())
    };
    if meta.in_shapes.is_empty() {
        bail!("artifact `{name}`: manifest declares no inputs");
    }
    let (nx, ny, nz) = grid_dims(&meta.in_shapes[0]);
    if name.starts_with("stencil1d") {
        need(2)?;
        Ok(stencil1d_ref(inputs[0], inputs[1]))
    } else if name.starts_with("stencil2d") {
        need(3)?;
        let spec = StencilSpec::dim2(nx, ny, inputs[1].to_vec(), inputs[2].to_vec())?;
        Ok(stencil_ref(inputs[0], &spec))
    } else if name.starts_with("stencil3d") {
        need(4)?;
        let spec = StencilSpec::dim3(
            nx,
            ny,
            nz,
            inputs[1].to_vec(),
            inputs[2].to_vec(),
            inputs[3].to_vec(),
        )?;
        Ok(stencil_ref(inputs[0], &spec))
    } else if name.starts_with("box2d") {
        need(2)?;
        let window = inputs[1];
        let side = (window.len() as f64).sqrt() as usize;
        ensure_square(window.len(), side)?;
        let r = (side - 1) / 2;
        let spec = StencilSpec::box2d(nx, ny, r, r, window.to_vec())?;
        Ok(stencil_ref(inputs[0], &spec))
    } else if let Some(rest) = name.strip_prefix("heat2d_run") {
        let steps: usize = rest
            .split('_')
            .next()
            .unwrap_or("")
            .parse()
            .with_context(|| format!("bad step count in `{name}`"))?;
        let mut grid = inputs[0].to_vec();
        for _ in 0..steps {
            grid = heat2d_step_ref(&grid, nx, ny, 0.2);
        }
        Ok(grid)
    } else if name.starts_with("heat2d_step") {
        Ok(heat2d_step_ref(inputs[0], nx, ny, 0.2))
    } else {
        bail!("no native interpreter for artifact `{name}`")
    }
}

fn ensure_square(len: usize, side: usize) -> Result<()> {
    if side * side != len || side % 2 == 0 {
        bail!("box window of {len} taps is not an odd square");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Artifact-dependent tests live in rust/tests/runtime_integration.rs
    // so a missing artifacts/ directory fails loudly there, not here.
    #[test]
    fn open_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/path").is_err());
    }

    #[test]
    fn grid_dims_orders_x_last() {
        assert_eq!(grid_dims(&[256]), (256, 1, 1));
        assert_eq!(grid_dims(&[449, 960]), (960, 449, 1));
        assert_eq!(grid_dims(&[6, 10, 12]), (12, 10, 6));
    }
}
