//! PJRT runtime — the L3↔L2 bridge.
//!
//! Loads the HLO-text artifacts `python/compile/aot.py` produced (JAX
//! model with the Pallas kernels inlined), compiles them once on the
//! PJRT CPU client, and executes them from Rust. Python never runs on
//! this path: the artifacts are self-contained.
//!
//! HLO *text* is the interchange format — jax >= 0.5 emits serialized
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;

pub use artifact::{ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Compile-once, execute-many runtime over `artifacts/`.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            dir,
            cache: HashMap::new(),
        })
    }

    /// The default artifact location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.entries.iter().find(|e| e.name == name)
    }

    /// Compile (or fetch the cached executable for) `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .meta(name)
                .with_context(|| format!("unknown artifact `{name}`"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling `{name}`"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on f64 inputs (shapes per the manifest).
    /// Returns the flattened f64 output.
    pub fn execute(&mut self, name: &str, inputs: &[&[f64]]) -> Result<Vec<f64>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("unknown artifact `{name}`"))?
            .clone();
        if inputs.len() != meta.in_shapes.len() {
            bail!(
                "`{name}` expects {} inputs, got {}",
                meta.in_shapes.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&meta.in_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("`{name}` input {i}: {} elements, expected {want}", data.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims)?;
            lits.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs so a
    // missing artifacts/ directory fails loudly there, not here. This
    // unit test only covers error paths that need no artifacts.
    #[test]
    fn open_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/path").is_err());
    }
}
