//! Phase 2 of the compile-once / execute-many API: execution.
//!
//! A [`Session`] binds an immutable [`CompiledStencil`] to a
//! [`Machine`] and executes it against input grids — any number of
//! times, from any number of threads ([`Session`] is `Send + Sync` and
//! [`Session::run`] takes `&self`). Execution walks the artifact's
//! stages in order; each chunk runs three precompiled pieces:
//!
//! 1. **Fused tiles** — the grid decomposes into the plan's halo-padded
//!    tiles, [`TileTask`]s go into a shared queue, and the session's
//!    **persistent worker pool** (one OS thread per hardware tile,
//!    spawned once on first use and reused by every subsequent batch,
//!    chunk and `run` call) pulls greedily (natural load balancing),
//!    instantiating a simulator over the stage's shared placed graph
//!    ([`Simulator::from_placed`] — no re-validation, no re-placement,
//!    no graph clone). The leader merges owned outputs into the global
//!    grid; the reported makespan is the slowest tile's total. A tile
//!    task that panics is caught on the worker and surfaced as an
//!    `Err` from [`Session::run`] — it never aborts the process, and
//!    the pool stays usable.
//! 2. **Time-tiled ring stages** — at fused depth `T > 1` the trapezoid
//!    only writes [`crate::stencil::temporal::valid_box`]; the
//!    artifact's per-layer band tiles
//!    ([`crate::compile::CompiledStage::ring`]) advance the boundary
//!    ring one step per stage against a scratch copy of the chunk
//!    input, and the final band — exactly the ring — is copied into the
//!    chunk output. That makes every chunk bitwise-equal to the
//!    iterated oracle on the **full** grid, not just the valid box.
//!    Because the bands read the scratch copy, the ring chain is data-
//!    independent of the fused tiles: in pooled mode the fused batch
//!    and the band stages share the pool (bands fill tile slots as
//!    fused tasks drain), and the reported makespan is
//!    `max(fused makespan, ring critical path)` — the only dependency
//!    gate is band `s` → band `s+1`, whose boxes actually intersect.
//!    The bands never serialize behind the whole fused trapezoid.
//! 3. **Halo exchange** — under either exchange flavour (the default)
//!    tiles retain their buffers across chunks, so every chunk after
//!    the cold first one finds its input fabric-resident: the
//!    compile-time [`ExchangeSchedule`] says which neighbor shipped
//!    each halo face, and the simulators run with
//!    [`Simulator::with_fabric_resident`]. Under [`HaloMode::Exchange`]
//!    each exchanged load is additionally **priced** by its compile-time
//!    Manhattan hop distance and a per-boundary link-bandwidth cap
//!    ([`crate::cgra::ExchangeCost`]): completion slips to
//!    `hit + hops/hops_per_cycle` cycles, so far neighbors cost more
//!    than near ones. Pricing is timing/accounting only — priced
//!    ([`HaloMode::Exchange`]), free ([`HaloMode::ExchangeFree`]) and
//!    reload ([`HaloMode::Reload`]) runs are bitwise-identical on
//!    values. Tiles whose input box overflows the fabric token budget
//!    cannot actually hold it: the artifact's
//!    [`crate::compile::ResidencyPlan`] spills them back to the cache
//!    path and the report carries the spilled points explicitly.
//!
//! Because each simulator run is deterministic and tile outputs merge
//! into disjoint owned boxes, the pooled execution is **bitwise
//! identical** to running every task sequentially on the caller thread
//! ([`ExecMode::Sequential`]) in every data-dependent observable:
//! output grid, per-task cycle counts, fire hashes and memory counters.
//! Only the *attribution* of tasks to hardware tiles (`per_tile`,
//! `makespan_cycles`) depends on scheduling. `rust/tests/sim_cores.rs`
//! pins the equality; [`Session::run_recorded`] /
//! [`Session::run_replay`] turn the per-task fingerprints into an
//! on-disk [`Trace`] for cross-build and cross-core regression checks.
//!
//! Resilience: [`Session::with_deadline`] arms a wall-clock watchdog —
//! when it expires, queued tile tasks are cancelled, in-flight
//! simulators are signalled to stop through a cooperative flag they
//! poll, and the run returns whatever chunks completed, tagged
//! [`Outcome::DeadlineExceeded`]; it never hangs and never panics.
//! [`Session::with_fault_plan`] threads a deterministic
//! [`FaultPlan`] into every tile simulator; because every injection
//! decision is a pure function of the seed and stable coordinates,
//! faulted runs stay bitwise identical across sim cores and exec
//! modes. Worker threads survive task panics (caught per task and
//! surfaced as typed errors), and `submit` respawns any worker that
//! somehow died before it enqueues new work.
//!
//! Nothing here plans or builds graphs — the
//! [`crate::stencil::metrics`] counters stay flat across `run` calls,
//! which `rust/tests/compile_once.rs` pins.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::{
    mesh_hop_cycles, CostRegion, ExchangeCost, Machine, PlacedGraph, SimCore, SimResult, Simulator,
};
use crate::error::ScgraError;
use crate::util::fault::FaultPlan;
use crate::compile::{CompiledStage, CompiledStencil, HaloMode};
use crate::stencil::decomp::{DecompKind, Tile};
use crate::stencil::exchange::{ExchangeSchedule, TileExchange, RING_MESH_HOPS};
use crate::stencil::{temporal, StencilSpec};
use crate::util::trace::{hash_f64s, Trace, TraceRecord};

/// One unit of work: a halo-padded tile of the global grid.
#[derive(Clone)]
pub struct TileTask {
    pub id: usize,
    pub tile: Tile,
    /// Contiguous copy of the tile's input box.
    pub input: Vec<f64>,
    /// The placed graph for the tile's shape — shared by every tile
    /// with the same input extents (the graph depends only on dims and
    /// the worker count, not the data).
    pub graph: Arc<PlacedGraph>,
    /// Warm-chunk fabric residency for *this* task: true when the
    /// chunk is warm under exchange **and** the residency plan covers
    /// the tile. Spilled tiles run with the plain cache/DRAM path.
    pub resident: bool,
    /// Hop-latency pricing for this task's fabric-resident loads
    /// (`None` = free exchange, reload, or a spilled/cold task).
    pub cost: Option<ExchangeCost>,
}

/// How tile tasks are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The session's persistent worker pool (default): one OS thread
    /// per hardware tile, spawned once and reused across batches and
    /// `run` calls.
    #[default]
    Pooled,
    /// Run every task inline on the calling thread, in task order
    /// (attribution lands on hardware tile 0). The differential
    /// baseline the pooled mode is pinned bitwise-equal against.
    Sequential,
}

/// One completed tile task: `(task id, hardware tile, tile, result)`.
type TaskResult = (usize, usize, Tile, SimResult);

/// Completion state of one submitted batch.
#[derive(Default)]
struct BatchDone {
    results: Vec<TaskResult>,
    /// Tasks accounted for (completed or cancelled by an error).
    completed: usize,
    /// First failure (error or caught panic) — cancels the batch.
    error: Option<String>,
}

/// Everything a batch's simulators need besides the tasks themselves —
/// shared by the pool and sequential mode so both execute identically.
#[derive(Clone)]
struct BatchParams {
    machine: Machine,
    core: SimCore,
    /// Armed fault plan forwarded to every simulator in the batch.
    fault: Option<FaultPlan>,
    /// Absolute wall-clock deadline for the whole run, if any.
    deadline: Option<Instant>,
    /// Cooperative cancel flag polled by in-flight simulators; `Some`
    /// exactly when `deadline` is. The watchdog (the submitter, on
    /// timeout) flips it; simulators bail out at their next check.
    cancel: Option<Arc<AtomicBool>>,
}

/// One batch of tile tasks submitted to the pool; the submitter blocks
/// on `done_cv` until every task is accounted for or the deadline
/// expires.
struct TileBatch {
    params: BatchParams,
    tasks: Mutex<VecDeque<TileTask>>,
    done: Mutex<BatchDone>,
    done_cv: Condvar,
    n_tasks: usize,
}

/// State shared between the pool handle and its worker threads.
struct PoolShared {
    /// FIFO of open batches; workers drain the front batch's tasks.
    queue: Mutex<VecDeque<Arc<TileBatch>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// Test hook: a worker that observes a nonzero count decrements it
    /// and exits as if it had died, exercising `submit`'s respawn path.
    /// Requires at least one surviving worker to drain open batches.
    kill_one: AtomicUsize,
}

/// Lock ignoring poisoning. Task panics are caught on the worker, so a
/// poisoned pool lock means a panic escaped pure bookkeeping code; the
/// guarded data (queues and counters) stays consistent under every
/// early exit, so recovering beats poisoning every later batch.
fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A persistent tile-worker pool: `threads` OS threads spawned once,
/// parked on a condvar between batches. Replaces the old
/// spawn-per-batch executor — a warm [`Session::run`] performs no
/// thread creation at all.
struct TilePool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Render a caught panic payload for the error message.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Simulate one tile task (shared by pool workers and sequential mode).
fn simulate_task(p: &BatchParams, task: TileTask) -> Result<SimResult> {
    let TileTask { input, graph, resident, cost, .. } = task;
    let mut sim = Simulator::from_placed(&graph, &p.machine, input.clone(), input)
        .with_core(p.core)
        .with_fabric_resident(resident)
        .with_exchange_cost(cost)
        .with_fault_plan(p.fault.clone());
    if let Some(c) = &p.cancel {
        sim = sim.with_cancel(Arc::clone(c));
    }
    sim.run()
}

fn worker_loop(worker_id: usize, shared: Arc<PoolShared>) {
    loop {
        // Test hook: die "catastrophically" when asked, so the respawn
        // path in `submit` is exercisable deterministically.
        let k = shared.kill_one.load(Ordering::Acquire);
        if k > 0
            && shared
                .kill_one
                .compare_exchange(k, k - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            return;
        }
        // Claim the front batch with unclaimed tasks (drained batches
        // are popped; their stragglers finish on whoever claimed them).
        let batch = {
            let mut q = lock_or_recover(&shared.queue);
            'find: loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                while let Some(b) = q.front() {
                    if lock_or_recover(&b.tasks).is_empty() {
                        q.pop_front();
                    } else {
                        break 'find Arc::clone(b);
                    }
                }
                q = shared.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Drain its tasks greedily.
        loop {
            let Some(task) = lock_or_recover(&batch.tasks).pop_front() else {
                break;
            };
            let task_id = task.id;
            let tile = task.tile;
            let outcome = catch_unwind(AssertUnwindSafe(|| simulate_task(&batch.params, task)));
            let failure = match outcome {
                Ok(Ok(res)) => {
                    let mut done = lock_or_recover(&batch.done);
                    done.results.push((task_id, worker_id, tile, res));
                    done.completed += 1;
                    if done.completed >= batch.n_tasks {
                        batch.done_cv.notify_all();
                    }
                    continue;
                }
                Ok(Err(e)) => format!("tile task {task_id}: {e}"),
                Err(p) => format!("tile task {task_id} panicked: {}", panic_msg(&*p)),
            };
            // Failure: cancel the batch's unclaimed tasks and account
            // for them so the submitter wakes. Tasks already claimed by
            // other workers account for themselves.
            let cancelled = {
                let mut t = lock_or_recover(&batch.tasks);
                let n = t.len();
                t.clear();
                n
            };
            let mut done = lock_or_recover(&batch.done);
            if done.error.is_none() {
                done.error = Some(failure);
            }
            done.completed += 1 + cancelled;
            if done.completed >= batch.n_tasks {
                batch.done_cv.notify_all();
            }
        }
    }
}

/// Outcome of one executed batch.
enum BatchOutput {
    /// Every task completed; results in task-id order.
    Done(Vec<TaskResult>),
    /// The run deadline expired mid-batch: `completed` of `total` tasks
    /// finished before the watchdog cancelled the rest.
    Deadline { completed: usize, total: usize },
}

impl TilePool {
    fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            kill_one: AtomicUsize::new(0),
        });
        let workers = (0..threads.max(1))
            .map(|w| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("scgra-tile-{w}"))
                    .spawn(move || worker_loop(w, s))
                    .expect("spawning tile worker")
            })
            .collect();
        Self {
            shared,
            workers: Mutex::new(workers),
        }
    }

    /// Replace any worker whose thread has exited (a panic that escaped
    /// the per-task `catch_unwind`, or the `kill_one` test hook). Called
    /// by `submit` before enqueueing, so one dead worker costs one
    /// respawn, never a permanently shrunken pool.
    fn respawn_dead_workers(&self) {
        let mut workers = lock_or_recover(&self.workers);
        for (w, slot) in workers.iter_mut().enumerate() {
            if slot.is_finished() {
                let s = Arc::clone(&self.shared);
                let fresh = std::thread::Builder::new()
                    .name(format!("scgra-tile-{w}"))
                    .spawn(move || worker_loop(w, s))
                    .expect("respawning tile worker");
                let dead = std::mem::replace(slot, fresh);
                let _ = dead.join();
            }
        }
    }

    /// Run a batch to completion (or to its deadline) and return the
    /// results sorted by task id. Blocks the caller; worker panics and
    /// task errors come back as `Err` with the first failure's message;
    /// an expired deadline comes back as [`BatchOutput::Deadline`] with
    /// the partial accounting.
    fn submit(&self, params: &BatchParams, tasks: VecDeque<TileTask>) -> Result<BatchOutput> {
        let n = tasks.len();
        if n == 0 {
            return Ok(BatchOutput::Done(Vec::new()));
        }
        // An already-expired deadline short-circuits before any work is
        // queued — this makes a zero/past deadline deterministic.
        if let Some(dl) = params.deadline {
            if Instant::now() >= dl {
                if let Some(c) = &params.cancel {
                    c.store(true, Ordering::Release);
                }
                return Ok(BatchOutput::Deadline { completed: 0, total: n });
            }
        }
        let batch = self.enqueue(params, tasks);
        self.wait(&batch)
    }

    /// Enqueue a batch without blocking — the overlap path: the chunk's
    /// fused batch goes in first, the ring band batches queue behind it
    /// (workers drain the front batch's *unclaimed* tasks, so bands
    /// start as soon as every fused task is claimed, concurrently with
    /// the in-flight fused stragglers). Pair with [`TilePool::wait`].
    fn enqueue(&self, params: &BatchParams, tasks: VecDeque<TileTask>) -> Arc<TileBatch> {
        let n = tasks.len();
        self.respawn_dead_workers();
        let batch = Arc::new(TileBatch {
            params: params.clone(),
            tasks: Mutex::new(tasks),
            done: Mutex::new(BatchDone::default()),
            done_cv: Condvar::new(),
            n_tasks: n,
        });
        if n > 0 {
            let mut q = lock_or_recover(&self.shared.queue);
            q.push_back(Arc::clone(&batch));
            self.shared.work_cv.notify_all();
        }
        batch
    }

    /// Block until an enqueued batch completes (or its deadline
    /// expires); same contract as [`TilePool::submit`].
    fn wait(&self, batch: &Arc<TileBatch>) -> Result<BatchOutput> {
        let n = batch.n_tasks;
        if n == 0 {
            return Ok(BatchOutput::Done(Vec::new()));
        }
        let params = &batch.params;
        let mut done = lock_or_recover(&batch.done);
        while done.completed < n {
            let Some(deadline) = params.deadline else {
                done = batch.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
                continue;
            };
            let now = Instant::now();
            if now >= deadline {
                // The watchdog fires on the submitter thread: signal
                // in-flight simulators to stop, drop the queued tasks,
                // and return the partial accounting immediately. The
                // stragglers poll the flag, bail out soon after, and
                // account to a batch nobody watches any more — the Arc
                // they hold keeps it alive exactly long enough.
                if let Some(c) = &params.cancel {
                    c.store(true, Ordering::Release);
                }
                lock_or_recover(&batch.tasks).clear();
                return Ok(BatchOutput::Deadline {
                    completed: done.results.len(),
                    total: n,
                });
            }
            let (g, _) = batch
                .done_cv
                .wait_timeout(done, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            done = g;
        }
        if let Some(e) = done.error.take() {
            bail!("{e}");
        }
        let mut results = std::mem::take(&mut done.results);
        drop(done);
        results.sort_by_key(|r| r.0);
        ensure!(
            results.len() == n,
            "lost tile results: {}/{n}",
            results.len()
        );
        Ok(BatchOutput::Done(results))
    }
}

impl Drop for TilePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Hold the queue lock while notifying so no worker misses the
        // flag between checking it and parking.
        drop(lock_or_recover(&self.shared.queue));
        self.shared.work_cv.notify_all();
        for h in lock_or_recover(&self.workers).drain(..) {
            let _ = h.join();
        }
    }
}

/// Execution backend for one chunk: the session's pool or the caller
/// thread.
#[derive(Clone, Copy)]
enum ExecRef<'a> {
    Pool(&'a TilePool),
    Sequential,
}

impl ExecRef<'_> {
    /// Run a batch, returning results in task-id order (or the partial
    /// deadline accounting). Sequential mode checks the deadline
    /// before each task — same typed outcome, coarser granularity.
    fn run_batch(&self, params: &BatchParams, tasks: VecDeque<TileTask>) -> Result<BatchOutput> {
        match self {
            ExecRef::Pool(pool) => pool.submit(params, tasks),
            ExecRef::Sequential => {
                let total = tasks.len();
                let mut results = Vec::with_capacity(total);
                for task in tasks {
                    if let Some(deadline) = params.deadline {
                        if Instant::now() >= deadline {
                            if let Some(c) = &params.cancel {
                                c.store(true, Ordering::Release);
                            }
                            return Ok(BatchOutput::Deadline {
                                completed: results.len(),
                                total,
                            });
                        }
                    }
                    let task_id = task.id;
                    let tile = task.tile;
                    let outcome = catch_unwind(AssertUnwindSafe(|| simulate_task(params, task)));
                    match outcome {
                        Ok(Ok(res)) => results.push((task_id, 0, tile, res)),
                        Ok(Err(e)) => bail!("tile task {task_id}: {e}"),
                        Err(p) => {
                            bail!("tile task {task_id} panicked: {}", panic_msg(&*p))
                        }
                    }
                }
                Ok(BatchOutput::Done(results))
            }
        }
    }
}

/// Per-hardware-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    /// Tile tasks executed on this hardware tile.
    pub strips: usize,
    /// Sum of simulated cycles over this tile's tasks.
    pub cycles: u64,
    /// Halo points this tile loaded beyond the outputs it owned.
    pub halo_points: u64,
    pub mem: MemStats,
}

/// Result of one executed chunk (one plan application).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    /// Number of tile tasks the decomposition produced.
    pub strips: usize,
    /// Resolved decomposition strategy.
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`.
    pub cuts: [usize; 3],
    /// §IV time-steps fused into each tile's pipeline this pass (1 =
    /// single-step; deeper fusion grows the per-tile halos by
    /// `radii * fused_steps` — visible in [`Self::halo_points`] — and
    /// divides the per-step DRAM traffic by the depth).
    pub fused_steps: usize,
    /// Total halo points loaded across tasks (redundant-load overhead).
    pub halo_points: u64,
    /// Fraction of the grid this chunk read from DRAM more than once.
    /// Equal to the plan's geometric overlap for cold chunks and reload
    /// mode; 0 for a warm exchange chunk (the halo arrived over fabric
    /// channels instead — spilled tiles' re-reads are reported
    /// separately in [`Self::spilled_points`]).
    pub redundant_read_fraction: f64,
    /// Points this chunk received through in-fabric halo exchange
    /// instead of DRAM (0 for cold chunks, reload mode, and the tiles
    /// the residency plan spilled).
    pub exchanged_points: u64,
    /// Input points of tiles that could **not** stay fabric-resident on
    /// this warm chunk (the residency plan's spill): they re-read their
    /// boxes through the cache exactly like reload mode. 0 for cold
    /// chunks, reload mode and fully-resident stages.
    pub spilled_points: u64,
    /// True when this warm exchange chunk had at least one spilled
    /// tile — the explicit flag that the chunk fell back to the reload
    /// path for part of the grid.
    pub exchange_spilled: bool,
    /// Boundary-ring points the time-tiled band stages computed and
    /// merged into the output (0 at fused depth 1 — there is no ring).
    pub ring_points: u64,
    /// Memory counters of the ring band stages, kept separate from
    /// `per_tile` so [`Self::total_loads`] stays the §IV fused-pipeline
    /// currency.
    pub ring_mem: MemStats,
    /// Chunk makespan: the fused batch's slowest hardware tile,
    /// overlapped with the ring chain — `max(fused makespan,
    /// ring critical path)`. The bands read a scratch copy of the chunk
    /// input, so they are data-independent of the fused tiles; the only
    /// serialization is band `s` → band `s+1` (telescoping boxes).
    pub makespan_cycles: u64,
    /// Critical path of the time-tiled ring chain: Σ over band stages
    /// of the slowest band in that stage (0 at fused depth 1).
    pub ring_critical_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl RunReport {
    /// Total grid-point loads across the fused tile array — the §IV
    /// currency: a fused chunk loads its input once regardless of depth,
    /// so at equal total steps a spatially-fused run loads strictly less
    /// than the host-driven loop. Exchange hits still count (the load
    /// issued; it was just served from fabric — see
    /// [`Self::dram_point_reads`]), and ring-stage loads are accounted
    /// separately in [`Self::ring_mem`].
    pub fn total_loads(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.loads).sum()
    }

    /// Loads the fused tiles actually sent to the cache/DRAM side: total
    /// loads minus fabric-resident exchange hits. Zero for a warm
    /// exchange chunk — the measurement behind the reported
    /// post-exchange `redundant_read_fraction`.
    pub fn dram_point_reads(&self) -> u64 {
        self.per_tile
            .iter()
            .map(|t| t.mem.loads - t.mem.exchanged)
            .sum()
    }

    /// Surcharge cycles the hop-latency pricer added to this chunk's
    /// exchanged loads (network hops + boundary-link queueing). Always
    /// 0 under [`HaloMode::ExchangeFree`], reload mode and cold chunks.
    pub fn exchanged_hop_cycles(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.exchanged_hop_cycles).sum()
    }
}

/// How a [`Session::run`] ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Every chunk ran to completion.
    Complete,
    /// The wall-clock deadline expired mid-run: queued tile tasks were
    /// cancelled, in-flight simulators were signalled to stop, and the
    /// [`RunOutcome`] carries only the chunks that fully completed.
    DeadlineExceeded {
        /// Tile tasks of the interrupted batch that finished in time.
        completed_tasks: usize,
        /// Tile tasks the interrupted batch held in total.
        total_tasks: usize,
    },
}

/// Everything one [`Session::run`] produced: the final grid and one
/// [`RunReport`] per executed chunk (host schedules: one per step).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub output: Vec<f64>,
    pub reports: Vec<RunReport>,
    /// Whether the run completed or was cut short by its deadline.
    pub outcome: Outcome,
}

impl RunOutcome {
    /// The last chunk's report (every *completed* execution has at
    /// least one). Panics on a deadline-exceeded outcome whose first
    /// chunk never finished — check [`Self::outcome`] first.
    pub fn final_report(&self) -> &RunReport {
        self.reports.last().expect("a completed execution always produces a report")
    }
}

/// A concurrent executor over a compiled artifact. Cheap to construct,
/// `Send + Sync`, and stateless across calls except for its lazily
/// spawned worker pool: every [`Session::run`] only instantiates
/// per-run simulator state from the artifact's shared placed graphs.
/// Clones share the pool.
#[derive(Clone)]
pub struct Session {
    compiled: Arc<CompiledStencil>,
    machine: Machine,
    /// Hardware tiles executing tile tasks (defaults to the compile
    /// options' tile count).
    tiles: usize,
    sim_core: SimCore,
    exec: ExecMode,
    /// Armed fault-injection plan applied to every tile simulator.
    fault: Option<FaultPlan>,
    /// Wall-clock budget per `run` call.
    deadline: Option<Duration>,
    /// Persistent worker pool, spawned on first pooled `run`.
    pool: OnceLock<Arc<TilePool>>,
}

impl Session {
    /// Build an executor from a compiled artifact and the machine to
    /// simulate on. Placement was fixed at compile time; `machine`
    /// drives the per-run memory system and the clock.
    pub fn new(compiled: Arc<CompiledStencil>, machine: Machine) -> Self {
        let tiles = compiled.options.tiles.max(1);
        Self {
            compiled,
            machine,
            tiles,
            sim_core: SimCore::default(),
            exec: ExecMode::default(),
            fault: None,
            deadline: None,
            pool: OnceLock::new(),
        }
    }

    /// Override the simulator scheduler core (bit-identical either way;
    /// `Event` is the default and the fast one).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the hardware tile count pulling tasks. Detaches from
    /// any already-spawned pool (the new count needs new workers).
    pub fn with_tiles(mut self, tiles: usize) -> Self {
        self.tiles = tiles.max(1);
        self.pool = OnceLock::new();
        self
    }

    /// Select the execution backend (default [`ExecMode::Pooled`]).
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Arm a deterministic fault-injection plan applied to every tile
    /// simulator in every subsequent run. `None` or an unarmed plan
    /// (all rates zero) is bitwise-free: identical results and counters
    /// to a session without one.
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.fault = plan.filter(FaultPlan::armed);
        self
    }

    /// Give every subsequent `run` call a wall-clock budget. When it
    /// expires mid-run, queued tile tasks are cancelled, in-flight
    /// simulators are signalled to stop, and the run returns the chunks
    /// that completed tagged [`Outcome::DeadlineExceeded`] — it never
    /// hangs and never panics.
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }

    pub fn compiled(&self) -> &Arc<CompiledStencil> {
        &self.compiled
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    fn exec_ref(&self) -> ExecRef<'_> {
        match self.exec {
            ExecMode::Sequential => ExecRef::Sequential,
            ExecMode::Pooled => {
                ExecRef::Pool(self.pool.get_or_init(|| Arc::new(TilePool::new(self.tiles))))
            }
        }
    }

    /// Execute the compiled workload (all `steps` it was compiled for)
    /// on `input`. Never plans, never builds or places a graph, and on
    /// a warm session never spawns a thread; safe to call concurrently
    /// from many threads on distinct inputs. Failures come back as the
    /// public [`ScgraError`] classification — a panicked tile task is
    /// [`ScgraError::PoolPoisoned`], a wedged simulator is
    /// [`ScgraError::Deadlock`] carrying the full forensic report.
    pub fn run(&self, input: &[f64]) -> Result<RunOutcome, ScgraError> {
        self.run_inner(input, None)
    }

    /// [`Session::run`], also capturing a [`Trace`]: one fingerprint
    /// record per executed tile task, in deterministic task order.
    pub fn run_recorded(&self, input: &[f64]) -> Result<(RunOutcome, Trace), ScgraError> {
        let mut records = Vec::new();
        let outcome = self.run_inner(input, Some(&mut records))?;
        Ok((outcome, Trace { records }))
    }

    /// Run and verify against a previously recorded [`Trace`]: any
    /// behavioural divergence (cycles, fires, tickets, fire hash or
    /// output hash of any tile task) fails with the first mismatch.
    /// Core-dependent counters (`wakeups`) are ignored, so a trace
    /// recorded under one sim core replays under the other. A run cut
    /// short by the deadline cannot be verified and fails with
    /// [`ScgraError::DeadlineExceeded`].
    pub fn run_replay(&self, input: &[f64], reference: &Trace) -> Result<RunOutcome, ScgraError> {
        let (outcome, trace) = self.run_recorded(input)?;
        if let Outcome::DeadlineExceeded {
            completed_tasks,
            total_tasks,
        } = outcome.outcome
        {
            return Err(ScgraError::DeadlineExceeded {
                completed_tasks,
                total_tasks,
                deadline_ms: self.deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
            });
        }
        trace.matches(reference).map_err(ScgraError::classify)?;
        Ok(outcome)
    }

    fn run_inner(
        &self,
        input: &[f64],
        mut trace: Option<&mut Vec<TraceRecord>>,
    ) -> Result<RunOutcome, ScgraError> {
        let spec = &self.compiled.spec;
        if input.len() != spec.grid_points() {
            return Err(ScgraError::InfeasibleSpec(format!(
                "input length {} != grid {}",
                input.len(),
                spec.grid_points()
            )));
        }
        let exec = self.exec_ref();
        let halo = self.compiled.options.halo;
        // One deadline and one cancel flag cover the whole run: every
        // chunk's batches inherit the same absolute expiry instant.
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let cancel = deadline.map(|_| Arc::new(AtomicBool::new(false)));
        let mut reports: Vec<RunReport> = Vec::with_capacity(self.compiled.total_chunks());
        for stage in &self.compiled.stages {
            for rep_i in 0..stage.repeats {
                let src: &[f64] = match reports.last() {
                    None => input,
                    Some(prev) => prev.output.as_slice(),
                };
                // The first chunk of the run is cold (its input comes
                // from DRAM no matter what); afterwards, exchange mode
                // finds the previous chunk's results fabric-resident —
                // via the intra-stage schedule between repeats, or the
                // entry schedule when crossing into the tail stage.
                let exchange = if halo.is_exchange() && !reports.is_empty() {
                    Some(if rep_i == 0 {
                        stage.entry_exchange.as_ref().unwrap_or(&stage.intra_exchange)
                    } else {
                        &stage.intra_exchange
                    })
                } else {
                    None
                };
                let chunk = execute_chunk(
                    &self.machine,
                    exec,
                    self.tiles,
                    self.sim_core,
                    spec,
                    src,
                    stage,
                    exchange,
                    halo,
                    reports.len() as u32,
                    trace.as_deref_mut(),
                    self.fault.as_ref(),
                    deadline,
                    cancel.as_ref(),
                )
                .map_err(ScgraError::classify)?;
                match chunk {
                    ChunkOutput::Report(rep) => reports.push(rep),
                    ChunkOutput::Deadline { completed, total } => {
                        // Partial result: the grid as of the last chunk
                        // that fully completed.
                        let output = match reports.last() {
                            Some(last) => last.output.clone(),
                            None => input.to_vec(),
                        };
                        return Ok(RunOutcome {
                            output,
                            reports,
                            outcome: Outcome::DeadlineExceeded {
                                completed_tasks: completed,
                                total_tasks: total,
                            },
                        });
                    }
                }
            }
        }
        let output = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok(RunOutcome {
            output,
            reports,
            outcome: Outcome::Complete,
        })
    }
}

/// Copy the `[lo, hi)` box from `src` into `dst` (both full grids).
fn copy_box(spec: &StencilSpec, dst: &mut [f64], src: &[f64], lo: [usize; 3], hi: [usize; 3]) {
    let (nx, ny) = (spec.nx, spec.ny);
    for z in lo[2]..hi[2] {
        for y in lo[1]..hi[1] {
            let row = (z * ny + y) * nx;
            dst[row + lo[0]..row + hi[0]].copy_from_slice(&src[row + lo[0]..row + hi[0]]);
        }
    }
}

/// Append one [`TraceRecord`] per task result (already in task order).
fn trace_batch(
    sink: &mut Vec<TraceRecord>,
    chunk: u32,
    phase: u32,
    results: &[TaskResult],
) {
    for (task_id, _, _, res) in results {
        sink.push(TraceRecord {
            chunk,
            phase,
            task: *task_id as u32,
            cycles: res.stats.cycles,
            fires: res.stats.total_fires(),
            tickets: res.stats.mem.loads + res.stats.mem.stores,
            fire_hash: res.stats.fire_hash,
            output_hash: hash_f64s(&res.output),
            wakeups: res.stats.wakeups,
        });
    }
}

/// What one [`execute_chunk`] call produced.
enum ChunkOutput {
    Report(RunReport),
    /// The run deadline expired inside one of the chunk's batches.
    Deadline { completed: usize, total: usize },
}

/// Lower one tile's compile-time [`TileExchange`] into the simulator's
/// [`ExchangeCost`]: one priced region per neighbor transfer (latency =
/// [`mesh_hop_cycles`] of its mesh Manhattan distance), the tile's own
/// previous-output box at zero surcharge, then the single-step-interior
/// catch-all that prices ring points at [`RING_MESH_HOPS`]. Region
/// order is the first-match-wins order [`ExchangeCost`] documents;
/// addresses matching nothing (the immutable grid frame) stay at flat
/// hit latency. Boxes arrive in global grid coordinates and are
/// rebased to the tile's input box, matching [`Tile::extract`]'s
/// row-major flattening.
fn exchange_cost(te: &TileExchange, tile: &Tile, m: &Machine) -> ExchangeCost {
    let local = |g: [usize; 3]| [g[0] - tile.in_lo[0], g[1] - tile.in_lo[1], g[2] - tile.in_lo[2]];
    let mut regions = Vec::with_capacity(te.from_tiles.len() + 2);
    for tr in &te.from_tiles {
        // The rebase assumes what the verifier's `exchange/ownership`
        // rule checks statically: every transfer box sits inside this
        // tile's input box (otherwise `local` would underflow).
        debug_assert!(
            crate::analysis::boxes::contains_box(tile.in_lo, tile.in_hi, tr.lo, tr.hi),
            "transfer box from tile {} escapes the receiver's input box",
            tr.src
        );
        regions.push(CostRegion {
            lo: local(tr.lo),
            hi: local(tr.hi),
            hop_cycles: mesh_hop_cycles(tr.mesh_hops, m),
        });
    }
    if let Some((lo, hi)) = te.own_box {
        regions.push(CostRegion {
            lo: local(lo),
            hi: local(hi),
            hop_cycles: 0,
        });
    }
    if let Some((lo, hi)) = te.interior_box {
        regions.push(CostRegion {
            lo: local(lo),
            hi: local(hi),
            hop_cycles: mesh_hop_cycles(RING_MESH_HOPS, m),
        });
    }
    ExchangeCost {
        ext: [tile.in_extent(0), tile.in_extent(1), tile.in_extent(2)],
        regions,
        link_words: m.link_words_per_cycle.max(1) as u64,
    }
}

/// Accounting from one chunk's completed ring chain.
#[derive(Default)]
struct RingRun {
    /// The scratch grid after the final band (empty when the stage has
    /// no ring).
    cur: Vec<f64>,
    mem: MemStats,
    outputs: u64,
    /// Sum of every band task's cycles (feeds `total_cycles`).
    cycles: u64,
    /// Critical path through the band chain: the sum over stages of the
    /// slowest band in that stage — the only serialization the
    /// telescoping band boxes actually force.
    critical: u64,
    /// Buffered trace records (phases 1..), appended after the fused
    /// batch's phase-0 records so the trace order is execution-mode
    /// independent.
    trace: Vec<TraceRecord>,
}

/// What the ring chain produced.
enum RingOut {
    Done(Box<RingRun>),
    Deadline { completed: usize, total: usize },
}

/// Advance the boundary ring through the stage's time-tiled band tiles
/// against a scratch copy of the chunk input. Band stage `s` depends
/// only on stage `s-1` (their boxes intersect); nothing here reads the
/// fused tiles' outputs, so in pooled mode the caller may enqueue the
/// fused batch first and let the bands overlap its stragglers.
fn run_ring(
    exec: ExecRef<'_>,
    params: &BatchParams,
    spec: &StencilSpec,
    input: &[f64],
    stage: &CompiledStage,
    chunk: u32,
    want_trace: bool,
) -> Result<RingOut> {
    let mut run = RingRun::default();
    if stage.ring.is_empty() {
        return Ok(RingOut::Done(Box::new(run)));
    }
    let mut cur = input.to_vec();
    for (band_i, bands) in stage.ring.iter().enumerate() {
        let tasks: VecDeque<TileTask> = bands
            .iter()
            .enumerate()
            .map(|(id, t)| TileTask {
                id,
                tile: *t,
                input: t.extract(spec, &cur),
                graph: Arc::clone(
                    &stage.ring_graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]],
                ),
                resident: false,
                cost: None,
            })
            .collect();
        let results = match exec.run_batch(params, tasks)? {
            BatchOutput::Done(r) => r,
            BatchOutput::Deadline { completed, total } => {
                return Ok(RingOut::Deadline { completed, total })
            }
        };
        if want_trace {
            trace_batch(&mut run.trace, chunk, band_i as u32 + 1, &results);
        }
        let mut stage_max = 0u64;
        for (_, _, tile, res) in results {
            tile.merge(spec, &mut cur, &res.output);
            stage_max = stage_max.max(res.stats.cycles);
            run.cycles += res.stats.cycles;
            run.mem.accumulate(&res.stats.mem);
            run.outputs += tile.out_points() as u64;
        }
        run.critical += stage_max;
    }
    run.cur = cur;
    Ok(RingOut::Done(Box::new(run)))
}

/// Execute one chunk: decompose `input` per the stage's plan, run every
/// fused tile task through the execution backend against the shared
/// placed graphs, merge the owned outputs, and advance the boundary
/// ring through the stage's time-tiled band tiles so the chunk output
/// equals the iterated oracle on the full grid. `exchange` is `Some`
/// for a warm chunk under an exchange-flavoured `halo`: tiles the
/// stage's [`crate::compile::ResidencyPlan`] covers run fabric-resident
/// (priced per hop under [`HaloMode::Exchange`], flat under
/// [`HaloMode::ExchangeFree`]); spilled tiles fall back to the
/// cache/DRAM path and their points land in the report's
/// `spilled_points`. In pooled mode the ring chain overlaps the fused
/// batch (the bands read a scratch input copy, so the only dependency
/// gates are band→band); the reported makespan is
/// `max(fused makespan, ring critical path)`. With a `trace` sink,
/// fingerprints are appended per batch (fused tiles = phase 0, ring
/// bands = phase 1..) in task order regardless of overlap.
/// `fault`/`deadline`/`cancel` thread the session's resilience state
/// into every batch (see [`BatchParams`]).
#[allow(clippy::too_many_arguments)]
fn execute_chunk(
    machine: &Machine,
    exec: ExecRef<'_>,
    hw_tiles: usize,
    core: SimCore,
    spec: &StencilSpec,
    input: &[f64],
    stage: &CompiledStage,
    exchange: Option<&ExchangeSchedule>,
    halo: HaloMode,
    chunk: u32,
    mut trace: Option<&mut Vec<TraceRecord>>,
    fault: Option<&FaultPlan>,
    deadline: Option<Instant>,
    cancel: Option<&Arc<AtomicBool>>,
) -> Result<ChunkOutput> {
    ensure!(
        input.len() == spec.grid_points(),
        "input length {} != grid {}",
        input.len(),
        spec.grid_points()
    );
    let t0 = Instant::now();
    let plan = &stage.plan;
    let warm = exchange.is_some();
    let params = BatchParams {
        machine: machine.clone(),
        core,
        fault: fault.cloned(),
        deadline,
        cancel: cancel.map(Arc::clone),
    };
    let tasks: VecDeque<TileTask> = plan
        .tiles
        .iter()
        .enumerate()
        .map(|(id, t)| {
            let resident = warm && stage.residency.resident[id];
            let cost = match exchange {
                Some(ex) if resident && halo == HaloMode::Exchange => {
                    Some(exchange_cost(&ex.tiles[id], t, machine))
                }
                _ => None,
            };
            TileTask {
                id,
                tile: *t,
                input: t.extract(spec, input),
                graph: Arc::clone(&stage.graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
                resident,
                cost,
            }
        })
        .collect();
    let n_tasks = tasks.len();

    // Fused batch + ring chain. Pooled: enqueue the fused batch without
    // blocking, run the bands (their batches queue behind it — workers
    // start them as soon as every fused task is claimed, overlapping the
    // fused stragglers), then collect the fused results. The fused wait
    // always happens before a ring failure propagates, so no batch is
    // abandoned mid-flight. Sequential keeps the natural order: fused
    // first, then bands.
    let (fused_out, ring_out) = match exec {
        ExecRef::Pool(pool) => {
            // Mirror `submit`'s short-circuit: an already-expired
            // deadline is deterministic, nothing gets queued.
            if let Some(dl) = deadline {
                if Instant::now() >= dl {
                    if let Some(c) = cancel {
                        c.store(true, Ordering::Release);
                    }
                    return Ok(ChunkOutput::Deadline {
                        completed: 0,
                        total: n_tasks,
                    });
                }
            }
            let fused = pool.enqueue(&params, tasks);
            let ring = run_ring(exec, &params, spec, input, stage, chunk, trace.is_some());
            let fused_out = pool.wait(&fused);
            (fused_out?, ring?)
        }
        ExecRef::Sequential => {
            let fused_out = exec.run_batch(&params, tasks)?;
            let ring = run_ring(exec, &params, spec, input, stage, chunk, trace.is_some())?;
            (fused_out, ring)
        }
    };
    let results = match fused_out {
        BatchOutput::Done(r) => r,
        BatchOutput::Deadline { completed, total } => {
            return Ok(ChunkOutput::Deadline { completed, total })
        }
    };
    let mut ring = match ring_out {
        RingOut::Done(r) => r,
        RingOut::Deadline { completed, total } => {
            return Ok(ChunkOutput::Deadline { completed, total })
        }
    };
    if let Some(sink) = trace.as_deref_mut() {
        trace_batch(sink, chunk, 0, &results);
        sink.append(&mut ring.trace);
    }

    // Merge owned outputs into the global grid (boundary = input copy).
    let mut output = input.to_vec();
    let mut per_tile = vec![TileReport::default(); hw_tiles];
    for (_, tile_id, tile, res) in results {
        tile.merge(spec, &mut output, &res.output);
        let rep = &mut per_tile[tile_id];
        rep.strips += 1;
        rep.cycles += res.stats.cycles;
        rep.halo_points += tile.halo_points() as u64;
        rep.mem.accumulate(&res.stats.mem);
    }
    let fused_makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
    let makespan = fused_makespan.max(ring.critical);
    let total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum::<u64>() + ring.cycles;

    // The final band — exactly interior ∖ valid_box — lands in the
    // chunk output.
    if let Some(last) = stage.ring.last() {
        for t in last {
            copy_box(spec, &mut output, &ring.cur, t.out_lo, t.out_hi);
        }
    }
    let ring_points = stage.ring_points() as u64;
    // Spilled tiles reload through the cache: only tiles the residency
    // plan covers actually receive shipped points.
    let exchanged_points = exchange
        .map(|ex| {
            ex.tiles
                .iter()
                .enumerate()
                .filter(|(id, _)| stage.residency.resident[*id])
                .map(|(_, te)| te.exchanged())
                .sum::<usize>()
        })
        .unwrap_or(0) as u64;

    // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output):
    // fused plans sum the per-layer trapezoid interiors, plus one
    // application per ring-band output.
    let total_flops = temporal::total_flops(spec, plan.fused_steps)
        + ring.outputs as f64 * spec.flops_per_output();

    let gflops = if makespan > 0 {
        total_flops * machine.clock_ghz / makespan as f64
    } else {
        0.0
    };
    Ok(ChunkOutput::Report(RunReport {
        output,
        strips: n_tasks,
        kind: plan.kind,
        cuts: plan.cuts,
        fused_steps: plan.fused_steps,
        halo_points: plan.halo_points() as u64,
        redundant_read_fraction: if warm {
            0.0
        } else {
            plan.redundant_read_fraction(spec)
        },
        exchanged_points,
        spilled_points: if warm {
            stage.residency.spilled_points as u64
        } else {
            0
        },
        exchange_spilled: warm && !stage.residency.fully_resident(),
        ring_points,
        ring_mem: ring.mem,
        makespan_cycles: makespan,
        ring_critical_cycles: ring.critical,
        total_cycles,
        total_flops,
        per_tile,
        gflops,
        wall_seconds: t0.elapsed().as_secs_f64(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{compile, CompileOptions};
    use crate::util::rng::XorShift;
    use crate::verify::golden::{max_abs_diff, stencil_ref, stencil_ref_steps};

    fn session(spec: &StencilSpec, steps: usize, opts: CompileOptions) -> Session {
        let machine = opts.machine.clone();
        Session::new(Arc::new(compile(spec, steps, &opts).unwrap()), machine)
    }

    /// Plain batch parameters: event core, no faults, no deadline.
    fn batch_params(machine: &Machine) -> BatchParams {
        BatchParams {
            machine: machine.clone(),
            core: SimCore::Event,
            fault: None,
            deadline: None,
            cancel: None,
        }
    }

    /// Unwrap a batch that ran with no deadline armed.
    fn done(out: BatchOutput) -> Vec<TaskResult> {
        match out {
            BatchOutput::Done(r) => r,
            BatchOutput::Deadline { .. } => panic!("no deadline was armed"),
        }
    }

    #[test]
    fn session_runs_single_step_against_oracle() {
        let spec = StencilSpec::heat2d(32, 14, 0.2);
        let mut rng = XorShift::new(0x5E55);
        let x = rng.normal_vec(32 * 14);
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.outcome, Outcome::Complete);
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
        assert_eq!(out.final_report().output, out.output);
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0xD1D1);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2));
        let a = s.run(&x).unwrap();
        let b = s.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            a.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>(),
            b.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_step_host_schedule_matches_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let s = session(
            &spec,
            3,
            CompileOptions::default()
                .with_workers(2)
                .with_tiles(2)
                .with_fuse(crate::compile::FuseMode::Host),
        );
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 3);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
    }

    #[test]
    fn session_rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let s = session(&spec, 1, CompileOptions::default().with_workers(1));
        assert!(s.run(&[0.0; 3]).is_err());
    }

    #[test]
    fn pool_is_reused_across_runs_and_clones_share_it() {
        let spec = StencilSpec::heat2d(20, 10, 0.2);
        let x = vec![1.0; 200];
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let a = s.run(&x).unwrap();
        let pool_ptr = Arc::as_ptr(s.pool.get().expect("pool spawned on first run"));
        let s2 = s.clone();
        let b = s2.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            pool_ptr,
            Arc::as_ptr(s2.pool.get().unwrap()),
            "clones must share the worker pool"
        );
    }

    #[test]
    fn panicked_tile_task_reports_error_and_pool_survives() {
        // A task whose input buffer is empty makes the simulator's
        // functional load index out of bounds -> panic on the worker.
        // The old executor aborted the whole process on join; now the
        // panic must surface as Err and the pool must stay usable.
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let machine = opts.machine.clone();
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let stage = &compiled.stages[0];
        let tile = stage.plan.tiles[0];
        let graph = Arc::clone(
            &stage.graphs[&[tile.in_extent(0), tile.in_extent(1), tile.in_extent(2)]],
        );
        let poisoned = TileTask {
            id: 0,
            tile,
            input: Vec::new(), // wrong length -> out-of-bounds load
            graph,
            resident: false,
            cost: None,
        };

        let pool = TilePool::new(2);
        let err = pool
            .submit(&batch_params(&machine), VecDeque::from([poisoned.clone()]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("panicked"), "got: {err}");

        // The pool survives and runs a healthy batch afterwards.
        let healthy = TileTask {
            input: tile.extract(&spec, &vec![1.0; 160]),
            ..poisoned.clone()
        };
        let ok = done(
            pool.submit(&batch_params(&machine), VecDeque::from([healthy]))
                .unwrap(),
        );
        assert_eq!(ok.len(), 1);

        // Sequential mode propagates the same class of error.
        let err2 = ExecRef::Sequential
            .run_batch(&batch_params(&machine), VecDeque::from([poisoned]))
            .unwrap_err()
            .to_string();
        assert!(err2.contains("panicked"), "got: {err2}");

        // And the classification boundary maps it to PoolPoisoned.
        assert_eq!(
            ScgraError::classify(anyhow::anyhow!("{err2}")).kind(),
            "pool-poisoned"
        );
    }

    #[test]
    fn failed_batch_cancels_remaining_tasks_without_hanging() {
        // One poisoned task among many: submit must return Err (not
        // hang waiting for cancelled tasks, not abort).
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let machine = opts.machine.clone();
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let stage = &compiled.stages[0];
        let input = vec![1.0; 160];
        let mut tasks: VecDeque<TileTask> = stage
            .plan
            .tiles
            .iter()
            .enumerate()
            .map(|(id, t)| TileTask {
                id,
                tile: *t,
                input: t.extract(&spec, &input),
                graph: Arc::clone(&stage.graphs
                    [&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
                resident: false,
                cost: None,
            })
            .collect();
        tasks.front_mut().unwrap().input = Vec::new(); // poison the first
        let pool = TilePool::new(1); // single worker: failure then cancel
        let err = pool
            .submit(&batch_params(&machine), tasks)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tile task"), "got: {err}");
    }

    #[test]
    fn sequential_mode_matches_pooled_outputs() {
        let spec = StencilSpec::heat2d(28, 14, 0.2);
        let mut rng = XorShift::new(0xAB);
        let x = rng.normal_vec(28 * 14);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2).with_tiles(3));
        let pooled = s.run(&x).unwrap();
        let seq = s.clone().with_exec(ExecMode::Sequential).run(&x).unwrap();
        assert_eq!(pooled.output, seq.output);
        for (p, q) in pooled.reports.iter().zip(&seq.reports) {
            assert_eq!(p.total_cycles, q.total_cycles);
            assert_eq!(p.strips, q.strips);
        }
    }

    #[test]
    fn recorded_trace_replays_and_detects_tampering() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0x77AC);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2).with_tiles(2));
        let (out, trace) = s.run_recorded(&x).unwrap();
        assert!(!trace.records.is_empty());
        let replayed = s.run_replay(&x, &trace).unwrap();
        assert_eq!(out.output, replayed.output);
        let mut tampered = trace.clone();
        tampered.records[0].fire_hash ^= 1;
        let err = s.run_replay(&x, &tampered).unwrap_err().to_string();
        assert!(err.contains("fire_hash"), "got: {err}");
    }

    #[test]
    fn expired_deadline_returns_partial_outcome_not_a_hang() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0xDEAD);
        let x = rng.normal_vec(24 * 12);
        for exec in [ExecMode::Pooled, ExecMode::Sequential] {
            let s = session(&spec, 2, CompileOptions::default().with_workers(2).with_tiles(2))
                .with_exec(exec)
                .with_deadline(Some(Duration::ZERO));
            let out = s.run(&x).unwrap();
            match out.outcome {
                Outcome::DeadlineExceeded {
                    completed_tasks,
                    total_tasks,
                } => {
                    assert!(total_tasks > 0);
                    assert!(completed_tasks <= total_tasks);
                }
                Outcome::Complete => panic!("a zero deadline cannot complete ({exec:?})"),
            }
            assert!(out.reports.is_empty(), "no chunk can finish in zero time");
            assert_eq!(out.output, x, "partial output falls back to the input grid");
            // A partial run cannot be replay-verified.
            let (_, trace) = session(&spec, 2, CompileOptions::default().with_workers(2))
                .run_recorded(&x)
                .unwrap();
            let err = s.run_replay(&x, &trace).unwrap_err();
            assert_eq!(err.kind(), "deadline-exceeded");
            // Removing the deadline restores a full run on the same
            // session (and, pooled, the same worker pool).
            let full = s.with_deadline(None).run(&x).unwrap();
            assert_eq!(full.outcome, Outcome::Complete);
            assert_eq!(full.reports.len(), 2);
        }
    }

    #[test]
    fn armed_fault_plan_converges_and_counts_retries_in_reports() {
        let spec = StencilSpec::heat2d(28, 14, 0.2);
        let mut rng = XorShift::new(0xFA17);
        let x = rng.normal_vec(28 * 14);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let clean = session(&spec, 2, opts.clone()).run(&x).unwrap();
        let plan = FaultPlan {
            seed: 7,
            fill_fail_pct: 35,
            ..FaultPlan::default()
        };
        let s = session(&spec, 2, opts).with_fault_plan(Some(plan));
        let faulted = s.run(&x).unwrap();
        assert_eq!(faulted.outcome, Outcome::Complete);
        assert_eq!(faulted.output, clean.output, "retries must converge bitwise");
        let retries: u64 = faulted
            .reports
            .iter()
            .flat_map(|r| r.per_tile.iter())
            .map(|t| t.mem.retries)
            .sum();
        assert!(retries > 0, "a 35% fill-failure plan must retry");
        // Pooled and sequential faulted runs stay bitwise identical.
        let seq = s.clone().with_exec(ExecMode::Sequential).run(&x).unwrap();
        assert_eq!(seq.output, faulted.output);
        // An unarmed plan is filtered out entirely.
        let noop = session(
            &spec,
            2,
            CompileOptions::default().with_workers(2).with_tiles(2),
        )
        .with_fault_plan(Some(FaultPlan::default()));
        assert!(noop.fault.is_none());
    }

    #[test]
    fn pool_respawns_a_dead_worker_and_batches_still_complete() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let machine = opts.machine.clone();
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let stage = &compiled.stages[0];
        let input = vec![1.0; 160];
        let make_tasks = || -> VecDeque<TileTask> {
            stage
                .plan
                .tiles
                .iter()
                .enumerate()
                .map(|(id, t)| TileTask {
                    id,
                    tile: *t,
                    input: t.extract(&spec, &input),
                    graph: Arc::clone(
                        &stage.graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]],
                    ),
                    resident: false,
                    cost: None,
                })
                .collect()
        };
        let pool = TilePool::new(2);
        // Ask exactly one worker to die; the survivor drains the batch.
        pool.shared.kill_one.store(1, Ordering::Release);
        let r = done(pool.submit(&batch_params(&machine), make_tasks()).unwrap());
        assert_eq!(r.len(), stage.plan.tiles.len());
        // Wait for the doomed worker to actually exit (it dies on its
        // way back to the park loop, possibly after the batch is done).
        for _ in 0..2000 {
            if lock_or_recover(&pool.workers).iter().any(|w| w.is_finished()) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(
            lock_or_recover(&pool.workers).iter().any(|w| w.is_finished()),
            "kill hook must take one worker down"
        );
        // The next submit notices the dead thread, respawns it, and the
        // batch completes on a full-strength pool.
        let r2 = done(pool.submit(&batch_params(&machine), make_tasks()).unwrap());
        assert_eq!(r2.len(), stage.plan.tiles.len());
        assert_eq!(pool.shared.kill_one.load(Ordering::Acquire), 0);
        let workers = lock_or_recover(&pool.workers);
        assert_eq!(workers.len(), 2);
        assert!(
            workers.iter().all(|w| !w.is_finished()),
            "dead worker must be respawned"
        );
    }
}
