//! Phase 2 of the compile-once / execute-many API: execution.
//!
//! A [`Session`] binds an immutable [`CompiledStencil`] to a
//! [`Machine`] and executes it against input grids — any number of
//! times, from any number of threads ([`Session`] is `Send + Sync` and
//! [`Session::run`] takes `&self`). Execution walks the artifact's
//! stages in order: each chunk decomposes the grid into the plan's
//! halo-padded tiles, pushes [`TileTask`]s into a shared queue, and
//! spawns one OS thread per hardware tile. Tiles pull greedily (natural
//! load balancing — the same work-stealing effect §IV's hybrid
//! algorithm relies on), instantiate a simulator over the stage's
//! shared placed graph ([`Simulator::from_placed`] — no re-validation,
//! no re-placement, no graph clone), and send results back over a
//! channel. The leader merges owned outputs into the global grid; the
//! reported makespan is the slowest tile's total, which is what 16
//! parallel tiles would take on silicon.
//!
//! Nothing here plans or builds graphs — the
//! [`crate::stencil::metrics`] counters stay flat across `run` calls,
//! which `rust/tests/compile_once.rs` pins.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::{Machine, PlacedGraph, SimCore, Simulator};
use crate::compile::CompiledStencil;
use crate::stencil::decomp::{DecompKind, DecompPlan, Tile};
use crate::stencil::{temporal, StencilSpec};

/// One unit of work: a halo-padded tile of the global grid.
#[derive(Clone)]
pub struct TileTask {
    pub id: usize,
    pub tile: Tile,
    /// Contiguous copy of the tile's input box.
    pub input: Vec<f64>,
    /// The placed graph for the tile's shape — shared by every tile
    /// with the same input extents (the graph depends only on dims and
    /// the worker count, not the data).
    pub graph: Arc<PlacedGraph>,
}

/// Per-hardware-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    /// Tile tasks executed on this hardware tile.
    pub strips: usize,
    /// Sum of simulated cycles over this tile's tasks.
    pub cycles: u64,
    /// Halo points this tile loaded beyond the outputs it owned.
    pub halo_points: u64,
    pub mem: MemStats,
}

/// Result of one executed chunk (one plan application).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    /// Number of tile tasks the decomposition produced.
    pub strips: usize,
    /// Resolved decomposition strategy.
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`.
    pub cuts: [usize; 3],
    /// §IV time-steps fused into each tile's pipeline this pass (1 =
    /// single-step; deeper fusion grows the per-tile halos by
    /// `radii * fused_steps` — visible in [`Self::halo_points`] — and
    /// divides the per-step DRAM traffic by the depth).
    pub fused_steps: usize,
    /// Total halo points loaded across tasks (redundant-load overhead).
    pub halo_points: u64,
    /// Fraction of the grid read more than once because of halo overlap.
    pub redundant_read_fraction: f64,
    /// Slowest tile's total cycles — the parallel makespan.
    pub makespan_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl RunReport {
    /// Total grid-point loads across the tile array — the §IV currency:
    /// a fused chunk loads its input once regardless of depth, so at
    /// equal total steps a spatially-fused run loads strictly less than
    /// the host-driven loop.
    pub fn total_loads(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.loads).sum()
    }
}

/// Everything one [`Session::run`] produced: the final grid and one
/// [`RunReport`] per executed chunk (host schedules: one per step).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub output: Vec<f64>,
    pub reports: Vec<RunReport>,
}

impl RunOutcome {
    /// The last chunk's report (every execution has at least one).
    pub fn final_report(&self) -> &RunReport {
        self.reports.last().expect("an execution always produces a report")
    }
}

/// A concurrent executor over a compiled artifact. Cheap to construct,
/// `Send + Sync`, and stateless across calls: every [`Session::run`]
/// only instantiates per-run simulator state from the artifact's shared
/// placed graphs.
#[derive(Clone)]
pub struct Session {
    compiled: Arc<CompiledStencil>,
    machine: Machine,
    /// Hardware tiles executing tile tasks (defaults to the compile
    /// options' tile count).
    tiles: usize,
    sim_core: SimCore,
}

impl Session {
    /// Build an executor from a compiled artifact and the machine to
    /// simulate on. Placement was fixed at compile time; `machine`
    /// drives the per-run memory system and the clock.
    pub fn new(compiled: Arc<CompiledStencil>, machine: Machine) -> Self {
        let tiles = compiled.options.tiles.max(1);
        Self {
            compiled,
            machine,
            tiles,
            sim_core: SimCore::default(),
        }
    }

    /// Override the simulator scheduler core (bit-identical either way;
    /// `Event` is the default and the fast one).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the hardware tile count pulling tasks.
    pub fn with_tiles(mut self, tiles: usize) -> Self {
        self.tiles = tiles.max(1);
        self
    }

    pub fn compiled(&self) -> &Arc<CompiledStencil> {
        &self.compiled
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Execute the compiled workload (all `steps` it was compiled for)
    /// on `input`. Never plans, never builds or places a graph; safe to
    /// call concurrently from many threads on distinct inputs.
    pub fn run(&self, input: &[f64]) -> Result<RunOutcome> {
        let spec = &self.compiled.spec;
        ensure!(
            input.len() == spec.grid_points(),
            "input length {} != grid {}",
            input.len(),
            spec.grid_points()
        );
        let mut reports: Vec<RunReport> = Vec::with_capacity(self.compiled.total_chunks());
        for stage in &self.compiled.stages {
            for _ in 0..stage.repeats {
                let src: &[f64] = match reports.last() {
                    None => input,
                    Some(prev) => prev.output.as_slice(),
                };
                let rep = execute_stage(
                    &self.machine,
                    self.tiles,
                    self.sim_core,
                    spec,
                    src,
                    &stage.plan,
                    &stage.graphs,
                )?;
                reports.push(rep);
            }
        }
        let output = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok(RunOutcome { output, reports })
    }
}

/// Execute one chunk: decompose `input` per `plan`, run every tile task
/// on the `hw_tiles`-thread pool against the shared placed graphs, and
/// merge the owned outputs. The shared core of [`Session::run`] and the
/// legacy [`crate::coordinator::Coordinator`] shim.
pub(crate) fn execute_stage(
    machine: &Machine,
    hw_tiles: usize,
    core: SimCore,
    spec: &StencilSpec,
    input: &[f64],
    plan: &DecompPlan,
    graphs: &HashMap<[usize; 3], Arc<PlacedGraph>>,
) -> Result<RunReport> {
    ensure!(
        input.len() == spec.grid_points(),
        "input length {} != grid {}",
        input.len(),
        spec.grid_points()
    );
    let t0 = std::time::Instant::now();
    let tasks: VecDeque<TileTask> = plan
        .tiles
        .iter()
        .enumerate()
        .map(|(id, t)| TileTask {
            id,
            tile: *t,
            input: t.extract(spec, input),
            graph: Arc::clone(&graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
        })
        .collect();
    let n_tasks = tasks.len();

    let queue = Arc::new(Mutex::new(tasks));
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for tile_id in 0..hw_tiles.min(n_tasks).max(1) {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        let machine = machine.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            loop {
                let task = { queue.lock().unwrap().pop_front() };
                let Some(task) = task else { break };
                let sim = Simulator::from_placed(
                    task.graph.as_ref(),
                    &machine,
                    task.input.clone(),
                    task.input,
                );
                let res = sim
                    .with_core(core)
                    .run()
                    .with_context(|| format!("tile task {}", task.id))?;
                tx.send((tile_id, task.tile, res)).ok();
            }
            Ok(())
        }));
    }
    drop(tx);

    // Merge owned outputs into the global grid (boundary = input copy).
    let mut output = input.to_vec();
    let mut per_tile = vec![TileReport::default(); hw_tiles];
    let mut received = 0;
    for (tile_id, tile, res) in rx {
        tile.merge(spec, &mut output, &res.output);
        let rep = &mut per_tile[tile_id];
        rep.strips += 1;
        rep.cycles += res.stats.cycles;
        rep.halo_points += tile.halo_points() as u64;
        rep.mem.accumulate(&res.stats.mem);
        received += 1;
    }
    for h in handles {
        h.join().expect("tile thread panicked")?;
    }
    ensure!(received == n_tasks, "lost tile results: {received}/{n_tasks}");

    // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output;
    // fused plans sum the per-layer trapezoid interiors).
    let total_flops = temporal::total_flops(spec, plan.fused_steps);

    let makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
    let total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum();
    let gflops = if makespan > 0 {
        total_flops * machine.clock_ghz / makespan as f64
    } else {
        0.0
    };
    Ok(RunReport {
        output,
        strips: n_tasks,
        kind: plan.kind,
        cuts: plan.cuts,
        fused_steps: plan.fused_steps,
        halo_points: plan.halo_points() as u64,
        redundant_read_fraction: plan.redundant_read_fraction(spec),
        makespan_cycles: makespan,
        total_cycles,
        total_flops,
        per_tile,
        gflops,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{compile, CompileOptions};
    use crate::util::rng::XorShift;
    use crate::verify::golden::{max_abs_diff, stencil_ref, stencil_ref_steps};

    fn session(spec: &StencilSpec, steps: usize, opts: CompileOptions) -> Session {
        let machine = opts.machine.clone();
        Session::new(Arc::new(compile(spec, steps, &opts).unwrap()), machine)
    }

    #[test]
    fn session_runs_single_step_against_oracle() {
        let spec = StencilSpec::heat2d(32, 14, 0.2);
        let mut rng = XorShift::new(0x5E55);
        let x = rng.normal_vec(32 * 14);
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 1);
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
        assert_eq!(out.final_report().output, out.output);
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0xD1D1);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2));
        let a = s.run(&x).unwrap();
        let b = s.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            a.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>(),
            b.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_step_host_schedule_matches_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let s = session(
            &spec,
            3,
            CompileOptions::default()
                .with_workers(2)
                .with_tiles(2)
                .with_fuse(crate::compile::FuseMode::Host),
        );
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 3);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
    }

    #[test]
    fn session_rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let s = session(&spec, 1, CompileOptions::default().with_workers(1));
        assert!(s.run(&[0.0; 3]).is_err());
    }
}
