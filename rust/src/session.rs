//! Phase 2 of the compile-once / execute-many API: execution.
//!
//! A [`Session`] binds an immutable [`CompiledStencil`] to a
//! [`Machine`] and executes it against input grids — any number of
//! times, from any number of threads ([`Session`] is `Send + Sync` and
//! [`Session::run`] takes `&self`). Execution walks the artifact's
//! stages in order; each chunk runs three precompiled pieces:
//!
//! 1. **Fused tiles** — the grid decomposes into the plan's halo-padded
//!    tiles, [`TileTask`]s go into a shared queue, and one OS thread
//!    per hardware tile pulls greedily (natural load balancing),
//!    instantiating a simulator over the stage's shared placed graph
//!    ([`Simulator::from_placed`] — no re-validation, no re-placement,
//!    no graph clone). The leader merges owned outputs into the global
//!    grid; the reported makespan is the slowest tile's total.
//! 2. **Time-tiled ring stages** — at fused depth `T > 1` the trapezoid
//!    only writes [`crate::stencil::temporal::valid_box`]; the
//!    artifact's per-layer band tiles
//!    ([`crate::compile::CompiledStage::ring`]) advance the boundary
//!    ring one step per stage against a scratch copy of the chunk
//!    input, and the final band — exactly the ring — is copied into the
//!    chunk output. That makes every chunk bitwise-equal to the
//!    iterated oracle on the **full** grid, not just the valid box.
//! 3. **Halo exchange** — under [`HaloMode::Exchange`] (the default)
//!    tiles retain their buffers across chunks, so every chunk after
//!    the cold first one finds its whole input fabric-resident: the
//!    compile-time [`ExchangeSchedule`] says which neighbor shipped
//!    each halo face, the simulators run with
//!    [`Simulator::with_fabric_resident`] (loads complete at hit
//!    latency, no cache/DRAM traffic — a timing/accounting change only,
//!    so exchange and reload runs are bitwise-identical), and the
//!    report's `redundant_read_fraction` drops to zero.
//!    [`HaloMode::Reload`] keeps the old re-read-everything behaviour
//!    as the differential baseline.
//!
//! Nothing here plans or builds graphs — the
//! [`crate::stencil::metrics`] counters stay flat across `run` calls,
//! which `rust/tests/compile_once.rs` pins.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::{Machine, PlacedGraph, SimCore, SimResult, Simulator};
use crate::compile::{CompiledStage, CompiledStencil, HaloMode};
use crate::stencil::decomp::{DecompKind, Tile};
use crate::stencil::exchange::ExchangeSchedule;
use crate::stencil::{temporal, StencilSpec};

/// One unit of work: a halo-padded tile of the global grid.
#[derive(Clone)]
pub struct TileTask {
    pub id: usize,
    pub tile: Tile,
    /// Contiguous copy of the tile's input box.
    pub input: Vec<f64>,
    /// The placed graph for the tile's shape — shared by every tile
    /// with the same input extents (the graph depends only on dims and
    /// the worker count, not the data).
    pub graph: Arc<PlacedGraph>,
}

/// Per-hardware-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    /// Tile tasks executed on this hardware tile.
    pub strips: usize,
    /// Sum of simulated cycles over this tile's tasks.
    pub cycles: u64,
    /// Halo points this tile loaded beyond the outputs it owned.
    pub halo_points: u64,
    pub mem: MemStats,
}

/// Result of one executed chunk (one plan application).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    /// Number of tile tasks the decomposition produced.
    pub strips: usize,
    /// Resolved decomposition strategy.
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`.
    pub cuts: [usize; 3],
    /// §IV time-steps fused into each tile's pipeline this pass (1 =
    /// single-step; deeper fusion grows the per-tile halos by
    /// `radii * fused_steps` — visible in [`Self::halo_points`] — and
    /// divides the per-step DRAM traffic by the depth).
    pub fused_steps: usize,
    /// Total halo points loaded across tasks (redundant-load overhead).
    pub halo_points: u64,
    /// Fraction of the grid this chunk read from DRAM more than once.
    /// Equal to the plan's geometric overlap for cold chunks and reload
    /// mode; 0 for a warm exchange chunk (the halo arrived over fabric
    /// channels instead).
    pub redundant_read_fraction: f64,
    /// Points this chunk received through in-fabric halo exchange
    /// instead of DRAM (0 for cold chunks and reload mode).
    pub exchanged_points: u64,
    /// Boundary-ring points the time-tiled band stages computed and
    /// merged into the output (0 at fused depth 1 — there is no ring).
    pub ring_points: u64,
    /// Memory counters of the ring band stages, kept separate from
    /// `per_tile` so [`Self::total_loads`] stays the §IV fused-pipeline
    /// currency.
    pub ring_mem: MemStats,
    /// Slowest tile's total cycles — the parallel makespan.
    pub makespan_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl RunReport {
    /// Total grid-point loads across the fused tile array — the §IV
    /// currency: a fused chunk loads its input once regardless of depth,
    /// so at equal total steps a spatially-fused run loads strictly less
    /// than the host-driven loop. Exchange hits still count (the load
    /// issued; it was just served from fabric — see
    /// [`Self::dram_point_reads`]), and ring-stage loads are accounted
    /// separately in [`Self::ring_mem`].
    pub fn total_loads(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.loads).sum()
    }

    /// Loads the fused tiles actually sent to the cache/DRAM side: total
    /// loads minus fabric-resident exchange hits. Zero for a warm
    /// exchange chunk — the measurement behind the reported
    /// post-exchange `redundant_read_fraction`.
    pub fn dram_point_reads(&self) -> u64 {
        self.per_tile
            .iter()
            .map(|t| t.mem.loads - t.mem.exchanged)
            .sum()
    }
}

/// Everything one [`Session::run`] produced: the final grid and one
/// [`RunReport`] per executed chunk (host schedules: one per step).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub output: Vec<f64>,
    pub reports: Vec<RunReport>,
}

impl RunOutcome {
    /// The last chunk's report (every execution has at least one).
    pub fn final_report(&self) -> &RunReport {
        self.reports.last().expect("an execution always produces a report")
    }
}

/// A concurrent executor over a compiled artifact. Cheap to construct,
/// `Send + Sync`, and stateless across calls: every [`Session::run`]
/// only instantiates per-run simulator state from the artifact's shared
/// placed graphs.
#[derive(Clone)]
pub struct Session {
    compiled: Arc<CompiledStencil>,
    machine: Machine,
    /// Hardware tiles executing tile tasks (defaults to the compile
    /// options' tile count).
    tiles: usize,
    sim_core: SimCore,
}

impl Session {
    /// Build an executor from a compiled artifact and the machine to
    /// simulate on. Placement was fixed at compile time; `machine`
    /// drives the per-run memory system and the clock.
    pub fn new(compiled: Arc<CompiledStencil>, machine: Machine) -> Self {
        let tiles = compiled.options.tiles.max(1);
        Self {
            compiled,
            machine,
            tiles,
            sim_core: SimCore::default(),
        }
    }

    /// Override the simulator scheduler core (bit-identical either way;
    /// `Event` is the default and the fast one).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the hardware tile count pulling tasks.
    pub fn with_tiles(mut self, tiles: usize) -> Self {
        self.tiles = tiles.max(1);
        self
    }

    pub fn compiled(&self) -> &Arc<CompiledStencil> {
        &self.compiled
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Execute the compiled workload (all `steps` it was compiled for)
    /// on `input`. Never plans, never builds or places a graph; safe to
    /// call concurrently from many threads on distinct inputs.
    pub fn run(&self, input: &[f64]) -> Result<RunOutcome> {
        let spec = &self.compiled.spec;
        ensure!(
            input.len() == spec.grid_points(),
            "input length {} != grid {}",
            input.len(),
            spec.grid_points()
        );
        let halo = self.compiled.options.halo;
        let mut reports: Vec<RunReport> = Vec::with_capacity(self.compiled.total_chunks());
        for stage in &self.compiled.stages {
            for rep_i in 0..stage.repeats {
                let src: &[f64] = match reports.last() {
                    None => input,
                    Some(prev) => prev.output.as_slice(),
                };
                // The first chunk of the run is cold (its input comes
                // from DRAM no matter what); afterwards, exchange mode
                // finds the previous chunk's results fabric-resident —
                // via the intra-stage schedule between repeats, or the
                // entry schedule when crossing into the tail stage.
                let exchange = if halo == HaloMode::Exchange && !reports.is_empty() {
                    Some(if rep_i == 0 {
                        stage.entry_exchange.as_ref().unwrap_or(&stage.intra_exchange)
                    } else {
                        &stage.intra_exchange
                    })
                } else {
                    None
                };
                let rep = execute_chunk(
                    &self.machine,
                    self.tiles,
                    self.sim_core,
                    spec,
                    src,
                    stage,
                    exchange,
                )?;
                reports.push(rep);
            }
        }
        let output = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok(RunOutcome { output, reports })
    }
}

/// Run a batch of tile tasks on the `hw_tiles`-thread pool and return
/// every `(hardware tile, task tile, result)` triple. With `resident`
/// set, simulators treat the whole input as fabric-resident
/// ([`Simulator::with_fabric_resident`]) — warm halo-exchange chunks.
fn run_pool(
    machine: &Machine,
    hw_tiles: usize,
    core: SimCore,
    resident: bool,
    tasks: VecDeque<TileTask>,
) -> Result<Vec<(usize, Tile, SimResult)>> {
    let n_tasks = tasks.len();
    if n_tasks == 0 {
        return Ok(Vec::new());
    }
    let queue = Arc::new(Mutex::new(tasks));
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for tile_id in 0..hw_tiles.min(n_tasks).max(1) {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        let machine = machine.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            loop {
                let task = { queue.lock().unwrap().pop_front() };
                let Some(task) = task else { break };
                let sim = Simulator::from_placed(
                    task.graph.as_ref(),
                    &machine,
                    task.input.clone(),
                    task.input,
                );
                let res = sim
                    .with_core(core)
                    .with_fabric_resident(resident)
                    .run()
                    .with_context(|| format!("tile task {}", task.id))?;
                tx.send((tile_id, task.tile, res)).ok();
            }
            Ok(())
        }));
    }
    drop(tx);
    let results: Vec<(usize, Tile, SimResult)> = rx.into_iter().collect();
    for h in handles {
        h.join().expect("tile thread panicked")?;
    }
    ensure!(
        results.len() == n_tasks,
        "lost tile results: {}/{n_tasks}",
        results.len()
    );
    Ok(results)
}

/// Copy the `[lo, hi)` box from `src` into `dst` (both full grids).
fn copy_box(spec: &StencilSpec, dst: &mut [f64], src: &[f64], lo: [usize; 3], hi: [usize; 3]) {
    let (nx, ny) = (spec.nx, spec.ny);
    for z in lo[2]..hi[2] {
        for y in lo[1]..hi[1] {
            let row = (z * ny + y) * nx;
            dst[row + lo[0]..row + hi[0]].copy_from_slice(&src[row + lo[0]..row + hi[0]]);
        }
    }
}

/// Execute one chunk: decompose `input` per the stage's plan, run every
/// fused tile task on the `hw_tiles`-thread pool against the shared
/// placed graphs, merge the owned outputs, then advance the boundary
/// ring through the stage's time-tiled band tiles so the chunk output
/// equals the iterated oracle on the full grid. The shared core of
/// [`Session::run`] and the legacy [`crate::coordinator::Coordinator`]
/// shim. `exchange` is `Some` for a warm chunk under
/// [`HaloMode::Exchange`]: every simulator runs fabric-resident and the
/// schedule's shipped-point count lands in the report.
pub(crate) fn execute_chunk(
    machine: &Machine,
    hw_tiles: usize,
    core: SimCore,
    spec: &StencilSpec,
    input: &[f64],
    stage: &CompiledStage,
    exchange: Option<&ExchangeSchedule>,
) -> Result<RunReport> {
    ensure!(
        input.len() == spec.grid_points(),
        "input length {} != grid {}",
        input.len(),
        spec.grid_points()
    );
    let t0 = std::time::Instant::now();
    let plan = &stage.plan;
    let resident = exchange.is_some();
    let tasks: VecDeque<TileTask> = plan
        .tiles
        .iter()
        .enumerate()
        .map(|(id, t)| TileTask {
            id,
            tile: *t,
            input: t.extract(spec, input),
            graph: Arc::clone(&stage.graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
        })
        .collect();
    let n_tasks = tasks.len();
    let results = run_pool(machine, hw_tiles, core, resident, tasks)?;

    // Merge owned outputs into the global grid (boundary = input copy).
    let mut output = input.to_vec();
    let mut per_tile = vec![TileReport::default(); hw_tiles];
    for (tile_id, tile, res) in results {
        tile.merge(spec, &mut output, &res.output);
        let rep = &mut per_tile[tile_id];
        rep.strips += 1;
        rep.cycles += res.stats.cycles;
        rep.halo_points += tile.halo_points() as u64;
        rep.mem.accumulate(&res.stats.mem);
    }
    let mut makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
    let mut total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum();

    // Time-tiled ring stages: band s advances the boundary ring to step
    // s against a scratch copy of the chunk input; bands run after the
    // fused trapezoid (a sequential barrier per stage), and the final
    // band — exactly interior ∖ valid_box — lands in the chunk output.
    let mut ring_mem = MemStats::default();
    let mut ring_outputs: u64 = 0;
    if !stage.ring.is_empty() {
        let mut cur = input.to_vec();
        for bands in &stage.ring {
            let tasks: VecDeque<TileTask> = bands
                .iter()
                .enumerate()
                .map(|(id, t)| TileTask {
                    id,
                    tile: *t,
                    input: t.extract(spec, &cur),
                    graph: Arc::clone(
                        &stage.ring_graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]],
                    ),
                })
                .collect();
            let results = run_pool(machine, hw_tiles, core, resident, tasks)?;
            let mut stage_max = 0u64;
            for (_, tile, res) in results {
                tile.merge(spec, &mut cur, &res.output);
                stage_max = stage_max.max(res.stats.cycles);
                total_cycles += res.stats.cycles;
                ring_mem.accumulate(&res.stats.mem);
                ring_outputs += tile.out_points() as u64;
            }
            makespan += stage_max;
        }
        if let Some(last) = stage.ring.last() {
            for t in last {
                copy_box(spec, &mut output, &cur, t.out_lo, t.out_hi);
            }
        }
    }
    let ring_points = stage.ring_points() as u64;

    // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output):
    // fused plans sum the per-layer trapezoid interiors, plus one
    // application per ring-band output.
    let total_flops = temporal::total_flops(spec, plan.fused_steps)
        + ring_outputs as f64 * spec.flops_per_output();

    let gflops = if makespan > 0 {
        total_flops * machine.clock_ghz / makespan as f64
    } else {
        0.0
    };
    Ok(RunReport {
        output,
        strips: n_tasks,
        kind: plan.kind,
        cuts: plan.cuts,
        fused_steps: plan.fused_steps,
        halo_points: plan.halo_points() as u64,
        redundant_read_fraction: if resident {
            0.0
        } else {
            plan.redundant_read_fraction(spec)
        },
        exchanged_points: exchange.map(|s| s.exchanged_points()).unwrap_or(0) as u64,
        ring_points,
        ring_mem,
        makespan_cycles: makespan,
        total_cycles,
        total_flops,
        per_tile,
        gflops,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{compile, CompileOptions};
    use crate::util::rng::XorShift;
    use crate::verify::golden::{max_abs_diff, stencil_ref, stencil_ref_steps};

    fn session(spec: &StencilSpec, steps: usize, opts: CompileOptions) -> Session {
        let machine = opts.machine.clone();
        Session::new(Arc::new(compile(spec, steps, &opts).unwrap()), machine)
    }

    #[test]
    fn session_runs_single_step_against_oracle() {
        let spec = StencilSpec::heat2d(32, 14, 0.2);
        let mut rng = XorShift::new(0x5E55);
        let x = rng.normal_vec(32 * 14);
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 1);
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
        assert_eq!(out.final_report().output, out.output);
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0xD1D1);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2));
        let a = s.run(&x).unwrap();
        let b = s.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            a.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>(),
            b.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_step_host_schedule_matches_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let s = session(
            &spec,
            3,
            CompileOptions::default()
                .with_workers(2)
                .with_tiles(2)
                .with_fuse(crate::compile::FuseMode::Host),
        );
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 3);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
    }

    #[test]
    fn session_rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let s = session(&spec, 1, CompileOptions::default().with_workers(1));
        assert!(s.run(&[0.0; 3]).is_err());
    }
}
