//! Phase 2 of the compile-once / execute-many API: execution.
//!
//! A [`Session`] binds an immutable [`CompiledStencil`] to a
//! [`Machine`] and executes it against input grids — any number of
//! times, from any number of threads ([`Session`] is `Send + Sync` and
//! [`Session::run`] takes `&self`). Execution walks the artifact's
//! stages in order; each chunk runs three precompiled pieces:
//!
//! 1. **Fused tiles** — the grid decomposes into the plan's halo-padded
//!    tiles, [`TileTask`]s go into a shared queue, and the session's
//!    **persistent worker pool** (one OS thread per hardware tile,
//!    spawned once on first use and reused by every subsequent batch,
//!    chunk and `run` call) pulls greedily (natural load balancing),
//!    instantiating a simulator over the stage's shared placed graph
//!    ([`Simulator::from_placed`] — no re-validation, no re-placement,
//!    no graph clone). The leader merges owned outputs into the global
//!    grid; the reported makespan is the slowest tile's total. A tile
//!    task that panics is caught on the worker and surfaced as an
//!    `Err` from [`Session::run`] — it never aborts the process, and
//!    the pool stays usable.
//! 2. **Time-tiled ring stages** — at fused depth `T > 1` the trapezoid
//!    only writes [`crate::stencil::temporal::valid_box`]; the
//!    artifact's per-layer band tiles
//!    ([`crate::compile::CompiledStage::ring`]) advance the boundary
//!    ring one step per stage against a scratch copy of the chunk
//!    input, and the final band — exactly the ring — is copied into the
//!    chunk output. That makes every chunk bitwise-equal to the
//!    iterated oracle on the **full** grid, not just the valid box.
//! 3. **Halo exchange** — under [`HaloMode::Exchange`] (the default)
//!    tiles retain their buffers across chunks, so every chunk after
//!    the cold first one finds its whole input fabric-resident: the
//!    compile-time [`ExchangeSchedule`] says which neighbor shipped
//!    each halo face, the simulators run with
//!    [`Simulator::with_fabric_resident`] (loads complete at hit
//!    latency, no cache/DRAM traffic — a timing/accounting change only,
//!    so exchange and reload runs are bitwise-identical), and the
//!    report's `redundant_read_fraction` drops to zero.
//!    [`HaloMode::Reload`] keeps the old re-read-everything behaviour
//!    as the differential baseline.
//!
//! Because each simulator run is deterministic and tile outputs merge
//! into disjoint owned boxes, the pooled execution is **bitwise
//! identical** to running every task sequentially on the caller thread
//! ([`ExecMode::Sequential`]) in every data-dependent observable:
//! output grid, per-task cycle counts, fire hashes and memory counters.
//! Only the *attribution* of tasks to hardware tiles (`per_tile`,
//! `makespan_cycles`) depends on scheduling. `rust/tests/sim_cores.rs`
//! pins the equality; [`Session::run_recorded`] /
//! [`Session::run_replay`] turn the per-task fingerprints into an
//! on-disk [`Trace`] for cross-build and cross-core regression checks.
//!
//! Nothing here plans or builds graphs — the
//! [`crate::stencil::metrics`] counters stay flat across `run` calls,
//! which `rust/tests/compile_once.rs` pins.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use anyhow::{bail, ensure, Result};

use crate::cgra::stats::MemStats;
use crate::cgra::{Machine, PlacedGraph, SimCore, SimResult, Simulator};
use crate::compile::{CompiledStage, CompiledStencil, HaloMode};
use crate::stencil::decomp::{DecompKind, Tile};
use crate::stencil::exchange::ExchangeSchedule;
use crate::stencil::{temporal, StencilSpec};
use crate::util::trace::{hash_f64s, Trace, TraceRecord};

/// One unit of work: a halo-padded tile of the global grid.
#[derive(Clone)]
pub struct TileTask {
    pub id: usize,
    pub tile: Tile,
    /// Contiguous copy of the tile's input box.
    pub input: Vec<f64>,
    /// The placed graph for the tile's shape — shared by every tile
    /// with the same input extents (the graph depends only on dims and
    /// the worker count, not the data).
    pub graph: Arc<PlacedGraph>,
}

/// How tile tasks are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The session's persistent worker pool (default): one OS thread
    /// per hardware tile, spawned once and reused across batches and
    /// `run` calls.
    #[default]
    Pooled,
    /// Run every task inline on the calling thread, in task order
    /// (attribution lands on hardware tile 0). The differential
    /// baseline the pooled mode is pinned bitwise-equal against.
    Sequential,
}

/// One completed tile task: `(task id, hardware tile, tile, result)`.
type TaskResult = (usize, usize, Tile, SimResult);

/// Completion state of one submitted batch.
#[derive(Default)]
struct BatchDone {
    results: Vec<TaskResult>,
    /// Tasks accounted for (completed or cancelled by an error).
    completed: usize,
    /// First failure (error or caught panic) — cancels the batch.
    error: Option<String>,
}

/// One batch of tile tasks submitted to the pool; the submitter blocks
/// on `done_cv` until every task is accounted for.
struct TileBatch {
    machine: Machine,
    core: SimCore,
    resident: bool,
    tasks: Mutex<VecDeque<TileTask>>,
    done: Mutex<BatchDone>,
    done_cv: Condvar,
    n_tasks: usize,
}

/// State shared between the pool handle and its worker threads.
struct PoolShared {
    /// FIFO of open batches; workers drain the front batch's tasks.
    queue: Mutex<VecDeque<Arc<TileBatch>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// A persistent tile-worker pool: `threads` OS threads spawned once,
/// parked on a condvar between batches. Replaces the old
/// spawn-per-batch executor — a warm [`Session::run`] performs no
/// thread creation at all.
struct TilePool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Render a caught panic payload for the error message.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Simulate one tile task (shared by pool workers and sequential mode).
fn simulate_task(
    machine: &Machine,
    core: SimCore,
    resident: bool,
    task: TileTask,
) -> Result<SimResult> {
    let sim = Simulator::from_placed(&task.graph, machine, task.input.clone(), task.input);
    sim.with_core(core).with_fabric_resident(resident).run()
}

fn worker_loop(worker_id: usize, shared: Arc<PoolShared>) {
    loop {
        // Claim the front batch with unclaimed tasks (drained batches
        // are popped; their stragglers finish on whoever claimed them).
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            'find: loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                while let Some(b) = q.front() {
                    if b.tasks.lock().unwrap().is_empty() {
                        q.pop_front();
                    } else {
                        break 'find Arc::clone(b);
                    }
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        // Drain its tasks greedily.
        loop {
            let Some(task) = batch.tasks.lock().unwrap().pop_front() else {
                break;
            };
            let task_id = task.id;
            let tile = task.tile;
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                simulate_task(&batch.machine, batch.core, batch.resident, task)
            }));
            let failure = match outcome {
                Ok(Ok(res)) => {
                    let mut done = batch.done.lock().unwrap();
                    done.results.push((task_id, worker_id, tile, res));
                    done.completed += 1;
                    if done.completed >= batch.n_tasks {
                        batch.done_cv.notify_all();
                    }
                    continue;
                }
                Ok(Err(e)) => format!("tile task {task_id}: {e}"),
                Err(p) => format!("tile task {task_id} panicked: {}", panic_msg(&*p)),
            };
            // Failure: cancel the batch's unclaimed tasks and account
            // for them so the submitter wakes. Tasks already claimed by
            // other workers account for themselves.
            let cancelled = {
                let mut t = batch.tasks.lock().unwrap();
                let n = t.len();
                t.clear();
                n
            };
            let mut done = batch.done.lock().unwrap();
            if done.error.is_none() {
                done.error = Some(failure);
            }
            done.completed += 1 + cancelled;
            if done.completed >= batch.n_tasks {
                batch.done_cv.notify_all();
            }
        }
    }
}

impl TilePool {
    fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads.max(1))
            .map(|w| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("scgra-tile-{w}"))
                    .spawn(move || worker_loop(w, s))
                    .expect("spawning tile worker")
            })
            .collect();
        Self {
            shared,
            workers: Mutex::new(workers),
        }
    }

    /// Run a batch to completion and return the results sorted by task
    /// id. Blocks the caller; worker panics and task errors come back
    /// as `Err` with the first failure's message.
    fn submit(
        &self,
        machine: &Machine,
        core: SimCore,
        resident: bool,
        tasks: VecDeque<TileTask>,
    ) -> Result<Vec<TaskResult>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let batch = Arc::new(TileBatch {
            machine: machine.clone(),
            core,
            resident,
            tasks: Mutex::new(tasks),
            done: Mutex::new(BatchDone::default()),
            done_cv: Condvar::new(),
            n_tasks: n,
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Arc::clone(&batch));
            self.shared.work_cv.notify_all();
        }
        let mut done = batch.done.lock().unwrap();
        while done.completed < n {
            done = batch.done_cv.wait(done).unwrap();
        }
        if let Some(e) = done.error.take() {
            bail!("{e}");
        }
        let mut results = std::mem::take(&mut done.results);
        drop(done);
        results.sort_by_key(|r| r.0);
        ensure!(
            results.len() == n,
            "lost tile results: {}/{n}",
            results.len()
        );
        Ok(results)
    }
}

impl Drop for TilePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Hold the queue lock while notifying so no worker misses the
        // flag between checking it and parking.
        drop(self.shared.queue.lock().unwrap());
        self.shared.work_cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Execution backend for one chunk: the session's pool or the caller
/// thread.
#[derive(Clone, Copy)]
enum ExecRef<'a> {
    Pool(&'a TilePool),
    Sequential,
}

impl ExecRef<'_> {
    /// Run a batch, returning results in task-id order.
    fn run_batch(
        &self,
        machine: &Machine,
        core: SimCore,
        resident: bool,
        tasks: VecDeque<TileTask>,
    ) -> Result<Vec<TaskResult>> {
        match self {
            ExecRef::Pool(pool) => pool.submit(machine, core, resident, tasks),
            ExecRef::Sequential => {
                let mut results = Vec::with_capacity(tasks.len());
                for task in tasks {
                    let task_id = task.id;
                    let tile = task.tile;
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        simulate_task(machine, core, resident, task)
                    }));
                    match outcome {
                        Ok(Ok(res)) => results.push((task_id, 0, tile, res)),
                        Ok(Err(e)) => bail!("tile task {task_id}: {e}"),
                        Err(p) => {
                            bail!("tile task {task_id} panicked: {}", panic_msg(&*p))
                        }
                    }
                }
                Ok(results)
            }
        }
    }
}

/// Per-hardware-tile accounting.
#[derive(Debug, Clone, Default)]
pub struct TileReport {
    /// Tile tasks executed on this hardware tile.
    pub strips: usize,
    /// Sum of simulated cycles over this tile's tasks.
    pub cycles: u64,
    /// Halo points this tile loaded beyond the outputs it owned.
    pub halo_points: u64,
    pub mem: MemStats,
}

/// Result of one executed chunk (one plan application).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub output: Vec<f64>,
    /// Number of tile tasks the decomposition produced.
    pub strips: usize,
    /// Resolved decomposition strategy.
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`.
    pub cuts: [usize; 3],
    /// §IV time-steps fused into each tile's pipeline this pass (1 =
    /// single-step; deeper fusion grows the per-tile halos by
    /// `radii * fused_steps` — visible in [`Self::halo_points`] — and
    /// divides the per-step DRAM traffic by the depth).
    pub fused_steps: usize,
    /// Total halo points loaded across tasks (redundant-load overhead).
    pub halo_points: u64,
    /// Fraction of the grid this chunk read from DRAM more than once.
    /// Equal to the plan's geometric overlap for cold chunks and reload
    /// mode; 0 for a warm exchange chunk (the halo arrived over fabric
    /// channels instead).
    pub redundant_read_fraction: f64,
    /// Points this chunk received through in-fabric halo exchange
    /// instead of DRAM (0 for cold chunks and reload mode).
    pub exchanged_points: u64,
    /// Boundary-ring points the time-tiled band stages computed and
    /// merged into the output (0 at fused depth 1 — there is no ring).
    pub ring_points: u64,
    /// Memory counters of the ring band stages, kept separate from
    /// `per_tile` so [`Self::total_loads`] stays the §IV fused-pipeline
    /// currency.
    pub ring_mem: MemStats,
    /// Slowest tile's total cycles — the parallel makespan.
    pub makespan_cycles: u64,
    /// Sum of cycles across tiles (serial-equivalent work).
    pub total_cycles: u64,
    pub total_flops: f64,
    pub per_tile: Vec<TileReport>,
    /// Aggregate achieved GFLOPS across the tile array.
    pub gflops: f64,
    /// Host wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl RunReport {
    /// Total grid-point loads across the fused tile array — the §IV
    /// currency: a fused chunk loads its input once regardless of depth,
    /// so at equal total steps a spatially-fused run loads strictly less
    /// than the host-driven loop. Exchange hits still count (the load
    /// issued; it was just served from fabric — see
    /// [`Self::dram_point_reads`]), and ring-stage loads are accounted
    /// separately in [`Self::ring_mem`].
    pub fn total_loads(&self) -> u64 {
        self.per_tile.iter().map(|t| t.mem.loads).sum()
    }

    /// Loads the fused tiles actually sent to the cache/DRAM side: total
    /// loads minus fabric-resident exchange hits. Zero for a warm
    /// exchange chunk — the measurement behind the reported
    /// post-exchange `redundant_read_fraction`.
    pub fn dram_point_reads(&self) -> u64 {
        self.per_tile
            .iter()
            .map(|t| t.mem.loads - t.mem.exchanged)
            .sum()
    }
}

/// Everything one [`Session::run`] produced: the final grid and one
/// [`RunReport`] per executed chunk (host schedules: one per step).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub output: Vec<f64>,
    pub reports: Vec<RunReport>,
}

impl RunOutcome {
    /// The last chunk's report (every execution has at least one).
    pub fn final_report(&self) -> &RunReport {
        self.reports.last().expect("an execution always produces a report")
    }
}

/// A concurrent executor over a compiled artifact. Cheap to construct,
/// `Send + Sync`, and stateless across calls except for its lazily
/// spawned worker pool: every [`Session::run`] only instantiates
/// per-run simulator state from the artifact's shared placed graphs.
/// Clones share the pool.
#[derive(Clone)]
pub struct Session {
    compiled: Arc<CompiledStencil>,
    machine: Machine,
    /// Hardware tiles executing tile tasks (defaults to the compile
    /// options' tile count).
    tiles: usize,
    sim_core: SimCore,
    exec: ExecMode,
    /// Persistent worker pool, spawned on first pooled `run`.
    pool: OnceLock<Arc<TilePool>>,
}

impl Session {
    /// Build an executor from a compiled artifact and the machine to
    /// simulate on. Placement was fixed at compile time; `machine`
    /// drives the per-run memory system and the clock.
    pub fn new(compiled: Arc<CompiledStencil>, machine: Machine) -> Self {
        let tiles = compiled.options.tiles.max(1);
        Self {
            compiled,
            machine,
            tiles,
            sim_core: SimCore::default(),
            exec: ExecMode::default(),
            pool: OnceLock::new(),
        }
    }

    /// Override the simulator scheduler core (bit-identical either way;
    /// `Event` is the default and the fast one).
    pub fn with_sim_core(mut self, core: SimCore) -> Self {
        self.sim_core = core;
        self
    }

    /// Override the hardware tile count pulling tasks. Detaches from
    /// any already-spawned pool (the new count needs new workers).
    pub fn with_tiles(mut self, tiles: usize) -> Self {
        self.tiles = tiles.max(1);
        self.pool = OnceLock::new();
        self
    }

    /// Select the execution backend (default [`ExecMode::Pooled`]).
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    pub fn compiled(&self) -> &Arc<CompiledStencil> {
        &self.compiled
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    fn exec_ref(&self) -> ExecRef<'_> {
        match self.exec {
            ExecMode::Sequential => ExecRef::Sequential,
            ExecMode::Pooled => {
                ExecRef::Pool(self.pool.get_or_init(|| Arc::new(TilePool::new(self.tiles))))
            }
        }
    }

    /// Execute the compiled workload (all `steps` it was compiled for)
    /// on `input`. Never plans, never builds or places a graph, and on
    /// a warm session never spawns a thread; safe to call concurrently
    /// from many threads on distinct inputs.
    pub fn run(&self, input: &[f64]) -> Result<RunOutcome> {
        self.run_inner(input, None)
    }

    /// [`Session::run`], also capturing a [`Trace`]: one fingerprint
    /// record per executed tile task, in deterministic task order.
    pub fn run_recorded(&self, input: &[f64]) -> Result<(RunOutcome, Trace)> {
        let mut records = Vec::new();
        let outcome = self.run_inner(input, Some(&mut records))?;
        Ok((outcome, Trace { records }))
    }

    /// Run and verify against a previously recorded [`Trace`]: any
    /// behavioural divergence (cycles, fires, tickets, fire hash or
    /// output hash of any tile task) fails with the first mismatch.
    /// Core-dependent counters (`wakeups`) are ignored, so a trace
    /// recorded under one sim core replays under the other.
    pub fn run_replay(&self, input: &[f64], reference: &Trace) -> Result<RunOutcome> {
        let (outcome, trace) = self.run_recorded(input)?;
        trace.matches(reference)?;
        Ok(outcome)
    }

    fn run_inner(
        &self,
        input: &[f64],
        mut trace: Option<&mut Vec<TraceRecord>>,
    ) -> Result<RunOutcome> {
        let spec = &self.compiled.spec;
        ensure!(
            input.len() == spec.grid_points(),
            "input length {} != grid {}",
            input.len(),
            spec.grid_points()
        );
        let exec = self.exec_ref();
        let halo = self.compiled.options.halo;
        let mut reports: Vec<RunReport> = Vec::with_capacity(self.compiled.total_chunks());
        for stage in &self.compiled.stages {
            for rep_i in 0..stage.repeats {
                let src: &[f64] = match reports.last() {
                    None => input,
                    Some(prev) => prev.output.as_slice(),
                };
                // The first chunk of the run is cold (its input comes
                // from DRAM no matter what); afterwards, exchange mode
                // finds the previous chunk's results fabric-resident —
                // via the intra-stage schedule between repeats, or the
                // entry schedule when crossing into the tail stage.
                let exchange = if halo == HaloMode::Exchange && !reports.is_empty() {
                    Some(if rep_i == 0 {
                        stage.entry_exchange.as_ref().unwrap_or(&stage.intra_exchange)
                    } else {
                        &stage.intra_exchange
                    })
                } else {
                    None
                };
                let rep = execute_chunk(
                    &self.machine,
                    exec,
                    self.tiles,
                    self.sim_core,
                    spec,
                    src,
                    stage,
                    exchange,
                    reports.len() as u32,
                    trace.as_deref_mut(),
                )?;
                reports.push(rep);
            }
        }
        let output = match reports.last() {
            Some(last) => last.output.clone(),
            None => input.to_vec(),
        };
        Ok(RunOutcome { output, reports })
    }
}

/// Copy the `[lo, hi)` box from `src` into `dst` (both full grids).
fn copy_box(spec: &StencilSpec, dst: &mut [f64], src: &[f64], lo: [usize; 3], hi: [usize; 3]) {
    let (nx, ny) = (spec.nx, spec.ny);
    for z in lo[2]..hi[2] {
        for y in lo[1]..hi[1] {
            let row = (z * ny + y) * nx;
            dst[row + lo[0]..row + hi[0]].copy_from_slice(&src[row + lo[0]..row + hi[0]]);
        }
    }
}

/// Append one [`TraceRecord`] per task result (already in task order).
fn trace_batch(
    sink: &mut Vec<TraceRecord>,
    chunk: u32,
    phase: u32,
    results: &[TaskResult],
) {
    for (task_id, _, _, res) in results {
        sink.push(TraceRecord {
            chunk,
            phase,
            task: *task_id as u32,
            cycles: res.stats.cycles,
            fires: res.stats.total_fires(),
            tickets: res.stats.mem.loads + res.stats.mem.stores,
            fire_hash: res.stats.fire_hash,
            output_hash: hash_f64s(&res.output),
            wakeups: res.stats.wakeups,
        });
    }
}

/// Execute one chunk: decompose `input` per the stage's plan, run every
/// fused tile task through the execution backend against the shared
/// placed graphs, merge the owned outputs, then advance the boundary
/// ring through the stage's time-tiled band tiles so the chunk output
/// equals the iterated oracle on the full grid. `exchange` is `Some`
/// for a warm chunk under [`HaloMode::Exchange`]: every simulator runs
/// fabric-resident and the schedule's shipped-point count lands in the
/// report. With a `trace` sink, fingerprints are appended per batch
/// (fused tiles = phase 0, ring bands = phase 1..) in task order.
#[allow(clippy::too_many_arguments)]
fn execute_chunk(
    machine: &Machine,
    exec: ExecRef<'_>,
    hw_tiles: usize,
    core: SimCore,
    spec: &StencilSpec,
    input: &[f64],
    stage: &CompiledStage,
    exchange: Option<&ExchangeSchedule>,
    chunk: u32,
    mut trace: Option<&mut Vec<TraceRecord>>,
) -> Result<RunReport> {
    ensure!(
        input.len() == spec.grid_points(),
        "input length {} != grid {}",
        input.len(),
        spec.grid_points()
    );
    let t0 = std::time::Instant::now();
    let plan = &stage.plan;
    let resident = exchange.is_some();
    let tasks: VecDeque<TileTask> = plan
        .tiles
        .iter()
        .enumerate()
        .map(|(id, t)| TileTask {
            id,
            tile: *t,
            input: t.extract(spec, input),
            graph: Arc::clone(&stage.graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
        })
        .collect();
    let n_tasks = tasks.len();
    let results = exec.run_batch(machine, core, resident, tasks)?;
    if let Some(sink) = trace.as_deref_mut() {
        trace_batch(sink, chunk, 0, &results);
    }

    // Merge owned outputs into the global grid (boundary = input copy).
    let mut output = input.to_vec();
    let mut per_tile = vec![TileReport::default(); hw_tiles];
    for (_, tile_id, tile, res) in results {
        tile.merge(spec, &mut output, &res.output);
        let rep = &mut per_tile[tile_id];
        rep.strips += 1;
        rep.cycles += res.stats.cycles;
        rep.halo_points += tile.halo_points() as u64;
        rep.mem.accumulate(&res.stats.mem);
    }
    let mut makespan = per_tile.iter().map(|t| t.cycles).max().unwrap_or(0);
    let mut total_cycles: u64 = per_tile.iter().map(|t| t.cycles).sum();

    // Time-tiled ring stages: band s advances the boundary ring to step
    // s against a scratch copy of the chunk input; bands run after the
    // fused trapezoid (a sequential barrier per stage), and the final
    // band — exactly interior ∖ valid_box — lands in the chunk output.
    let mut ring_mem = MemStats::default();
    let mut ring_outputs: u64 = 0;
    if !stage.ring.is_empty() {
        let mut cur = input.to_vec();
        for (band_i, bands) in stage.ring.iter().enumerate() {
            let tasks: VecDeque<TileTask> = bands
                .iter()
                .enumerate()
                .map(|(id, t)| TileTask {
                    id,
                    tile: *t,
                    input: t.extract(spec, &cur),
                    graph: Arc::clone(
                        &stage.ring_graphs[&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]],
                    ),
                })
                .collect();
            let results = exec.run_batch(machine, core, resident, tasks)?;
            if let Some(sink) = trace.as_deref_mut() {
                trace_batch(sink, chunk, band_i as u32 + 1, &results);
            }
            let mut stage_max = 0u64;
            for (_, _, tile, res) in results {
                tile.merge(spec, &mut cur, &res.output);
                stage_max = stage_max.max(res.stats.cycles);
                total_cycles += res.stats.cycles;
                ring_mem.accumulate(&res.stats.mem);
                ring_outputs += tile.out_points() as u64;
            }
            makespan += stage_max;
        }
        if let Some(last) = stage.ring.last() {
            for t in last {
                copy_box(spec, &mut output, &cur, t.out_lo, t.out_hi);
            }
        }
    }
    let ring_points = stage.ring_points() as u64;

    // Exact FLOP count from the spec (MUL = 1, MAC = 2 per output):
    // fused plans sum the per-layer trapezoid interiors, plus one
    // application per ring-band output.
    let total_flops = temporal::total_flops(spec, plan.fused_steps)
        + ring_outputs as f64 * spec.flops_per_output();

    let gflops = if makespan > 0 {
        total_flops * machine.clock_ghz / makespan as f64
    } else {
        0.0
    };
    Ok(RunReport {
        output,
        strips: n_tasks,
        kind: plan.kind,
        cuts: plan.cuts,
        fused_steps: plan.fused_steps,
        halo_points: plan.halo_points() as u64,
        redundant_read_fraction: if resident {
            0.0
        } else {
            plan.redundant_read_fraction(spec)
        },
        exchanged_points: exchange.map(|s| s.exchanged_points()).unwrap_or(0) as u64,
        ring_points,
        ring_mem,
        makespan_cycles: makespan,
        total_cycles,
        total_flops,
        per_tile,
        gflops,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{compile, CompileOptions};
    use crate::util::rng::XorShift;
    use crate::verify::golden::{max_abs_diff, stencil_ref, stencil_ref_steps};

    fn session(spec: &StencilSpec, steps: usize, opts: CompileOptions) -> Session {
        let machine = opts.machine.clone();
        Session::new(Arc::new(compile(spec, steps, &opts).unwrap()), machine)
    }

    #[test]
    fn session_runs_single_step_against_oracle() {
        let spec = StencilSpec::heat2d(32, 14, 0.2);
        let mut rng = XorShift::new(0x5E55);
        let x = rng.normal_vec(32 * 14);
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 1);
        let want = stencil_ref(&x, &spec);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
        assert_eq!(out.final_report().output, out.output);
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0xD1D1);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2));
        let a = s.run(&x).unwrap();
        let b = s.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            a.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>(),
            b.reports.iter().map(|r| r.makespan_cycles).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_step_host_schedule_matches_iterated_oracle() {
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let mut rng = XorShift::new(0xFEED);
        let x = rng.normal_vec(20 * 12);
        let s = session(
            &spec,
            3,
            CompileOptions::default()
                .with_workers(2)
                .with_tiles(2)
                .with_fuse(crate::compile::FuseMode::Host),
        );
        let out = s.run(&x).unwrap();
        assert_eq!(out.reports.len(), 3);
        let want = stencil_ref_steps(&spec, &x, 3);
        assert!(max_abs_diff(&out.output, &want) < 1e-11);
    }

    #[test]
    fn session_rejects_wrong_input_length() {
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let s = session(&spec, 1, CompileOptions::default().with_workers(1));
        assert!(s.run(&[0.0; 3]).is_err());
    }

    #[test]
    fn pool_is_reused_across_runs_and_clones_share_it() {
        let spec = StencilSpec::heat2d(20, 10, 0.2);
        let x = vec![1.0; 200];
        let s = session(&spec, 1, CompileOptions::default().with_workers(2).with_tiles(2));
        let a = s.run(&x).unwrap();
        let pool_ptr = Arc::as_ptr(s.pool.get().expect("pool spawned on first run"));
        let s2 = s.clone();
        let b = s2.run(&x).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(
            pool_ptr,
            Arc::as_ptr(s2.pool.get().unwrap()),
            "clones must share the worker pool"
        );
    }

    #[test]
    fn panicked_tile_task_reports_error_and_pool_survives() {
        // A task whose input buffer is empty makes the simulator's
        // functional load index out of bounds -> panic on the worker.
        // The old executor aborted the whole process on join; now the
        // panic must surface as Err and the pool must stay usable.
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let machine = opts.machine.clone();
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let stage = &compiled.stages[0];
        let tile = stage.plan.tiles[0];
        let graph = Arc::clone(
            &stage.graphs[&[tile.in_extent(0), tile.in_extent(1), tile.in_extent(2)]],
        );
        let poisoned = TileTask {
            id: 0,
            tile,
            input: Vec::new(), // wrong length -> out-of-bounds load
            graph,
        };

        let pool = TilePool::new(2);
        let err = pool
            .submit(&machine, SimCore::Event, false, VecDeque::from([poisoned.clone()]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("panicked"), "got: {err}");

        // The pool survives and runs a healthy batch afterwards.
        let healthy = TileTask {
            input: tile.extract(&spec, &vec![1.0; 160]),
            ..poisoned.clone()
        };
        let ok = pool
            .submit(&machine, SimCore::Event, false, VecDeque::from([healthy]))
            .unwrap();
        assert_eq!(ok.len(), 1);

        // Sequential mode propagates the same class of error.
        let err2 = ExecRef::Sequential
            .run_batch(&machine, SimCore::Event, false, VecDeque::from([poisoned]))
            .unwrap_err()
            .to_string();
        assert!(err2.contains("panicked"), "got: {err2}");
    }

    #[test]
    fn failed_batch_cancels_remaining_tasks_without_hanging() {
        // One poisoned task among many: submit must return Err (not
        // hang waiting for cancelled tasks, not abort).
        let spec = StencilSpec::heat2d(16, 10, 0.2);
        let opts = CompileOptions::default().with_workers(2).with_tiles(2);
        let machine = opts.machine.clone();
        let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
        let stage = &compiled.stages[0];
        let input = vec![1.0; 160];
        let mut tasks: VecDeque<TileTask> = stage
            .plan
            .tiles
            .iter()
            .enumerate()
            .map(|(id, t)| TileTask {
                id,
                tile: *t,
                input: t.extract(&spec, &input),
                graph: Arc::clone(&stage.graphs
                    [&[t.in_extent(0), t.in_extent(1), t.in_extent(2)]]),
            })
            .collect();
        tasks.front_mut().unwrap().input = Vec::new(); // poison the first
        let pool = TilePool::new(1); // single worker: failure then cancel
        let err = pool
            .submit(&machine, SimCore::Event, false, tasks)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tile task"), "got: {err}");
    }

    #[test]
    fn sequential_mode_matches_pooled_outputs() {
        let spec = StencilSpec::heat2d(28, 14, 0.2);
        let mut rng = XorShift::new(0xAB);
        let x = rng.normal_vec(28 * 14);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2).with_tiles(3));
        let pooled = s.run(&x).unwrap();
        let seq = s.clone().with_exec(ExecMode::Sequential).run(&x).unwrap();
        assert_eq!(pooled.output, seq.output);
        for (p, q) in pooled.reports.iter().zip(&seq.reports) {
            assert_eq!(p.total_cycles, q.total_cycles);
            assert_eq!(p.strips, q.strips);
        }
    }

    #[test]
    fn recorded_trace_replays_and_detects_tampering() {
        let spec = StencilSpec::heat2d(24, 12, 0.2);
        let mut rng = XorShift::new(0x77AC);
        let x = rng.normal_vec(24 * 12);
        let s = session(&spec, 2, CompileOptions::default().with_workers(2).with_tiles(2));
        let (out, trace) = s.run_recorded(&x).unwrap();
        assert!(!trace.records.is_empty());
        let replayed = s.run_replay(&x, &trace).unwrap();
        assert_eq!(out.output, replayed.output);
        let mut tampered = trace.clone();
        tampered.records[0].fire_hash ^= 1;
        let err = s.run_replay(&x, &tampered).unwrap_err().to_string();
        assert!(err.contains("fire_hash"), "got: {err}");
    }
}
