//! §III-B "Blocking" — strip mining when the fabric cannot buffer
//! `2*ry` rows of the grid.
//!
//! The length of the rows kept inside the CGRA queues is limited by
//! on-fabric storage; if `x_dim` is too large, the grid is blocked into
//! vertical strips with `rx`-wide halos so that each strip's mandatory
//! buffering fits. The coordinator executes strips independently (they
//! only share read-only halo input), which is also the §IV / §VIII-A
//! multi-tile decomposition unit.

use anyhow::{ensure, Result};

use super::map2d::required_buffer_tokens;
use super::spec::StencilSpec;

/// One vertical strip: output columns `[out_lo, out_hi)` of the global
/// grid, computed from input columns `[in_lo, in_hi)` (halo included).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Strip {
    pub out_lo: usize,
    pub out_hi: usize,
    pub in_lo: usize,
    pub in_hi: usize,
}

impl Strip {
    /// Width of the strip's input sub-grid.
    pub fn in_width(&self) -> usize {
        self.in_hi - self.in_lo
    }

    /// Number of output columns this strip owns.
    pub fn out_width(&self) -> usize {
        self.out_hi - self.out_lo
    }
}

/// Plan vertical strips whose output columns tile the interior
/// `[rx, nx - rx)` exactly, each strip `out_width <= block_w`.
pub fn strips_for_width(spec: &StencilSpec, block_w: usize) -> Vec<Strip> {
    let rx = spec.rx;
    let interior = spec.nx - 2 * rx;
    let mut strips = Vec::new();
    let mut lo = rx;
    while lo < rx + interior {
        let hi = usize::min(lo + block_w, rx + interior);
        strips.push(Strip {
            out_lo: lo,
            out_hi: hi,
            in_lo: lo - rx,
            in_hi: hi + rx,
        });
        lo = hi;
    }
    strips
}

/// Largest strip width whose per-strip mandatory buffering fits
/// `budget_tokens`, and the resulting plan. Errors if even the minimum
/// strip (one output column wave per worker) cannot fit.
pub fn plan(
    spec: &StencilSpec,
    w: usize,
    budget_tokens: usize,
) -> Result<(usize, Vec<Strip>)> {
    ensure!(!spec.is_1d(), "blocking applies to 2-D stencils");
    let interior = spec.nx - 2 * spec.rx;
    // Buffering is monotone in strip width → binary search the widest
    // feasible block_w.
    let fits = |bw: usize| {
        let sub = spec.strip(0, bw + 2 * spec.rx);
        required_buffer_tokens(&sub, w) <= budget_tokens
    };
    ensure!(
        fits(w.max(1)),
        "even a {}-column strip exceeds the fabric budget of {} tokens",
        w,
        budget_tokens
    );
    let (mut lo, mut hi) = (w, interior); // lo feasible, search up to full width
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    Ok((lo, strips_for_width(spec, lo)))
}

/// Default on-fabric token budget: 256 PEs with (paper §II-A) small
/// input/output queues plus scratchpad-backed spill — sized so the
/// Table-I 2-D workload (960 cols, rx=ry=12, w=5) runs without strip
/// mining, matching the paper's single-CGRA simulation.
pub const DEFAULT_FABRIC_TOKENS: usize = 64 * 1024;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_tile_the_interior_exactly() {
        let spec = StencilSpec::paper_2d();
        for bw in [64, 100, 936, 937, 1000] {
            let strips = strips_for_width(&spec, bw);
            assert_eq!(strips[0].out_lo, spec.rx);
            assert_eq!(strips.last().unwrap().out_hi, spec.nx - spec.rx);
            for w in strips.windows(2) {
                assert_eq!(w[0].out_hi, w[1].out_lo, "gap/overlap");
            }
            let total: usize = strips.iter().map(|s| s.out_width()).sum();
            assert_eq!(total, spec.nx - 2 * spec.rx);
        }
    }

    #[test]
    fn halos_extend_by_rx() {
        let spec = StencilSpec::paper_2d();
        for s in strips_for_width(&spec, 200) {
            assert_eq!(s.in_lo + spec.rx, s.out_lo);
            assert_eq!(s.in_hi - spec.rx, s.out_hi);
            assert!(s.in_hi <= spec.nx);
        }
    }

    #[test]
    fn paper_2d_fits_default_budget_unblocked() {
        let spec = StencilSpec::paper_2d();
        let (bw, strips) = plan(&spec, 5, DEFAULT_FABRIC_TOKENS).unwrap();
        assert_eq!(bw, spec.nx - 2 * spec.rx, "no strip mining needed");
        assert_eq!(strips.len(), 1);
    }

    #[test]
    fn small_budget_forces_strips() {
        let spec = StencilSpec::paper_2d();
        // Full width needs ~37k tokens; 22k forces strip mining but still
        // admits a minimal strip.
        let (bw, strips) = plan(&spec, 5, 22_000).unwrap();
        assert!(bw < spec.nx - 2 * spec.rx);
        assert!(strips.len() > 1);
        // Monotonicity: smaller budget, narrower strips.
        let (bw2, _) = plan(&spec, 5, 17_000).unwrap();
        assert!(bw2 <= bw);
    }

    #[test]
    fn impossible_budget_is_an_error() {
        let spec = StencilSpec::paper_2d();
        assert!(plan(&spec, 5, 10).is_err());
    }

    #[test]
    fn plan_width_is_maximal() {
        // The returned width must be feasible and width+1 infeasible
        // (unless full interior).
        let spec = StencilSpec::paper_2d();
        let budget = 25_000;
        let (bw, _) = plan(&spec, 5, budget).unwrap();
        let sub = spec.strip(0, bw + 2 * spec.rx);
        assert!(required_buffer_tokens(&sub, 5) <= budget);
        if bw < spec.nx - 2 * spec.rx {
            let sub2 = spec.strip(0, bw + 1 + 2 * spec.rx);
            assert!(required_buffer_tokens(&sub2, 5) > budget);
        }
    }
}
