//! N-dimensional tile decomposition — the multi-tile partitioning layer
//! (§IV / §VIII-A).
//!
//! The §III-B strip miner only knew x-axis vertical strips; this module
//! generalizes it to axis-aligned [`Tile`]s with per-axis halos so 1-D,
//! 2-D and 3-D grids (star or box) all decompose onto the tile array.
//! A [`DecompPlan`] picks the cut axes per [`DecompKind`]:
//!
//! * **Slab** — one cut axis: x strips in 1-D/2-D (the legacy §III-B
//!   blocking unit), z planes in 3-D.
//! * **Pencil** — two cut axes: x+y in 2-D; y+z in 3-D, keeping the
//!   row-major x axis contiguous (the classic pencil decomposition).
//! * **Block** — every axis.
//! * **Auto** — the coarsest kind that both fits the per-tile token
//!   budget and yields enough tiles to feed the array.
//!
//! Per-tile on-fabric buffering is checked against the §III-B /
//! plane-buffering capacity math ([`required_tokens`] dispatches to the
//! `map2d`/`map3d` formulas), binary-searching the cut count along the
//! buffer-relevant axes: an x cut shrinks every row the delay lines
//! hold; a y cut additionally shrinks the 3-D plane-buffer depth; z
//! cuts never reduce buffering, only work. Tiles only share read-only
//! halo input, so the coordinator executes them independently.
//!
//! **Where the halo bytes come from is a separate, per-chunk decision.**
//! The plan records the overlap geometry —
//! [`DecompPlan::redundant_read_fraction`] is the fraction of the grid
//! that more than one tile reads — but whether that overlap costs DRAM
//! traffic depends on the halo mode
//! ([`crate::compile::HaloMode`]): under `reload` every chunk re-reads
//! its full input box from memory, so the fraction is paid on every
//! chunk; under `exchange` the [`crate::stencil::exchange`] schedule
//! ships each halo point from the neighboring tile that owns it (or
//! from this tile's own previous chunk) through in-fabric channels, so
//! after the cold first chunk the fraction drops to zero. The planner
//! itself is mode-independent: the same cuts, halos and graphs serve
//! both modes, which is what makes the exchange-vs-reload differential
//! suite a pure data-movement comparison.
//!
//! The §IV temporal dimension composes with the same machinery:
//! [`plan_fused`] searches the deepest fused depth `T` whose per-tile
//! `T`-layer pipeline ([`temporal::required_tokens`]) still fits the
//! token budget, widening every tile halo to `radii * T` so a tile can
//! compute `T` steps of its owned outputs with no inter-tile traffic.
//! The fused trapezoid shrinks layer by layer — layer `ℓ` of a tile
//! computes an interior narrowed by `radii * ℓ`, so the useful worker
//! count shrinks with it ([`DecompPlan::layer_workers`]); the boundary
//! ring outside [`temporal::valid_box`] is covered by the time-tiled
//! band stages ([`temporal::ring_band_boxes`]) the compiler attaches to
//! every fused stage.

use anyhow::{bail, ensure, Result};

use super::map1d::tap_capacity_1d;
use super::spec::StencilSpec;
use super::{map2d, map3d, temporal};

/// Default on-fabric token budget: 256 PEs with (paper §II-A) small
/// input/output queues plus scratchpad-backed spill — sized so the
/// Table-I 2-D workload (960 cols, rx=ry=12, w=5) runs without strip
/// mining, matching the paper's single-CGRA simulation.
pub const DEFAULT_FABRIC_TOKENS: usize = 64 * 1024;

/// Cut strategy of a decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecompKind {
    /// One cut axis (x in 1-D/2-D, z in 3-D).
    Slab,
    /// Two cut axes (x+y in 2-D, y+z in 3-D).
    Pencil,
    /// Every grid axis.
    Block,
    /// Coarsest kind that fits the budget and feeds the array.
    Auto,
}

impl DecompKind {
    /// Parse a CLI/config value (`slab|pencil|block|auto`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "slab" => DecompKind::Slab,
            "pencil" => DecompKind::Pencil,
            "block" => DecompKind::Block,
            "auto" => DecompKind::Auto,
            other => bail!("unknown decomposition `{other}` (slab|pencil|block|auto)"),
        })
    }
}

impl std::fmt::Display for DecompKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // `pad` (not `write_str`) so callers' width/alignment apply.
        f.pad(match self {
            DecompKind::Slab => "slab",
            DecompKind::Pencil => "pencil",
            DecompKind::Block => "block",
            DecompKind::Auto => "auto",
        })
    }
}

/// One axis-aligned block of the decomposition, in `[x, y, z]` order:
/// the tile owns the output box `[out_lo, out_hi)` of the global grid
/// and computes it from the input box `[in_lo, in_hi)` (halo included;
/// `in = out` widened by the stencil radius along every axis). Unused
/// axes carry extent 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub out_lo: [usize; 3],
    pub out_hi: [usize; 3],
    pub in_lo: [usize; 3],
    pub in_hi: [usize; 3],
}

impl Tile {
    /// Build a tile from its output box, widening by the radius `r`
    /// along every axis for the input halo — the single point defining
    /// the halo semantics every decomposition path shares.
    pub fn with_halo(out_lo: [usize; 3], out_hi: [usize; 3], r: [usize; 3]) -> Self {
        Self {
            out_lo,
            out_hi,
            in_lo: [out_lo[0] - r[0], out_lo[1] - r[1], out_lo[2] - r[2]],
            in_hi: [out_hi[0] + r[0], out_hi[1] + r[1], out_hi[2] + r[2]],
        }
    }

    /// Output extent along `axis`.
    pub fn out_extent(&self, axis: usize) -> usize {
        self.out_hi[axis] - self.out_lo[axis]
    }

    /// Input (halo-padded) extent along `axis`.
    pub fn in_extent(&self, axis: usize) -> usize {
        self.in_hi[axis] - self.in_lo[axis]
    }

    /// Output points this tile owns.
    pub fn out_points(&self) -> usize {
        (0..3).map(|a| self.out_extent(a)).product()
    }

    /// Input points this tile reads (halo included).
    pub fn in_points(&self) -> usize {
        (0..3).map(|a| self.in_extent(a)).product()
    }

    /// Halo points: read but not owned (the redundant-load overhead of
    /// executing the tile independently).
    pub fn halo_points(&self) -> usize {
        self.in_points() - self.out_points()
    }

    /// The spec restricted to this tile's input box; its interior is
    /// exactly the tile's output box.
    pub fn sub_spec(&self, spec: &StencilSpec) -> StencilSpec {
        spec.restrict(self.in_lo, self.in_hi)
    }

    /// Strided copy of the tile's input box out of the global grid
    /// (row-major x, then y, then z — the same layout as the grid).
    pub fn extract(&self, spec: &StencilSpec, input: &[f64]) -> Vec<f64> {
        let (nx, ny) = (spec.nx, spec.ny);
        let width = self.in_extent(0);
        let mut out = Vec::with_capacity(self.in_points());
        for z in self.in_lo[2]..self.in_hi[2] {
            for y in self.in_lo[1]..self.in_hi[1] {
                let row = (z * ny + y) * nx + self.in_lo[0];
                out.extend_from_slice(&input[row..row + width]);
            }
        }
        out
    }

    /// Merge the tile's owned outputs from `sub_out` (a buffer shaped
    /// like the tile's input box) back into the global grid.
    pub fn merge(&self, spec: &StencilSpec, global: &mut [f64], sub_out: &[f64]) {
        let (nx, ny) = (spec.nx, spec.ny);
        let (sub_nx, sub_ny) = (self.in_extent(0), self.in_extent(1));
        let ox = self.out_lo[0] - self.in_lo[0];
        for z in self.out_lo[2]..self.out_hi[2] {
            for y in self.out_lo[1]..self.out_hi[1] {
                let src =
                    ((z - self.in_lo[2]) * sub_ny + (y - self.in_lo[1])) * sub_nx + ox;
                let dst = (z * ny + y) * nx;
                global[dst + self.out_lo[0]..dst + self.out_hi[0]]
                    .copy_from_slice(&sub_out[src..src + self.out_extent(0)]);
            }
        }
    }
}

/// A chosen decomposition: the resolved cut strategy, the number of
/// cuts per axis (`[x, y, z]`), the §IV fused depth, and the tiles
/// themselves (z-major order: z outermost, x innermost).
#[derive(Debug, Clone, PartialEq)]
pub struct DecompPlan {
    /// Resolved kind — never [`DecompKind::Auto`].
    pub kind: DecompKind,
    /// Cuts per axis, `[x, y, z]`; the product is the tile count.
    pub cuts: [usize; 3],
    /// §IV time-steps each tile fuses per memory round-trip (1 = the
    /// single-step mapper; tile halos are `radii * fused_steps` wide).
    pub fused_steps: usize,
    /// Compute workers per tile the plan was budgeted for — recorded so
    /// the plan is self-describing: executing or serializing it needs no
    /// out-of-band worker count.
    pub workers: usize,
    pub tiles: Vec<Tile>,
}

impl DecompPlan {
    /// Total halo points across tiles (points loaded but not owned).
    pub fn halo_points(&self) -> usize {
        self.tiles.iter().map(|t| t.halo_points()).sum()
    }

    /// Total input points loaded across tiles (grid + halo overlap).
    pub fn total_input_points(&self) -> usize {
        self.tiles.iter().map(|t| t.in_points()).sum()
    }

    /// Fraction of the grid read more than once because of halo
    /// overlap: `(Σ tile inputs - grid points) / grid points`. Zero for
    /// a single tile. This is the *geometric* overlap; whether it costs
    /// DRAM traffic depends on the halo mode (see the module docs).
    pub fn redundant_read_fraction(&self, spec: &StencilSpec) -> f64 {
        let grid = spec.grid_points() as f64;
        (self.total_input_points() as f64 - grid) / grid
    }

    /// Useful compute workers per fused layer, for the worst (narrowest)
    /// tile: layer `ℓ` (0-based) of a `T`-deep pipeline writes an
    /// interior narrowed by `rx * (ℓ + 1)` per side, so past workers
    /// beyond that x-extent no output column remains to interleave. The
    /// mapped graph keeps the planned uniform `workers` on every layer
    /// (idle lanes simply stream); this view is the occupancy the
    /// roofline and reports charge.
    pub fn layer_workers(&self, spec: &StencilSpec) -> Vec<usize> {
        let rx = spec.rx;
        let min_in_x = self
            .tiles
            .iter()
            .map(|t| t.in_extent(0))
            .min()
            .unwrap_or(spec.nx);
        (1..=self.fused_steps)
            .map(|l| {
                let out_x = min_in_x.saturating_sub(2 * rx * l).max(1);
                self.workers.min(out_x).max(1)
            })
            .collect()
    }
}

/// Mandatory on-fabric buffering (tokens) for `spec` with `w` workers,
/// dispatched by dimensionality — the capacity math the budget search
/// drives. 1-D has no delay lines, only the per-tap chain queues.
pub fn required_tokens(spec: &StencilSpec, w: usize) -> usize {
    if spec.is_3d() {
        map3d::required_buffer_tokens(spec, w)
    } else if spec.is_2d() {
        map2d::required_buffer_tokens(spec, w)
    } else {
        w * (0..spec.points())
            .map(|t| tap_capacity_1d(spec.rx, w, t))
            .sum::<usize>()
    }
}

/// Grid extents per axis, `[x, y, z]` (unused axes are 1).
fn extents(spec: &StencilSpec) -> [usize; 3] {
    [spec.nx, spec.ny, spec.nz]
}

/// Radii per axis, `[x, y, z]` (unused axes are 0).
fn radii(spec: &StencilSpec) -> [usize; 3] {
    [spec.rx, spec.ry, spec.rz]
}

/// Interior (computed-output) extents per axis after `steps` fused
/// time-steps (the §IV trapezoid shrinks by `radii * steps`); unused
/// axes are 1.
fn interiors_depth(spec: &StencilSpec, steps: usize) -> [usize; 3] {
    let (n, r) = (extents(spec), radii(spec));
    [
        n[0].saturating_sub(2 * r[0] * steps),
        n[1].saturating_sub(2 * r[1] * steps),
        n[2].saturating_sub(2 * r[2] * steps),
    ]
}

/// Axes a kind may cut, for a grid of `ndim` dimensions.
fn cut_axes(kind: DecompKind, ndim: usize) -> Vec<usize> {
    match (kind, ndim) {
        (DecompKind::Slab, 3) => vec![2],
        (DecompKind::Pencil, 2) => vec![0, 1],
        (DecompKind::Pencil, 3) => vec![1, 2],
        (DecompKind::Block, 2) => vec![0, 1],
        (DecompKind::Block, 3) => vec![0, 1, 2],
        // 1-D has only x; Slab in 1-D/2-D cuts x (legacy strips).
        _ => vec![0],
    }
}

/// Maximum cuts per axis: x is limited so every worker keeps at least
/// one output column per tile; y/z are limited by the interior width.
fn axis_caps(spec: &StencilSpec, w: usize, steps: usize) -> [usize; 3] {
    let i = interiors_depth(spec, steps);
    [(i[0] / w.max(1)).max(1), i[1].max(1), i[2].max(1)]
}

/// Smallest `k` with `k^n >= x`.
fn nth_root_ceil(x: usize, n: usize) -> usize {
    if x <= 1 || n == 0 {
        return 1;
    }
    let mut k = (x as f64).powf(1.0 / n as f64).round().max(1.0) as usize;
    while k.pow(n as u32) < x {
        k += 1;
    }
    while k > 1 && (k - 1).pow(n as u32) >= x {
        k -= 1;
    }
    k
}

/// Cut the interior `[r, n - r)` of every axis into `cuts[a]` near-equal
/// chunks and return the tiles (z-major order). `cuts` is clamped to
/// `[1, interior]` per axis. The output boxes tile the interior exactly;
/// input boxes widen by the radius along every axis.
pub fn tiles_for_cuts(spec: &StencilSpec, cuts: [usize; 3]) -> Vec<Tile> {
    tiles_for_cuts_depth(spec, cuts, 1)
}

/// [`tiles_for_cuts`] for a `steps`-deep fused plan: the owned output
/// boxes tile the *trapezoid-shrunk* interior `[r*steps, n - r*steps)`
/// and the input halos widen by `radii * steps` — each tile reads enough
/// neighborhood to compute `steps` time-steps of its outputs without
/// talking to any other tile.
pub fn tiles_for_cuts_depth(spec: &StencilSpec, cuts: [usize; 3], steps: usize) -> Vec<Tile> {
    let (n, r) = (extents(spec), radii(spec));
    let h = [r[0] * steps, r[1] * steps, r[2] * steps];
    let mut ranges: [Vec<(usize, usize)>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for a in 0..3 {
        let interior = n[a] - 2 * h[a];
        let k = cuts[a].clamp(1, interior.max(1));
        let (base, rem) = (interior / k, interior % k);
        let mut lo = h[a];
        for i in 0..k {
            let len = base + usize::from(i < rem);
            ranges[a].push((lo, lo + len));
            lo += len;
        }
    }
    let mut tiles =
        Vec::with_capacity(ranges[0].len() * ranges[1].len() * ranges[2].len());
    for &(zlo, zhi) in &ranges[2] {
        for &(ylo, yhi) in &ranges[1] {
            for &(xlo, xhi) in &ranges[0] {
                tiles.push(Tile::with_halo([xlo, ylo, zlo], [xhi, yhi, zhi], h));
            }
        }
    }
    tiles
}

/// The largest (worst-buffering) tile a cut vector produces, as a
/// restricted sub-spec — the shape the budget check simulates.
fn worst_sub_spec(spec: &StencilSpec, cuts: [usize; 3], steps: usize) -> StencilSpec {
    let r = radii(spec);
    let i = interiors_depth(spec, steps);
    let mut hi = [0usize; 3];
    for a in 0..3 {
        let k = cuts[a].clamp(1, i[a].max(1));
        hi[a] = i[a].div_ceil(k) + 2 * r[a] * steps;
    }
    spec.restrict([0, 0, 0], hi)
}

/// Budget check: the worst tile's `steps`-deep temporal pipeline must
/// fit the per-tile token budget ([`temporal::required_tokens`]; at
/// `steps = 1` that is exactly the single-step [`required_tokens`]).
fn fits(spec: &StencilSpec, w: usize, budget: usize, cuts: [usize; 3], steps: usize) -> bool {
    temporal::required_tokens(&worst_sub_spec(spec, cuts, steps), w, steps) <= budget
}

/// Plan a decomposition with a resolved (non-Auto) kind and a fixed
/// fused depth.
fn plan_kind(
    spec: &StencilSpec,
    w: usize,
    budget_tokens: usize,
    kind: DecompKind,
    tiles: usize,
    steps: usize,
) -> Result<DecompPlan> {
    let axes = cut_axes(kind, spec.ndim());
    let caps = axis_caps(spec, w, steps);

    // Distribute the requested tile count across the cut axes,
    // outermost axis first (z cuts are free of buffering cost).
    let mut cuts = [1usize; 3];
    let mut want = tiles.max(1);
    let mut left = axes.len();
    for &a in axes.iter().rev() {
        cuts[a] = nth_root_ceil(want, left).clamp(1, caps[a]);
        want = want.div_ceil(cuts[a]);
        left -= 1;
    }

    // Budget: binary-search the smallest cut count that fits along the
    // buffer-relevant axes (x shrinks delay-line rows; y shrinks the
    // 3-D plane-buffer depth). Buffering is monotone in tile extent, so
    // the search is sound.
    let buffer_axes: Vec<usize> = axes
        .iter()
        .copied()
        .filter(|&a| a == 0 || (a == 1 && spec.is_3d()))
        .collect();
    if !fits(spec, w, budget_tokens, cuts, steps) {
        for &a in &buffer_axes {
            let with = |cuts: [usize; 3], v: usize| {
                let mut c = cuts;
                c[a] = v;
                c
            };
            if !fits(spec, w, budget_tokens, with(cuts, caps[a]), steps) {
                // Even the finest cut along this axis is not enough on
                // its own — saturate it and try the next axis.
                cuts[a] = caps[a];
                continue;
            }
            let (mut lo, mut hi) = (cuts[a], caps[a]);
            while lo < hi {
                let mid = (lo + hi) / 2;
                if fits(spec, w, budget_tokens, with(cuts, mid), steps) {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            cuts[a] = lo;
            break;
        }
    }
    let hint = match kind {
        DecompKind::Block => "fewer workers or a bigger fabric",
        _ => "a finer --decomp (pencil/block), fewer workers, or a bigger fabric",
    };
    ensure!(
        fits(spec, w, budget_tokens, cuts, steps),
        "even the finest {} decomposition exceeds the fabric budget of {} tokens \
         at fused depth {} (try {})",
        kind,
        budget_tokens,
        steps,
        hint
    );

    Ok(DecompPlan {
        kind,
        cuts,
        fused_steps: steps,
        workers: w,
        tiles: tiles_for_cuts_depth(spec, cuts, steps),
    })
}

/// Plan the decomposition of `spec` for a `tiles`-tile array whose
/// per-tile on-fabric budget is `budget_tokens`, with `w` workers per
/// tile. `Auto` resolves to the coarsest kind that fits the budget and
/// yields at least `tiles` tiles (falling back to the best feasible
/// kind when the grid is too small).
pub fn plan(
    spec: &StencilSpec,
    w: usize,
    budget_tokens: usize,
    kind: DecompKind,
    tiles: usize,
) -> Result<DecompPlan> {
    plan_depth(spec, w, budget_tokens, kind, tiles, 1)
}

/// [`plan`] at a fixed §IV fused depth: tiles carry `radii * steps`
/// halos and the budget check runs the `steps`-deep
/// [`temporal::required_tokens`] capacity math.
pub fn plan_depth(
    spec: &StencilSpec,
    w: usize,
    budget_tokens: usize,
    kind: DecompKind,
    tiles: usize,
    steps: usize,
) -> Result<DecompPlan> {
    ensure!(w >= 1, "need at least one worker");
    ensure!(steps >= 1, "need at least one time-step");
    super::metrics::count_plan();
    let (n, r) = (extents(spec), radii(spec));
    for a in 0..spec.ndim() {
        ensure!(
            n[a] > 2 * r[a] * steps,
            "decomposition needs a nonempty interior: axis {} has extent {} \
             with stencil radius {} and fused depth {}",
            a,
            n[a],
            r[a],
            steps
        );
    }
    match kind {
        DecompKind::Auto => {
            let mut best: Option<DecompPlan> = None;
            let mut last_err = None;
            for k in [DecompKind::Slab, DecompKind::Pencil, DecompKind::Block] {
                match plan_kind(spec, w, budget_tokens, k, tiles, steps) {
                    Ok(p) => {
                        if p.tiles.len() >= tiles.max(1) {
                            return Ok(p);
                        }
                        // Not enough parallelism — remember the best
                        // count seen and try a finer kind.
                        let better = match &best {
                            None => true,
                            Some(b) => p.tiles.len() > b.tiles.len(),
                        };
                        if better {
                            best = Some(p);
                        }
                    }
                    Err(e) => last_err = Some(e),
                }
            }
            match (best, last_err) {
                (Some(p), _) => Ok(p),
                (None, Some(e)) => Err(e),
                (None, None) => bail!("no feasible decomposition"),
            }
        }
        k => plan_kind(spec, w, budget_tokens, k, tiles, steps),
    }
}

/// Plan a §IV spatially-fused decomposition: search the deepest fused
/// depth `T <= max_steps` with a feasible plan — nonempty trapezoid
/// interiors and the worst tile's `T`-deep temporal buffering within
/// the per-tile budget. [`temporal::required_tokens`] is monotone in
/// depth, so the scan walks down from the deepest grid-admissible `T`
/// and returns the first (deepest) feasible plan; every extra fused
/// step removes one whole-grid DRAM round-trip, which is the §IV win.
pub fn plan_fused(
    spec: &StencilSpec,
    w: usize,
    budget_tokens: usize,
    kind: DecompKind,
    tiles: usize,
    max_steps: usize,
) -> Result<DecompPlan> {
    ensure!(max_steps >= 1, "need at least one time-step");
    let (n, r) = (extents(spec), radii(spec));
    let mut cap = max_steps;
    for a in 0..spec.ndim() {
        if r[a] > 0 {
            cap = cap.min((n[a] - 1) / (2 * r[a]));
        }
    }
    let mut last_err = None;
    for t in (1..=cap.max(1)).rev() {
        match plan_depth(spec, w, budget_tokens, kind, tiles, t) {
            Ok(p) => return Ok(p),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no feasible fused decomposition")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec::{symmetric_taps, y_taps, z_taps};

    fn spec3d(nx: usize, ny: usize, nz: usize) -> StencilSpec {
        StencilSpec::dim3(nx, ny, nz, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap()
    }

    #[test]
    fn slab_tiles_partition_the_2d_interior_exactly() {
        let spec = StencilSpec::paper_2d();
        for k in [1usize, 3, 16, 936, 1000] {
            let tiles = tiles_for_cuts(&spec, [k, 1, 1]);
            assert_eq!(tiles[0].out_lo[0], spec.rx);
            assert_eq!(tiles.last().unwrap().out_hi[0], spec.nx - spec.rx);
            for w in tiles.windows(2) {
                assert_eq!(w[0].out_hi[0], w[1].out_lo[0], "gap/overlap");
            }
            let total: usize = tiles.iter().map(|t| t.out_points()).sum();
            assert_eq!(total, spec.interior_outputs(), "cuts={k}");
            // Full extent along the uncut y axis: the whole interior.
            for t in &tiles {
                assert_eq!(t.out_lo[1], spec.ry);
                assert_eq!(t.out_hi[1], spec.ny - spec.ry);
                assert_eq!(t.in_lo[1], 0);
                assert_eq!(t.in_hi[1], spec.ny);
            }
        }
    }

    #[test]
    fn halos_extend_by_the_radius_on_every_axis() {
        let spec = spec3d(14, 10, 8);
        for t in tiles_for_cuts(&spec, [2, 2, 2]) {
            for a in 0..3 {
                assert_eq!(t.in_lo[a] + spec.radii()[a], t.out_lo[a]);
                assert_eq!(t.in_hi[a] - spec.radii()[a], t.out_hi[a]);
                assert!(t.in_hi[a] <= [spec.nx, spec.ny, spec.nz][a]);
            }
            assert!(t.halo_points() > 0);
        }
    }

    #[test]
    fn pencil_3d_tiles_cover_interior_disjointly() {
        let spec = spec3d(12, 11, 9);
        let plan = plan(&spec, 2, DEFAULT_FABRIC_TOKENS, DecompKind::Pencil, 6).unwrap();
        assert_eq!(plan.cuts[0], 1, "pencil keeps x contiguous");
        assert!(plan.tiles.len() >= 6);
        let total: usize = plan.tiles.iter().map(|t| t.out_points()).sum();
        assert_eq!(total, spec.interior_outputs());
        // Pairwise disjoint output boxes.
        for (i, a) in plan.tiles.iter().enumerate() {
            for b in plan.tiles.iter().skip(i + 1) {
                let overlap = (0..3).all(|ax| {
                    a.out_lo[ax] < b.out_hi[ax] && b.out_lo[ax] < a.out_hi[ax]
                });
                assert!(!overlap, "tiles overlap");
            }
        }
    }

    #[test]
    fn slab_3d_cuts_z_only() {
        let spec = spec3d(10, 8, 12);
        let plan = plan(&spec, 2, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 4).unwrap();
        assert_eq!(plan.kind, DecompKind::Slab);
        assert_eq!(plan.cuts[0], 1);
        assert_eq!(plan.cuts[1], 1);
        assert_eq!(plan.cuts[2], 4);
        assert_eq!(plan.tiles.len(), 4);
    }

    #[test]
    fn paper_2d_fits_default_budget_in_one_tile() {
        let spec = StencilSpec::paper_2d();
        let plan = plan(&spec, 5, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 1).unwrap();
        assert_eq!(plan.cuts, [1, 1, 1], "no strip mining needed");
        assert_eq!(plan.tiles.len(), 1);
        assert_eq!(plan.halo_points(), 0);
        assert_eq!(plan.redundant_read_fraction(&spec), 0.0);
    }

    #[test]
    fn small_budget_forces_x_cuts_monotonically() {
        let spec = StencilSpec::paper_2d();
        // Full width needs ~37k tokens; 22k forces strip mining.
        let p1 = plan(&spec, 5, 22_000, DecompKind::Slab, 1).unwrap();
        assert!(p1.cuts[0] > 1);
        let p2 = plan(&spec, 5, 17_000, DecompKind::Slab, 1).unwrap();
        assert!(p2.cuts[0] >= p1.cuts[0], "smaller budget, finer cuts");
        assert!(p1.redundant_read_fraction(&spec) > 0.0);
    }

    #[test]
    fn budget_search_returns_coarsest_feasible_x_cut() {
        let spec = StencilSpec::paper_2d();
        let budget = 25_000;
        let plan = plan(&spec, 5, budget, DecompKind::Slab, 1).unwrap();
        let k = plan.cuts[0];
        let interior = spec.nx - 2 * spec.rx;
        let ext = |k: usize| interior.div_ceil(k) + 2 * spec.rx;
        let sub = spec.restrict([0, 0, 0], [ext(k), spec.ny, 1]);
        assert!(required_tokens(&sub, 5) <= budget);
        if k > 1 {
            let coarser = spec.restrict([0, 0, 0], [ext(k - 1), spec.ny, 1]);
            assert!(required_tokens(&coarser, 5) > budget, "search not maximal");
        }
    }

    #[test]
    fn impossible_budget_is_an_error() {
        let spec = StencilSpec::paper_2d();
        assert!(plan(&spec, 5, 10, DecompKind::Slab, 1).is_err());
        assert!(plan(&spec, 5, 10, DecompKind::Block, 1).is_err());
    }

    #[test]
    fn auto_prefers_slab_when_it_feeds_the_array() {
        let spec = StencilSpec::paper_2d();
        let plan = plan(&spec, 5, DEFAULT_FABRIC_TOKENS, DecompKind::Auto, 16).unwrap();
        assert_eq!(plan.kind, DecompKind::Slab);
        assert_eq!(plan.cuts[0], 16);
        assert_eq!(plan.tiles.len(), 16);
    }

    #[test]
    fn auto_escalates_past_slab_when_z_cuts_cannot_shrink_buffers() {
        let spec = spec3d(40, 20, 12);
        // One token below the whole-grid requirement: a z-only slab cut
        // cannot reduce buffering, so Auto must escalate to pencil.
        let budget = required_tokens(&spec, 2) - 1;
        let plan = plan(&spec, 2, budget, DecompKind::Auto, 1).unwrap();
        assert_eq!(plan.kind, DecompKind::Pencil);
        assert!(plan.cuts[1] > 1, "expected a y cut, got {:?}", plan.cuts);
        let worst: usize = plan
            .tiles
            .iter()
            .map(|t| required_tokens(&t.sub_spec(&spec), 2))
            .max()
            .unwrap();
        assert!(worst <= budget);
    }

    #[test]
    fn tile_count_exceeding_interior_is_clamped() {
        let spec = StencilSpec::dim1(20, symmetric_taps(2)).unwrap(); // interior 16
        let plan = plan(&spec, 1, DEFAULT_FABRIC_TOKENS, DecompKind::Auto, 64).unwrap();
        assert!(!plan.tiles.is_empty() && plan.tiles.len() <= 16);
        let total: usize = plan.tiles.iter().map(|t| t.out_points()).sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn extract_then_merge_is_identity() {
        let spec = spec3d(9, 7, 6);
        let input: Vec<f64> = (0..spec.grid_points()).map(|i| i as f64).collect();
        for tile in tiles_for_cuts(&spec, [2, 2, 2]) {
            let sub = tile.extract(&spec, &input);
            assert_eq!(sub.len(), tile.in_points());
            // Spot-check the sub-grid layout.
            let sub_spec = tile.sub_spec(&spec);
            assert_eq!(sub_spec.grid_points(), sub.len());
            let mut global = input.clone();
            tile.merge(&spec, &mut global, &sub);
            assert_eq!(global, input, "merge of an extract must be a no-op");
        }
    }

    #[test]
    fn required_tokens_matches_layer_formulas() {
        let s2 = StencilSpec::heat2d(20, 14, 0.2);
        assert_eq!(required_tokens(&s2, 2), map2d::required_buffer_tokens(&s2, 2));
        let s3 = StencilSpec::heat3d(10, 6, 5, 0.1);
        assert_eq!(required_tokens(&s3, 2), map3d::required_buffer_tokens(&s3, 2));
        let s1 = StencilSpec::dim1(64, symmetric_taps(2)).unwrap();
        let want: usize = (0..5).map(|t| tap_capacity_1d(2, 2, t)).sum::<usize>() * 2;
        assert_eq!(required_tokens(&s1, 2), want);
    }

    #[test]
    fn plan_fused_prefers_deepest_feasible_depth() {
        let spec = StencilSpec::heat2d(40, 24, 0.2);
        let p = plan_fused(&spec, 2, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 1, 3).unwrap();
        assert_eq!(p.fused_steps, 3);
        // Owned boxes tile the trapezoid-shrunk interior exactly, with
        // radii * depth halos.
        let total: usize = p.tiles.iter().map(|t| t.out_points()).sum();
        assert_eq!(total, (40 - 6) * (24 - 6));
        for t in &p.tiles {
            assert_eq!(t.out_lo[0] - t.in_lo[0], 3);
            assert_eq!(t.out_lo[1] - t.in_lo[1], 3);
        }
    }

    #[test]
    fn plan_fused_respects_budget_per_tile() {
        let spec = StencilSpec::heat2d(64, 32, 0.2);
        let w = 2;
        let budget = temporal::required_tokens(&spec, w, 2);
        let p = plan_fused(&spec, w, budget, DecompKind::Slab, 1, 4).unwrap();
        assert!(p.fused_steps >= 2, "budget admits at least depth 2");
        let worst: usize = p
            .tiles
            .iter()
            .map(|t| temporal::required_tokens(&t.sub_spec(&spec), w, p.fused_steps))
            .max()
            .unwrap();
        assert!(worst <= budget, "{worst} > {budget}");
    }

    #[test]
    fn plan_fused_depth_capped_by_grid() {
        // 10-wide interior, r = 1: at most 4 fused steps fit the grid.
        let spec = StencilSpec::heat2d(10, 10, 0.2);
        let p =
            plan_fused(&spec, 1, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 1, 64).unwrap();
        assert!(p.fused_steps >= 1 && p.fused_steps <= 4, "{}", p.fused_steps);
    }

    #[test]
    fn single_step_plans_report_depth_one() {
        let spec = StencilSpec::paper_2d();
        let p = plan(&spec, 5, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 4).unwrap();
        assert_eq!(p.fused_steps, 1);
    }

    #[test]
    fn layer_workers_taper_with_fused_depth() {
        let spec = StencilSpec::heat2d(24, 16, 0.2);
        let p = plan_fused(&spec, 4, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 2, 3).unwrap();
        let lw = p.layer_workers(&spec);
        assert_eq!(lw.len(), p.fused_steps);
        // Monotone non-increasing, never zero, capped by the plan width.
        for w in lw.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(lw.iter().all(|&w| w >= 1 && w <= p.workers));
        // Worst tile: in-x = interior/2 + 2*r*T; layer ℓ keeps
        // in-x - 2*(ℓ+1) columns.
        let min_in_x = p.tiles.iter().map(|t| t.in_extent(0)).min().unwrap();
        assert_eq!(lw[0], p.workers.min(min_in_x - 2));
        assert_eq!(*lw.last().unwrap(), p.workers.min(min_in_x - 2 * p.fused_steps));
    }

    #[test]
    fn nth_root_ceil_basics() {
        assert_eq!(nth_root_ceil(16, 2), 4);
        assert_eq!(nth_root_ceil(17, 2), 5);
        assert_eq!(nth_root_ceil(8, 3), 2);
        assert_eq!(nth_root_ceil(9, 3), 3);
        assert_eq!(nth_root_ceil(1, 3), 1);
        assert_eq!(nth_root_ceil(7, 1), 7);
    }
}
