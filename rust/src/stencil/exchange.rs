//! Inter-tile halo exchange — the data-movement schedule that makes
//! redundant DRAM halo reads disappear (ROADMAP open item 2).
//!
//! A [`DecompPlan`] gives every tile an input box that overlaps its
//! neighbors' output boxes by `radii * fused_steps`. Under `reload`
//! halo mode the overlap is re-read from DRAM on every chunk; under
//! `exchange` it is shipped through in-fabric channels from whoever
//! already holds the current value, StencilFlow-style. This module
//! computes *who that is*, per receiving tile, for one chunk boundary:
//!
//! * **resident** — points the tile already holds: its own previous
//!   output box, plus the immutable grid frame outside the single-step
//!   interior (Dirichlet boundary — read once in the cold chunk, valid
//!   forever).
//! * **from_tiles** — points inside a *different* tile's previous
//!   output box: a face/edge/corner transfer from that neighbor.
//! * **from_ring** — points in the boundary ring between the previous
//!   chunk's [`temporal::valid_box`] and the single-step interior,
//!   freshly computed by the time-tiled band stages
//!   ([`temporal::ring_band_boxes`]) and broadcast from wherever those
//!   bands ran.
//!
//! The three classes partition each tile's input box exactly (previous
//! output boxes tile the valid region, the ring and the frame are
//! disjoint from them and each other), so
//! `resident + exchanged == in_points` per tile — the invariant the
//! accounting tests pin.
//!
//! # Priced transfers
//!
//! Each [`Transfer`] also carries the **Manhattan mesh distance**
//! between producer and consumer ([`mesh_coords`] ranks every tile's
//! output origin per axis, recovering the logical tile grid the cuts
//! induce) and the global-coordinate intersection box it covers. At run
//! time the session converts mesh hops into a per-load latency
//! surcharge and a per-boundary bandwidth cap
//! ([`crate::cgra::memory::ExchangeCost`]): a warm exchange chunk still
//! runs with the whole input buffer fabric-resident, but loads landing
//! inside a transfer's box complete at
//! `hit_latency + hop_cycles` and at most `link_words_per_cycle`
//! transfers start per cycle per boundary. Ring points are priced at
//! [`RING_MESH_HOPS`] (the bands run somewhere on the fabric; one mesh
//! hop is the nearest-neighbor assumption). The surcharge is a pure
//! function of the load-issue sequence, so it changes *timing and
//! accounting only* and cannot perturb values — the basis of the
//! priced-vs-free-vs-reload bitwise differential suite.

use super::decomp::{DecompPlan, Tile};
use super::spec::StencilSpec;
use super::temporal;

/// Mesh distance charged for boundary-ring points (see module docs).
pub const RING_MESH_HOPS: usize = 1;

/// One producer -> consumer halo transfer at a chunk boundary: the
/// intersection of the receiving tile's input box with a *different*
/// tile's previous output box.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Transfer {
    /// Source tile index in the previous chunk's plan.
    pub src: usize,
    /// Points shipped.
    pub points: usize,
    /// Manhattan distance between producer and consumer on the logical
    /// tile mesh (1 = face neighbor, 2 = edge/diagonal, ...).
    pub mesh_hops: usize,
    /// Covered box `[lo, hi)` in global grid coordinates.
    pub lo: [usize; 3],
    pub hi: [usize; 3],
}

/// Where one receiving tile's input box comes from at a chunk boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileExchange {
    /// Points already on this tile: own previous outputs + immutable
    /// grid frame.
    pub resident: usize,
    /// One priced transfer per neighbor whose previous output box
    /// overlaps this tile's input box.
    pub from_tiles: Vec<Transfer>,
    /// Points from the previous chunk's time-tiled boundary ring.
    pub from_ring: usize,
    /// Intersection of this tile's input box with its *own* previous
    /// output box (`None` when empty): loads here are unpriced.
    pub own_box: Option<([usize; 3], [usize; 3])>,
    /// Intersection of this tile's input box with the single-step
    /// interior — the catch-all that prices ring points after the
    /// specific transfer/own boxes have matched (frame points fall
    /// outside it and stay unpriced).
    pub interior_box: Option<([usize; 3], [usize; 3])>,
}

impl TileExchange {
    /// Points shipped over fabric channels (everything not resident).
    pub fn exchanged(&self) -> usize {
        self.from_ring + self.from_tiles.iter().map(|t| t.points).sum::<usize>()
    }
}

/// Logical mesh coordinate of every tile: the per-axis rank of its
/// output origin among the plan's distinct cut positions. Tiles of one
/// plan tile the valid box on an axis-aligned grid, so ranking `out_lo`
/// per axis recovers the (x, y, z) tile-grid position the decomposition
/// induced — the geometry hop distances are measured on.
pub fn mesh_coords(plan: &DecompPlan) -> Vec<[usize; 3]> {
    let mut axis_starts: [Vec<usize>; 3] = Default::default();
    for (a, starts) in axis_starts.iter_mut().enumerate() {
        let mut v: Vec<usize> = plan.tiles.iter().map(|t| t.out_lo[a]).collect();
        v.sort_unstable();
        v.dedup();
        *starts = v;
    }
    plan.tiles
        .iter()
        .map(|t| {
            let mut c = [0usize; 3];
            for a in 0..3 {
                c[a] = axis_starts[a]
                    .binary_search(&t.out_lo[a])
                    .expect("tile origin is one of the plan's cut positions");
            }
            c
        })
        .collect()
}

fn manhattan(a: [usize; 3], b: [usize; 3]) -> usize {
    (0..3).map(|i| a[i].abs_diff(b[i])).sum()
}

/// The per-chunk exchange schedule: one [`TileExchange`] per tile of
/// the *receiving* plan. Built against the plan of the chunk that just
/// finished (`prev`), which may differ from the receiving plan at a
/// stage boundary (e.g. full-depth stage → shallower tail stage).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExchangeSchedule {
    pub tiles: Vec<TileExchange>,
}

// Box arithmetic is shared with the static verifier
// (`crate::analysis::boxes`): the coverage invariant asserted below in
// debug builds and the `exchange/coverage` diagnostic `scgra check`
// emits are one implementation, so they cannot drift apart.
use crate::analysis::boxes::{isect, isect_box};

impl ExchangeSchedule {
    /// Partition every receiving tile's input box by source. `prev` is
    /// the plan of the chunk whose results are on fabric; tiles are
    /// matched to array slots by index (slot `t` keeps its buffer across
    /// chunks), so `plan.tiles[t]` receives `prev.tiles[t]`'s outputs
    /// for free.
    pub fn build(spec: &StencilSpec, plan: &DecompPlan, prev: &DecompPlan) -> Self {
        let dims = [spec.nx, spec.ny, spec.nz];
        let radii = [spec.rx, spec.ry, spec.rz];
        let ilo = radii;
        let ihi = [
            dims[0] - radii[0],
            dims[1] - radii[1],
            dims[2] - radii[2],
        ];
        let (vlo, vhi) = temporal::valid_box(spec, prev.fused_steps);
        let recv_coords = mesh_coords(plan);
        let prev_coords = mesh_coords(prev);
        let tiles = plan
            .tiles
            .iter()
            .enumerate()
            .map(|(t, tile)| {
                Self::tile_exchange(
                    tile,
                    recv_coords[t],
                    t,
                    prev,
                    &prev_coords,
                    ilo,
                    ihi,
                    vlo,
                    vhi,
                )
            })
            .collect();
        Self { tiles }
    }

    #[allow(clippy::too_many_arguments)]
    fn tile_exchange(
        tile: &Tile,
        coord: [usize; 3],
        t: usize,
        prev: &DecompPlan,
        prev_coords: &[[usize; 3]],
        ilo: [usize; 3],
        ihi: [usize; 3],
        vlo: [usize; 3],
        vhi: [usize; 3],
    ) -> TileExchange {
        let (lo, hi) = (tile.in_lo, tile.in_hi);
        let total = tile.in_points();
        let interior = isect(lo, hi, ilo, ihi);
        let frame = total - interior;
        let mut own = 0usize;
        let mut own_box = None;
        let mut from_tiles = Vec::new();
        let mut in_valid = 0usize;
        for (u, p) in prev.tiles.iter().enumerate() {
            let Some((blo, bhi)) = isect_box(lo, hi, p.out_lo, p.out_hi) else {
                continue;
            };
            let v = isect(lo, hi, p.out_lo, p.out_hi);
            in_valid += v;
            if u == t {
                own += v;
                own_box = Some((blo, bhi));
            } else {
                from_tiles.push(Transfer {
                    src: u,
                    points: v,
                    mesh_hops: manhattan(coord, prev_coords[u]).max(1),
                    lo: blo,
                    hi: bhi,
                });
            }
        }
        // Previous output boxes tile the previous valid box exactly, so
        // anything of the interior outside them is the boundary ring.
        // Asserted through the same coverage computation the verifier's
        // `exchange/coverage` rule runs on saved artifacts.
        #[cfg(debug_assertions)]
        {
            let owned: Vec<_> =
                prev.tiles.iter().map(|p| (p.out_lo, p.out_hi)).collect();
            if let Some(why) =
                crate::analysis::boxes::valid_coverage_violation(lo, hi, &owned, vlo, vhi)
            {
                panic!("tile {t}: {why}");
            }
            debug_assert_eq!(in_valid, isect(lo, hi, vlo, vhi));
        }
        let from_ring = interior - in_valid;
        TileExchange {
            resident: own + frame,
            from_tiles,
            from_ring,
            own_box,
            interior_box: isect_box(lo, hi, ilo, ihi),
        }
    }

    /// Total points shipped over fabric channels this chunk boundary.
    pub fn exchanged_points(&self) -> usize {
        self.tiles.iter().map(|t| t.exchanged()).sum()
    }

    /// Total points already resident (no movement at all).
    pub fn resident_points(&self) -> usize {
        self.tiles.iter().map(|t| t.resident).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::decomp::{plan_depth, DecompKind, DEFAULT_FABRIC_TOKENS};
    use crate::stencil::spec::{symmetric_taps, y_taps, z_taps};

    fn plan_of(spec: &StencilSpec, kind: DecompKind, tiles: usize, steps: usize) -> DecompPlan {
        plan_depth(spec, 2, DEFAULT_FABRIC_TOKENS, kind, tiles, steps).unwrap()
    }

    /// Brute-force point classification must match the box arithmetic.
    fn check_partition(spec: &StencilSpec, plan: &DecompPlan, prev: &DecompPlan) {
        let sched = ExchangeSchedule::build(spec, plan, prev);
        let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
        let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
        let (vlo, vhi) = crate::stencil::temporal::valid_box(spec, prev.fused_steps);
        for (t, (tile, ex)) in plan.tiles.iter().zip(&sched.tiles).enumerate() {
            let mut resident = 0;
            let mut ring = 0;
            let mut from = vec![0usize; prev.tiles.len()];
            for z in tile.in_lo[2]..tile.in_hi[2] {
                for y in tile.in_lo[1]..tile.in_hi[1] {
                    for x in tile.in_lo[0]..tile.in_hi[0] {
                        let interior = (rx..nx - rx).contains(&x)
                            && (ry..ny - ry).contains(&y)
                            && (rz..nz - rz).contains(&z);
                        if !interior {
                            resident += 1; // immutable frame
                            continue;
                        }
                        let owner = prev.tiles.iter().position(|p| {
                            (p.out_lo[0]..p.out_hi[0]).contains(&x)
                                && (p.out_lo[1]..p.out_hi[1]).contains(&y)
                                && (p.out_lo[2]..p.out_hi[2]).contains(&z)
                        });
                        match owner {
                            Some(u) if u == t => resident += 1,
                            Some(u) => from[u] += 1,
                            None => {
                                // Must be the ring, not a coverage hole.
                                let valid = (vlo[0]..vhi[0]).contains(&x)
                                    && (vlo[1]..vhi[1]).contains(&y)
                                    && (vlo[2]..vhi[2]).contains(&z);
                                assert!(!valid, "valid point without an owner");
                                ring += 1;
                            }
                        }
                    }
                }
            }
            assert_eq!(ex.resident, resident, "tile {t} resident");
            assert_eq!(ex.from_ring, ring, "tile {t} ring");
            let mut want: Vec<(usize, usize)> = from
                .iter()
                .enumerate()
                .filter(|&(u, &n)| n > 0 && u != t)
                .map(|(u, &n)| (u, n))
                .collect();
            want.sort_unstable();
            let mut got: Vec<(usize, usize)> =
                ex.from_tiles.iter().map(|tr| (tr.src, tr.points)).collect();
            got.sort_unstable();
            assert_eq!(got, want, "tile {t} sources");
            assert_eq!(ex.resident + ex.exchanged(), tile.in_points(), "tile {t} total");
            for tr in &ex.from_tiles {
                let vol: usize = (0..3).map(|a| tr.hi[a] - tr.lo[a]).product();
                assert_eq!(vol, tr.points, "tile {t} transfer box volume");
                assert!(tr.mesh_hops >= 1, "tile {t} transfer hops");
            }
        }
    }

    #[test]
    fn steady_state_partition_is_exact_2d() {
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        for kind in [DecompKind::Slab, DecompKind::Block] {
            for steps in [1usize, 2] {
                let p = plan_of(&spec, kind, 4, steps);
                check_partition(&spec, &p, &p);
            }
        }
    }

    #[test]
    fn steady_state_partition_is_exact_3d_pencil() {
        let spec =
            StencilSpec::dim3(14, 12, 10, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap();
        let p = plan_of(&spec, DecompKind::Pencil, 6, 2);
        assert!(p.tiles.len() >= 6);
        check_partition(&spec, &p, &p);
    }

    #[test]
    fn stage_transition_partition_is_exact() {
        // Full-depth stage feeding a shallower tail stage: receiving
        // tiles own the shrunk trapezoid, the previous valid box
        // differs, and the schedule must still partition exactly.
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let full = plan_of(&spec, DecompKind::Slab, 4, 2);
        let tail = plan_of(&spec, DecompKind::Slab, 4, 1);
        check_partition(&spec, &tail, &full);
        check_partition(&spec, &full, &tail);
    }

    #[test]
    fn multi_tile_plans_exchange_their_halos() {
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let p = plan_of(&spec, DecompKind::Slab, 4, 1);
        let s = ExchangeSchedule::build(&spec, &p, &p);
        // Depth 1 has no ring; every halo point comes from a neighbor.
        assert_eq!(s.exchanged_points(), p.halo_points());
        assert!(s.tiles.iter().all(|t| t.from_ring == 0));
        // Interior tiles have a left and a right source.
        assert_eq!(s.tiles[1].from_tiles.len(), 2);
    }

    #[test]
    fn single_tile_exchanges_only_the_ring() {
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let p = plan_of(&spec, DecompKind::Slab, 1, 2);
        assert_eq!(p.tiles.len(), 1);
        let s = ExchangeSchedule::build(&spec, &p, &p);
        assert!(s.tiles[0].from_tiles.is_empty());
        assert_eq!(
            s.tiles[0].from_ring,
            crate::stencil::temporal::ring_point_count(&spec, 2)
        );
    }

    #[test]
    fn single_tile_unfused_is_fully_resident() {
        // Degenerate case: one tile, depth 1 — no neighbors, no ring.
        // The partition must still be exact with zero exchanged points.
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let p = plan_of(&spec, DecompKind::Slab, 1, 1);
        assert_eq!(p.tiles.len(), 1);
        let s = ExchangeSchedule::build(&spec, &p, &p);
        let ex = &s.tiles[0];
        assert!(ex.from_tiles.is_empty());
        assert_eq!(ex.from_ring, 0);
        assert_eq!(ex.exchanged(), 0);
        assert_eq!(ex.resident, p.tiles[0].in_points());
        check_partition(&spec, &p, &p);
    }

    #[test]
    fn zero_radius_axes_keep_the_partition_exact() {
        // 1-D spec: ry = rz = 0. Axes with zero radius contribute no
        // halo, transfers run along x only, and
        // `resident + exchanged == in_points` must hold per tile.
        let spec = StencilSpec::dim1(40, symmetric_taps(2)).unwrap();
        for steps in [1usize, 2] {
            let p = plan_of(&spec, DecompKind::Slab, 3, steps);
            assert!(p.tiles.len() >= 2);
            check_partition(&spec, &p, &p);
            let s = ExchangeSchedule::build(&spec, &p, &p);
            for ex in &s.tiles {
                for tr in &ex.from_tiles {
                    // x-neighbor transfers only: full extent on y/z.
                    assert_eq!((tr.lo[1], tr.hi[1]), (0, 1));
                    assert_eq!((tr.lo[2], tr.hi[2]), (0, 1));
                }
            }
            assert!(s.tiles.iter().any(|ex| !ex.from_tiles.is_empty()));
        }
    }

    #[test]
    fn mesh_coords_rank_the_tile_grid() {
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let p = plan_of(&spec, DecompKind::Block, 4, 1);
        let coords = mesh_coords(&p);
        assert_eq!(coords.len(), p.tiles.len());
        // Coordinates are unique and bounded by the per-axis cut counts.
        let mut seen = coords.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), coords.len(), "duplicate mesh coordinate");
        for c in &coords {
            for a in 0..3 {
                assert!(c[a] < p.cuts[a].max(1), "coord {c:?} axis {a}");
            }
        }
    }

    #[test]
    fn transfer_hops_follow_mesh_distance() {
        // A 2x2 block plan: face neighbors are 1 mesh hop, the diagonal
        // corner is 2 — strictly farther, which is what makes the
        // priced latency model able to distinguish near from far.
        let spec = StencilSpec::heat2d(26, 18, 0.2);
        let p = plan_of(&spec, DecompKind::Block, 4, 1);
        assert_eq!((p.cuts[0], p.cuts[1]), (2, 2), "expected a 2x2 block plan");
        let coords = mesh_coords(&p);
        let s = ExchangeSchedule::build(&spec, &p, &p);
        let mut saw = [false, false]; // [face, diagonal]
        for (t, ex) in s.tiles.iter().enumerate() {
            for tr in &ex.from_tiles {
                let want = (0..3)
                    .map(|a| coords[t][a].abs_diff(coords[tr.src][a]))
                    .sum::<usize>();
                assert_eq!(tr.mesh_hops, want, "tile {t} <- {}", tr.src);
                match want {
                    1 => saw[0] = true,
                    2 => saw[1] = true,
                    _ => panic!("unexpected distance {want} on a 2x2 mesh"),
                }
            }
        }
        assert!(saw[0] && saw[1], "plan exposes both near and far transfers");
    }
}
