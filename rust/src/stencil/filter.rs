//! Data-filtering PE configuration (§III-A "Data-filtering PEs", Fig 6).
//!
//! A reader worker broadcasts every value it loads down its column of
//! MUL/MAC PEs; each tap only needs a subset, so a filter PE in front of
//! each tap drops the "not-needed" tokens. The paper gives two schemes —
//! the `0^m 1^n 0^p` bit pattern and the row/col-id compare — and this
//! module derives both *analytically* from the stencil geometry, worker
//! count and tap position.
//!
//! Geometry conventions (see `stencil::mod`): reader `ρ` loads columns
//! `c ≡ ρ (mod w)` in ascending row-major order; compute worker `j` owns
//! output columns `o ≡ j (mod w)`. Tap `t` of worker `j`'s x chain
//! (`t = 0 .. 2rx`) therefore consumes columns `o + t - rx`, which live in
//! reader `(j + t + w - rx % w ... ) mod w`'s stream.

use crate::dfg::node::FilterSpec;

/// Reader that feeds x-chain tap `t` of worker `j` (offset `t - rx`).
pub fn x_tap_reader(j: usize, t: usize, rx: usize, w: usize) -> usize {
    // (j + t - rx) mod w, computed without underflow.
    (j + t + w * (rx / w + 1) - rx) % w
}

/// Reader that feeds every y-chain tap of worker `j`: the one loading the
/// worker's own output columns (§III-B — "all MUL/MAC's input comes from
/// only one particular reader worker").
pub fn y_tap_reader(j: usize, w: usize) -> usize {
    j % w
}

/// Number of columns `c ≡ ρ (mod w)` with `c < hi` (tokens per row a
/// reader produces before column `hi`).
fn count_cols(rho: usize, w: usize, hi: usize) -> u64 {
    if hi <= rho {
        0
    } else {
        ((hi - rho - 1) / w + 1) as u64
    }
}

/// §III-A bit-pattern filter for x-chain tap `t` of worker `j` on a 1-D
/// grid of `nx` points: pass tokens whose column maps to a valid interior
/// output `o = c - (t - rx) ∈ [rx, nx - rx)`.
///
/// Returns the per-row (here: whole-stream) `0^m 1^n 0^p` pattern. The
/// paper's radius-1, w=1 example yields `1^(N-2) 0^2` for the MUL,
/// `0 1^(N-2) 0` and `0^2 1^(N-2)` for the MACs.
pub fn x_tap_bits(j: usize, t: usize, rx: usize, w: usize, nx: usize) -> FilterSpec {
    let rho = x_tap_reader(j, t, rx, w);
    let total = count_cols(rho, w, nx);
    // Valid token columns: c ∈ [t, nx - 2rx + t)  (so that o ∈ [rx, nx-rx)).
    let lo = count_cols(rho, w, t);
    let hi = count_cols(rho, w, nx - 2 * rx + t);
    FilterSpec::Bits {
        m: lo,
        n: hi - lo,
        p: total - hi,
    }
}

/// Row/col-id filter for x-chain tap `t` of worker `j` on an
/// `nx` x `ny` grid: pass tokens tagged with interior rows and the tap's
/// shifted column window.
pub fn x_tap_rowcol(t: usize, rx: usize, ry: usize, nx: usize, ny: usize) -> FilterSpec {
    FilterSpec::RowCol {
        row_lo: ry as u32,
        row_hi: (ny - ry) as u32,
        col_lo: t as u32,
        col_hi: (nx - 2 * rx + t) as u32,
    }
}

/// Row/col-id filter for y-chain tap `u` (`u = 0 .. 2ry-1`, row offset
/// `off = (u < ry ? u : u+1) - ry`): pass tokens whose row is the tap's
/// shifted interior row window and whose column is an interior output
/// column.
pub fn y_tap_rowcol(u: usize, rx: usize, ry: usize, nx: usize, ny: usize) -> FilterSpec {
    let k = if u < ry { u } else { u + 1 }; // skip the centre row
    let off = k as i64 - ry as i64;
    FilterSpec::RowCol {
        row_lo: (ry as i64 + off) as u32,
        row_hi: (ny as i64 - ry as i64 + off) as u32,
        col_lo: rx as u32,
        col_hi: (nx - rx) as u32,
    }
}

/// Row offset of y-chain tap `u` relative to the output row.
pub fn y_tap_offset(u: usize, ry: usize) -> i64 {
    let k = if u < ry { u } else { u + 1 };
    k as i64 - ry as i64
}

/// Reader that feeds a tap with column offset `dx ∈ [-rx, rx]` of worker
/// `j` — the general form of [`x_tap_reader`], used by the box and 3-D
/// mappings where taps carry explicit `(dz, dy, dx)` offsets.
pub fn tap_reader(j: usize, dx: i64, rx: usize, w: usize) -> usize {
    x_tap_reader(j, (dx + rx as i64) as usize, rx, w)
}

/// Row/col-id filter for a general 2-D tap offset `(dy, dx)`: pass tokens
/// whose row lies in the tap-shifted interior row window and whose column
/// lies in the tap-shifted interior column window. Degenerates to
/// [`x_tap_rowcol`] at `dy = 0` and to [`y_tap_rowcol`] at `dx = 0`.
pub fn tap_rowcol(dy: i64, dx: i64, rx: usize, ry: usize, nx: usize, ny: usize) -> FilterSpec {
    FilterSpec::RowCol {
        row_lo: (ry as i64 + dy) as u32,
        row_hi: (ny as i64 - ry as i64 + dy) as u32,
        col_lo: (rx as i64 + dx) as u32,
        col_hi: (nx as i64 - rx as i64 + dx) as u32,
    }
}

/// Volume filter for a general 3-D tap offset `(dz, dy, dx)` on an
/// `nx * ny * nz` grid whose tokens carry flattened `z * ny + y` row
/// tags: pass the tap-shifted interior window along every axis.
#[allow(clippy::too_many_arguments)]
pub fn tap_vol(
    dz: i64,
    dy: i64,
    dx: i64,
    rx: usize,
    ry: usize,
    rz: usize,
    nx: usize,
    ny: usize,
    nz: usize,
) -> FilterSpec {
    FilterSpec::Vol {
        z_lo: (rz as i64 + dz) as u32,
        z_hi: (nz as i64 - rz as i64 + dz) as u32,
        y_lo: (ry as i64 + dy) as u32,
        y_hi: (ny as i64 - ry as i64 + dy) as u32,
        col_lo: (rx as i64 + dx) as u32,
        col_hi: (nx as i64 - rx as i64 + dx) as u32,
        ny: ny as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn paper_fig6_patterns() {
        // 3-pt stencil (rx=1), one worker, one reader, grid N.
        let n = 10usize;
        // MUL (t=0): 1^(N-2) 0 0
        assert_eq!(
            x_tap_bits(0, 0, 1, 1, n),
            FilterSpec::Bits { m: 0, n: (n - 2) as u64, p: 2 }
        );
        // first MAC (t=1): 0 1^(N-2) 0
        assert_eq!(
            x_tap_bits(0, 1, 1, 1, n),
            FilterSpec::Bits { m: 1, n: (n - 2) as u64, p: 1 }
        );
        // second MAC (t=2): 0 0 1^(N-2)
        assert_eq!(
            x_tap_bits(0, 2, 1, 1, n),
            FilterSpec::Bits { m: 2, n: (n - 2) as u64, p: 0 }
        );
    }

    #[test]
    fn x_tap_reader_matches_paper_interleave() {
        // rx=1, w=3 (Fig 3/5): worker 0's MUL (t=0) eats in[o-1] — the
        // stream of reader 2 when o ≡ 0 (cols ≡ -1 ≡ 2 mod 3).
        assert_eq!(x_tap_reader(0, 0, 1, 3), 2);
        assert_eq!(x_tap_reader(0, 1, 1, 3), 0);
        assert_eq!(x_tap_reader(0, 2, 1, 3), 1);
        // Worker 1's taps shift by one reader.
        assert_eq!(x_tap_reader(1, 0, 1, 3), 0);
    }

    /// The pairing invariant the whole mapping rests on: for every tap,
    /// the k-th *passed* token of its (filtered) reader stream is exactly
    /// the input the k-th output of that worker needs.
    #[test]
    fn kth_passed_token_matches_kth_output_1d() {
        let mut rng = XorShift::new(0xF00D);
        for _case in 0..200 {
            let rx = rng.range(1, 5);
            let w = rng.range(1, 7);
            let nx = rng.range(2 * rx + 2, 80);
            for j in 0..w {
                // Worker j's outputs, in order.
                let outputs: Vec<usize> = (rx..nx - rx)
                    .filter(|o| o % w == j % w)
                    .collect();
                for t in 0..=2 * rx {
                    let rho = x_tap_reader(j, t, rx, w);
                    let spec = x_tap_bits(j, t, rx, w, nx);
                    // Reader rho's stream of columns.
                    let stream: Vec<usize> =
                        (rho..nx).step_by(w).collect();
                    let passed: Vec<usize> = stream
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| spec.passes(*i as u64, 0, 0))
                        .map(|(_, c)| *c)
                        .collect();
                    assert_eq!(
                        passed.len(),
                        outputs.len(),
                        "tap {t} worker {j} (w={w} nx={nx} rx={rx})"
                    );
                    for (k, &o) in outputs.iter().enumerate() {
                        // Token column must be o + t - rx.
                        let want = (o + t) as i64 - rx as i64;
                        assert_eq!(passed[k] as i64, want, "k={k}");
                    }
                }
            }
        }
    }

    /// Same invariant for the 2-D row/col scheme: per tap, passed tokens
    /// enumerate (row-major) exactly the worker's outputs, shifted by the
    /// tap offset.
    #[test]
    fn kth_passed_token_matches_kth_output_2d() {
        let mut rng = XorShift::new(0xBEEF);
        for _case in 0..100 {
            let rx = rng.range(1, 4);
            let ry = rng.range(1, 4);
            let w = rng.range(1, 5);
            let nx = rng.range(2 * rx + 2, 24);
            let ny = rng.range(2 * ry + 2, 20);
            for j in 0..w {
                let outputs: Vec<(usize, usize)> = (ry..ny - ry)
                    .flat_map(|r| {
                        (rx..nx - rx)
                            .filter(move |c| c % w == j % w)
                            .map(move |c| (r, c))
                    })
                    .collect();
                // x-chain taps.
                for t in 0..=2 * rx {
                    let rho = x_tap_reader(j, t, rx, w);
                    let spec = x_tap_rowcol(t, rx, ry, nx, ny);
                    let passed: Vec<(usize, usize)> = (0..ny)
                        .flat_map(|r| (rho..nx).step_by(w).map(move |c| (r, c)))
                        .filter(|&(r, c)| spec.passes(0, r as u32, c as u32))
                        .collect();
                    assert_eq!(passed.len(), outputs.len(), "x tap {t}");
                    for (k, &(orow, ocol)) in outputs.iter().enumerate() {
                        assert_eq!(passed[k].0, orow);
                        assert_eq!(
                            passed[k].1 as i64,
                            (ocol + t) as i64 - rx as i64
                        );
                    }
                }
                // y-chain taps.
                for u in 0..2 * ry {
                    let rho = y_tap_reader(j, w);
                    let spec = y_tap_rowcol(u, rx, ry, nx, ny);
                    let off = y_tap_offset(u, ry);
                    let passed: Vec<(usize, usize)> = (0..ny)
                        .flat_map(|r| (rho..nx).step_by(w).map(move |c| (r, c)))
                        .filter(|&(r, c)| spec.passes(0, r as u32, c as u32))
                        .collect();
                    assert_eq!(passed.len(), outputs.len(), "y tap {u}");
                    for (k, &(orow, ocol)) in outputs.iter().enumerate() {
                        assert_eq!(passed[k].0 as i64, orow as i64 + off);
                        assert_eq!(passed[k].1, ocol);
                    }
                }
            }
        }
    }

    #[test]
    fn y_tap_offsets_skip_centre() {
        // ry = 2: offsets -2, -1, +1, +2.
        let offs: Vec<i64> = (0..4).map(|u| y_tap_offset(u, 2)).collect();
        assert_eq!(offs, vec![-2, -1, 1, 2]);
    }

    #[test]
    fn tap_rowcol_generalizes_x_and_y_schemes() {
        let (rx, ry, nx, ny) = (2usize, 3usize, 20usize, 15usize);
        for t in 0..=2 * rx {
            let dx = t as i64 - rx as i64;
            assert_eq!(tap_rowcol(0, dx, rx, ry, nx, ny), x_tap_rowcol(t, rx, ry, nx, ny));
        }
        for u in 0..2 * ry {
            let dy = y_tap_offset(u, ry);
            assert_eq!(tap_rowcol(dy, 0, rx, ry, nx, ny), y_tap_rowcol(u, rx, ry, nx, ny));
        }
    }

    #[test]
    fn tap_reader_matches_x_tap_reader() {
        for w in 1..=5 {
            for j in 0..w {
                for dx in -3i64..=3 {
                    assert_eq!(
                        tap_reader(j, dx, 3, w),
                        x_tap_reader(j, (dx + 3) as usize, 3, w)
                    );
                }
            }
        }
    }

    /// The pairing invariant for the 3-D volume scheme: per tap
    /// `(dz, dy, dx)`, the k-th passed token of the reader stream is
    /// exactly the tap-shifted k-th output of that worker.
    #[test]
    fn kth_passed_token_matches_kth_output_3d() {
        let mut rng = XorShift::new(0x3D3D);
        for _case in 0..40 {
            let rx = rng.range(1, 3);
            let ry = rng.range(1, 3);
            let rz = rng.range(1, 3);
            let w = rng.range(1, 4);
            let nx = rng.range(2 * rx + 2, 14);
            let ny = rng.range(2 * ry + 2, 12);
            let nz = rng.range(2 * rz + 2, 10);
            for j in 0..w {
                let outputs: Vec<(usize, usize, usize)> = (rz..nz - rz)
                    .flat_map(|z| {
                        (ry..ny - ry).flat_map(move |y| {
                            (rx..nx - rx)
                                .filter(move |c| c % w == j % w)
                                .map(move |c| (z, y, c))
                        })
                    })
                    .collect();
                for (dz, dy, dx) in [
                    (0i64, 0i64, 1i64),
                    (0, -(ry as i64), 0),
                    (rz as i64, 0, 0),
                    (-(rz as i64), ry as i64, -(rx as i64)),
                ] {
                    let rho = tap_reader(j, dx, rx, w);
                    let spec = tap_vol(dz, dy, dx, rx, ry, rz, nx, ny, nz);
                    let passed: Vec<(usize, usize)> = (0..nz * ny)
                        .flat_map(|r| (rho..nx).step_by(w).map(move |c| (r, c)))
                        .filter(|&(r, c)| spec.passes(0, r as u32, c as u32))
                        .collect();
                    assert_eq!(passed.len(), outputs.len(), "tap ({dz},{dy},{dx})");
                    for (k, &(oz, oy, oc)) in outputs.iter().enumerate() {
                        let want_row =
                            (oz as i64 + dz) * ny as i64 + oy as i64 + dy;
                        assert_eq!(passed[k].0 as i64, want_row);
                        assert_eq!(passed[k].1 as i64, oc as i64 + dx);
                    }
                }
            }
        }
    }
}
