//! §III-A — mapping a (2rx+1)-point 1-D stencil onto the CGRA.
//!
//! The computation is a four-stage pipeline (read, compute, write, sync),
//! each stage run by `w` interleaved logical workers:
//!
//! * **Readers** load the input grid round-robin (Fig 3): reader `ρ` loads
//!   columns `c ≡ ρ (mod w)` and broadcasts each value down its column of
//!   filters (Fig 4 — PEs in the same column receive data from the same
//!   reader).
//! * **Compute workers** are MAC chains (Fig 5): worker `j` owns outputs
//!   `o ≡ j (mod w)` and runs `1 MUL + 2rx MACs`, one PE per coefficient
//!   (PEs in the same row share a coefficient).
//! * **Data filters** in front of every tap drop the broadcast tokens the
//!   tap does not need, using the `0^m 1^n 0^p` patterns of Fig 6.
//! * **Writers** store outputs via their control units' address streams;
//!   **sync workers** count the acks and a done-tree signals the host.

use anyhow::{ensure, Result};

use crate::dfg::node::{AddrIter, Op, Stage};
use crate::dfg::{Dsl, Graph};

use super::filter::{x_tap_bits, x_tap_reader};
use super::spec::StencilSpec;
use super::{first_output_col, outputs_per_row};

/// Extra queue slack beyond the analytic wave backlog (covers network
/// latency and pipeline jitter).
pub const QUEUE_SLACK: usize = 4;

/// Capacity the data queue feeding chain position `t` needs (t = 0 is the
/// MUL): the systolic pipeline skew — MAC `t` fires output `i` roughly
/// `t * L` cycles after the data wave for output `i` arrives, where `L`
/// (~2 cycles) is the per-stage partial-forwarding latency on the mesh —
/// plus the x-wave jitter of `2rx/w` waves between earliest and latest
/// tap. Undersizing this throttles the whole pipeline: the tap's filter
/// stalls, the reader broadcast stalls behind it, and every worker slows
/// (measured: 76% -> 95% of roofline on the Table-I 1-D workload when
/// the skew term uses 2t instead of t).
pub fn tap_capacity_1d(rx: usize, w: usize, t: usize) -> usize {
    2 * t + 2 * rx / w + QUEUE_SLACK
}

/// Build the §III-A dataflow graph for `spec` with `w` workers.
///
/// The resulting graph computes the interior outputs `[rx, nx - rx)`;
/// boundary points are copied by the caller (see `verify::golden`).
pub fn build(spec: &StencilSpec, w: usize) -> Result<Graph> {
    ensure!(spec.is_1d(), "map1d requires a 1-D spec");
    ensure!(w >= 1, "need at least one worker");
    let nx = spec.nx;
    let rx = spec.rx;
    let taps = 2 * rx + 1;

    let mut d = Dsl::new();

    // Readers + their control units (§III-A "Control Units").
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(rho as u32, w as u32, nx as u32))
            .out(&format!("r{rho}.addr"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("r{rho}.addr"))
            .out(&format!("r{rho}.data"));
    }

    for j in 0..w {
        // Data filters: one per tap, fed by the tap's reader broadcast.
        for t in 0..taps {
            let rho = x_tap_reader(j, t, rx, w);
            d.op(&format!("w{j}.f{t}"), Op::Filter, Stage::Compute)
                .worker(j)
                .filter(x_tap_bits(j, t, rx, w, nx))
                .input(0, &format!("r{rho}.data"))
                .out(&format!("w{j}.t{t}"));
        }
        // MAC chain: MUL on tap 0, MACs after (Fig 5).
        d.op(&format!("w{j}.mul"), Op::Mul, Stage::Compute)
            .worker(j)
            .coeff(spec.cx[0])
            .input_cap(0, &format!("w{j}.t0"), tap_capacity_1d(rx, w, 0))
            .out(&format!("w{j}.p0"));
        for t in 1..taps {
            d.op(&format!("w{j}.mac{t}"), Op::Mac, Stage::Compute)
                .worker(j)
                .coeff(spec.cx[t])
                .input(0, &format!("w{j}.p{}", t - 1))
                .input_cap(1, &format!("w{j}.t{t}"), tap_capacity_1d(rx, w, t))
                .out(&format!("w{j}.p{t}"));
        }
        // Writer + its control unit.
        let first = first_output_col(j, w, rx);
        let count = outputs_per_row(j, w, nx, rx) as u64;
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(first as u32, w as u32, (nx - rx) as u32))
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &format!("w{j}.p{}", taps - 1))
            .out(&format!("w{j}.ack"));
        // Synchronization worker: counts this writer's stores (§III-A).
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }

    // Combine per-worker done signals into the host "done".
    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

/// DP-op count the graph *should* have: `w * (2rx + 1)` — Fig 7's
/// "6 workers, 102 DP ops" for the 17-pt stencil.
pub fn expected_dp_ops(spec: &StencilSpec, w: usize) -> usize {
    w * spec.points()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::node::Op;

    fn spec_3pt(nx: usize) -> StencilSpec {
        StencilSpec::dim1(nx, vec![0.25, 0.5, 0.25]).unwrap()
    }

    #[test]
    fn builds_paper_running_example() {
        // 3-pt stencil, 3 workers (Fig 3-5).
        let g = build(&spec_3pt(32), 3).unwrap();
        // Per worker: 1 MUL + 2 MAC + 3 filters + st.cu + st + sync = 9,
        // plus readers: (cu + ld) * 3, plus done: 1.
        assert_eq!(g.dp_ops(), 9);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 3);
        assert_eq!(h[&Op::Mac], 6);
        assert_eq!(h[&Op::Filter], 9);
        assert_eq!(h[&Op::Load], 3);
        assert_eq!(h[&Op::Store], 3);
        assert_eq!(h[&Op::SyncCount], 3);
        assert_eq!(h[&Op::AddrGen], 6);
        assert_eq!(h[&Op::DoneTree], 1);
    }

    #[test]
    fn fig7_structure_17pt_6_workers() {
        // Fig 7: nx = 194400, rx = 8, 17-pt, 6 workers, 102 DP ops.
        let spec = StencilSpec::paper_1d();
        let g = build(&spec, 6).unwrap();
        assert_eq!(g.dp_ops(), 102);
        assert_eq!(g.dp_ops(), expected_dp_ops(&spec, 6));
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 6);
        assert_eq!(h[&Op::Mac], 96);
        assert_eq!(h[&Op::Filter], 6 * 17);
    }

    #[test]
    fn single_worker_works() {
        let g = build(&spec_3pt(16), 1).unwrap();
        assert_eq!(g.dp_ops(), 3);
    }

    #[test]
    fn sync_counts_partition_interior() {
        let spec = spec_3pt(29);
        let g = build(&spec, 4).unwrap();
        let total: u64 = g
            .nodes
            .iter()
            .filter(|n| n.op == Op::SyncCount)
            .map(|n| n.expected.unwrap())
            .sum();
        assert_eq!(total, (spec.nx - 2 * spec.rx) as u64);
    }

    #[test]
    fn graph_is_valid_across_widths() {
        let spec = StencilSpec::dim1(64, crate::stencil::spec::symmetric_taps(3)).unwrap();
        for w in 1..=8 {
            let g = build(&spec, w).unwrap();
            assert!(crate::dfg::validate::check(&g).is_empty(), "w={w}");
            assert_eq!(g.dp_ops(), w * 7);
        }
    }

    #[test]
    fn mandatory_capacity_grows_with_radius_and_position() {
        assert!(tap_capacity_1d(8, 1, 0) > tap_capacity_1d(1, 1, 0));
        assert!(tap_capacity_1d(8, 6, 0) < tap_capacity_1d(8, 1, 0));
        assert!(tap_capacity_1d(8, 6, 16) > tap_capacity_1d(8, 6, 0));
    }

    #[test]
    fn rejects_2d_spec() {
        let s = StencilSpec::heat2d(16, 16, 0.2);
        assert!(build(&s, 2).is_err());
    }
}
