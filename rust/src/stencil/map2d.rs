//! §III-B — mapping a 2-D star stencil onto the CGRA.
//!
//! Natural extension of the 1-D algorithm (Fig 9): the x contribution is
//! computed exactly like stencil1D; the y contribution continues the same
//! MAC chain (the paper counts `48 MAC + 1 MUL = 49 DP ops` per worker for
//! `rx = ry = 12` — one MUL plus a fused chain over all remaining taps, so
//! the partial sums of the x and y dimensions are combined by the chain
//! itself).
//!
//! Key §III-B properties implemented here:
//!
//! * **Reader sharing** — no separate readers for the y dimension: the
//!   same `w` readers feed both chains; y-chain taps of worker `j` all
//!   come from the single reader that loads the worker's own output
//!   columns.
//! * **Mandatory buffering via PE-to-PE forwarding** — §II's "data loaded
//!   can be passed from a PE to a neighbor PE directly and thus reused":
//!   each reader stream flows through a *delay line* of `2*ry` copy PEs,
//!   each stage holding one row's worth of the stream. A tap with row
//!   offset `off` reads the line at stage `ry - off`, so every tap of an
//!   output receives its token at the same wall-time and the fabric holds
//!   exactly the paper's goal of `2*ry*x_dim` values (+ pipeline-skew
//!   queues), not one copy per tap.
//! * **Row/col-id filtering** — 2-D filters use the paper's second scheme
//!   (compare the row id of the token), since the bit-pattern period
//!   varies per row when `nx % w != 0`.
//!
//! Undersized delay stages deadlock the pipeline — demonstrated by a
//! failure-injection test in `rust/tests/`.

use anyhow::{ensure, Result};

use crate::dfg::node::{AddrIter, Op, Stage};
use crate::dfg::{Dsl, Graph};

use super::filter::{
    tap_reader, tap_rowcol, x_tap_reader, x_tap_rowcol, y_tap_offset, y_tap_reader,
    y_tap_rowcol,
};
use super::map1d::QUEUE_SLACK;
use super::spec::StencilSpec;
use super::{first_output_col, outputs_per_row};

/// Raw (pre-filter) tokens reader `rho` produces per grid row.
pub fn raw_per_row(spec: &StencilSpec, rho: usize, w: usize) -> usize {
    if spec.nx <= rho {
        0
    } else {
        (spec.nx - rho - 1) / w + 1
    }
}

/// Capacity of one delay-line stage of reader `rho`: one row of the raw
/// stream plus slack. The line's total capacity between two tap points
/// must cover their row distance or the graph deadlocks (§III-B
/// "Mandatory Buffering").
pub fn stage_capacity(spec: &StencilSpec, rho: usize, w: usize) -> usize {
    raw_per_row(spec, rho, w) + QUEUE_SLACK
}

/// Capacity of the data queue feeding chain position `k` (0 = the MUL):
/// the systolic pipeline skew (MAC `k` fires output `i` at wave
/// `i + k*L`, with `L` ~ 2 cycles of per-stage partial latency on the
/// mesh) plus the x-wave jitter. See `map1d::tap_capacity_1d`.
pub fn chain_capacity(spec: &StencilSpec, w: usize, k: usize) -> usize {
    2 * k + 2 * spec.rx / w + QUEUE_SLACK
}

/// Total mandatory buffering (tokens) the mapping needs: delay-line
/// stages + chain data queues — the quantity §III-B compares against
/// on-fabric storage to decide tile decomposition (see [`super::decomp`]).
/// The delay-line part is the paper's `2*ry*x_dim` goal. Star and box
/// shapes need the same delay depth (`2*ry` rows) and the same chain
/// length (`points()` taps), so one formula covers both.
pub fn required_buffer_tokens(spec: &StencilSpec, w: usize) -> usize {
    let mut total = 0;
    for rho in 0..w {
        total += 2 * spec.ry * stage_capacity(spec, rho, w);
    }
    let chain_len = spec.points();
    for _j in 0..w {
        for k in 0..chain_len {
            total += chain_capacity(spec, w, k);
        }
    }
    total
}

/// Build the §III-B dataflow graph for `spec` with `w` workers. Star
/// specs follow Fig 9–11; [`crate::stencil::spec::StencilShape::Box`]
/// specs run the same reader/delay-line front end with one fused MAC
/// chain over the dense window.
pub fn build(spec: &StencilSpec, w: usize) -> Result<Graph> {
    ensure!(!spec.is_1d(), "map2d requires a 2-D spec (use map1d)");
    ensure!(!spec.is_3d(), "map2d requires a 2-D spec (use map3d)");
    ensure!(w >= 1, "need at least one worker");
    if spec.is_box() {
        return build_box(spec, w);
    }
    let (nx, ny, rx, ry) = (spec.nx, spec.ny, spec.rx, spec.ry);
    let x_taps = 2 * rx + 1;
    let y_taps = 2 * ry;

    let mut d = Dsl::new();

    // Shared readers: row-major over the whole grid, interleaved by
    // column (one reader per congruence class), each followed by its
    // 2*ry-stage delay line. Stage `s` of reader `rho` publishes signal
    // `r{rho}.d{s}`; stage 0 is the load itself.
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: 0,
                row_hi: ny as u32,
                col_start: rho as u32,
                col_hi: nx as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("r{rho}.addr"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("r{rho}.addr"))
            .out(&format!("r{rho}.d0"));
        let cap = stage_capacity(spec, rho, w);
        for s in 1..=y_taps {
            d.op(&format!("r{rho}.copy{s}"), Op::Copy, Stage::Reader)
                .input_cap(0, &format!("r{rho}.d{}", s - 1), cap)
                .out(&format!("r{rho}.d{s}"));
        }
    }

    for j in 0..w {
        // ---- x chain (identical in shape to stencil1D, Fig 9 left).
        // x taps read their reader's line at stage `ry` so they are
        // wall-time aligned with the y taps. ----
        for t in 0..x_taps {
            let rho = x_tap_reader(j, t, rx, w);
            d.op(&format!("w{j}.x.f{t}"), Op::Filter, Stage::Compute)
                .worker(j)
                .filter(x_tap_rowcol(t, rx, ry, nx, ny))
                .input(0, &format!("r{rho}.d{ry}"))
                .out(&format!("w{j}.x.t{t}"));
        }
        d.op(&format!("w{j}.x.mul"), Op::Mul, Stage::Compute)
            .worker(j)
            .coeff(spec.cx[0])
            .input_cap(0, &format!("w{j}.x.t0"), chain_capacity(spec, w, 0))
            .out(&format!("w{j}.x.p0"));
        for t in 1..x_taps {
            d.op(&format!("w{j}.x.mac{t}"), Op::Mac, Stage::Compute)
                .worker(j)
                .coeff(spec.cx[t])
                .input(0, &format!("w{j}.x.p{}", t - 1))
                .input_cap(1, &format!("w{j}.x.t{t}"), chain_capacity(spec, w, t))
                .out(&format!("w{j}.x.p{t}"));
        }

        // ---- y chain: continues the same partial-sum chain (Fig 9
        // right); all taps fed by ONE reader's delay line at the stage
        // matching the tap's row offset (reader sharing + forwarding). ----
        let rho_y = y_tap_reader(j, w);
        let mut prev = format!("w{j}.x.p{}", x_taps - 1);
        for u in 0..y_taps {
            let off = y_tap_offset(u, ry);
            let stage = (ry as i64 - off) as usize;
            d.op(&format!("w{j}.y.f{u}"), Op::Filter, Stage::Compute)
                .worker(j)
                .filter(y_tap_rowcol(u, rx, ry, nx, ny))
                .input(0, &format!("r{rho_y}.d{stage}"))
                .out(&format!("w{j}.y.t{u}"));
            let next = format!("w{j}.y.p{u}");
            d.op(&format!("w{j}.y.mac{u}"), Op::Mac, Stage::Compute)
                .worker(j)
                .coeff(spec.cy[u])
                .input(0, &prev)
                .input_cap(
                    1,
                    &format!("w{j}.y.t{u}"),
                    chain_capacity(spec, w, x_taps + u),
                )
                .out(&next);
            prev = next;
        }

        // ---- writer + sync ----
        let first = first_output_col(j, w, rx);
        let count = (outputs_per_row(j, w, nx, rx) * (ny - 2 * ry)) as u64;
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: ry as u32,
                row_hi: (ny - ry) as u32,
                col_start: first as u32,
                col_hi: (nx - rx) as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &prev)
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }

    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

/// Box-shape variant: the same shared readers + `2*ry`-stage delay lines
/// feed one fused MUL/MAC chain per worker over the dense
/// `(2ry+1) x (2rx+1)` window. A tap with offset `(dy, dx)` reads reader
/// `(j + dx) mod w`'s line at stage `ry - dy` (so all window taps of an
/// output arrive wall-time aligned) through a row/col filter shifted by
/// the tap offset.
fn build_box(spec: &StencilSpec, w: usize) -> Result<Graph> {
    let (nx, ny, rx, ry) = (spec.nx, spec.ny, spec.rx, spec.ry);
    let taps = spec.chain_taps();

    let mut d = Dsl::new();

    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: 0,
                row_hi: ny as u32,
                col_start: rho as u32,
                col_hi: nx as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("r{rho}.addr"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("r{rho}.addr"))
            .out(&format!("r{rho}.d0"));
        let cap = stage_capacity(spec, rho, w);
        for s in 1..=2 * ry {
            d.op(&format!("r{rho}.copy{s}"), Op::Copy, Stage::Reader)
                .input_cap(0, &format!("r{rho}.d{}", s - 1), cap)
                .out(&format!("r{rho}.d{s}"));
        }
    }

    for j in 0..w {
        let mut prev = String::new();
        for (k, &(_dz, dy, dx, coeff)) in taps.iter().enumerate() {
            let rho = tap_reader(j, dx, rx, w);
            let stage = (ry as i64 - dy) as usize;
            d.op(&format!("w{j}.f{k}"), Op::Filter, Stage::Compute)
                .worker(j)
                .filter(tap_rowcol(dy, dx, rx, ry, nx, ny))
                .input(0, &format!("r{rho}.d{stage}"))
                .out(&format!("w{j}.t{k}"));
            let next = format!("w{j}.p{k}");
            if k == 0 {
                d.op(&format!("w{j}.mul"), Op::Mul, Stage::Compute)
                    .worker(j)
                    .coeff(coeff)
                    .input_cap(0, &format!("w{j}.t{k}"), chain_capacity(spec, w, k))
                    .out(&next);
            } else {
                d.op(&format!("w{j}.mac{k}"), Op::Mac, Stage::Compute)
                    .worker(j)
                    .coeff(coeff)
                    .input(0, &prev)
                    .input_cap(1, &format!("w{j}.t{k}"), chain_capacity(spec, w, k))
                    .out(&next);
            }
            prev = next;
        }

        let first = first_output_col(j, w, rx);
        let count = (outputs_per_row(j, w, nx, rx) * (ny - 2 * ry)) as u64;
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: ry as u32,
                row_hi: (ny - ry) as u32,
                col_start: first as u32,
                col_hi: (nx - rx) as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &prev)
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }

    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heat5pt_structure() {
        let spec = StencilSpec::heat2d(16, 12, 0.2);
        let g = build(&spec, 3).unwrap();
        // Per worker: 1 MUL + 2 x-MAC + 2 y-MAC = 5 DP ops.
        assert_eq!(g.dp_ops(), 15);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 3);
        assert_eq!(h[&Op::Mac], 12);
        // Filters: (3 x-taps + 2 y-taps) per worker.
        assert_eq!(h[&Op::Filter], 15);
        assert_eq!(h[&Op::Load], 3);
        // Delay lines: 2*ry copies per reader.
        assert_eq!(h[&Op::Copy], 3 * 2);
    }

    #[test]
    fn fig11_structure_49pt_5_workers() {
        // Fig 11: 49-pt 2-D stencil, rx = ry = 12, 5 workers.
        let spec = StencilSpec::paper_2d();
        let g = build(&spec, 5).unwrap();
        // §VI: each worker requires 49 DP ops (48 MAC + 1 MUL).
        assert_eq!(g.dp_ops(), 5 * 49);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 5);
        assert_eq!(h[&Op::Mac], 5 * 48);
        // Delay lines hold the paper's 2*ry rows per reader.
        assert_eq!(h[&Op::Copy], 5 * 24);
    }

    #[test]
    fn sync_counts_partition_interior() {
        let spec = StencilSpec::dim2(
            21,
            17,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(3),
        )
        .unwrap();
        for w in 1..=4 {
            let g = build(&spec, w).unwrap();
            let total: u64 = g
                .nodes
                .iter()
                .filter(|n| n.op == Op::SyncCount)
                .map(|n| n.expected.unwrap())
                .sum();
            assert_eq!(total, spec.interior_outputs() as u64, "w={w}");
        }
    }

    #[test]
    fn delay_line_holds_2ry_rows() {
        // Total delay-line capacity across readers ≈ 2*ry*nx — the
        // paper's "keep 2ry*x_dim data inside the queues" goal.
        let spec = StencilSpec::paper_2d();
        let w = 5;
        let line_total: usize = (0..w)
            .map(|rho| 2 * spec.ry * stage_capacity(&spec, rho, w))
            .sum();
        let goal = 2 * spec.ry * spec.nx;
        assert!(line_total >= goal, "{line_total} < {goal}");
        // Within slack overhead of the goal.
        assert!(line_total <= goal + 2 * spec.ry * w * (QUEUE_SLACK + 1));
    }

    #[test]
    fn required_tokens_matches_built_graph() {
        let spec = StencilSpec::heat2d(20, 14, 0.2);
        let w = 2;
        let g = build(&spec, w).unwrap();
        // Sum the mandatory capacities in the graph: delay stages (Copy
        // port 0), Mul port 0 and Mac port 1.
        let mut got = 0usize;
        for n in &g.nodes {
            match n.op {
                Op::Copy => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mul => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mac => got += g.channels[g.input(n.id, 1).unwrap()].capacity,
                _ => {}
            }
        }
        assert_eq!(got, required_buffer_tokens(&spec, w));
    }

    #[test]
    fn rejects_1d_spec() {
        let s = StencilSpec::dim1(64, vec![0.25, 0.5, 0.25]).unwrap();
        assert!(build(&s, 2).is_err());
    }

    #[test]
    fn rejects_3d_spec() {
        let s = StencilSpec::heat3d(10, 8, 6, 0.1);
        assert!(build(&s, 2).is_err());
    }

    #[test]
    fn box_structure_3x3_window() {
        // 9-pt dense window: 1 MUL + 8 MAC per worker, one filter per tap.
        let spec = StencilSpec::box2d(
            16,
            12,
            1,
            1,
            crate::stencil::spec::uniform_box_taps(1, 1, 0),
        )
        .unwrap();
        let g = build(&spec, 2).unwrap();
        assert_eq!(g.dp_ops(), 2 * 9);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 2);
        assert_eq!(h[&Op::Mac], 2 * 8);
        assert_eq!(h[&Op::Filter], 2 * 9);
        // Delay lines are the same 2*ry rows as the star mapping.
        assert_eq!(h[&Op::Copy], 2 * 2);
        assert!(crate::dfg::validate::check(&g).is_empty());
    }

    #[test]
    fn box_sync_counts_partition_interior() {
        let spec = StencilSpec::box2d(
            18,
            11,
            2,
            1,
            crate::stencil::spec::uniform_box_taps(2, 1, 0),
        )
        .unwrap();
        for w in 1..=3 {
            let g = build(&spec, w).unwrap();
            let total: u64 = g
                .nodes
                .iter()
                .filter(|n| n.op == Op::SyncCount)
                .map(|n| n.expected.unwrap())
                .sum();
            assert_eq!(total, spec.interior_outputs() as u64, "w={w}");
        }
    }

    #[test]
    fn box_required_tokens_matches_built_graph() {
        let spec = StencilSpec::box2d(
            20,
            10,
            1,
            2,
            crate::stencil::spec::uniform_box_taps(1, 2, 0),
        )
        .unwrap();
        let w = 2;
        let g = build(&spec, w).unwrap();
        let mut got = 0usize;
        for n in &g.nodes {
            match n.op {
                Op::Copy => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mul => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mac => got += g.channels[g.input(n.id, 1).unwrap()].capacity,
                _ => {}
            }
        }
        assert_eq!(got, required_buffer_tokens(&spec, w));
    }

    #[test]
    fn valid_across_worker_counts() {
        let spec = StencilSpec::heat2d(18, 10, 0.2);
        for w in 1..=5 {
            let g = build(&spec, w).unwrap();
            assert!(crate::dfg::validate::check(&g).is_empty(), "w={w}");
        }
    }
}
