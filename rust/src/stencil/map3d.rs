//! §III generalized to three dimensions — mapping 3-D star and box
//! stencils onto the CGRA via *plane buffering*.
//!
//! The 2-D mapping (§III-B) keeps `2*ry` rows inside the fabric as a
//! delay line of row-sized copy stages. The third dimension extends the
//! same idea one level up: a z-neighbor lives exactly `ny` grid rows away
//! in the row-major stream, so a *plane buffer is `ny` row buffers* —
//! rows of row-buffers. Concretely:
//!
//! * **Readers** stream the whole volume row-major (the flattened
//!   `z * ny + y` plane-row is the token's row tag), interleaved by
//!   column exactly as in 1-D/2-D — reader `ρ` owns columns
//!   `c ≡ ρ (mod w)`.
//! * **Delay lines** — each reader feeds a chain of copy PEs, one grid
//!   row per stage. A tap with offset `(dz, dy, dx)` reads the line at
//!   stage `(rz*ny + ry) - (dz*ny + dy)`, so every tap of an output
//!   fires at the same wall-time and the fabric holds the 3-D analogue
//!   of the paper's mandatory-buffering goal: `2*rz` planes plus `2*ry`
//!   rows of the stream (`required_buffer_tokens`).
//! * **Filters** use the volume scheme
//!   ([`crate::dfg::node::FilterSpec::Vol`]): the
//!   flattened row tag is unflattened to `(z, y)` and compared against
//!   the tap-shifted interior window along every axis.
//! * **Compute workers** run one fused MUL + MAC chain per worker in
//!   [`StencilSpec::chain_taps`] order (x, then y, then z for star;
//!   z-major dense for box), reusing `map2d`'s
//!   [`chain_capacity`](super::map2d::chain_capacity) skew model.
//! * **Writers/sync** use plane-mode address generators
//!   ([`AddrIter::dim3`]) over the interior `z`/`y`/`x` ranges.

use anyhow::{ensure, Result};

use crate::dfg::node::{AddrIter, Op, Stage};
use crate::dfg::{Dsl, Graph};

use super::filter::{tap_reader, tap_vol};
use super::map2d;
use super::spec::StencilSpec;
use super::{first_output_col, outputs_per_row};

/// Raw (pre-filter) tokens reader `rho` produces per grid row — the
/// column interleave is identical to the 2-D mapping.
pub fn raw_per_row(spec: &StencilSpec, rho: usize, w: usize) -> usize {
    map2d::raw_per_row(spec, rho, w)
}

/// Capacity of one delay-line stage (one grid row of the raw stream plus
/// slack) — identical to the 2-D stage size; the 3-D mapping just needs
/// more stages.
pub fn stage_capacity(spec: &StencilSpec, rho: usize, w: usize) -> usize {
    map2d::stage_capacity(spec, rho, w)
}

/// Capacity of the data queue feeding chain position `k` (0 = the MUL).
pub fn chain_capacity(spec: &StencilSpec, w: usize, k: usize) -> usize {
    map2d::chain_capacity(spec, w, k)
}

/// Delay-line stage a tap with offsets `(dz, dy)` reads: row distance
/// from the most-delayed alignment point, `(rz*ny + ry) - (dz*ny + dy)`.
pub fn tap_stage(spec: &StencilSpec, dz: i64, dy: i64) -> usize {
    let align = (spec.rz * spec.ny + spec.ry) as i64;
    (align - (dz * spec.ny as i64 + dy)) as usize
}

/// Number of delay-line stages each reader needs: the deepest tap's
/// stage. For a 3-D star this is `2*rz*ny + ry` (the `dz = -rz` z tap);
/// for a box it is `2*(rz*ny + ry)` (the far corner of the window).
pub fn delay_stages(spec: &StencilSpec, w: usize) -> usize {
    let _ = w; // depth is shape-determined; workers only set stage width
    spec.chain_taps()
        .iter()
        .map(|&(dz, dy, _, _)| tap_stage(spec, dz, dy))
        .max()
        .unwrap_or(0)
}

/// Total mandatory buffering (tokens): delay-line stages + chain data
/// queues — the 3-D analogue of [`map2d::required_buffer_tokens`]. The
/// delay-line part is `~2*rz*ny*nx + 2*ry*nx` tokens, the plane-buffer
/// goal.
pub fn required_buffer_tokens(spec: &StencilSpec, w: usize) -> usize {
    let stages = delay_stages(spec, w);
    let mut total = 0;
    for rho in 0..w {
        total += stages * stage_capacity(spec, rho, w);
    }
    let chain_len = spec.points();
    for _j in 0..w {
        for k in 0..chain_len {
            total += chain_capacity(spec, w, k);
        }
    }
    total
}

/// Build the 3-D dataflow graph for `spec` (star or box) with `w`
/// workers.
pub fn build(spec: &StencilSpec, w: usize) -> Result<Graph> {
    ensure!(spec.is_3d(), "map3d requires a 3-D spec");
    ensure!(w >= 1, "need at least one worker");
    let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
    let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
    let taps = spec.chain_taps();
    let stages = delay_stages(spec, w);

    let mut d = Dsl::new();

    // Shared readers over the whole volume, plus their delay lines.
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: 0,
                row_hi: (nz * ny) as u32,
                col_start: rho as u32,
                col_hi: nx as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("r{rho}.addr"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("r{rho}.addr"))
            .out(&format!("r{rho}.d0"));
        let cap = stage_capacity(spec, rho, w);
        for s in 1..=stages {
            d.op(&format!("r{rho}.copy{s}"), Op::Copy, Stage::Reader)
                .input_cap(0, &format!("r{rho}.d{}", s - 1), cap)
                .out(&format!("r{rho}.d{s}"));
        }
    }

    for j in 0..w {
        let mut prev = String::new();
        for (k, &(dz, dy, dx, coeff)) in taps.iter().enumerate() {
            let rho = tap_reader(j, dx, rx, w);
            let stage = tap_stage(spec, dz, dy);
            d.op(&format!("w{j}.f{k}"), Op::Filter, Stage::Compute)
                .worker(j)
                .filter(tap_vol(dz, dy, dx, rx, ry, rz, nx, ny, nz))
                .input(0, &format!("r{rho}.d{stage}"))
                .out(&format!("w{j}.t{k}"));
            let next = format!("w{j}.p{k}");
            if k == 0 {
                d.op(&format!("w{j}.mul"), Op::Mul, Stage::Compute)
                    .worker(j)
                    .coeff(coeff)
                    .input_cap(0, &format!("w{j}.t{k}"), chain_capacity(spec, w, k))
                    .out(&next);
            } else {
                d.op(&format!("w{j}.mac{k}"), Op::Mac, Stage::Compute)
                    .worker(j)
                    .coeff(coeff)
                    .input(0, &prev)
                    .input_cap(1, &format!("w{j}.t{k}"), chain_capacity(spec, w, k))
                    .out(&next);
            }
            prev = next;
        }

        // Writer + sync over the interior volume.
        let first = first_output_col(j, w, rx);
        let count = (outputs_per_row(j, w, nx, rx) * (ny - 2 * ry) * (nz - 2 * rz)) as u64;
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim3(
                rz as u32,
                (nz - rz) as u32,
                ry as u32,
                (ny - ry) as u32,
                ny as u32,
                first as u32,
                (nx - rx) as u32,
                w as u32,
                nx as u32,
            ))
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &prev)
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }

    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec::uniform_box_taps;

    fn star7(nx: usize, ny: usize, nz: usize) -> StencilSpec {
        StencilSpec::heat3d(nx, ny, nz, 0.1)
    }

    #[test]
    fn star7_structure() {
        // 7-pt star, 2 workers: 1 MUL + 6 MAC per worker.
        let spec = star7(10, 8, 6);
        let g = build(&spec, 2).unwrap();
        assert_eq!(g.dp_ops(), 2 * 7);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 2);
        assert_eq!(h[&Op::Mac], 2 * 6);
        assert_eq!(h[&Op::Filter], 2 * 7);
        assert_eq!(h[&Op::Load], 2);
        // Delay lines: max stage = 2*rz*ny + ry = 2*8 + 1 = 17 per reader.
        assert_eq!(delay_stages(&spec, 2), 17);
        assert_eq!(h[&Op::Copy], 2 * 17);
        assert!(crate::dfg::validate::check(&g).is_empty());
    }

    #[test]
    fn box27_structure() {
        let spec =
            StencilSpec::box3d(10, 7, 6, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
        let g = build(&spec, 1).unwrap();
        assert_eq!(g.dp_ops(), 27);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Mul], 1);
        assert_eq!(h[&Op::Mac], 26);
        assert_eq!(h[&Op::Filter], 27);
        // Box corner tap needs the full 2*(rz*ny + ry) = 2*(7+1) stages.
        assert_eq!(delay_stages(&spec, 1), 16);
        assert_eq!(h[&Op::Copy], 16);
        assert!(crate::dfg::validate::check(&g).is_empty());
    }

    #[test]
    fn sync_counts_partition_interior() {
        let spec = star7(11, 7, 5);
        for w in 1..=3 {
            let g = build(&spec, w).unwrap();
            let total: u64 = g
                .nodes
                .iter()
                .filter(|n| n.op == Op::SyncCount)
                .map(|n| n.expected.unwrap())
                .sum();
            assert_eq!(total, spec.interior_outputs() as u64, "w={w}");
        }
    }

    #[test]
    fn tap_stage_alignment() {
        let spec = star7(10, 8, 6); // ny = 8
        // Centre tap: full alignment delay rz*ny + ry = 9.
        assert_eq!(tap_stage(&spec, 0, 0), 9);
        // +z neighbor arrives ny rows later -> shallower stage.
        assert_eq!(tap_stage(&spec, 1, 0), 1);
        // -z neighbor needs a full extra plane of delay.
        assert_eq!(tap_stage(&spec, -1, 0), 17);
        // y neighbors sit one row either side of the centre stage.
        assert_eq!(tap_stage(&spec, 0, -1), 10);
        assert_eq!(tap_stage(&spec, 0, 1), 8);
    }

    #[test]
    fn required_tokens_matches_built_graph() {
        for spec in [
            star7(10, 6, 5),
            StencilSpec::box3d(9, 7, 5, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap(),
        ] {
            let w = 2;
            let g = build(&spec, w).unwrap();
            let mut got = 0usize;
            for n in &g.nodes {
                match n.op {
                    Op::Copy => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                    Op::Mul => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                    Op::Mac => got += g.channels[g.input(n.id, 1).unwrap()].capacity,
                    _ => {}
                }
            }
            assert_eq!(got, required_buffer_tokens(&spec, w));
        }
    }

    #[test]
    fn rejects_2d_and_1d_specs() {
        assert!(build(&StencilSpec::heat2d(12, 12, 0.2), 2).is_err());
        assert!(build(&StencilSpec::dim1(32, vec![0.25, 0.5, 0.25]).unwrap(), 2).is_err());
    }

    #[test]
    fn valid_across_worker_counts() {
        let spec = star7(9, 6, 5);
        for w in 1..=4 {
            let g = build(&spec, w).unwrap();
            assert!(crate::dfg::validate::check(&g).is_empty(), "w={w}");
        }
    }
}
