//! §III — mapping star stencils onto the CGRA.
//!
//! The mapper decomposes a stencil into the paper's four pipeline stages —
//! reading input, computing output, writing output, synchronization — each
//! run by `w` parallel logical workers, and emits the dataflow graph the
//! simulator executes:
//!
//! * [`spec`] — the stencil specification (dims, radius, coefficients) and
//!   the §VI arithmetic-intensity math.
//! * [`filter`] — data-filtering PE configuration (Fig 6): the
//!   `0^m 1^n 0^p` bit patterns and the row/col-id scheme.
//! * [`map1d`] — the §III-A 1-D mapping (Fig 3–7).
//! * [`map2d`] — the §III-B 2-D mapping (Fig 9–11) with mandatory
//!   buffering, extended to dense box windows.
//! * [`map3d`] — the 3-D extension: plane buffering (rows of
//!   row-buffers) for star and box stencils.
//! * [`decomp`] — N-dim tile decomposition (slab/pencil/block cuts with
//!   per-axis halos) when the fabric cannot hold the whole grid's
//!   mandatory buffering, and for multi-tile execution.
//! * [`temporal`] — the §IV multi-time-step pipeline, shape-generic
//!   (`temporal::build_nd` fuses `T` steps of any 1-D/2-D/3-D star or
//!   box spec into one spatial pipeline), plus the time-tiled boundary
//!   band geometry (`temporal::ring_band_boxes`).
//! * [`exchange`] — the inter-tile halo-exchange schedule: which
//!   neighbor ships each halo point at a chunk boundary, so steady-state
//!   chunks re-read nothing from DRAM.

pub mod decomp;
pub mod exchange;
pub mod filter;
pub mod map1d;
pub mod map2d;
pub mod map3d;
pub mod spec;
pub mod temporal;

pub use spec::{StencilShape, StencilSpec};

use anyhow::Result;

use crate::dfg::Graph;

/// Process-wide work counters pinning the compile-once contract.
///
/// Every decomposition plan ([`decomp::plan_depth`]) and every DFG
/// construction ([`build_graph`], [`temporal::build_nd`]) bumps a
/// monotone counter here. The counters exist so tests can assert
/// *deltas*: executing a `CompiledStencil` must leave both unchanged,
/// and a plan-cache hit must do zero planning/graph work. They are
/// global and relaxed — meaningful only as before/after differences in
/// a test that serializes its measurements.
pub mod metrics {
    use std::sync::atomic::{AtomicU64, Ordering};

    static PLANS: AtomicU64 = AtomicU64::new(0);
    static GRAPH_BUILDS: AtomicU64 = AtomicU64::new(0);

    pub(crate) fn count_plan() {
        PLANS.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count_graph_build() {
        GRAPH_BUILDS.fetch_add(1, Ordering::Relaxed);
    }

    /// Decomposition plans computed since process start.
    pub fn plans() -> u64 {
        PLANS.load(Ordering::Relaxed)
    }

    /// Dataflow graphs built since process start.
    pub fn graph_builds() -> u64 {
        GRAPH_BUILDS.load(Ordering::Relaxed)
    }
}

/// Map any supported spec (1-D/2-D/3-D, star or box) to its dataflow
/// graph — the single entry point the simulator helpers and the CLI use.
pub fn build_graph(spec: &StencilSpec, w: usize) -> Result<Graph> {
    metrics::count_graph_build();
    if spec.is_3d() {
        map3d::build(spec, w)
    } else if spec.is_1d() {
        map1d::build(spec, w)
    } else {
        map2d::build(spec, w)
    }
}

/// First output column owned by worker `j`: the smallest `c >= rx` with
/// `c ≡ j (mod w)` (§III-A interleaving).
pub fn first_output_col(j: usize, w: usize, rx: usize) -> usize {
    first_output_col_at(j, w, rx)
}

/// Generalized interleave origin: the smallest `c >= lo` with
/// `c ≡ j (mod w)` — the §IV temporal pipeline uses it with
/// `lo = rx * steps` (the trapezoid-shrunk output window).
pub fn first_output_col_at(j: usize, w: usize, lo: usize) -> usize {
    lo + ((j % w) + w - (lo % w)) % w
}

/// Number of outputs worker `j` owns along a row of `nx` points.
pub fn outputs_per_row(j: usize, w: usize, nx: usize, rx: usize) -> usize {
    let first = first_output_col(j, w, rx);
    let hi = nx - rx;
    if first >= hi {
        0
    } else {
        (hi - first - 1) / w + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_output_col_examples() {
        // rx=1, w=3: worker 0 owns 3,6,..; worker 1 owns 1,4,..; worker 2 owns 2,5,..
        assert_eq!(first_output_col(0, 3, 1), 3);
        assert_eq!(first_output_col(1, 3, 1), 1);
        assert_eq!(first_output_col(2, 3, 1), 2);
        // rx=8, w=6: first cols are the smallest >= 8 congruent to j mod 6.
        for j in 0..6 {
            let c = first_output_col(j, 6, 8);
            assert!(c >= 8 && c < 8 + 6);
            assert_eq!(c % 6, j % 6);
        }
    }

    #[test]
    fn outputs_partition_the_interior() {
        // Across workers, outputs per row must sum to nx - 2*rx.
        for &(nx, rx, w) in &[(20usize, 1usize, 3usize), (194400, 8, 6), (960, 12, 5), (17, 3, 4)] {
            let total: usize = (0..w).map(|j| outputs_per_row(j, w, nx, rx)).sum();
            assert_eq!(total, nx - 2 * rx, "nx={nx} rx={rx} w={w}");
        }
    }

    #[test]
    fn outputs_disjoint_between_workers() {
        let (nx, rx, w) = (29usize, 2usize, 4usize);
        let mut seen = vec![false; nx];
        for j in 0..w {
            let mut c = first_output_col(j, w, rx);
            while c < nx - rx {
                assert!(!seen[c], "col {c} claimed twice");
                seen[c] = true;
                c += w;
            }
        }
        for (c, s) in seen.iter().enumerate() {
            assert_eq!(*s, (rx..nx - rx).contains(&c), "col {c}");
        }
    }
}
