//! Stencil specification — the generalized shape model — and the §VI
//! arithmetic-intensity arithmetic.
//!
//! A spec describes an N-dimensional (N ≤ 3) stencil over a row-major
//! grid (`x` contiguous, then `y`, then `z`) with one of two
//! [`StencilShape`]s:
//!
//! * **Star** (§II-B): taps only along the axes. `cx` holds the
//!   `2*rx + 1` taps along x (centre included), `cy` the `2*ry` taps
//!   along y and `cz` the `2*rz` taps along z (centres excluded — the
//!   centre is counted once, in the x chain), each ordered
//!   `-r, .., -1, +1, .., +r`. A 1-D stencil has `ny = nz = 1`,
//!   `ry = rz = 0` and empty `cy`/`cz`.
//! * **Box**: the full dense neighborhood. `box_taps` holds one
//!   coefficient per window point, z-major / row-major
//!   (`dz` outermost, `dx` innermost), `(2rz+1)*(2ry+1)*(2rx+1)` values
//!   with the centre included.
//!
//! The legacy `nx/ny/rx/ry/cx/cy` fields are the canonical storage for
//! the first two dimensions, so all §III 1-D/2-D callers (and the
//! Table-I reproductions) are unchanged; [`StencilSpec::dims`] /
//! [`StencilSpec::radii`] expose the N-dim view.

use anyhow::{ensure, Result};

/// Bytes per double-precision grid point (the paper evaluates in FP64).
pub const BYTES_PER_POINT: f64 = 8.0;

/// Neighborhood shape of a stencil.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StencilShape {
    /// Axis-aligned taps only (the paper's §II-B star).
    Star,
    /// Full dense `(2r+1)^d` neighborhood.
    Box,
}

#[derive(Debug, Clone, PartialEq)]
pub struct StencilSpec {
    /// Neighborhood shape.
    pub shape: StencilShape,
    /// Grid width (x dimension, contiguous in memory).
    pub nx: usize,
    /// Grid height (y dimension); 1 for a 1-D stencil.
    pub ny: usize,
    /// Grid depth (z dimension); 1 for a 1-D/2-D stencil.
    pub nz: usize,
    /// Radius along x.
    pub rx: usize,
    /// Radius along y; 0 for a 1-D stencil.
    pub ry: usize,
    /// Radius along z; 0 for a 1-D/2-D stencil.
    pub rz: usize,
    /// Star: `2*rx + 1` coefficients along x (centre included).
    pub cx: Vec<f64>,
    /// Star: `2*ry` coefficients along y (centre excluded).
    pub cy: Vec<f64>,
    /// Star: `2*rz` coefficients along z (centre excluded).
    pub cz: Vec<f64>,
    /// Box: dense window coefficients, z-major; empty for star shapes.
    pub box_taps: Vec<f64>,
}

impl StencilSpec {
    /// (2r+1)-point 1-D star stencil (Fig 1).
    pub fn dim1(nx: usize, coeffs: Vec<f64>) -> Result<Self> {
        ensure!(coeffs.len() % 2 == 1 && coeffs.len() >= 3, "need odd #coeffs >= 3");
        let rx = (coeffs.len() - 1) / 2;
        ensure!(nx > 2 * rx, "grid {nx} too small for radius {rx}");
        Ok(Self {
            shape: StencilShape::Star,
            nx,
            ny: 1,
            nz: 1,
            rx,
            ry: 0,
            rz: 0,
            cx: coeffs,
            cy: Vec::new(),
            cz: Vec::new(),
            box_taps: Vec::new(),
        })
    }

    /// 2-D star stencil (Fig 8): `cx` with centre, `cy` without.
    pub fn dim2(nx: usize, ny: usize, cx: Vec<f64>, cy: Vec<f64>) -> Result<Self> {
        ensure!(cx.len() % 2 == 1 && cx.len() >= 3, "cx must have odd length >= 3");
        ensure!(cy.len() % 2 == 0 && !cy.is_empty(), "cy must have even nonzero length");
        let rx = (cx.len() - 1) / 2;
        let ry = cy.len() / 2;
        ensure!(nx > 2 * rx, "nx {nx} too small for rx {rx}");
        ensure!(ny > 2 * ry, "ny {ny} too small for ry {ry}");
        Ok(Self {
            shape: StencilShape::Star,
            nx,
            ny,
            nz: 1,
            rx,
            ry,
            rz: 0,
            cx,
            cy,
            cz: Vec::new(),
            box_taps: Vec::new(),
        })
    }

    /// 3-D star stencil: `cx` with centre, `cy` and `cz` without.
    pub fn dim3(
        nx: usize,
        ny: usize,
        nz: usize,
        cx: Vec<f64>,
        cy: Vec<f64>,
        cz: Vec<f64>,
    ) -> Result<Self> {
        ensure!(cx.len() % 2 == 1 && cx.len() >= 3, "cx must have odd length >= 3");
        ensure!(cy.len() % 2 == 0 && !cy.is_empty(), "cy must have even nonzero length");
        ensure!(cz.len() % 2 == 0 && !cz.is_empty(), "cz must have even nonzero length");
        let rx = (cx.len() - 1) / 2;
        let ry = cy.len() / 2;
        let rz = cz.len() / 2;
        ensure!(nx > 2 * rx, "nx {nx} too small for rx {rx}");
        ensure!(ny > 2 * ry, "ny {ny} too small for ry {ry}");
        ensure!(nz > 2 * rz, "nz {nz} too small for rz {rz}");
        Ok(Self {
            shape: StencilShape::Star,
            nx,
            ny,
            nz,
            rx,
            ry,
            rz,
            cx,
            cy,
            cz,
            box_taps: Vec::new(),
        })
    }

    /// 2-D box stencil: `taps` is the `(2ry+1) x (2rx+1)` dense window,
    /// row-major (`dy` outer, `dx` inner), centre included.
    pub fn box2d(nx: usize, ny: usize, rx: usize, ry: usize, taps: Vec<f64>) -> Result<Self> {
        ensure!(rx >= 1 && ry >= 1, "box radii must be >= 1");
        ensure!(
            taps.len() == (2 * rx + 1) * (2 * ry + 1),
            "box2d needs {} taps, got {}",
            (2 * rx + 1) * (2 * ry + 1),
            taps.len()
        );
        ensure!(nx > 2 * rx, "nx {nx} too small for rx {rx}");
        ensure!(ny > 2 * ry, "ny {ny} too small for ry {ry}");
        Ok(Self {
            shape: StencilShape::Box,
            nx,
            ny,
            nz: 1,
            rx,
            ry,
            rz: 0,
            cx: Vec::new(),
            cy: Vec::new(),
            cz: Vec::new(),
            box_taps: taps,
        })
    }

    /// 3-D box stencil: `taps` is the dense
    /// `(2rz+1) x (2ry+1) x (2rx+1)` window, z-major, centre included.
    #[allow(clippy::too_many_arguments)]
    pub fn box3d(
        nx: usize,
        ny: usize,
        nz: usize,
        rx: usize,
        ry: usize,
        rz: usize,
        taps: Vec<f64>,
    ) -> Result<Self> {
        ensure!(rx >= 1 && ry >= 1 && rz >= 1, "box radii must be >= 1");
        let want = (2 * rx + 1) * (2 * ry + 1) * (2 * rz + 1);
        ensure!(taps.len() == want, "box3d needs {} taps, got {}", want, taps.len());
        ensure!(nx > 2 * rx, "nx {nx} too small for rx {rx}");
        ensure!(ny > 2 * ry, "ny {ny} too small for ry {ry}");
        ensure!(nz > 2 * rz, "nz {nz} too small for rz {rz}");
        Ok(Self {
            shape: StencilShape::Box,
            nx,
            ny,
            nz,
            rx,
            ry,
            rz,
            cx: Vec::new(),
            cy: Vec::new(),
            cz: Vec::new(),
            box_taps: taps,
        })
    }

    /// The Table-I 1-D workload: 17-pt, rx = 8, grid 194400, unit-ish taps.
    pub fn paper_1d() -> Self {
        let rx = 8;
        let cx = symmetric_taps(rx);
        Self::dim1(194400, cx).unwrap()
    }

    /// The Table-I 2-D workload: 49-pt oil/gas seismic stencil,
    /// rx = ry = 12, grid 960 x 449.
    pub fn paper_2d() -> Self {
        let (rx, ry) = (12, 12);
        Self::dim2(960, 449, symmetric_taps(rx), y_taps(ry)).unwrap()
    }

    /// 5-point 2-D Jacobi heat stencil (Fig 8) on an `nx` x `ny` grid.
    pub fn heat2d(nx: usize, ny: usize, alpha: f64) -> Self {
        Self::dim2(
            nx,
            ny,
            vec![alpha, 1.0 - 4.0 * alpha, alpha],
            vec![alpha, alpha],
        )
        .unwrap()
    }

    /// 7-point 3-D Jacobi heat stencil on an `nx` x `ny` x `nz` grid.
    pub fn heat3d(nx: usize, ny: usize, nz: usize, alpha: f64) -> Self {
        Self::dim3(
            nx,
            ny,
            nz,
            vec![alpha, 1.0 - 6.0 * alpha, alpha],
            vec![alpha, alpha],
            vec![alpha, alpha],
        )
        .unwrap()
    }

    pub fn is_1d(&self) -> bool {
        self.ny == 1 && self.nz == 1
    }

    pub fn is_2d(&self) -> bool {
        self.ny > 1 && self.nz == 1
    }

    pub fn is_3d(&self) -> bool {
        self.nz > 1
    }

    pub fn is_box(&self) -> bool {
        self.shape == StencilShape::Box
    }

    /// Number of grid dimensions (1, 2 or 3).
    pub fn ndim(&self) -> usize {
        if self.is_3d() {
            3
        } else if self.is_2d() {
            2
        } else {
            1
        }
    }

    /// Grid extents, x first, truncated to [`Self::ndim`] entries.
    pub fn dims(&self) -> Vec<usize> {
        [self.nx, self.ny, self.nz][..self.ndim()].to_vec()
    }

    /// Radii, x first, truncated to [`Self::ndim`] entries.
    pub fn radii(&self) -> Vec<usize> {
        [self.rx, self.ry, self.rz][..self.ndim()].to_vec()
    }

    /// Stencil points = DP ops per worker. Star: `(2rx+1) + 2ry + 2rz`
    /// (1 MUL + the MAC chain; §VI counts 49 for rx=ry=12). Box: the
    /// full window size.
    pub fn points(&self) -> usize {
        match self.shape {
            StencilShape::Star => self.cx.len() + self.cy.len() + self.cz.len(),
            StencilShape::Box => self.box_taps.len(),
        }
    }

    /// FLOPs per computed output: 1 for the MUL + 2 per MAC
    /// (= `2*points - 1`; §VI's `16*2+1 = 33` for the 17-pt stencil).
    pub fn flops_per_output(&self) -> f64 {
        2.0 * self.points() as f64 - 1.0
    }

    /// Computed (interior) outputs:
    /// `(nx - 2rx) * (ny - 2ry) * (nz - 2rz)`.
    pub fn interior_outputs(&self) -> usize {
        (self.nx - 2 * self.rx)
            * (self.ny.saturating_sub(2 * self.ry))
            * (self.nz.saturating_sub(2 * self.rz))
    }

    /// Total grid points.
    pub fn grid_points(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Total FLOPs for one stencil application.
    pub fn total_flops(&self) -> f64 {
        self.flops_per_output() * self.interior_outputs() as f64
    }

    /// Total DRAM traffic: read the input once, write the output once
    /// (the whole point of the CGRA mapping — §II-B data reuse).
    pub fn total_bytes(&self) -> f64 {
        2.0 * self.grid_points() as f64 * BYTES_PER_POINT
    }

    /// §VI arithmetic intensity (FLOPs per byte).
    ///
    /// 1-D paper example: `(16*2+1)*(194400-16) / ((194400+194400)*8)
    /// = 2.06`; 2-D: `(48*2+1)*((449-24)*(960-24)) / (2*(960*449)*8)
    /// = 5.59`.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.total_flops() / self.total_bytes()
    }

    /// The taps in the MAC-chain emission order of the mapper, as
    /// `(dz, dy, dx, coeff)` offsets relative to the output point. The
    /// first entry is the MUL; the rest continue the fused chain. This
    /// single enumeration defines both the DFG chain layout and the
    /// golden-oracle accumulation order, so all layers agree bitwise.
    ///
    /// Star order: x taps left-to-right, then y taps `-ry..-1, +1..+ry`,
    /// then z taps likewise. Box order: z-major over the dense window.
    pub fn chain_taps(&self) -> Vec<(i64, i64, i64, f64)> {
        let (rx, ry, rz) = (self.rx as i64, self.ry as i64, self.rz as i64);
        match self.shape {
            StencilShape::Star => {
                let mut v = Vec::with_capacity(self.points());
                for (t, &c) in self.cx.iter().enumerate() {
                    v.push((0, 0, t as i64 - rx, c));
                }
                for (u, &c) in self.cy.iter().enumerate() {
                    let k = if u < self.ry { u } else { u + 1 };
                    v.push((0, k as i64 - ry, 0, c));
                }
                for (u, &c) in self.cz.iter().enumerate() {
                    let k = if u < self.rz { u } else { u + 1 };
                    v.push((k as i64 - rz, 0, 0, c));
                }
                v
            }
            StencilShape::Box => {
                let mut v = Vec::with_capacity(self.points());
                let mut i = 0;
                for dz in -rz..=rz {
                    for dy in -ry..=ry {
                        for dx in -rx..=rx {
                            v.push((dz, dy, dx, self.box_taps[i]));
                            i += 1;
                        }
                    }
                }
                v
            }
        }
    }

    /// Restrict the spec to the axis-aligned box `[lo, hi)` of the grid
    /// (`[x, y, z]` order, halo included) — the N-dim decomposition unit
    /// of [`super::decomp`]. Radii and taps are unchanged, so the
    /// sub-grid's interior is the box shrunk by the radius along every
    /// axis.
    pub fn restrict(&self, lo: [usize; 3], hi: [usize; 3]) -> Self {
        let n = [self.nx, self.ny, self.nz];
        for a in 0..3 {
            assert!(
                lo[a] < hi[a] && hi[a] <= n[a],
                "bad restriction on axis {a}: [{}, {}) of {}",
                lo[a],
                hi[a],
                n[a]
            );
        }
        Self {
            nx: hi[0] - lo[0],
            ny: hi[1] - lo[1],
            nz: hi[2] - lo[2],
            ..self.clone()
        }
    }
}

/// Symmetric normalized x-taps (centre-weighted), `2r + 1` values.
/// Shape matches finite-difference coefficients: decaying with distance.
pub fn symmetric_taps(r: usize) -> Vec<f64> {
    let mut c = vec![0.0; 2 * r + 1];
    for k in 0..=r {
        let v = 1.0 / (1.0 + k as f64);
        c[r - k] = v;
        c[r + k] = v;
    }
    // Normalize to sum 1 so repeated application stays bounded.
    let s: f64 = c.iter().sum();
    c.iter_mut().for_each(|v| *v /= s);
    c
}

/// Symmetric y-taps without the centre, `2r` values ordered
/// `-r..-1, +1..+r`.
pub fn y_taps(r: usize) -> Vec<f64> {
    let mut c = vec![0.0; 2 * r];
    for k in 1..=r {
        let v = 0.5 / (1.0 + k as f64);
        c[r - k] = v;
        c[r + k - 1] = v;
    }
    let s: f64 = c.iter().sum();
    // Keep the y contribution small relative to x (sum 0.5) for stability.
    c.iter_mut().for_each(|v| *v *= 0.5 / s);
    c
}

/// Symmetric z-taps without the centre, `2r` values ordered
/// `-r..-1, +1..+r` (same decaying weights as [`y_taps`]).
pub fn z_taps(r: usize) -> Vec<f64> {
    y_taps(r)
}

/// Uniform normalized dense-window taps for a box stencil:
/// `(2rz+1)*(2ry+1)*(2rx+1)` equal coefficients summing to 1.
pub fn uniform_box_taps(rx: usize, ry: usize, rz: usize) -> Vec<f64> {
    let n = (2 * rx + 1) * (2 * ry + 1) * (2 * rz + 1);
    vec![1.0 / n as f64; n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_1d_arithmetic_intensity() {
        let s = StencilSpec::paper_1d();
        assert_eq!(s.points(), 17);
        assert_eq!(s.flops_per_output(), 33.0);
        // (16*2+1)*(194400-16)/((194400+194400)*8) = 2.06
        let ai = s.arithmetic_intensity();
        assert!((ai - 2.06).abs() < 0.01, "ai = {ai}");
    }

    #[test]
    fn paper_2d_arithmetic_intensity() {
        let s = StencilSpec::paper_2d();
        assert_eq!(s.points(), 49);
        assert_eq!(s.flops_per_output(), 97.0);
        // (48*2+1)*((449-24)*(960-24))/((2*960*449)*8) = 5.59
        let ai = s.arithmetic_intensity();
        assert!((ai - 5.59).abs() < 0.01, "ai = {ai}");
    }

    #[test]
    fn heat2d_is_5_point() {
        let s = StencilSpec::heat2d(64, 64, 0.2);
        assert_eq!(s.points(), 5);
        assert_eq!(s.rx, 1);
        assert_eq!(s.ry, 1);
        let sum: f64 = s.cx.iter().chain(s.cy.iter()).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heat3d_is_7_point() {
        let s = StencilSpec::heat3d(16, 12, 10, 0.1);
        assert_eq!(s.points(), 7);
        assert_eq!((s.rx, s.ry, s.rz), (1, 1, 1));
        assert!(s.is_3d() && !s.is_box());
        assert_eq!(s.dims(), vec![16, 12, 10]);
        assert_eq!(s.radii(), vec![1, 1, 1]);
        let sum: f64 =
            s.cx.iter().chain(s.cy.iter()).chain(s.cz.iter()).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dim1_rejects_even_coeffs() {
        assert!(StencilSpec::dim1(100, vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn dim1_rejects_tiny_grid() {
        assert!(StencilSpec::dim1(16, symmetric_taps(8)).is_err());
    }

    #[test]
    fn dim2_rejects_odd_cy() {
        assert!(StencilSpec::dim2(32, 32, vec![1., 2., 3.], vec![1.0]).is_err());
    }

    #[test]
    fn dim3_rejects_bad_shapes() {
        let cx = vec![0.25, 0.5, 0.25];
        assert!(StencilSpec::dim3(8, 8, 8, cx.clone(), vec![0.1], vec![0.1, 0.1]).is_err());
        assert!(StencilSpec::dim3(8, 8, 2, cx, vec![0.1, 0.1], vec![0.1, 0.1]).is_err());
    }

    #[test]
    fn box2d_window_size_checked() {
        assert!(StencilSpec::box2d(16, 16, 1, 1, vec![0.1; 9]).is_ok());
        assert!(StencilSpec::box2d(16, 16, 1, 1, vec![0.1; 8]).is_err());
        assert!(StencilSpec::box2d(16, 16, 0, 1, vec![0.1; 3]).is_err());
    }

    #[test]
    fn box3d_points_and_flops() {
        let s = StencilSpec::box3d(10, 9, 8, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
        assert_eq!(s.points(), 27);
        assert_eq!(s.flops_per_output(), 53.0);
        assert!(s.is_box() && s.is_3d());
        assert_eq!(s.interior_outputs(), 8 * 7 * 6);
    }

    #[test]
    fn chain_taps_star_order_matches_section_iii() {
        // 2-D star: x left-to-right, then y -ry..-1,+1..+ry.
        let s = StencilSpec::dim2(8, 8, vec![1.0, 2.0, 3.0], vec![4.0, 5.0]).unwrap();
        assert_eq!(
            s.chain_taps(),
            vec![
                (0, 0, -1, 1.0),
                (0, 0, 0, 2.0),
                (0, 0, 1, 3.0),
                (0, -1, 0, 4.0),
                (0, 1, 0, 5.0),
            ]
        );
    }

    #[test]
    fn chain_taps_box_is_z_major_dense() {
        let taps: Vec<f64> = (0..27).map(|i| i as f64).collect();
        let s = StencilSpec::box3d(8, 8, 8, 1, 1, 1, taps).unwrap();
        let ct = s.chain_taps();
        assert_eq!(ct.len(), 27);
        assert_eq!(ct[0], (-1, -1, -1, 0.0));
        assert_eq!(ct[13], (0, 0, 0, 13.0)); // centre
        assert_eq!(ct[26], (1, 1, 1, 26.0));
    }

    #[test]
    fn taps_are_normalized_and_symmetric() {
        for r in 1..=12 {
            let c = symmetric_taps(r);
            assert_eq!(c.len(), 2 * r + 1);
            assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            for k in 0..r {
                assert_eq!(c[k], c[2 * r - k]);
            }
        }
    }

    #[test]
    fn uniform_box_taps_sum_to_one() {
        let t = uniform_box_taps(2, 1, 1);
        assert_eq!(t.len(), 5 * 3 * 3);
        assert!((t.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn restrict_preserves_radii_and_shape() {
        let s = StencilSpec::paper_2d();
        let t = s.restrict([100, 0, 0], [300, s.ny, 1]);
        assert_eq!(t.nx, 200);
        assert_eq!(t.ny, s.ny);
        assert_eq!(t.rx, 12);
        assert!(t.is_2d());

        let v = StencilSpec::heat3d(16, 12, 10, 0.1);
        let u = v.restrict([2, 1, 3], [14, 9, 8]);
        assert_eq!((u.nx, u.ny, u.nz), (12, 8, 5));
        assert_eq!(u.radii(), v.radii());
        assert!(u.is_3d());
    }

    #[test]
    #[should_panic(expected = "bad restriction")]
    fn restrict_rejects_out_of_bounds() {
        let s = StencilSpec::paper_2d();
        let _ = s.restrict([0, 0, 0], [s.nx + 1, s.ny, 1]);
    }

    #[test]
    fn dimensionality_predicates() {
        assert!(StencilSpec::paper_1d().is_1d());
        assert!(StencilSpec::paper_2d().is_2d());
        assert!(StencilSpec::heat3d(8, 8, 8, 0.1).is_3d());
        assert_eq!(StencilSpec::paper_1d().ndim(), 1);
        assert_eq!(StencilSpec::paper_2d().ndim(), 2);
        assert_eq!(StencilSpec::heat3d(8, 8, 8, 0.1).ndim(), 3);
    }
}
