//! Stencil specification and the §VI arithmetic-intensity arithmetic.
//!
//! A *star* stencil (§II-B) is described by its grid (`nx`, `ny`), radii
//! (`rx`, `ry`) and coefficient vectors: `cx` holds the `2*rx + 1` taps
//! along x (centre included), `cy` the `2*ry` taps along y (centre
//! excluded — it is counted once, in the x chain), ordered
//! `j-ry, .., j-1, j+1, .., j+ry`. A 1-D stencil has `ny = 1, ry = 0` and
//! an empty `cy`.

use anyhow::{ensure, Result};

/// Bytes per double-precision grid point (the paper evaluates in FP64).
pub const BYTES_PER_POINT: f64 = 8.0;

#[derive(Debug, Clone, PartialEq)]
pub struct StencilSpec {
    /// Grid width (x dimension, contiguous in memory).
    pub nx: usize,
    /// Grid height (y dimension); 1 for a 1-D stencil.
    pub ny: usize,
    /// Radius along x.
    pub rx: usize,
    /// Radius along y; 0 for a 1-D stencil.
    pub ry: usize,
    /// `2*rx + 1` coefficients along x (centre included).
    pub cx: Vec<f64>,
    /// `2*ry` coefficients along y (centre excluded).
    pub cy: Vec<f64>,
}

impl StencilSpec {
    /// (2r+1)-point 1-D stencil (Fig 1).
    pub fn dim1(nx: usize, coeffs: Vec<f64>) -> Result<Self> {
        ensure!(coeffs.len() % 2 == 1 && coeffs.len() >= 3, "need odd #coeffs >= 3");
        let rx = (coeffs.len() - 1) / 2;
        ensure!(nx > 2 * rx, "grid {nx} too small for radius {rx}");
        Ok(Self { nx, ny: 1, rx, ry: 0, cx: coeffs, cy: Vec::new() })
    }

    /// 2-D star stencil (Fig 8): `cx` with centre, `cy` without.
    pub fn dim2(nx: usize, ny: usize, cx: Vec<f64>, cy: Vec<f64>) -> Result<Self> {
        ensure!(cx.len() % 2 == 1 && cx.len() >= 3, "cx must have odd length >= 3");
        ensure!(cy.len() % 2 == 0 && !cy.is_empty(), "cy must have even nonzero length");
        let rx = (cx.len() - 1) / 2;
        let ry = cy.len() / 2;
        ensure!(nx > 2 * rx, "nx {nx} too small for rx {rx}");
        ensure!(ny > 2 * ry, "ny {ny} too small for ry {ry}");
        Ok(Self { nx, ny, rx, ry, cx, cy })
    }

    /// The Table-I 1-D workload: 17-pt, rx = 8, grid 194400, unit-ish taps.
    pub fn paper_1d() -> Self {
        let rx = 8;
        let cx = symmetric_taps(rx);
        Self::dim1(194400, cx).unwrap()
    }

    /// The Table-I 2-D workload: 49-pt oil/gas seismic stencil,
    /// rx = ry = 12, grid 960 x 449.
    pub fn paper_2d() -> Self {
        let (rx, ry) = (12, 12);
        Self::dim2(960, 449, symmetric_taps(rx), y_taps(ry)).unwrap()
    }

    /// 5-point 2-D Jacobi heat stencil (Fig 8) on an `nx` x `ny` grid.
    pub fn heat2d(nx: usize, ny: usize, alpha: f64) -> Self {
        Self::dim2(
            nx,
            ny,
            vec![alpha, 1.0 - 4.0 * alpha, alpha],
            vec![alpha, alpha],
        )
        .unwrap()
    }

    pub fn is_1d(&self) -> bool {
        self.ry == 0
    }

    /// Stencil points = DP ops per worker: `(2rx+1) + 2ry`
    /// (1 MUL + the MAC chain; §VI counts 49 for rx=ry=12).
    pub fn points(&self) -> usize {
        self.cx.len() + self.cy.len()
    }

    /// FLOPs per computed output: 1 for the MUL + 2 per MAC
    /// (= `2*points - 1`; §VI's `16*2+1 = 33` for the 17-pt stencil).
    pub fn flops_per_output(&self) -> f64 {
        2.0 * self.points() as f64 - 1.0
    }

    /// Computed (interior) outputs: `(nx - 2rx) * (ny - 2ry)`.
    pub fn interior_outputs(&self) -> usize {
        (self.nx - 2 * self.rx) * (self.ny.saturating_sub(2 * self.ry))
    }

    /// Total grid points.
    pub fn grid_points(&self) -> usize {
        self.nx * self.ny
    }

    /// Total FLOPs for one stencil application.
    pub fn total_flops(&self) -> f64 {
        self.flops_per_output() * self.interior_outputs() as f64
    }

    /// Total DRAM traffic: read the input once, write the output once
    /// (the whole point of the CGRA mapping — §II-B data reuse).
    pub fn total_bytes(&self) -> f64 {
        2.0 * self.grid_points() as f64 * BYTES_PER_POINT
    }

    /// §VI arithmetic intensity (FLOPs per byte).
    ///
    /// 1-D paper example: `(16*2+1)*(194400-16) / ((194400+194400)*8)
    /// = 2.06`; 2-D: `(48*2+1)*((449-24)*(960-24)) / (2*(960*449)*8)
    /// = 5.59`.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.total_flops() / self.total_bytes()
    }

    /// Restrict the spec to a vertical strip `[col_lo, col_hi)` of the
    /// grid *including* halo columns — the §III-B blocking unit. Outputs
    /// of the strip are its interior columns.
    pub fn strip(&self, col_lo: usize, col_hi: usize) -> Self {
        assert!(col_lo < col_hi && col_hi <= self.nx);
        Self {
            nx: col_hi - col_lo,
            ..self.clone()
        }
    }
}

/// Symmetric normalized x-taps (centre-weighted), `2r + 1` values.
/// Shape matches finite-difference coefficients: decaying with distance.
pub fn symmetric_taps(r: usize) -> Vec<f64> {
    let mut c = vec![0.0; 2 * r + 1];
    for k in 0..=r {
        let v = 1.0 / (1.0 + k as f64);
        c[r - k] = v;
        c[r + k] = v;
    }
    // Normalize to sum 1 so repeated application stays bounded.
    let s: f64 = c.iter().sum();
    c.iter_mut().for_each(|v| *v /= s);
    c
}

/// Symmetric y-taps without the centre, `2r` values ordered
/// `-r..-1, +1..+r`.
pub fn y_taps(r: usize) -> Vec<f64> {
    let mut c = vec![0.0; 2 * r];
    for k in 1..=r {
        let v = 0.5 / (1.0 + k as f64);
        c[r - k] = v;
        c[r + k - 1] = v;
    }
    let s: f64 = c.iter().sum();
    // Keep the y contribution small relative to x (sum 0.5) for stability.
    c.iter_mut().for_each(|v| *v *= 0.5 / s);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_1d_arithmetic_intensity() {
        let s = StencilSpec::paper_1d();
        assert_eq!(s.points(), 17);
        assert_eq!(s.flops_per_output(), 33.0);
        // (16*2+1)*(194400-16)/((194400+194400)*8) = 2.06
        let ai = s.arithmetic_intensity();
        assert!((ai - 2.06).abs() < 0.01, "ai = {ai}");
    }

    #[test]
    fn paper_2d_arithmetic_intensity() {
        let s = StencilSpec::paper_2d();
        assert_eq!(s.points(), 49);
        assert_eq!(s.flops_per_output(), 97.0);
        // (48*2+1)*((449-24)*(960-24))/((2*960*449)*8) = 5.59
        let ai = s.arithmetic_intensity();
        assert!((ai - 5.59).abs() < 0.01, "ai = {ai}");
    }

    #[test]
    fn heat2d_is_5_point() {
        let s = StencilSpec::heat2d(64, 64, 0.2);
        assert_eq!(s.points(), 5);
        assert_eq!(s.rx, 1);
        assert_eq!(s.ry, 1);
        let sum: f64 = s.cx.iter().chain(s.cy.iter()).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dim1_rejects_even_coeffs() {
        assert!(StencilSpec::dim1(100, vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn dim1_rejects_tiny_grid() {
        assert!(StencilSpec::dim1(16, symmetric_taps(8)).is_err());
    }

    #[test]
    fn dim2_rejects_odd_cy() {
        assert!(StencilSpec::dim2(32, 32, vec![1., 2., 3.], vec![1.0]).is_err());
    }

    #[test]
    fn taps_are_normalized_and_symmetric() {
        for r in 1..=12 {
            let c = symmetric_taps(r);
            assert_eq!(c.len(), 2 * r + 1);
            assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            for k in 0..r {
                assert_eq!(c[k], c[2 * r - k]);
            }
        }
    }

    #[test]
    fn strip_preserves_radius_and_height() {
        let s = StencilSpec::paper_2d();
        let t = s.strip(100, 300);
        assert_eq!(t.nx, 200);
        assert_eq!(t.ny, s.ny);
        assert_eq!(t.rx, 12);
    }
}
